"""Serving-layer benchmarks, driven through :class:`repro.serve.DKSService`.

  fig_serve_throughput — throughput + tail latency vs micro-batch size:
  the same request trace replayed by concurrent closed-loop clients at
  several ``max_batch`` settings.  The result cache is OFF so the curve
  measures batching, not caching; ``pad_batches="max"`` keeps the vmapped
  executor at one batch shape per keyword count, and an untimed warm-up
  replay pays the compilation so the timed pass measures serving.

``python -m benchmarks.run`` writes the rows to
``experiments/BENCH_serve.json`` (the serving perf-trajectory file —
compare across commits like BENCH_dks.json).
"""

from __future__ import annotations

import time

from benchmarks.common import load
from repro.serve import DKSService, ServeConfig
from repro.serve.loadgen import make_trace, replay


def fig_serve_throughput(dataset="sec-rdfabout-cpu",
                         batch_sizes=(1, 2, 4, 8), n_clients=8,
                         n_requests=24, unique=8, k=1):
    """Throughput + p50/p95 latency + batch-fill per ``max_batch``.

    ``max_batch=1`` is the no-batching baseline (every request its own
    dispatch); the gap to larger settings is the amortization the
    micro-batcher buys under this client concurrency.  Caveat for reading
    the numbers on this single-core CPU container: a vmapped lane is extra
    *serial* compute here, so larger batches mostly amortize dispatch
    overhead and can lose on raw throughput — the batching win appears on
    parallel hardware, where lanes share the device program.  The curve's
    shape across commits is still the regression signal."""
    bench = load(dataset)
    trace = make_trace(bench.index, n_requests, unique=unique, k=k, seed=3)
    rows = []
    for mb in batch_sizes:
        cfg = ServeConfig(max_batch=mb, max_wait_ms=10.0, cache_size=0,
                          extract=False, pad_batches="max")
        # Untimed warm-up: pays the one batch-shape trace per keyword
        # count so the timed replay measures serving, not compilation.
        with DKSService(bench.engine, cfg) as svc:
            replay(svc, trace[: max(2 * mb, 4)],
                   n_clients=min(n_clients, 4))
        with DKSService(bench.engine, cfg) as svc:
            t0 = time.perf_counter()
            replay(svc, trace, n_clients=n_clients)
            wall = time.perf_counter() - t0
            st = svc.stats()
        rows.append({
            "max_batch": mb,
            "throughput_rps": round(st.throughput_rps, 2),
            "p50_ms": round(st.p50_ms, 1),
            "p95_ms": round(st.p95_ms, 1),
            "mean_batch_fill": round(st.mean_batch_fill, 2),
            "dispatches": st.batch_dispatches,
            "wall_s": round(wall, 2),
        })
    return rows
