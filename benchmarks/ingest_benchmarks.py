"""Graph-store benchmarks: ingestion throughput, artifact open time, and
the live-graph delta path.

  fig_ingest — the store subsystem's reason to exist, measured:
  (a) ingest throughput (edges/s) for the synthetic from_graph path and
      for the streaming TSV reader (dictionary encoding + chunked
      accumulation + degree weights + CSR);
  (b) artifact write wall time (atomic npy + manifest + checksums);
  (c) engine-ready wall time, open-vs-rebuild: mmap-open the artifact and
      build a QueryEngine versus re-generating the graph and rebuilding
      from scratch.  The open path must win — that is the asserted
      acceptance criterion (a serve restart should cost milliseconds of
      manifest parsing, not a re-ingest) — and one query is checked
      bit-identical across the two engines while we're there.

  fig_delta — the live-graph subsystem's reason to exist, measured:
  appending the last ~10% of a dump as a delta artifact and opening the
  merged chain versus re-ingesting the whole union from text.  The delta
  path must win — that is the asserted acceptance criterion (a graph
  update should cost time proportional to the *fragment*, not the
  graph) — and the chain's merged weights are checked bit-identical to
  the union re-ingest while we're there.  Chain-open vs base-open time
  is recorded separately: the chain pays one merge (build_graph over the
  union edges) per open, which is the number compaction exists to
  reclaim.

``python -m benchmarks.run`` writes the rows to
``experiments/BENCH_ingest.json`` (perf-trajectory file — compare across
commits like BENCH_dks.json / BENCH_serve.json).
"""

from __future__ import annotations

import tempfile
import time
from pathlib import Path

import numpy as np

from repro.configs import DKS_CONFIGS
from repro.engine import ExecutionPolicy, QueryEngine
from repro.graph.generators import lod_like_graph
from repro.store import from_graph, ingest_tsv, open_artifact, write_artifact
from repro.store.ingest import write_tsv


def fig_ingest(dataset: str = "sec-rdfabout-cpu") -> dict:
    ds = DKS_CONFIGS[dataset]
    policy = ExecutionPolicy(max_supersteps=32)

    with tempfile.TemporaryDirectory(prefix="repro-bench-ingest-") as td:
        td = Path(td)

        # -- rebuild path: generate + pack + index, engine from scratch --
        t0 = time.perf_counter()
        g, tokens = lod_like_graph(ds.n_nodes, ds.n_edges, seed=ds.seed,
                                   vocab=ds.vocab, tau=ds.tau)
        engine_mem = QueryEngine.build(g, tokens=tokens, policy=policy)
        t_rebuild = time.perf_counter() - t0

        # -- ingest (from_graph envelope) + artifact write ---------------
        t0 = time.perf_counter()
        result = from_graph(g, tokens=tokens, tau=ds.tau,
                            edges_requested=ds.n_edges)
        artifact = write_artifact(td / "artifact", result.graph,
                                  result.index, tau=ds.tau,
                                  stats=result.stats.as_dict())
        t_write = time.perf_counter() - t0

        # -- streaming text path: TSV reader over the same edges ---------
        tsv = td / "edges.tsv"
        write_tsv(tsv, g.src, g.dst)
        t0 = time.perf_counter()
        tsv_result = ingest_tsv(tsv, tau=ds.tau)
        t_tsv = time.perf_counter() - t0
        assert tsv_result.stats.edges_directed == g.n_edges_directed

        # -- open path: mmap artifact -> engine ---------------------------
        # artifact_open_s is recorded separately: since the lazy token
        # table (binary search over the mmap) it is O(1) in vocabulary —
        # the number to watch as artifacts grow to 16M-node scale.
        t0 = time.perf_counter()
        reopened = open_artifact(td / "artifact")
        t_open_art = time.perf_counter() - t0
        t0 = time.perf_counter()
        engine_art = QueryEngine.build(artifact=reopened, policy=policy)
        t_open = t_open_art + time.perf_counter() - t0

        # Parity spot-check (the full property test lives in
        # tests/test_store.py).
        vocab = sorted(engine_mem.index.vocabulary(),
                       key=engine_mem.index.df)
        q = [t for t in vocab if engine_mem.index.df(t) >= 2][:2]
        np.testing.assert_array_equal(
            engine_mem.query(q, k=1, extract=False).weights,
            engine_art.query(q, k=1, extract=False).weights)

        assert t_open < t_rebuild, (
            f"artifact open ({t_open:.2f}s) not faster than rebuild "
            f"({t_rebuild:.2f}s) — the store lost its reason to exist")

        return {
            "dataset": ds.name,
            "n_nodes": g.n_nodes,
            "n_edges_directed": g.n_edges_directed,
            "ingest_write_s": round(t_write, 3),
            "ingest_write_edges_per_s": round(
                g.n_edges_directed / t_write, 1),
            "tsv_stream_s": round(t_tsv, 3),
            "tsv_stream_edges_per_s": round(
                g.n_edges_directed / t_tsv, 1),
            "artifact_mb": round(artifact.nbytes() / 1e6, 2),
            "artifact_open_s": round(t_open_art, 4),
            "engine_ready_open_s": round(t_open, 3),
            "engine_ready_rebuild_s": round(t_rebuild, 3),
            "open_speedup": round(t_rebuild / t_open, 2),
        }


def fig_delta(dataset: str = "sec-rdfabout-cpu",
              delta_frac: float = 0.1) -> dict:
    from repro.store import DeltaBuilder, open_chain

    ds = DKS_CONFIGS[dataset]
    g, _tokens = lod_like_graph(ds.n_nodes, ds.n_edges, seed=ds.seed,
                                vocab=ds.vocab, tau=ds.tau)

    with tempfile.TemporaryDirectory(prefix="repro-bench-delta-") as td:
        td = Path(td)
        n_base = int(round(g.n_edges_directed * (1.0 - delta_frac)))
        write_tsv(td / "union.tsv", g.src, g.dst)
        write_tsv(td / "base.tsv", g.src[:n_base], g.dst[:n_base])
        write_tsv(td / "frag.tsv", g.src[n_base:], g.dst[n_base:])

        base_result = ingest_tsv(td / "base.tsv", tau=ds.tau)
        base = write_artifact(td / "base", base_result.graph,
                              base_result.index, tau=ds.tau,
                              stats=base_result.stats.as_dict(),
                              names=base_result.names)

        # -- full re-ingest: the whole union back through the reader ----
        t0 = time.perf_counter()
        union = ingest_tsv(td / "union.tsv", tau=ds.tau)
        t_full = time.perf_counter() - t0

        # -- delta path: fragment -> delta artifact -> merged chain -----
        t0 = time.perf_counter()
        builder = DeltaBuilder(base)
        builder.add_file(td / "frag.tsv")
        delta = builder.write(td / "delta")
        t_build = time.perf_counter() - t0
        chain = open_chain(base, delta)
        chain_graph = chain.graph()
        t_delta = time.perf_counter() - t0

        np.testing.assert_array_equal(
            chain_graph.w, union.graph.w,
            err_msg="chain weights diverged from the union re-ingest")

        assert t_delta < t_full, (
            f"delta apply ({t_delta:.2f}s) not faster than full "
            f"re-ingest ({t_full:.2f}s) — the live path lost its reason "
            "to exist")

        # -- open costs: merged chain vs plain base ----------------------
        t0 = time.perf_counter()
        open_chain(td / "base", td / "delta").graph()
        t_chain_open = time.perf_counter() - t0
        t0 = time.perf_counter()
        open_artifact(td / "base").graph()
        t_base_open = time.perf_counter() - t0

        return {
            "dataset": ds.name,
            "n_edges_base": n_base,
            "n_edges_delta": int(g.n_edges_directed - n_base),
            "new_nodes": delta.n_new_nodes,
            "delta_build_s": round(t_build, 3),
            "delta_apply_s": round(t_delta, 3),
            "full_reingest_s": round(t_full, 3),
            "delta_speedup": round(t_full / t_delta, 2),
            "chain_open_s": round(t_chain_open, 3),
            "base_open_s": round(t_base_open, 4),
        }
