"""Shared benchmark setup: synthetic LOD graphs + the paper's query
generation strategy (Sec. 7.1, after Coffman et al.): keywords picked by
document frequency so keyword-node counts span ~10 .. ~10^4 (Fig. 9), with
keyword counts 2..m_max, N queries per count.

Each dataset loads once into a :class:`repro.engine.QueryEngine`; the
benchmarks drive all measurements through it.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.configs import DKS_CONFIGS
from repro.engine import ExecutionPolicy, QueryEngine
from repro.graph.generators import lod_like_graph
from repro.graph.index import InvertedIndex


@dataclasses.dataclass
class Bench:
    name: str
    engine: QueryEngine
    queries: list[list[int]]   # token lists, grouped by keyword count

    @property
    def g(self):
        return self.engine.graph

    @property
    def dg(self):
        return self.engine.device_graph

    @property
    def index(self) -> InvertedIndex:
        return self.engine.index


@functools.lru_cache(maxsize=4)
def load(dataset: str = "sec-rdfabout-cpu", m_max: int = 4,
         per_count: int = 5) -> Bench:
    ds = DKS_CONFIGS[dataset]
    g, tokens = lod_like_graph(ds.n_nodes, ds.n_edges, seed=ds.seed,
                               vocab=ds.vocab, tau=ds.tau)
    engine = QueryEngine.build(
        g, tokens=tokens, policy=ExecutionPolicy(max_supersteps=32))
    index = engine.index
    # Rank tokens by df; sample across the df spectrum (paper Fig. 9:
    # keyword-node counts grow exponentially across queries).
    vocab = sorted(index.vocabulary(), key=index.df)
    usable = [t for t in vocab if index.df(t) >= 2]
    rng = np.random.default_rng(ds.seed + 99)
    queries = []
    for m in range(2, m_max + 1):
        for qi in range(per_count):
            # Geometric spread over the df spectrum.
            lo = int(len(usable) * qi / per_count)
            hi = min(len(usable) - 1, lo + max(2 * m, 10))
            picks = rng.choice(np.arange(lo, hi + 1), size=m, replace=False)
            queries.append([usable[int(p)] for p in picks])
    return Bench(name=ds.name, engine=engine, queries=queries)
