"""Shared benchmark setup: synthetic LOD graphs + the paper's query
generation strategy (Sec. 7.1, after Coffman et al.): keywords picked by
document frequency so keyword-node counts span ~10 .. ~10^4 (Fig. 9), with
keyword counts 2..m_max, N queries per count."""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.configs import DKS_CONFIGS
from repro.graph.generators import lod_like_graph
from repro.graph.index import InvertedIndex


@dataclasses.dataclass
class Bench:
    name: str
    g: object
    dg: object
    index: InvertedIndex
    queries: list[list[int]]   # token lists, grouped by keyword count


@functools.lru_cache(maxsize=4)
def load(dataset: str = "sec-rdfabout-cpu", m_max: int = 4,
         per_count: int = 5) -> Bench:
    ds = DKS_CONFIGS[dataset]
    g, tokens = lod_like_graph(ds.n_nodes, ds.n_edges, seed=ds.seed,
                               vocab=ds.vocab, tau=ds.tau)
    index = InvertedIndex.from_token_matrix(tokens)
    # Rank tokens by df; sample across the df spectrum (paper Fig. 9:
    # keyword-node counts grow exponentially across queries).
    vocab = sorted(index.vocabulary(), key=index.df)
    usable = [t for t in vocab if index.df(t) >= 2]
    rng = np.random.default_rng(ds.seed + 99)
    queries = []
    for m in range(2, m_max + 1):
        for qi in range(per_count):
            # Geometric spread over the df spectrum.
            lo = int(len(usable) * qi / per_count)
            hi = min(len(usable) - 1, lo + max(2 * m, 10))
            picks = rng.choice(np.arange(lo, hi + 1), size=m, replace=False)
            queries.append([usable[int(p)] for p in picks])
    return Bench(name=ds.name, g=g, dg=g.to_device(), index=index,
                 queries=queries)


def masks_for(bench: Bench, query: list[int]) -> np.ndarray:
    masks = bench.index.keyword_masks(query, bench.g.n_nodes)
    v_pad = bench.dg.v_pad
    if masks.shape[1] < v_pad:
        masks = np.pad(masks, ((0, 0), (0, v_pad - masks.shape[1])))
    return masks
