"""DKS benchmarks, one per paper table/figure (Sec. 7.2).

Scaled to this CPU container via the *-cpu synthetic datasets; the same
code paths drive the full-scale graphs on a pod.

  table1   — % time per DKS component, K ∈ {1,2,5,10}      (paper Table 1)
  fig10    — per-query normalized time vs vanilla BFS      (paper Fig. 10)
  fig11    — deep-message counts vs K                      (paper Fig. 11)
  fig12    — SPA-ratio under a message budget              (paper Fig. 12)
  fig13    — % nodes explored                              (paper Fig. 13)
  fig14    — messages as % of |E|                          (paper Fig. 14)
  fig15    — parallel efficiency proxy (edge-cut + balance) (paper Fig. 15)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Bench, load, masks_for
from repro import INF
from repro.core.baselines import vanilla_parallel_bfs
from repro.core.dks import DKSConfig, run_dks, run_dks_instrumented
from repro.core.spa import spa_cover_dp, spa_ratio
from repro.graph.partition import edge_cut, hash_partition


def _run(bench: Bench, query, k, **kw):
    masks = masks_for(bench, query)
    cfg = DKSConfig(m=len(query), k=k, max_supersteps=32, **kw)
    t0 = time.perf_counter()
    state = jax.block_until_ready(run_dks(bench.dg, jnp.asarray(masks), cfg))
    return state, time.perf_counter() - t0


def table1_phase_breakdown(dataset="sec-rdfabout-cpu", ks=(1, 2, 5, 10),
                           n_queries=3):
    """Percentage of time per component, by K."""
    bench = load(dataset)
    rows = []
    for k in ks:
        agg = {"send_bfs": 0.0, "receive": 0.0, "evaluate": 0.0,
               "send_agg": 0.0}
        for q in bench.queries[:n_queries]:
            masks = masks_for(bench, q)
            cfg = DKSConfig(m=len(q), k=k, max_supersteps=24)
            _, info = run_dks_instrumented(bench.dg, jnp.asarray(masks), cfg)
            for key in agg:
                agg[key] += info["timings"][key]
        total = sum(agg.values()) or 1.0
        rows.append({"K": k, **{key: round(100 * v / total, 1)
                                for key, v in agg.items()}})
    return rows


def fig10_time_vs_queries(dataset="sec-rdfabout-cpu", k=1):
    bench = load(dataset)
    # Vanilla parallel BFS reference (whole-graph traversal).
    src0 = jnp.zeros(bench.dg.v_pad, bool).at[0].set(True)
    t0 = time.perf_counter()
    jax.block_until_ready(vanilla_parallel_bfs(bench.dg, src0))
    bfs_time = time.perf_counter() - t0
    rows = []
    for q in bench.queries:
        state, dt = _run(bench, q, k)
        rows.append({
            "m": len(q),
            "kw_nodes": int(sum(bench.index.df(t) for t in q)),
            "time_s": round(dt, 3),
            "vs_bfs": round(dt / bfs_time, 2),
            "supersteps": int(state.step),
            "best": float(state.topk_w[0]),
        })
    return {"bfs_time_s": round(bfs_time, 3), "queries": rows}


def fig11_deep_messages(dataset="sec-rdfabout-cpu", ks=(1, 2, 5, 10),
                        n_queries=5):
    bench = load(dataset)
    rows = []
    for k in ks:
        deep = []
        for q in bench.queries[:n_queries]:
            state, _ = _run(bench, q, k)
            deep.append(float(state.msgs_deep))
        rows.append({"K": k, "mean_deep_msgs": float(np.mean(deep)),
                     "max_deep_msgs": float(np.max(deep))})
    return rows


def fig12_spa_ratio(dataset="sec-rdfabout-cpu", budget=50_000.0, k=1,
                    n_queries=8):
    """Force early stop via the message budget; report SPA-ratio (=0 when
    the exit criterion was satisfied, per the paper's convention)."""
    bench = load(dataset)
    rows = []
    for q in bench.queries[:n_queries]:
        state, _ = _run(bench, q, k, message_budget=budget)
        if bool(state.budget_hit):
            shat = state.s_front + bench.dg.e_min()
            spa = spa_cover_dp(shat, len(q))
            r = float(spa_ratio(state.topk_w[0], spa))
        else:
            r = 0.0
        rows.append({"m": len(q), "budget_hit": bool(state.budget_hit),
                     "spa_ratio": round(r, 3) if np.isfinite(r) else -1.0,
                     "best": float(state.topk_w[0])})
    return rows


def fig13_explored(dataset="sec-rdfabout-cpu", ks=(1, 2, 5, 10)):
    bench = load(dataset)
    rows = []
    for q in bench.queries:
        fr = []
        for k in ks:
            state, _ = _run(bench, q, k)
            fr.append(float(jnp.mean(state.visited[: bench.g.n_nodes])))
        rows.append({"m": len(q), "explored_pct": round(100 * np.mean(fr), 1)})
    return rows


def fig14_messages(dataset="sec-rdfabout-cpu", ks=(1, 2, 5, 10),
                   n_queries=6):
    bench = load(dataset)
    e = bench.dg.n_edges
    rows = []
    for k in ks:
        fracs = []
        for q in bench.queries[:n_queries]:
            state, _ = _run(bench, q, k)
            fracs.append((float(state.msgs_bfs) + float(state.msgs_deep)) / e)
        rows.append({"K": k, "msgs_pct_of_E": round(100 * np.mean(fracs), 1)})
    return rows


def fig15_parallel_efficiency(dataset="sec-rdfabout-cpu",
                              worker_counts=(1, 2, 4, 8, 16, 35)):
    """Structural parallel-efficiency model (single-core container): for
    each worker count, hash-partition the graph and report edge-cut (comm
    volume fraction) and max/mean shard load (straggler bound).  Predicted
    speedup = workers / (load_imbalance + cut * comm_factor) — the same
    saturation shape as paper Fig. 15."""
    bench = load(dataset)
    g = bench.g
    deg = np.diff(g.indptr)
    rows = []
    for w in worker_counts:
        part = hash_partition(g.n_nodes, w, seed=1)
        cut = edge_cut(g, part)
        loads = np.zeros(w)
        np.add.at(loads, part.shard_of[part.inv_perm[np.arange(g.n_nodes)]],
                  deg)
        imbalance = float(loads.max() / max(loads.mean(), 1e-9))
        comm_factor = 1.5  # per-message network cost vs local compute
        speedup = w / (imbalance + cut * comm_factor)
        rows.append({"workers": w, "edge_cut": round(cut, 3),
                     "load_imbalance": round(imbalance, 3),
                     "predicted_speedup": round(speedup, 2)})
    return rows
