"""DKS benchmarks, one per paper table/figure (Sec. 7.2), all served
through :class:`repro.engine.QueryEngine`.

Scaled to this CPU container via the *-cpu synthetic datasets; the same
code paths drive the full-scale graphs on a pod.

  table1   — % time per DKS component, K ∈ {1,2,5,10}      (paper Table 1)
  fig10    — per-query normalized time vs vanilla BFS      (paper Fig. 10)
  fig11    — deep-message counts vs K                      (paper Fig. 11)
  fig12    — SPA-ratio under a message budget              (paper Fig. 12)
  fig13    — % nodes explored                              (paper Fig. 13)
  fig14    — messages as % of |E|                          (paper Fig. 14)
  fig15    — parallel efficiency proxy (edge-cut + balance) (paper Fig. 15)
  fig15_sharded — executable sharded-vs-single wall times  (paper Fig. 15)
  fig_extract — host vs device-batched tree reconstruction vs bucket size
  fig_telemetry — superstep-telemetry carry overhead (bit-identical
                  answers asserted; the production-observability tax)
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Bench, load
from repro.core.baselines import vanilla_parallel_bfs
from repro.engine import ExecutionPolicy, QueryEngine, QueryResult
from repro.graph.partition import edge_cut, hash_partition


def _run(bench: Bench, query, k, **kw) -> QueryResult:
    return bench.engine.query(query, k=k, extract=False, **kw)


def table1_phase_breakdown(dataset="sec-rdfabout-cpu", ks=(1, 2, 5, 10),
                           n_queries=3):
    """Percentage of time per component, by K."""
    bench = load(dataset)
    rows = []
    for k in ks:
        agg = {"send_bfs": 0.0, "receive": 0.0, "evaluate": 0.0,
               "send_agg": 0.0}
        for q in bench.queries[:n_queries]:
            _, info = bench.engine.query_instrumented(
                q, k=k, extract=False, max_supersteps=24)
            for key in agg:
                agg[key] += info["timings"][key]
        total = sum(agg.values()) or 1.0
        rows.append({"K": k, **{key: round(100 * v / total, 1)
                                for key, v in agg.items()}})
    return rows


def fig10_time_vs_queries(dataset="sec-rdfabout-cpu", k=1):
    bench = load(dataset)
    # Vanilla parallel BFS reference (whole-graph traversal).
    src0 = jnp.zeros(bench.dg.v_pad, bool).at[0].set(True)
    t0 = time.perf_counter()
    jax.block_until_ready(vanilla_parallel_bfs(bench.dg, src0))
    bfs_time = time.perf_counter() - t0
    rows = []
    for q in bench.queries:
        res = _run(bench, q, k)
        rows.append({
            "m": res.m,
            "kw_nodes": res.kw_nodes,
            "time_s": round(res.wall_time_s, 3),
            "vs_bfs": round(res.wall_time_s / bfs_time, 2),
            "supersteps": res.supersteps,
            "best": res.best_weight,
        })
    return {"bfs_time_s": round(bfs_time, 3), "queries": rows}


def fig11_deep_messages(dataset="sec-rdfabout-cpu", ks=(1, 2, 5, 10),
                        n_queries=5):
    bench = load(dataset)
    rows = []
    for k in ks:
        deep = [_run(bench, q, k).msgs_deep
                for q in bench.queries[:n_queries]]
        rows.append({"K": k, "mean_deep_msgs": float(np.mean(deep)),
                     "max_deep_msgs": float(np.max(deep))})
    return rows


def fig12_spa_ratio(dataset="sec-rdfabout-cpu", budget=50_000.0, k=1,
                    n_queries=8):
    """Force early stop via the message budget; report SPA-ratio (=0 when
    the exit criterion was satisfied, per the paper's convention)."""
    bench = load(dataset)
    rows = []
    for q in bench.queries[:n_queries]:
        res = _run(bench, q, k, message_budget=budget)
        rows.append({"m": res.m, "budget_hit": res.budget_hit,
                     "capped": res.capped,
                     "spa_ratio": (round(res.spa_ratio, 3)
                                   if np.isfinite(res.spa_ratio) else -1.0),
                     "best": res.best_weight})
    return rows


def fig13_explored(dataset="sec-rdfabout-cpu", ks=(1, 2, 5, 10)):
    bench = load(dataset)
    rows = []
    for q in bench.queries:
        fr = [_run(bench, q, k).explored_frac for k in ks]
        rows.append({"m": len(q), "explored_pct": round(100 * np.mean(fr), 1)})
    return rows


def fig14_messages(dataset="sec-rdfabout-cpu", ks=(1, 2, 5, 10),
                   n_queries=6):
    bench = load(dataset)
    e = bench.engine.n_edges
    rows = []
    for k in ks:
        fracs = [_run(bench, q, k).msgs_total / e
                 for q in bench.queries[:n_queries]]
        rows.append({"K": k, "msgs_pct_of_E": round(100 * np.mean(fracs), 1)})
    return rows


def fig15_parallel_efficiency(dataset="sec-rdfabout-cpu",
                              worker_counts=(1, 2, 4, 8, 16, 35)):
    """Structural parallel-efficiency model (single-core container): for
    each worker count, hash-partition the graph and report edge-cut (comm
    volume fraction) and max/mean shard load (straggler bound).  Predicted
    speedup = workers / (load_imbalance + cut * comm_factor) — the same
    saturation shape as paper Fig. 15."""
    bench = load(dataset)
    g = bench.g
    deg = np.diff(g.indptr)
    rows = []
    for w in worker_counts:
        part = hash_partition(g.n_nodes, w, seed=1)
        cut = edge_cut(g, part)
        loads = np.zeros(w)
        np.add.at(loads, part.shard_of[part.inv_perm[np.arange(g.n_nodes)]],
                  deg)
        imbalance = float(loads.max() / max(loads.mean(), 1e-9))
        comm_factor = 1.5  # per-message network cost vs local compute
        speedup = w / (imbalance + cut * comm_factor)
        rows.append({"workers": w, "edge_cut": round(cut, 3),
                     "load_imbalance": round(imbalance, 3),
                     "predicted_speedup": round(speedup, 2)})
    return rows


def fig15_sharded_vs_single(dataset="sec-rdfabout-cpu", k=1, n_queries=4,
                            shard_counts=None):
    """Paper Fig. 15's axis, *executed*: the same queries served by the
    dense single-program engine and the frontier-compressed shard_map
    engine at every shard count in ``shard_counts`` (default: one point
    at n_shards=|local devices|; ``benchmarks.run --shards N`` sweeps
    1..N — on CPU expose extra devices first with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).  On one
    core per shard this measures the shard_map machinery's overhead; on
    a pod the identical code path is the scaling curve.  Parity of the
    top-K weights is asserted per query and per shard count — the
    benchmark doubles as an end-to-end correctness check of the revived
    sharded path.  Shard counts beyond the visible device count are
    recorded as skipped rows, never silently dropped."""
    import jax

    bench = load(dataset)
    n_dev = jax.local_device_count()
    if shard_counts is None:
        shard_counts = (n_dev,)
    queries = bench.queries[:n_queries]
    # Untimed warm-up, one query per (m, k) shape on the dense engine:
    # the timed rows must measure execution, not first-trace compilation.
    for m in sorted({len(q) for q in queries}):
        warm = next(q for q in queries if len(q) == m)
        bench.engine.query(warm, k=k, extract=False)
    rows = []
    for n_shards in shard_counts:
        if n_shards > n_dev:
            rows.append({"n_shards": n_shards, "skipped":
                         f"only {n_dev} local device(s) visible"})
            continue
        sharded = QueryEngine.build(
            bench.g, index=bench.index,
            policy=ExecutionPolicy(partition="sharded", n_shards=n_shards,
                                   max_supersteps=32, frontier_frac=1.0))
        for m in sorted({len(q) for q in queries}):
            warm = next(q for q in queries if len(q) == m)
            sharded.query(warm, k=k, extract=False)
        for q in queries:
            rs = bench.engine.query(q, k=k, extract=False)
            rh = sharded.query(q, k=k, extract=False)
            # Tolerant parity check: on multi-device meshes shard-order
            # float reductions may differ in the last ulp; a real
            # divergence still aborts loudly.
            match = bool(np.allclose(rs.weights, rh.weights,
                                     rtol=1e-5, atol=1e-5))
            assert match, (
                f"sharded/single top-K diverged for {q} at "
                f"n_shards={n_shards}: {rh.weights} vs {rs.weights}")
            rows.append({
                "m": rs.m,
                "n_shards": sharded.device_graph.n_shards,
                "single_s": round(rs.wall_time_s, 4),
                "sharded_s": round(rh.wall_time_s, 4),
                "speedup": round(
                    rs.wall_time_s / max(rh.wall_time_s, 1e-9), 3),
                "weights_match": match,
                "supersteps": rh.supersteps,
            })
    return rows


def fig_sharded_batch(n_nodes=4000, n_edges=12000, k=1, batch=16,
                      repeats=5):
    """The restored sharded batch win, measured: a bucket of same-m
    queries rides the lane-batched driver as ONE device program (the
    lane axis lives inside the shard_map body) versus serving the same
    bucket as sequential single-query runs — which is exactly what the
    pre-driver engine was forced to do (shard_map under vmap is
    unsupported).  Dispatch count is asserted via ``execute_count`` (one
    per bucket, the acceptance criterion) and the wall-time speedup is
    asserted >= 1 even at 1 shard on a single core, where the batch can
    only win by amortizing per-query dispatch + host overhead (compute
    is serialized either way); on a real mesh the lanes share every
    collective too.  A dedicated mid-size synthetic graph and a wide
    bucket keep that overhead fraction — and so the measured margin —
    well clear of timer noise (~1.2x here vs ~1.1x at sec-rdfabout
    scale).  Best-of-``repeats`` timings, warmed."""
    from repro.graph.generators import lod_like_graph
    from repro.graph.index import InvertedIndex, mid_df_tokens

    g, tokens = lod_like_graph(n_nodes, n_edges, seed=7, vocab=200)
    index = InvertedIndex.from_token_matrix(tokens)
    sharded = QueryEngine.build(
        g, index=index,
        policy=ExecutionPolicy(partition="sharded", max_supersteps=32,
                               frontier_frac=1.0))
    q = mid_df_tokens(index)[:2]
    queries = [q] * batch  # same-m (and same-length lanes: a pure
    # dispatch-amortization measurement, robust on one core)
    sharded.query(q, k=k, extract=False)          # warm the 1-lane fused
    sharded.query_batch(queries, k=k, extract=False)  # warm the bucket
    before = sharded.execute_count
    t_batched = min(_timed(lambda: sharded.query_batch(
        queries, k=k, extract=False)) for _ in range(repeats))
    n_exec = sharded.execute_count - before
    assert n_exec == repeats, (
        f"sharded bucket took {n_exec} device executions for {repeats} "
        f"batch calls — expected exactly one per bucket")

    def sequential():
        for qq in queries:
            sharded.query(qq, k=k, extract=False)

    t_sequential = min(_timed(sequential) for _ in range(repeats))
    speedup = t_sequential / max(t_batched, 1e-9)
    assert speedup >= 1.0, (
        f"sharded lane-batched bucket slower than sequential serving "
        f"({t_batched:.3f}s vs {t_sequential:.3f}s) — the restored "
        f"batch path lost its reason to exist")
    return {
        "m": len(q),
        "batch": batch,
        "n_shards": sharded.device_graph.n_shards,
        "batched_bucket_s": round(t_batched, 4),
        "sequential_bucket_s": round(t_sequential, 4),
        "speedup": round(speedup, 3),
        "executions_per_bucket": 1,
    }


def fig_weighted_relax(n_nodes=4000, n_edges=12000, k=2, repeats=5):
    """Weight-policy cost at query time, measured: the typed channel is
    folded into the effective weight vector ONCE at engine build
    (:func:`repro.graph.weights.apply_weight_policy`), so the relaxation
    kernels stay single-weight — a confidence-blended engine must run the
    *same* device program as the default degree engine, just over
    different weight values.  Three asserts make that the acceptance
    criterion: (a) under the default policy a typed graph serves
    bit-identical weights to its untyped twin (the channel rides along
    invisibly); (b) the two policies produce distinct ``cache_token``s on
    the same build inputs (answers must never cross policies); (c) the
    confidence engine's per-superstep time stays within 1.5x of the
    degree engine's (a regression here means policy work leaked into the
    superstep loop).  Best-of-``repeats`` timings, warmed per engine."""
    from repro.graph import WeightPolicy, build_graph
    from repro.graph.generators import lod_like_graph
    from repro.graph.index import InvertedIndex, mid_df_tokens

    g, tokens = lod_like_graph(n_nodes, n_edges, seed=13, vocab=200)
    rng = np.random.default_rng(13)
    pred = rng.integers(0, 3, size=len(g.src)).astype(np.int32)
    conf = rng.uniform(0.5, 2.0, size=len(g.src)).astype(np.float32)
    gt = build_graph(g.src, g.dst, g.n_nodes, w=g.w,
                     pred=pred, conf=conf,
                     pred_names=["cites", "knows", "funds"])
    index = InvertedIndex.from_token_matrix(tokens)

    e_plain = QueryEngine.build(g, index=index,
                                policy=ExecutionPolicy(max_supersteps=32))
    e_deg = QueryEngine.build(gt, index=index,
                              policy=ExecutionPolicy(max_supersteps=32))
    e_conf = QueryEngine.build(
        gt, index=index,
        policy=ExecutionPolicy(
            max_supersteps=32,
            weights=WeightPolicy(kind="confidence", blend=1.0)))

    mid = mid_df_tokens(index)
    q = mid[:: max(1, len(mid) // 3)][:3]

    r_plain = e_plain.query(q, k=k, extract=False)   # doubles as warm-up
    r_deg = e_deg.query(q, k=k, extract=False)
    r_conf = e_conf.query(q, k=k, extract=False)
    np.testing.assert_array_equal(
        r_plain.weights, r_deg.weights,
        err_msg="typed channel changed default-policy answers — the "
                "degree policy must leave a typed graph's weights alone")
    assert e_deg.cache_token(q, k=k) != e_conf.cache_token(q, k=k), (
        "two weight policies over the same build share a cache token — "
        "a result cache would serve one policy's answers to the other")

    t_deg = min(_timed(lambda: e_deg.query(q, k=k, extract=False))
                for _ in range(repeats))
    t_conf = min(_timed(lambda: e_conf.query(q, k=k, extract=False))
                 for _ in range(repeats))
    per_step_deg = t_deg / max(r_deg.supersteps, 1)
    per_step_conf = t_conf / max(r_conf.supersteps, 1)
    ratio = per_step_conf / max(per_step_deg, 1e-9)
    assert ratio <= 1.5, (
        f"confidence policy costs {ratio:.2f}x per superstep vs degree "
        f"({per_step_conf*1e3:.2f} vs {per_step_deg*1e3:.2f} ms) — weight "
        f"policy work leaked into the superstep loop")
    return {
        "m": len(q),
        "k": k,
        "n_nodes": n_nodes,
        "degree_s": round(t_deg, 4),
        "confidence_s": round(t_conf, 4),
        "degree_supersteps": r_deg.supersteps,
        "confidence_supersteps": r_conf.supersteps,
        "per_superstep_ratio": round(ratio, 3),
        "default_policy_parity": True,
        "distinct_cache_tokens": True,
    }


def fig_extract(n_nodes=6000, n_edges=18000, k=3, buckets=(1, 4, 8, 16),
                repeats=3):
    """Answer-tree reconstruction cost: per-query host extraction vs the
    device-batched backtracer (:mod:`repro.answers.batched`), over bucket
    size.  Both paths start from the same final DKS tables and return
    bit-identical trees (asserted at the widest bucket); the host path
    argsorts each lane's full ``[V, 2^m, K]`` table and backtraces each
    candidate in Python, while the batched path resolves the top
    candidates of *all* lanes in one jitted device program and replays
    only ragged stragglers on the host.  The batched win must show by 8
    lanes (the acceptance bar) — per-lane host work is O(V·2^m·K) and
    serial, the kernel amortizes across the lane axis.  Best-of-
    ``repeats``, warmed per bucket shape (one compile per lane count)."""
    from repro.answers import BatchedBacktracer
    from repro.core.reconstruct import collect_answers
    from repro.graph.generators import lod_like_graph
    from repro.graph.index import InvertedIndex, mid_df_tokens

    g, tokens = lod_like_graph(n_nodes, n_edges, seed=11, vocab=200)
    index = InvertedIndex.from_token_matrix(tokens)
    engine = QueryEngine.build(
        g, index=index, policy=ExecutionPolicy(max_supersteps=32))
    mid = mid_df_tokens(index)
    q = mid[:: max(1, len(mid) // 3)][:3]
    max_b = max(buckets)
    res = engine.query_batch([q] * max_b, k=k, extract=False,
                             keep_state=True)
    S_all = np.stack([np.asarray(r.state.S) for r in res])
    masks = np.stack([engine._masks(list(q), True)[0]] * max_b)
    mask_host = masks[0][:, : engine.n_nodes]
    bt = BatchedBacktracer(g)

    def host_bucket(n):
        for i in range(n):
            collect_answers(S_all[i], g, mask_host, k=k)

    def batched_bucket(n):
        bt.extract_lanes(S_all[:n], masks[:n], k=k,
                         n_nodes=engine.n_nodes)

    rows = []
    for L in buckets:
        host_bucket(1)                      # touch caches
        batched_bucket(L)                   # compile this lane count
        t_host = min(_timed(lambda: host_bucket(L))
                     for _ in range(repeats))
        t_batched = min(_timed(lambda: batched_bucket(L))
                        for _ in range(repeats))
        speedup = t_host / max(t_batched, 1e-9)
        if L >= 8:
            assert speedup > 1.0, (
                f"device-batched reconstruction slower than per-query "
                f"host extraction at {L} lanes ({t_batched:.3f}s vs "
                f"{t_host:.3f}s) — the batched backtracer lost its "
                f"reason to exist")
        rows.append({"lanes": L, "host_s": round(t_host, 4),
                     "batched_s": round(t_batched, 4),
                     "speedup": round(speedup, 3)})
    # Parity at the widest bucket: same tree keys, same weights.
    got = bt.extract_lanes(S_all, masks, k=k, n_nodes=engine.n_nodes)
    for i in range(max_b):
        ref, _ = collect_answers(S_all[i], g, mask_host, k=k)
        ans, _ = got[i]
        assert [(a.root, a.weight, tuple(sorted(a.edges))) for a in ans] \
            == [(a.root, a.weight, tuple(sorted(a.edges))) for a in ref], (
            f"batched reconstruction diverged from host on lane {i}")
    return {"m": len(q), "k": k, "n_nodes": n_nodes,
            "device_resolved": bt.device_resolved,
            "host_fallbacks": bt.host_fallbacks,
            "buckets": rows}


def fig_telemetry(dataset="sec-rdfabout-cpu", k=1, repeats=5,
                  n_queries=3):
    """Cost of production superstep telemetry, measured: the SAME fused
    while-loop with and without the per-superstep counter carry
    (``ExecutionPolicy(telemetry=True)`` — frontier size, cumulative
    bfs/deep messages, frozen lanes, stacked into a bounded device
    buffer; see :mod:`repro.obs.telemetry`).  Two asserts make the
    "always-on telemetry" claim the acceptance criterion: (a) answers
    are BIT-identical with telemetry on (the counters are pure reads of
    the post-step state — ``assert_array_equal``, not allclose, on
    weights and roots); (b) per-superstep time stays within 1.25x (the
    hard in-code bar; the recorded ratio is the trajectory number and
    sits ~1.0x — the carry adds four reductions and one buffer row
    write per superstep).  Warm-ups double as the parity check.
    Timings are INTERLEAVED best-of-``repeats`` pairs (base, telemetry,
    base, telemetry, ...): back-to-back blocks bias the ratio by
    whatever load drift happens between them, while interleaving gives
    both variants the same shot at every quiet window.  Ratio is
    aggregated over the total superstep count so long runs weigh more
    than short ones."""
    bench = load(dataset)
    base = bench.engine
    tel = QueryEngine.build(
        bench.g, index=bench.index,
        policy=ExecutionPolicy(max_supersteps=32, telemetry=True))
    queries = bench.queries[:n_queries]
    rows = []
    t_base_total = t_tel_total = 0.0
    steps_total = 0
    for q in queries:
        r_base = base.query(q, k=k, extract=False)   # warm-up + reference
        r_tel = tel.query(q, k=k, extract=False)
        np.testing.assert_array_equal(
            r_base.weights, r_tel.weights,
            err_msg=f"telemetry changed answer weights for {q}")
        np.testing.assert_array_equal(
            r_base.roots, r_tel.roots,
            err_msg=f"telemetry changed answer roots for {q}")
        assert r_tel.telemetry is not None and \
            r_tel.telemetry.n_steps == r_tel.supersteps, (
            "telemetry buffer rows diverged from the superstep count")
        assert r_base.telemetry is None, (
            "baseline engine unexpectedly produced telemetry")
        pairs = [(_timed(lambda: base.query(q, k=k, extract=False)),
                  _timed(lambda: tel.query(q, k=k, extract=False)))
                 for _ in range(repeats)]
        t_base = min(p[0] for p in pairs)
        t_tel = min(p[1] for p in pairs)
        steps = max(r_base.supersteps, 1)
        t_base_total += t_base
        t_tel_total += t_tel
        steps_total += steps
        rows.append({"m": len(q), "supersteps": r_base.supersteps,
                     "base_s": round(t_base, 4),
                     "telemetry_s": round(t_tel, 4),
                     "ratio": round(t_tel / max(t_base, 1e-9), 3)})
    per_step_ratio = (t_tel_total / steps_total) / \
        max(t_base_total / steps_total, 1e-9)
    assert per_step_ratio <= 1.25, (
        f"telemetry costs {per_step_ratio:.2f}x per superstep — the "
        f"counter carry stopped being a rider on the fused loop")
    return {"k": k, "bit_identical": True,
            "per_superstep_ratio": round(per_step_ratio, 3),
            "queries": rows}


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
