"""Kernel micro-benchmarks (CPU wall-time for the jnp paths; the Pallas
variants are validated in interpret mode and their TPU characteristics are
derived structurally in EXPERIMENTS.md §Roofline).

Reported as name,us_per_call,derived rows for benchmarks.run."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import INF
from repro.core import semiring
from repro.core.dks import DKSConfig, combine
from repro.core.spa import split_pairs


def _time(fn, *args, iters=5):
    out = jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def random_table(v, m, k, seed=0):
    rng = np.random.default_rng(seed)
    s = rng.integers(1, 30, size=(v, 1 << m, k)).astype(np.float32)
    s[rng.random(s.shape) > 0.5] = INF
    s = np.array(semiring.sorted_unique_k(jnp.asarray(s), k))
    s[:, 0, :] = INF
    return jnp.asarray(s)


def bench_subset_combine(v=20_000, m=4, k=2):
    """Batched-pass jnp combine vs sequential-scan variant (the kernel's
    single-pass schedule, emulated) — shows the pass-count tradeoff."""
    s = random_table(v, m, k)
    cfg_batched = DKSConfig(m=m, k=k, combine_impl="jnp")

    us_batched, out_b = _time(
        jax.jit(lambda x: combine(x, cfg_batched)), s)

    # Sequential scan over pairs (one pass, k-round merge per pair).
    pairs = split_pairs(m)
    t_ids = jnp.asarray([p[0] for p in pairs])
    a_ids = jnp.asarray([p[1] for p in pairs])
    b_ids = jnp.asarray([p[2] for p in pairs])

    @jax.jit
    def sequential(s):
        def body(s, tab):
            t, a, b = tab
            cand = semiring.outer_combine(s[:, a, :], s[:, b, :])
            merged = semiring.topk_merge(
                jax.lax.dynamic_index_in_dim(s, t, 1, keepdims=False), cand)
            return jax.lax.dynamic_update_index_in_dim(
                s, merged, t, 1), None
        s, _ = jax.lax.scan(body, s, (t_ids, a_ids, b_ids))
        return s

    us_seq, out_s = _time(sequential, s)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_s),
                               atol=1e-4)
    return [
        {"name": f"subset_combine_batched_v{v}_m{m}_k{k}",
         "us_per_call": round(us_batched, 1),
         "derived": f"passes={cfg_batched.n_combine_passes()}"},
        {"name": f"subset_combine_sequential_v{v}_m{m}_k{k}",
         "us_per_call": round(us_seq, 1),
         "derived": f"pairs={len(pairs)}"},
    ]


def bench_segment_topk(e=200_000, v=20_000, f=16, k=2):
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(e, f)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    us, _ = _time(jax.jit(lambda x, s: semiring.segment_topk_min(x, s, v, k)),
                  vals, seg)
    return [{"name": f"segment_topk_e{e}_v{v}_f{f}_k{k}",
             "us_per_call": round(us, 1),
             "derived": f"rounds={k}"}]


def fig_lane_kernel(v=800, e=3200, m=3, k=2, lane_counts=(1, 4, 8)):
    """The fused pallas lane-superstep kernel vs the vmapped jnp
    superstep chain: per-superstep wall time at several lane counts,
    parity-checked bit-identically at every point.

    The timed unit is ONE jitted ``lane_superstep`` call — the body both
    the fused while-loop and the stepwise drivers repeat — so the ratio
    is the whole-query ratio minus host overhead.  On CPU the kernel
    runs in interpret mode (``interpret=True`` in the result): those
    wall times measure the emulation, not the kernel — the row is a
    trend/parity record there, and a device measurement on TPU/GPU.
    Structural economy is measured either way: ``jaxpr_eqns`` counts
    equations in each path's jaxpr and ``pallas_calls`` asserts the
    fused path is exactly one launch."""
    from repro.core.driver import lane_init, lane_superstep
    from repro.engine import ExecutionPolicy, QueryEngine
    from repro.graph.generators import lod_like_graph
    from repro.graph.index import InvertedIndex, mid_df_tokens
    from repro.kernels.lane_superstep import interpret_default

    g, tokens = lod_like_graph(v, e, seed=0, vocab=60, tau=1001)
    index = InvertedIndex.from_token_matrix(tokens)
    ej = QueryEngine.build(g, index=index, policy=ExecutionPolicy(
        backend="jnp", max_supersteps=16))
    ep = QueryEngine.build(g, index=index, policy=ExecutionPolicy(
        backend="pallas", max_supersteps=16))
    cfg_j = ej.policy.dks_config(m, k)
    cfg_p = ep.policy.dks_config(m, k)
    mid = mid_df_tokens(index)
    queries = [list(mid[i:i + m]) for i in range(max(lane_counts))]

    def all_eqns(jaxpr):
        out = list(jaxpr.eqns)
        for eq in jaxpr.eqns:
            for p in eq.params.values():
                inner = getattr(p, "jaxpr", None)
                if inner is not None:
                    out += all_eqns(getattr(inner, "jaxpr", inner))
        return out

    step_j = jax.jit(lambda s: lane_superstep(ej.device_graph, s, cfg_j))
    step_p = jax.jit(lambda s: lane_superstep(
        ep.device_graph, s, cfg_p, csr=ep.lane_csr))

    rows = []
    jaxpr_eqns = pallas_calls = None
    for lanes in lane_counts:
        masks = jnp.asarray(np.stack(
            [ej._masks(q)[0] for q in queries[:lanes]]))
        st = lane_init(ej.device_graph, masks, cfg_j)
        if jaxpr_eqns is None:
            ej_eqns = all_eqns(jax.make_jaxpr(step_j)(st).jaxpr)
            ep_eqns = all_eqns(jax.make_jaxpr(step_p)(st).jaxpr)
            pallas_calls = sum(1 for q in ep_eqns
                               if q.primitive.name == "pallas_call")
            assert pallas_calls == 1, pallas_calls
            jaxpr_eqns = {"jnp": len(ej_eqns), "pallas": len(ep_eqns)}
        us_j, out_j = _time(step_j, st)
        us_p, out_p = _time(step_p, st)
        if not np.array_equal(np.asarray(out_j.S), np.asarray(out_p.S)):
            raise AssertionError(f"kernel parity broke at lanes={lanes}")
        rows.append({
            "lanes": lanes,
            "jnp_us_per_step": round(us_j, 1),
            "pallas_us_per_step": round(us_p, 1),
            "speedup": round(us_j / us_p, 3) if us_p else None,
            "parity": "bit-identical",
        })
    return {
        "graph": {"v": v, "e": e, "m": m, "k": k},
        "interpret": interpret_default(),
        "jaxpr_eqns": jaxpr_eqns,
        "pallas_calls_per_superstep": pallas_calls,
        "rows": rows,
    }


def bench_attention(b=1, s=512, h=8, dh=64):
    from repro.models.attention import attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    kv = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    us_naive, o1 = _time(
        jax.jit(lambda q, k, v: attention(q, k, v, impl="naive")), q, kv, kv)
    us_c32, o2 = _time(
        jax.jit(lambda q, k, v: attention(q, k, v, impl="chunked_f32",
                                          block=128)), q, kv, kv)
    us_cbf, o3 = _time(
        jax.jit(lambda q, k, v: attention(q, k, v, impl="chunked",
                                          block=128)), q, kv, kv)
    us_fl, o4 = _time(
        jax.jit(lambda q, k, v: attention(q, k, v, impl="flash_jax",
                                          block=128)), q, kv, kv)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=3e-2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o4), atol=3e-2)
    return [
        {"name": f"attention_naive_s{s}", "us_per_call": round(us_naive, 1),
         "derived": "materialized SxS"},
        {"name": f"attention_chunked_f32_s{s}", "us_per_call": round(us_c32, 1),
         "derived": "online softmax f32"},
        {"name": f"attention_chunked_bf16_s{s}", "us_per_call": round(us_cbf, 1),
         "derived": "online softmax bf16 scores"},
        {"name": f"attention_flash_jax_s{s}", "us_per_call": round(us_fl, 1),
         "derived": "custom VJP"},
    ]
