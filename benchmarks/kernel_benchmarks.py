"""Kernel micro-benchmarks (CPU wall-time for the jnp paths; the Pallas
variants are validated in interpret mode and their TPU characteristics are
derived structurally in EXPERIMENTS.md §Roofline).

Reported as name,us_per_call,derived rows for benchmarks.run."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import INF
from repro.core import semiring
from repro.core.dks import DKSConfig, combine
from repro.core.spa import split_pairs


def _time(fn, *args, iters=5):
    out = jax.block_until_ready(fn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def random_table(v, m, k, seed=0):
    rng = np.random.default_rng(seed)
    s = rng.integers(1, 30, size=(v, 1 << m, k)).astype(np.float32)
    s[rng.random(s.shape) > 0.5] = INF
    s = np.array(semiring.sorted_unique_k(jnp.asarray(s), k))
    s[:, 0, :] = INF
    return jnp.asarray(s)


def bench_subset_combine(v=20_000, m=4, k=2):
    """Batched-pass jnp combine vs sequential-scan variant (the kernel's
    single-pass schedule, emulated) — shows the pass-count tradeoff."""
    s = random_table(v, m, k)
    cfg_batched = DKSConfig(m=m, k=k, combine_impl="jnp")

    us_batched, out_b = _time(
        jax.jit(lambda x: combine(x, cfg_batched)), s)

    # Sequential scan over pairs (one pass, k-round merge per pair).
    pairs = split_pairs(m)
    t_ids = jnp.asarray([p[0] for p in pairs])
    a_ids = jnp.asarray([p[1] for p in pairs])
    b_ids = jnp.asarray([p[2] for p in pairs])

    @jax.jit
    def sequential(s):
        def body(s, tab):
            t, a, b = tab
            cand = semiring.outer_combine(s[:, a, :], s[:, b, :])
            merged = semiring.topk_merge(
                jax.lax.dynamic_index_in_dim(s, t, 1, keepdims=False), cand)
            return jax.lax.dynamic_update_index_in_dim(
                s, merged, t, 1), None
        s, _ = jax.lax.scan(body, s, (t_ids, a_ids, b_ids))
        return s

    us_seq, out_s = _time(sequential, s)
    np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_s),
                               atol=1e-4)
    return [
        {"name": f"subset_combine_batched_v{v}_m{m}_k{k}",
         "us_per_call": round(us_batched, 1),
         "derived": f"passes={cfg_batched.n_combine_passes()}"},
        {"name": f"subset_combine_sequential_v{v}_m{m}_k{k}",
         "us_per_call": round(us_seq, 1),
         "derived": f"pairs={len(pairs)}"},
    ]


def bench_segment_topk(e=200_000, v=20_000, f=16, k=2):
    rng = np.random.default_rng(0)
    vals = jnp.asarray(rng.normal(size=(e, f)).astype(np.float32))
    seg = jnp.asarray(rng.integers(0, v, e).astype(np.int32))
    us, _ = _time(jax.jit(lambda x, s: semiring.segment_topk_min(x, s, v, k)),
                  vals, seg)
    return [{"name": f"segment_topk_e{e}_v{v}_f{f}_k{k}",
             "us_per_call": round(us, 1),
             "derived": f"rounds={k}"}]


def bench_attention(b=1, s=512, h=8, dh=64):
    from repro.models.attention import attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    kv = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
    us_naive, o1 = _time(
        jax.jit(lambda q, k, v: attention(q, k, v, impl="naive")), q, kv, kv)
    us_c32, o2 = _time(
        jax.jit(lambda q, k, v: attention(q, k, v, impl="chunked_f32",
                                          block=128)), q, kv, kv)
    us_cbf, o3 = _time(
        jax.jit(lambda q, k, v: attention(q, k, v, impl="chunked",
                                          block=128)), q, kv, kv)
    us_fl, o4 = _time(
        jax.jit(lambda q, k, v: attention(q, k, v, impl="flash_jax",
                                          block=128)), q, kv, kv)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-3)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=3e-2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o4), atol=3e-2)
    return [
        {"name": f"attention_naive_s{s}", "us_per_call": round(us_naive, 1),
         "derived": "materialized SxS"},
        {"name": f"attention_chunked_f32_s{s}", "us_per_call": round(us_c32, 1),
         "derived": "online softmax f32"},
        {"name": f"attention_chunked_bf16_s{s}", "us_per_call": round(us_cbf, 1),
         "derived": "online softmax bf16 scores"},
        {"name": f"attention_flash_jax_s{s}", "us_per_call": round(us_fl, 1),
         "derived": "custom VJP"},
    ]
