"""Benchmark harness entry: ``PYTHONPATH=src python -m benchmarks.run``.

One benchmark per paper table/figure (Sec. 7.2), plus kernel micro-benches.
Prints ``name,us_per_call,derived`` CSV rows and writes the full structured
results to experiments/bench_results.json, plus the machine-readable
per-figure wall-time summary experiments/BENCH_dks.json and the serving
summary experiments/BENCH_serve.json (throughput + p95 vs micro-batch
size) — the perf trajectory files; compare them across commits to spot
regressions.

``--full`` runs the complete query suite (slower); default is a CPU-sized
subset exercising every code path.
"""

from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import time
from pathlib import Path

OUT = Path(__file__).resolve().parent.parent / "experiments"


def provenance() -> dict:
    """Stamp for every BENCH_*.json record: git commit, jax version, and
    device kind — so cross-commit trajectories are self-describing (a
    regression can be attributed to a commit / jax bump / hardware swap
    without consulting external logs)."""
    import jax

    try:
        repo = Path(__file__).resolve().parent
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=repo, timeout=10,
        ).stdout.strip() or None
        if commit:
            dirty = subprocess.run(
                ["git", "status", "--porcelain"], capture_output=True,
                text=True, cwd=repo, timeout=10,
            ).stdout.strip()
            if dirty:
                # Uncommitted changes produced these numbers: say so, or
                # the trajectory attributes them to the parent commit.
                commit += "-dirty"
    except (OSError, subprocess.SubprocessError):
        commit = None
    dev = jax.devices()[0]
    return {
        "commit": commit,
        "jax": jax.__version__,
        "n_devices": len(jax.devices()),
        "device_kind": getattr(dev, "device_kind", dev.platform),
        "platform": dev.platform,
        # Host identity: wall-time trajectories only compare within one
        # machine class; these two fields make cross-host noise visible.
        "hostname": socket.gethostname(),
        "cpu_count": os.cpu_count(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--shards", type=int, default=None,
        help="sweep fig15_sharded_vs_single over shard counts 1..N "
             "(expose CPU devices first with "
             "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    args, _ = ap.parse_known_args()

    from benchmarks import dks_benchmarks as dks
    from benchmarks import ingest_benchmarks as ing
    from benchmarks import kernel_benchmarks as kb
    from benchmarks import serve_benchmarks as sv

    results = {}
    rows = []
    fig_wall_s = {}

    def selected(name):
        if args.only is None:
            return True
        if args.only == "kernels":
            # The kernel surface spans differently-named figures and
            # micro-benches; a plain substring match would select none.
            return name == "fig_lane_kernel" or name.startswith("bench_")
        return args.only in name

    def record(name, fn, *fargs, **fkw):
        if not selected(name):
            return
        t0 = time.perf_counter()
        out = fn(*fargs, **fkw)
        dt = time.perf_counter() - t0
        results[name] = out
        fig_wall_s[name] = round(dt, 3)
        rows.append((name, round(dt * 1e6, 1), "paper-figure"))
        print(f"# --- {name} ({dt:.1f}s) ---")
        print(json.dumps(out, indent=1)[:2000])

    record("table1_phase_breakdown", dks.table1_phase_breakdown,
           n_queries=3 if not args.full else 10)
    record("fig10_time_vs_queries", dks.fig10_time_vs_queries)
    record("fig11_deep_messages", dks.fig11_deep_messages,
           n_queries=3 if not args.full else 10)
    record("fig12_spa_ratio", dks.fig12_spa_ratio,
           n_queries=4 if not args.full else 12)
    record("fig13_explored", dks.fig13_explored,
           ks=(1, 2) if not args.full else (1, 2, 5, 10))
    record("fig14_messages", dks.fig14_messages,
           n_queries=3 if not args.full else 10)
    record("fig15_parallel_efficiency", dks.fig15_parallel_efficiency)
    record("fig15_sharded_vs_single", dks.fig15_sharded_vs_single,
           n_queries=2 if not args.full else 8,
           shard_counts=(tuple(range(1, args.shards + 1))
                         if args.shards else None))
    record("fig_sharded_batch", dks.fig_sharded_batch)
    record("fig_weighted_relax", dks.fig_weighted_relax)
    record("fig_extract", dks.fig_extract,
           buckets=(1, 4, 8) if not args.full else (1, 4, 8, 16))
    record("fig_telemetry", dks.fig_telemetry,
           repeats=3 if not args.full else 5,
           n_queries=2 if not args.full else 4)
    record("fig_serve_throughput", sv.fig_serve_throughput,
           batch_sizes=(1, 4) if not args.full else (1, 2, 4, 8),
           n_requests=12 if not args.full else 32,
           unique=4 if not args.full else 8)
    record("fig_ingest", ing.fig_ingest)
    record("fig_delta", ing.fig_delta)
    record("fig_lane_kernel", kb.fig_lane_kernel,
           lane_counts=(1, 4) if not args.full else (1, 4, 8, 16))

    print("\nname,us_per_call,derived")
    for bench_fn in (kb.bench_subset_combine, kb.bench_segment_topk,
                     kb.bench_attention):
        if not selected(bench_fn.__name__):
            continue
        for r in bench_fn():
            rows.append((r["name"], r["us_per_call"], r["derived"]))
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
    OUT.mkdir(exist_ok=True)
    (OUT / "bench_results.json").write_text(json.dumps(results, indent=1))
    print(f"\nwrote {OUT / 'bench_results.json'}")
    stamp = provenance()

    # The trajectory files are committed and compared across commits, so
    # a filtered run (--only) must not clobber them with partial or
    # foreign data.  BENCH_dks spans many figures: only an unfiltered run
    # writes it.  BENCH_serve holds a single figure, so it is written
    # whenever that figure ran in full.
    dks_figs = {k: v for k, v in fig_wall_s.items()
                if k not in ("fig_serve_throughput", "fig_ingest",
                             "fig_delta", "fig_lane_kernel")}
    if dks_figs and args.only is None:
        bench_dks = {
            **stamp,
            "full": bool(args.full),
            "per_figure_wall_s": dks_figs,
            "sharded_vs_single": results.get("fig15_sharded_vs_single"),
            "sharded_batch": results.get("fig_sharded_batch"),
            "weighted_relax": results.get("fig_weighted_relax"),
            "extract": results.get("fig_extract"),
            "telemetry": results.get("fig_telemetry"),
        }
        (OUT / "BENCH_dks.json").write_text(json.dumps(bench_dks, indent=1))
        print(f"wrote {OUT / 'BENCH_dks.json'}")
    if "fig_serve_throughput" in results:
        bench_serve = {
            **stamp,
            "full": bool(args.full),
            "wall_s": fig_wall_s.get("fig_serve_throughput"),
            "throughput_vs_batch": results["fig_serve_throughput"],
        }
        (OUT / "BENCH_serve.json").write_text(
            json.dumps(bench_serve, indent=1))
        print(f"wrote {OUT / 'BENCH_serve.json'}")
    if "fig_lane_kernel" in results:
        # Single-figure trajectory file, like BENCH_serve: written
        # whenever the fig ran (including under --only kernels).  The
        # record carries the interpret flag — CPU rows measure the
        # interpreter and are trend/parity data, not device numbers.
        bench_kernels = {
            **stamp,
            "full": bool(args.full),
            "wall_s": fig_wall_s.get("fig_lane_kernel"),
            "lane_kernel": results["fig_lane_kernel"],
        }
        (OUT / "BENCH_kernels.json").write_text(
            json.dumps(bench_kernels, indent=1))
        print(f"wrote {OUT / 'BENCH_kernels.json'}")
    if "fig_ingest" in results:
        bench_ingest = {
            **stamp,
            "full": bool(args.full),
            "wall_s": fig_wall_s.get("fig_ingest"),
            "ingest": results["fig_ingest"],
            "delta": results.get("fig_delta"),
        }
        (OUT / "BENCH_ingest.json").write_text(
            json.dumps(bench_ingest, indent=1))
        print(f"wrote {OUT / 'BENCH_ingest.json'}")


if __name__ == "__main__":
    main()
