from repro.data.pipeline import (  # noqa: F401
    PrefetchIterator, lm_synthetic_stream, recsys_synthetic_stream,
)
