"""Data pipeline: deterministic synthetic streams + background prefetch.

Every stream is seeded and shard-aware (``shard_id`` / ``n_shards`` skip
pattern) so multi-host training reads disjoint data without coordination,
and a restarted job resumes at an exact batch index (fault tolerance: the
checkpoint stores the step, the stream is re-seeked with ``skip``).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import numpy as np


def lm_synthetic_stream(
    vocab: int, batch: int, seq: int, seed: int = 0,
    shard_id: int = 0, n_shards: int = 1, skip: int = 0,
) -> Iterator[dict]:
    """Zipf-ish token batches with next-token labels (deterministic)."""
    step = skip
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)
    while True:
        rng = np.random.default_rng(
            (seed * 1_000_003 + step * n_shards + shard_id) % (2**63))
        toks = rng.choice(vocab, size=(batch, seq + 1), p=probs).astype(np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        step += 1


def recsys_synthetic_stream(
    cfg, batch: int, seed: int = 0, shard_id: int = 0, n_shards: int = 1,
    skip: int = 0,
) -> Iterator[dict]:
    """Criteo-like batches: log-normal dense, Zipf sparse ids, CTR labels
    correlated with a hidden linear model (so training loss moves)."""
    step = skip
    while True:
        rng = np.random.default_rng(
            (seed * 999_983 + step * n_shards + shard_id) % (2**63))
        dense = rng.lognormal(0.0, 1.0, (batch, cfg.n_dense)).astype(np.float32)
        sparse = np.stack(
            [np.minimum(rng.zipf(1.3, batch), cfg.vocab_sizes[i]) - 1
             for i in range(cfg.n_sparse)], axis=1).astype(np.int32)
        w = np.linspace(-1, 1, cfg.n_dense)
        logit = dense @ w * 0.1 + rng.normal(0, 1, batch)
        label = (logit > 0).astype(np.int32)
        yield {"dense": np.log1p(dense), "sparse": sparse, "label": label}
        step += 1


class PrefetchIterator:
    """Background-thread prefetch with bounded queue (overlaps host batch
    synthesis/IO with device steps)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: BaseException | None = None

        def worker():
            try:
                for item in it:
                    self._q.put(item)
            except BaseException as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.put(self._done)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item
