"""Streaming extraction: overlap host-side tree reconstruction with the
remaining device supersteps.

The stepwise driver freezes finished lanes while the rest of the bucket
keeps iterating.  A frozen lane's table is final — its answer trees can be
reconstructed *now*, on a host worker thread, while the device runs the
next supersteps for the unfinished lanes.  By the time the loop exits,
most extractions are already done; deadline queries get best-so-far trees
for interrupted lanes the same way.

:class:`ExtractionOverlap` is the single-use helper the engine's deadline
loop drives: ``submit(lane, S, masks)`` as lanes freeze (snapshotting the
lane's table on the caller's thread — the device buffer may keep
mutating), then ``result(lane, ...)`` at the end (collects the overlap
result, or extracts inline for lanes never submitted — e.g. interrupted
ones, whose best-so-far table is only known at deadline)."""

from __future__ import annotations

import concurrent.futures

import numpy as np

from repro.core.reconstruct import AnswerTree, collect_answers
from repro.graph.structure import Graph


class ExtractionOverlap:
    """One query-batch's worth of overlapped host extractions.

    Not thread-safe for concurrent ``submit``; the intended caller is the
    engine's (single-threaded) stepwise loop, with the actual numpy
    reconstruction running on ``workers`` background threads (pure numpy —
    the GIL is released in the argsort/array ops and the device is never
    touched, so the overlap is real).
    """

    def __init__(self, graph: Graph, k: int, candidate_factor: int = 4,
                 workers: int = 2) -> None:
        self.graph = graph
        self.k = k
        self.candidate_factor = candidate_factor
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=max(1, workers), thread_name_prefix="extract")
        self._futures: dict[int, concurrent.futures.Future] = {}
        self.overlapped = 0   # extractions that ran during device steps
        self.inline = 0       # extractions that ran at collection time

    def submit(self, lane: int, S_lane, masks: np.ndarray) -> None:
        """Queue extraction for a lane that just froze.  ``S_lane`` is the
        lane's final table (any array-like; snapshotted to host numpy here,
        synchronously, so later device writes can't race); ``masks`` is
        ``[m, V_real]`` bool."""
        if lane in self._futures:
            return
        S = np.asarray(S_lane)
        masks = np.asarray(masks)
        self.overlapped += 1
        self._futures[lane] = self._pool.submit(
            collect_answers, S, self.graph, masks, self.k,
            self.candidate_factor)

    def pending(self, lane: int) -> bool:
        return lane in self._futures

    def result(self, lane: int, S_lane=None,
               masks: np.ndarray | None = None
               ) -> tuple[list[AnswerTree], bool]:
        """Collect a lane's ``(answers, exhausted)``.  Lanes never
        submitted (interrupted at deadline, or overlap disabled) extract
        inline from the provided table."""
        fut = self._futures.get(lane)
        if fut is not None:
            return fut.result()
        if S_lane is None or masks is None:
            raise ValueError(f"lane {lane} was never submitted and no "
                             "table was provided for inline extraction")
        self.inline += 1
        return collect_answers(
            np.asarray(S_lane), self.graph, np.asarray(masks), self.k,
            self.candidate_factor)

    def stats(self) -> dict[str, int]:
        """``{overlapped, inline}`` extraction counts — how much of the
        bucket's tree reconstruction actually hid behind device steps."""
        return {"overlapped": self.overlapped, "inline": self.inline}

    def close(self) -> None:
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "ExtractionOverlap":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
