"""repro.answers — the answer subsystem: from the final DP table to
servable, ranked, diversified answer trees.

Layers (ROADMAP: "answer trees as a product surface"):

  batched    — device-batched lane-parallel backtrace over a whole bucket
               (bit-for-bit host parity; ragged stragglers fall back to
               the host search)
  diversify  — Jaccard tree distance, MMR diversified ordering, greedy
               clustering (duplication-free top-K)
  render     — label-rendered trees (RenderedTree) and cursor pagination
               (TreePage)
  streaming  — ExtractionOverlap: reconstruct frozen lanes' trees on host
               threads while the device finishes the bucket

Public API:
  BatchedBacktracer, BatchedBacktrace, split_pair_table
  tree_distance, diversified_order, top_k_diverse, cluster_trees
  RenderedTree, RenderedEdge, TreePage, render_tree, paginate
  ExtractionOverlap
"""

from repro.answers.batched import (  # noqa: F401
    BatchedBacktrace,
    BatchedBacktracer,
    split_pair_table,
)
from repro.answers.diversify import (  # noqa: F401
    cluster_trees,
    diversified_order,
    top_k_diverse,
    tree_distance,
)
from repro.answers.render import (  # noqa: F401
    RenderedEdge,
    RenderedTree,
    TreePage,
    default_label,
    paginate,
    render_tree,
)
from repro.answers.streaming import ExtractionOverlap  # noqa: F401
