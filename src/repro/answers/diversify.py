"""Diversified ranking over answer trees.

Weight-ranked top-K often returns K near-copies of the best tree (one
swapped leaf each).  Following the duplication-free top-K of "Effective
Keyword Search in Graphs" and KlusTree-style clustering (PAPERS.md), this
module re-orders a weight-ranked candidate list so the head of the list
covers *distinct* explanations:

- :func:`tree_distance` — Jaccard distance over the trees' node∪edge sets;
- :func:`diversified_order` — greedy maximal-marginal-relevance (MMR)
  permutation of the whole list (serving paginates over it);
- :func:`top_k_diverse` — the first ``k`` of that permutation;
- :func:`cluster_trees` — greedy leader clustering (each tree joins the
  first representative within ``threshold`` distance).

Everything here is pure host-side set algebra over already-extracted
trees; ranking never re-touches the device.
"""

from __future__ import annotations

from typing import Sequence

from repro.core.reconstruct import AnswerTree


def _elements(tree: AnswerTree) -> frozenset:
    """The comparable identity of a tree: its nodes plus its edges (edges
    tagged so an edge (u, v) never collides with node ids)."""
    return frozenset(tree.nodes) | frozenset(("e", u, v) for u, v in tree.edges)


def tree_distance(a: AnswerTree, b: AnswerTree) -> float:
    """Jaccard distance over node∪edge sets: 0 = identical structure,
    1 = disjoint."""
    ea, eb = _elements(a), _elements(b)
    union = len(ea | eb)
    if union == 0:
        return 0.0
    return 1.0 - len(ea & eb) / union


def diversified_order(
    trees: Sequence[AnswerTree],
    lambda_: float = 0.5,
) -> list[int]:
    """Greedy MMR permutation of ``trees`` (assumed weight-ranked, best
    first).

    At each step pick the unselected tree maximizing
    ``lambda_ * relevance - (1 - lambda_) * max_similarity_to_selected``
    where relevance is the (normalized) inverse weight rank and similarity
    is ``1 - tree_distance``.  ``lambda_=1`` reproduces the input order;
    ``lambda_=0`` is pure farthest-point diversification.  Returns a full
    permutation of indices so callers can paginate without re-ranking.
    """
    n = len(trees)
    if n == 0:
        return []
    if not 0.0 <= lambda_ <= 1.0:
        raise ValueError(f"lambda_ must be in [0, 1], got {lambda_}")
    # Relevance from rank, not raw weight: scale-free across graphs.
    rel = [1.0 - i / n for i in range(n)]
    selected: list[int] = [0]  # the best tree always leads
    remaining = list(range(1, n))
    max_sim = {i: 1.0 - tree_distance(trees[i], trees[0]) for i in remaining}
    while remaining:
        best, best_score = None, None
        for i in remaining:
            score = lambda_ * rel[i] - (1.0 - lambda_) * max_sim[i]
            if best_score is None or score > best_score:
                best, best_score = i, score
        remaining.remove(best)
        selected.append(best)
        for i in remaining:
            sim = 1.0 - tree_distance(trees[i], trees[best])
            if sim > max_sim[i]:
                max_sim[i] = sim
    return selected


def top_k_diverse(
    trees: Sequence[AnswerTree],
    k: int,
    lambda_: float = 0.5,
) -> list[AnswerTree]:
    """The ``k`` most representative trees of a weight-ranked list (MMR
    order; see :func:`diversified_order`)."""
    order = diversified_order(trees, lambda_)
    return [trees[i] for i in order[: max(k, 0)]]


def cluster_trees(
    trees: Sequence[AnswerTree],
    threshold: float = 0.5,
) -> list[list[int]]:
    """Greedy leader clustering: scan in rank order; each tree joins the
    cluster of the first representative within ``threshold`` Jaccard
    distance, else founds a new cluster.  Returns clusters as index lists
    (cluster leaders are the answer-set's distinct explanations)."""
    clusters: list[list[int]] = []
    for i, t in enumerate(trees):
        for members in clusters:
            if tree_distance(t, trees[members[0]]) <= threshold:
                members.append(i)
                break
        else:
            clusters.append([i])
    return clusters
