"""Servable answer-tree payloads: label rendering and pagination.

An :class:`~repro.core.reconstruct.AnswerTree` is raw node ids — fine for
parity tests, useless for a client.  This module turns trees into
explanations: entity labels from the artifact's label blob, per-edge
weights, and a cursor-paginated page over a ranked list.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.core.reconstruct import AnswerTree, _edge_weight
from repro.graph.structure import Graph


@dataclasses.dataclass(frozen=True)
class RenderedEdge:
    u: int
    v: int
    u_label: str
    v_label: str
    weight: float
    # Provenance of the effective edge (typed graphs only): the predicate
    # name and confidence of the cheapest parallel entry — the one the
    # backtrace resolved.  None / 1.0 on untyped graphs.
    predicate: str | None = None
    confidence: float = 1.0


@dataclasses.dataclass(frozen=True)
class RenderedTree:
    """One label-rendered answer: the interconnection among the query
    entities, as served to a client."""

    root: int
    root_label: str
    weight: float
    nodes: tuple[int, ...]
    node_labels: tuple[str, ...]
    edges: tuple[RenderedEdge, ...]

    def describe(self) -> str:
        """One-line human rendering: root, weight, then each edge as
        ``label --w-- label`` (``label --w[predicate]-- label`` on typed
        graphs)."""
        if not self.edges:
            return f"[{self.weight:.3f}] {self.root_label} (single node)"

        def _edge(e: RenderedEdge) -> str:
            tag = f"{e.weight:.2f}"
            if e.predicate is not None:
                tag += f"[{e.predicate}]"
            return f"{e.u_label} --{tag}-- {e.v_label}"

        parts = " ; ".join(_edge(e) for e in self.edges)
        return f"[{self.weight:.3f}] root={self.root_label}: {parts}"


@dataclasses.dataclass(frozen=True)
class TreePage:
    """One page of ranked trees plus the cursor protocol.

    ``cursor`` is the rank offset this page starts at; ``next_cursor`` is
    None on the last page.  ``ranking`` records which order the cursor
    walks ("weight" or "diverse"); ``exhausted`` mirrors the collector's
    flag (True when the table holds fewer distinct trees than requested).
    """

    items: tuple[RenderedTree, ...]
    cursor: int
    next_cursor: int | None
    total: int
    ranking: str
    exhausted: bool


def default_label(v: int) -> str:
    return f"node:{v}"


def render_tree(
    tree: AnswerTree,
    label_fn: Callable[[int], str] | None = None,
    graph: Graph | None = None,
) -> RenderedTree:
    """Label-render one tree.  ``label_fn`` maps node id -> entity string
    (default ``node:<id>``); ``graph`` supplies true per-edge weights
    (omitted -> edge weights rendered as 0) and, when typed, the
    provenance tag (predicate name + confidence) of each effective edge.
    """
    label_fn = label_fn or default_label

    def _render_edge(u: int, v: int) -> RenderedEdge:
        weight = 0.0
        predicate: str | None = None
        confidence = 1.0
        if graph is not None:
            weight = round(_edge_weight(graph, u, v), 6)
            info = graph.edge_channel(u, v)
            if info is not None:
                predicate, confidence = info
        return RenderedEdge(
            u=u, v=v, u_label=label_fn(u), v_label=label_fn(v),
            weight=weight, predicate=predicate, confidence=confidence)

    edges = tuple(_render_edge(u, v) for u, v in tree.edges)
    return RenderedTree(
        root=tree.root,
        root_label=label_fn(tree.root),
        weight=tree.weight,
        nodes=tree.nodes,
        node_labels=tuple(label_fn(n) for n in tree.nodes),
        edges=edges,
    )


def paginate(
    trees: Sequence[AnswerTree],
    order: Sequence[int],
    cursor: int,
    page_size: int,
    ranking: str,
    exhausted: bool,
    label_fn: Callable[[int], str] | None = None,
    graph: Graph | None = None,
) -> TreePage:
    """Cut one :class:`TreePage` out of a ranked permutation.

    ``order`` is a permutation of ``range(len(trees))`` (from
    :func:`repro.answers.diversified_order` or ``range(n)`` for weight
    order); ``cursor`` indexes into that permutation.  Rendering happens
    per page — only the served slice pays the label lookups."""
    total = len(order)
    cursor = max(0, min(int(cursor), total))
    page_size = max(1, int(page_size))
    sel = order[cursor:cursor + page_size]
    items = tuple(render_tree(trees[i], label_fn, graph) for i in sel)
    nxt = cursor + len(sel)
    return TreePage(
        items=items,
        cursor=cursor,
        next_cursor=nxt if nxt < total else None,
        total=total,
        ranking=ranking,
        exhausted=exhausted,
    )
