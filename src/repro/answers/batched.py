"""Device-batched answer-tree backtrace (the paper's ``V_K`` role, on
device, for a whole lane bucket at once).

The host :func:`repro.core.reconstruct.backtrace` recovers one tree by a
recursive first-match search over split decompositions (``val == S[v,a,i]
+ S[v,b,j]``, ``a ⊎ b = ks``) and edge decompositions (``val == S[u,ks,j]
+ w(u,v)``).  Per candidate that is a Python recursion of numpy point
lookups — fine for one query, a serial bottleneck for a bucket.

This module runs the *same* search as one device program over the final
lane-batched table ``S[L, V, 2^m, K]`` (the lane conventions of
:mod:`repro.core.driver`): top-``C`` candidate cells per lane are selected
with ``lax.top_k`` (ties at lower cell index first — exactly the host's
stable value-ascending order), and every candidate walks a bounded
obligation queue top-down (children always land behind the cursor, so
one first-choice resolve per step covers the whole tree):

- **leaf**: ``val <= tol`` at a node covering every singleton keyword;
- **split**: first matching ``(a-pair, i, j)`` in the host's scan order
  (submask pairs descending from ``(ks-1) & ks``, slot prefixes honoring
  the host's early ``break``\\ s);
- **edge**: first matching ``(neighbor, j)`` in CSR neighbor order.

Because every obligation takes the host's *first* choice, a fully
resolved candidate is bit-identical to the host recursion (which only
deviates from first choices by backtracking out of a failed subtree — and
a failed subtree here marks the whole candidate).  Anything the bounded
pass cannot prove — a dead-end obligation, buffer/iteration overflow, a
node with more neighbors than the degree window — is a **ragged
straggler**: the candidate falls back to the host ``backtrace``, so the
final answer set is always bit-for-bit the host's.  The decomposition
records are replayed on the host into the host's exact edge order, then
pruned / cycle-repaired / deduped / ranked by the shared
:func:`repro.core.reconstruct.collect_answers` collector.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import INF
from repro.core.reconstruct import _TOL, AnswerTree, backtrace, collect_answers
from repro.graph.structure import Graph

# Obligation kinds in the device buffer.
_PENDING, _LEAF, _SPLIT, _EDGE, _FAIL = 0, 1, 2, 3, 4
_UNUSED = -1


@functools.lru_cache(maxsize=16)
def split_pair_table(m: int) -> tuple[np.ndarray, np.ndarray]:
    """Per keyword-subset ``ks``: the ordered ``(a, b)`` submask pairs the
    host split scan visits (``a`` descending from ``(ks-1) & ks``, only
    ``a <= b`` kept).  Padded with ``a = 0`` (never a valid submask).
    Shapes ``[2^m, P]`` with ``P >= 1``."""
    n_sets = 1 << m
    pairs: list[list[tuple[int, int]]] = []
    for ks in range(n_sets):
        row = []
        a = (ks - 1) & ks
        while a:
            b = ks ^ a
            if a <= b:
                row.append((a, b))
            a = (a - 1) & ks
        pairs.append(row)
    p_max = max(1, max(len(row) for row in pairs))
    pa = np.zeros((n_sets, p_max), np.int32)
    pb = np.zeros((n_sets, p_max), np.int32)
    for ks, row in enumerate(pairs):
        for i, (a, b) in enumerate(row):
            pa[ks, i], pb[ks, i] = a, b
    return pa, pb


@dataclasses.dataclass
class BatchedBacktrace:
    """Host copy of one device backtrace pass (all lanes, all candidates).

    ``cand_idx[L, C]`` are flat ``(root * K + slot)`` cell indices in the
    device's value-ascending scan order; ``fail[L, C]`` marks ragged
    stragglers (host fallback).  The per-obligation record arrays
    (``node/kind/child0/child1/edge_u``, each ``[L, C, B]``) replay into
    the host backtrace's exact edge order via :meth:`replay_edges`."""

    cand_idx: np.ndarray
    cand_val: np.ndarray
    fail: np.ndarray
    node: np.ndarray
    kind: np.ndarray
    child0: np.ndarray
    child1: np.ndarray
    edge_u: np.ndarray

    @property
    def n_candidates(self) -> int:
        return self.cand_idx.shape[1]

    def replay_edges(self, lane: int, cand: int) -> list[tuple[int, int]] | None:
        """Reconstruct the host-ordered edge list for one resolved
        candidate; None when the device pass flagged it ragged."""
        if self.fail[lane, cand]:
            return None
        kind = self.kind[lane, cand]
        node = self.node[lane, cand]
        child0 = self.child0[lane, cand]
        child1 = self.child1[lane, cand]
        edge_u = self.edge_u[lane, cand]
        out: list[tuple[int, int]] = []
        # Explicit stack replaying the host recursion's emit order: a split
        # emits left edges then right, an edge decomposition emits its
        # subtree first, then itself (post-order).
        stack: list[tuple[int, int]] = [(0, 0)]
        while stack:
            slot, phase = stack.pop()
            kd = int(kind[slot])
            if kd == _LEAF:
                continue
            if kd == _SPLIT:
                stack.append((int(child1[slot]), 0))
                stack.append((int(child0[slot]), 0))
            elif kd == _EDGE:
                if phase == 0:
                    stack.append((slot, 1))
                    stack.append((int(child0[slot]), 0))
                else:
                    v, u = int(node[slot]), int(edge_u[slot])
                    out.append((min(v, u), max(v, u)))
            else:
                # Pending/fail slot on a "resolved" path: treat as ragged.
                return None
        return out


class BatchedBacktracer:
    """Per-graph device backtracer: candidate selection + obligation
    expansion fused into one jitted program per ``(L, C, m, K)`` shape.

    ``degree_cap`` bounds the per-obligation neighbor window (a node with
    more neighbors whose match lies beyond the window falls back to the
    host — correctness never depends on the cap).  ``buffer`` bounds the
    per-candidate obligation count (= tree edges + splits + leaves).
    """

    def __init__(self, graph: Graph, degree_cap: int = 2048,
                 buffer: int = 64) -> None:
        self.graph = graph
        deg_max = int(np.diff(graph.indptr).max()) if graph.n_nodes else 1
        self.degree_cap = max(1, min(degree_cap, max(deg_max, 1)))
        self.buffer = buffer
        # Host CSR, device-resident: indices/ew in the exact neighbor order
        # the host backtrace scans (ascending neighbor id per node).  An
        # edgeless graph keeps one sentinel entry (never selected: every
        # node's degree window is empty) so gathers stay in-bounds.
        indices = np.asarray(graph.indices, np.int32)
        ews = np.asarray(graph.ew, np.float32)
        if indices.size == 0:
            indices, ews = np.zeros(1, np.int32), np.full(1, INF, np.float32)
        self._indptr = jnp.asarray(np.asarray(graph.indptr, np.int32))
        self._esrc = jnp.asarray(indices)
        self._ew = jnp.asarray(ews)
        self._kernels: dict[tuple, Any] = {}
        # Introspection: how much the device pass actually resolved.
        self.device_resolved = 0
        self.host_fallbacks = 0

    def stats(self) -> dict[str, int]:
        """``{device_resolved, host_fallbacks}`` — obligation backtraces
        the device program settled vs ragged stragglers that re-ran the
        host search (both monotone over the tracer's lifetime; the
        metrics registry exports them as counters)."""
        return {"device_resolved": self.device_resolved,
                "host_fallbacks": self.host_fallbacks}

    # -- device kernel --------------------------------------------------

    def _kernel(self, L: int, C: int, m: int, K: int):
        key = (L, C, m, K)
        fn = self._kernels.get(key)
        if fn is not None:
            return fn
        full = (1 << m) - 1
        B = self.buffer
        D = self.degree_cap
        pa_np, pb_np = split_pair_table(m)
        pa = jnp.asarray(pa_np)
        pb = jnp.asarray(pb_np)
        indptr, esrc, ew = self._indptr, self._esrc, self._ew
        tol = jnp.float32(_TOL)
        inf = jnp.float32(INF)

        def resolve(S, kw, v, s, x):
            """First-choice decomposition of one obligation ``(v, s, x)``
            — the host scan orders, vectorized."""
            # Leaf: zero value at a node covering every singleton of s.
            bits = (s >> jnp.arange(m)) & 1
            covered = jnp.all((bits == 0) | kw[jnp.arange(m), v])
            leaf = (x <= tol) & covered
            # Split scan over (a-pair, i, j) in host lexicographic order.
            a = pa[s]
            b = pb[s]
            Sa = S[v, a, :]                               # [P, K]
            Sb = S[v, b, :]
            # cumprod == the host's prefix `break` semantics per slot.
            ia_ok = jnp.cumprod(
                ((Sa <= x + tol) & (Sa < inf)).astype(jnp.int32), axis=1) > 0
            jb_ok = jnp.cumprod((Sb < inf).astype(jnp.int32), axis=1) > 0
            close = jnp.abs(Sa[:, :, None] + Sb[:, None, :] - x) <= tol
            smatch = ((a > 0)[:, None, None] & ia_ok[:, :, None]
                      & jb_ok[:, None, :] & close)
            sflat = smatch.reshape(-1)
            s_found = jnp.any(sflat)
            sidx = jnp.argmax(sflat)
            p_i, i_i, j_i = sidx // (K * K), (sidx // K) % K, sidx % K
            sa, sb = a[p_i], b[p_i]
            sva, svb = Sa[p_i, i_i], Sb[p_i, j_i]
            # Edge scan over (CSR neighbor, j) in host order.
            start = indptr[v]
            deg = indptr[v + 1] - start
            off = jnp.arange(D)
            ei = jnp.clip(start + off, 0, esrc.shape[0] - 1)
            u = esrc[ei]                                  # [D]
            w = ew[ei]
            emask = (off < deg) & (w < inf) & (w <= x + tol)
            Su = S[u, s, :]                               # [D, K]
            ju_ok = jnp.cumprod((Su < inf).astype(jnp.int32), axis=1) > 0
            eclose = jnp.abs(Su - (x - w)[:, None]) <= tol
            ematch = emask[:, None] & ju_ok & eclose
            eflat = ematch.reshape(-1)
            e_found = jnp.any(eflat)
            eidx = jnp.argmax(eflat)
            d_i, ej = eidx // K, eidx % K
            eu, ev = u[d_i], Su[d_i, ej]
            kind = jnp.where(
                leaf, _LEAF,
                jnp.where(s_found, _SPLIT,
                          jnp.where(e_found, _EDGE, _FAIL)))
            # Child obligations: split -> (v,sa,sva),(v,sb,svb);
            # edge -> (eu,s,ev).
            c0 = jnp.where(kind == _SPLIT,
                           jnp.stack([v, sa, 0]),
                           jnp.stack([eu, s, 0])).astype(jnp.int32)
            c0v = jnp.where(kind == _SPLIT, sva, ev)
            c1 = jnp.stack([v, sb, 0]).astype(jnp.int32)
            c1v = svb
            return kind.astype(jnp.int32), c0[0], c0[1], c0v, c1[0], c1[1], c1v, eu

        def one(S, kw, root, val, valid):
            # Obligation queue with a cursor: children are always appended
            # *behind* the cursor (at slots n, n+1 > it), so one resolve
            # per iteration walks the whole tree in BFS order — the loop
            # runs tree-size iterations and each touches O(P·K² + D·K)
            # table cells, instead of re-resolving every buffer slot every
            # round.  Arrays carry a sacrificial B-th slot that absorbs
            # masked / overflowing writes.
            node = jnp.zeros(B + 1, jnp.int32).at[0].set(root)
            ks = jnp.zeros(B + 1, jnp.int32).at[0].set(full)
            vals = jnp.zeros(B + 1, jnp.float32).at[0].set(val)
            kind = jnp.full(B + 1, _UNUSED, jnp.int32).at[0].set(_PENDING)
            child0 = jnp.full(B + 1, _UNUSED, jnp.int32)
            child1 = jnp.full(B + 1, _UNUSED, jnp.int32)
            edge_u = jnp.full(B + 1, _UNUSED, jnp.int32)
            n = jnp.int32(1)
            fail = ~valid
            it = jnp.int32(0)

            def cond(carry):
                node, ks, vals, kind, child0, child1, edge_u, n, fail, it = carry
                return (it < n) & ~fail

            def body(carry):
                node, ks, vals, kind, child0, child1, edge_u, n, fail, it = carry
                kd, c0n, c0s, c0v, c1n, c1s, c1v, eu = resolve(
                    S, kw, node[it], ks[it], vals[it])
                fail = fail | (kd == _FAIL)
                cnt = jnp.where(kd == _SPLIT, 2,
                                jnp.where(kd == _EDGE, 1, 0))
                new_n = n + cnt
                fail = fail | (new_n > B)
                has0 = (kd == _SPLIT) | (kd == _EDGE)
                has1 = kd == _SPLIT
                idx0 = jnp.where(has0, jnp.minimum(n, B), B)
                idx1 = jnp.where(has1, jnp.minimum(n + 1, B), B)
                node = node.at[idx0].set(c0n).at[idx1].set(c1n)
                ks = ks.at[idx0].set(c0s).at[idx1].set(c1s)
                vals = vals.at[idx0].set(c0v).at[idx1].set(c1v)
                kind = (kind.at[idx0].set(_PENDING).at[idx1].set(_PENDING)
                        .at[it].set(kd))
                child0 = child0.at[it].set(jnp.where(has0, idx0, _UNUSED))
                child1 = child1.at[it].set(jnp.where(has1, idx1, _UNUSED))
                edge_u = edge_u.at[it].set(
                    jnp.where(kd == _EDGE, eu, _UNUSED))
                return (node, ks, vals, kind, child0, child1, edge_u,
                        jnp.minimum(new_n, B), fail, it + 1)

            carry = (node, ks, vals, kind, child0, child1, edge_u, n, fail, it)
            carry = jax.lax.while_loop(cond, body, carry)
            node, ks, vals, kind, child0, child1, edge_u, n, fail, it = carry
            return dict(node=node[:B], kind=kind[:B], child0=child0[:B],
                        child1=child1[:B], edge_u=edge_u[:B], fail=fail)

        def kernel(S_lanes, kw_lanes):
            # Candidate selection: value-ascending with ties at lower cell
            # index first (top_k of the negated values), matching the
            # host's stable argsort exactly.
            flat = S_lanes[:, :, full, :].reshape(L, -1)
            neg, idx = jax.lax.top_k(-flat, C)
            vals = -neg
            roots = (idx // K).astype(jnp.int32)
            valid = vals < inf
            per_cand = jax.vmap(one, in_axes=(None, None, 0, 0, 0))
            per_lane = jax.vmap(per_cand, in_axes=(0, 0, 0, 0, 0))
            recs = per_lane(S_lanes, kw_lanes, roots, vals, valid)
            return idx, vals, recs

        fn = jax.jit(kernel)
        self._kernels[key] = fn
        return fn

    # -- host orchestration ---------------------------------------------

    def backtrace_lanes(self, S_lanes, kw_lanes, k: int,
                        candidate_factor: int = 4) -> BatchedBacktrace:
        """One device program: top-``k * candidate_factor`` candidates per
        lane, backtraced.  ``S_lanes``: ``[L, Vp, 2^m, K]`` (device);
        ``kw_lanes``: ``[L, m, Vp]`` bool."""
        L, _vp, n_sets, K = S_lanes.shape
        m = int(n_sets).bit_length() - 1
        C = max(1, min(int(np.prod(S_lanes.shape[1::2])),
                       max(k, 1) * candidate_factor))
        fn = self._kernel(L, C, m, K)
        idx, vals, recs = jax.block_until_ready(
            fn(jnp.asarray(S_lanes), jnp.asarray(kw_lanes)))
        return BatchedBacktrace(
            cand_idx=np.asarray(idx), cand_val=np.asarray(vals),
            fail=np.asarray(recs["fail"]), node=np.asarray(recs["node"]),
            kind=np.asarray(recs["kind"]), child0=np.asarray(recs["child0"]),
            child1=np.asarray(recs["child1"]),
            edge_u=np.asarray(recs["edge_u"]))

    def extract_lanes(
        self,
        S_lanes,
        kw_lanes: np.ndarray,
        k: int,
        candidate_factor: int = 4,
        lanes: list[int] | None = None,
        n_nodes: int | None = None,
    ) -> list[tuple[list[AnswerTree], bool]]:
        """Device-batched :func:`collect_answers` for a whole bucket.

        Returns ``(ranked_answers, exhausted)`` per requested lane —
        bit-identical to the host path: device-resolved candidates replay
        the host's first-choice search, ragged stragglers re-run the host
        ``backtrace``, and collection/pruning/ranking is the shared host
        collector either way.  ``lanes``: which lanes to collect (default
        all — serving passes the real lanes of a padded bucket).
        ``n_nodes``: real node count (kw mask columns beyond it are
        padding)."""
        batch = self.backtrace_lanes(S_lanes, kw_lanes, k, candidate_factor)
        S_host = np.asarray(S_lanes)
        kw_host = np.asarray(kw_lanes)
        V = n_nodes if n_nodes is not None else self.graph.n_nodes
        m = kw_host.shape[1]
        full = (1 << m) - 1
        out: list[tuple[list[AnswerTree], bool]] = []
        for lane in (range(S_host.shape[0]) if lanes is None else lanes):
            S = S_host[lane]
            kw = kw_host[lane][:, :V]

            def from_device(pos: int, root: int, val: float,
                            _lane=lane, _S=S, _kw=kw):
                # Use the device record only when the device's pos-th
                # candidate is the host's pos-th candidate (same cell, same
                # value) — a tie-order sanity check; mismatch or a ragged
                # straggler re-runs the host search.
                if pos < batch.n_candidates:
                    K = _S.shape[2]
                    ci = int(batch.cand_idx[_lane, pos])
                    cv = float(batch.cand_val[_lane, pos])
                    if ci // K == root and abs(cv - val) <= 1e-6:
                        edges = batch.replay_edges(_lane, pos)
                        if edges is not None:
                            self.device_resolved += 1
                            return edges
                self.host_fallbacks += 1
                return backtrace(_S, self.graph, _kw, root, full, val)

            answers, exhausted = collect_answers(
                S, self.graph, kw, k, candidate_factor,
                backtrace_fn=from_device)
            out.append((answers, exhausted))
        return out
