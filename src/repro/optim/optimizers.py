"""Optimizers (no external deps): AdamW with f32 master weights, global-norm
clipping, cosine schedule, and gradient-accumulation support.

Optimizer state shards exactly like the parameters (the param_specs tree is
reused leaf-for-leaf), which is what makes the FSDP memory math work at
104B/132B scale.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params: Any) -> OptState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        mu=jax.tree_util.tree_map(zeros32, params),
        nu=jax.tree_util.tree_map(zeros32, params),
        count=jnp.zeros((), jnp.int32),
    )


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(
    cfg: AdamWConfig, grads: Any, opt: OptState, params: Any,
) -> tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.max_grad_norm)
    count = opt.count + 1
    lr = cosine_schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v = cfg.b2 * v + (1.0 - cfg.b2) * g32 * g32
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, grads, opt.mu, opt.nu, params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(mu=new_mu, nu=new_nu, count=count), metrics
