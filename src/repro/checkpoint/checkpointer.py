"""Sharded checkpointing with async save, retention, and elastic restore.

Layout (no external deps — npz per leaf + JSON manifest):

    <dir>/step_<N>/
        manifest.json       # tree structure, shapes, dtypes, step, mesh
        leaf_<i>.npy        # one array per pytree leaf (host-gathered)
        _COMMITTED          # written last: crash-safe commit marker

Fault-tolerance contract (exercised by tests):
- a save interrupted before ``_COMMITTED`` is ignored by ``latest_step``
  (checkpoint/restart after node failure never sees a torn write);
- ``restore_tree`` re-shards onto WHATEVER mesh the restoring process uses
  (elastic scaling: restore a 256-chip checkpoint on 512 chips or on 1 CPU);
- async mode overlaps serialization with the next training step and joins
  on exit (straggler-safe: a slow disk never blocks the step loop).
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize bfloat16/fp8 natively: store a bit-equal uint view
# and record the logical dtype in the manifest.
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    name = arr.dtype.name
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name])
    return arr


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_AS:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten_with_paths(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_tree(tree: Any, directory: str | Path, step: int) -> Path:
    """Synchronous host-gather save; returns the committed directory."""
    directory = Path(directory)
    out = directory / f"step_{step}"
    tmp = directory / f".tmp_step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten_with_paths(tree)
    meta = {"step": step, "n_leaves": len(leaves),
            "treedef": str(treedef),
            "shapes": [], "dtypes": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i}.npy", _to_savable(arr))
        meta["shapes"].append(list(arr.shape))
        meta["dtypes"].append(arr.dtype.name)
    (tmp / "manifest.json").write_text(json.dumps(meta))
    (tmp / "_COMMITTED").write_text("ok")
    if out.exists():
        shutil.rmtree(out)
    tmp.rename(out)
    return out


def latest_step(directory: str | Path) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for d in directory.iterdir():
        if d.name.startswith("step_") and (d / "_COMMITTED").exists():
            try:
                steps.append(int(d.name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore_tree(template: Any, directory: str | Path, step: int,
                 shardings: Any | None = None) -> Any:
    """Restore into the template's structure; device_put with ``shardings``
    (pytree of NamedSharding) reshards elastically onto the current mesh."""
    src = Path(directory) / f"step_{step}"
    if not (src / "_COMMITTED").exists():
        raise FileNotFoundError(f"no committed checkpoint at {src}")
    meta = json.loads((src / "manifest.json").read_text())
    leaves, treedef = _flatten_with_paths(template)
    if meta["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {meta['n_leaves']} leaves, template has "
            f"{len(leaves)} — architecture mismatch")
    shard_leaves = (jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
    )[0] if shardings is not None else [None] * len(leaves))
    out = []
    for i, (leaf, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(src / f"leaf_{i}.npy")
        arr = _from_savable(arr, meta["dtypes"][i])
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != "
                             f"template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class Checkpointer:
    """Async checkpointer with retention."""

    def __init__(self, directory: str | Path, keep: int = 3,
                 async_save: bool = True):
        self.directory = Path(directory)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _save(self, host_tree, step: int):
        try:
            save_tree(host_tree, self.directory, step)
            self._gc()
        except BaseException as e:  # noqa: BLE001
            self._error = e

    def save(self, tree: Any, step: int):
        self.wait()
        # Device->host copy happens on the caller thread (ordered wrt the
        # step loop); disk IO overlaps with subsequent steps.
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=self._save, args=(host_tree, step), daemon=True)
            self._thread.start()
        else:
            self._save(host_tree, step)
            self.wait()

    def _gc(self):
        steps = sorted(
            int(d.name.split("_")[1]) for d in self.directory.iterdir()
            if d.name.startswith("step_") and (d / "_COMMITTED").exists())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s}", ignore_errors=True)

    def latest(self) -> int | None:
        return latest_step(self.directory)

    def restore(self, template: Any, step: int | None = None,
                shardings: Any | None = None) -> tuple[Any, int]:
        self.wait()
        if step is None:
            step = self.latest()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return restore_tree(template, self.directory, step, shardings), step
