"""Synthetic graph generators.

The paper benchmarks on two Linked-Open-Data RDF graphs (sec-rdfabout:
460k nodes / 500k edges; bluk-bnb: 16.1M nodes / 46.6M edges).  Those dumps
are not redistributable here, so we generate structurally-similar synthetic
stand-ins: power-law (RMAT-style) entity graphs with Zipf-distributed text
labels, which reproduce the paper's regime of keyword-node counts spanning
~10 .. ~500k per query (paper Fig. 9).  Deterministic via explicit seeds.
"""

from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph, build_graph


def rmat_edges(
    n_nodes: int,
    n_edges: int,
    seed: int = 0,
    a: float = 0.57, b: float = 0.19, c: float = 0.19,
    max_resample_rounds: int = 16,
) -> tuple[np.ndarray, np.ndarray]:
    """R-MAT power-law edge generator (Chakrabarti et al., SDM'04).

    Self-loops are rejected and their slots *resampled* (each of up to
    ``max_resample_rounds`` rounds draws 2x the remaining deficit, so the
    deficit shrinks super-geometrically even at high per-draw self-loop
    probability), so the result carries exactly ``n_edges`` edges instead
    of silently undershooting the requested size the way a filter-only
    implementation does.  Deterministic for a given seed (the resample
    draws continue the same rng stream).  Only pathological configs
    (``n_nodes == 1``, where every edge is a self-loop) come up short
    after the bounded retries — callers that care should check the length
    (ingestion stats report requested vs produced).
    """
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(n_nodes, 2))))

    def draw(n: int) -> tuple[np.ndarray, np.ndarray]:
        src = np.zeros(n, np.int64)
        dst = np.zeros(n, np.int64)
        for _level in range(scale):
            r = rng.random(n)
            # Quadrant probabilities a, b, c, d.
            go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
            go_down = r >= a + b
            src = src * 2 + go_down.astype(np.int64)
            dst = dst * 2 + go_right.astype(np.int64)
        src %= n_nodes
        dst %= n_nodes
        keep = src != dst
        return src[keep], dst[keep]

    src, dst = draw(n_edges)
    for _round in range(max_resample_rounds):
        deficit = n_edges - len(src)
        if deficit == 0:
            break
        s2, d2 = draw(max(2 * deficit, 64))
        src = np.concatenate([src, s2[:deficit]])
        dst = np.concatenate([dst, d2[:deficit]])
    return src.astype(np.int32), dst.astype(np.int32)


def lod_like_graph(
    n_nodes: int,
    n_edges: int,
    seed: int = 0,
    vocab: int = 1000,
    labels_per_node: int = 2,
    tau: int = 1001,
) -> tuple[Graph, np.ndarray]:
    """Power-law graph + Zipf token labels. Returns (graph, tokens[V, L])."""
    src, dst = rmat_edges(n_nodes, n_edges, seed=seed)
    g = build_graph(src, dst, n_nodes, tau=tau)
    rng = np.random.default_rng(seed + 1)
    # Zipf-ish token assignment: token frequency ~ 1/rank.
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)
    tokens = rng.choice(vocab, size=(n_nodes, labels_per_node), p=probs)
    return g, tokens.astype(np.int32)


def grid_graph(rows: int, cols: int, w: float = 1.0) -> Graph:
    """Unit-weight 2D grid (deterministic structure for exactness tests)."""
    def nid(r, c):
        return r * cols + c

    src, dst = [], []
    for r in range(rows):
        for c in range(cols):
            if c + 1 < cols:
                src.append(nid(r, c)); dst.append(nid(r, c + 1))
            if r + 1 < rows:
                src.append(nid(r, c)); dst.append(nid(r + 1, c))
    n = rows * cols
    return build_graph(src, dst, n, w=np.full(len(src), w, np.float32))


def random_weighted_graph(
    n_nodes: int, n_edges: int, seed: int = 0, max_w: int = 5
) -> Graph:
    """Random connected-ish multigraph with small integer weights (tests)."""
    rng = np.random.default_rng(seed)
    # A random spanning chain guarantees connectivity.
    perm = rng.permutation(n_nodes)
    chain_src = perm[:-1]
    chain_dst = perm[1:]
    extra = max(0, n_edges - (n_nodes - 1))
    es = rng.integers(0, n_nodes, extra)
    ed = rng.integers(0, n_nodes, extra)
    keep = es != ed
    src = np.concatenate([chain_src, es[keep]]).astype(np.int32)
    dst = np.concatenate([chain_dst, ed[keep]]).astype(np.int32)
    w = rng.integers(1, max_w + 1, len(src)).astype(np.float32)
    return build_graph(src, dst, n_nodes, w=w)
