"""Fanout neighbor sampler (GraphSAGE-style) for the ``minibatch_lg`` shape.

Produces fixed-shape padded subgraphs (seed nodes + per-hop sampled
neighbors) suitable for jit: node ids int32[N_sub], edge list int32[E_sub],
valid masks.  Sampling runs on host (numpy) inside the data pipeline; the
returned arrays are what ``train_step`` consumes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structure import Graph


@dataclasses.dataclass
class SampledSubgraph:
    node_ids: np.ndarray    # int32[N_sub] global ids (0-padded)
    node_valid: np.ndarray  # bool[N_sub]
    edge_src: np.ndarray    # int32[E_sub] local indices into node_ids
    edge_dst: np.ndarray    # int32[E_sub]
    edge_valid: np.ndarray  # bool[E_sub]
    seed_count: int         # first seed_count nodes are the batch seeds

    @property
    def n_sub(self) -> int:
        return len(self.node_ids)


def plan_sizes(batch_nodes: int, fanout: list[int]) -> tuple[int, int]:
    """Padded (n_nodes, n_edges) of a fanout sample."""
    n = batch_nodes
    total_nodes = batch_nodes
    total_edges = 0
    for f in fanout:
        total_edges += n * f
        n = n * f
        total_nodes += n
    return total_nodes, total_edges


def sample_subgraph(
    g: Graph,
    seeds: np.ndarray,
    fanout: list[int],
    seed: int = 0,
) -> SampledSubgraph:
    """Uniform fanout sampling with replacement; fixed output shapes."""
    rng = np.random.default_rng(seed)
    n_pad, e_pad = plan_sizes(len(seeds), fanout)

    node_ids = np.zeros(n_pad, np.int32)
    node_valid = np.zeros(n_pad, bool)
    edge_src = np.zeros(e_pad, np.int32)
    edge_dst = np.zeros(e_pad, np.int32)
    edge_valid = np.zeros(e_pad, bool)

    node_ids[: len(seeds)] = seeds
    node_valid[: len(seeds)] = True
    frontier_lo, frontier_hi = 0, len(seeds)
    n_cursor, e_cursor = len(seeds), 0

    deg = np.diff(g.indptr)
    for f in fanout:
        width = frontier_hi - frontier_lo
        for i in range(frontier_lo, frontier_hi):
            v = int(node_ids[i])
            valid_v = bool(node_valid[i])
            d = int(deg[v]) if valid_v else 0
            for j in range(f):
                slot_n = n_cursor + (i - frontier_lo) * f + j
                slot_e = e_cursor + (i - frontier_lo) * f + j
                if d > 0:
                    pick = g.indices[g.indptr[v] + rng.integers(0, d)]
                    node_ids[slot_n] = pick
                    node_valid[slot_n] = True
                    edge_src[slot_e] = slot_n
                    edge_dst[slot_e] = i
                    edge_valid[slot_e] = True
        n_cursor += width * f
        e_cursor += width * f
        frontier_lo, frontier_hi = n_cursor - width * f, n_cursor
    return SampledSubgraph(
        node_ids=node_ids, node_valid=node_valid,
        edge_src=edge_src, edge_dst=edge_dst, edge_valid=edge_valid,
        seed_count=len(seeds),
    )
