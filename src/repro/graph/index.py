"""Inverted index: token -> keyword-node ids (paper Sec. 4 pre-processing).

DKS starts from the keyword-nodes of every query keyword; this is the
index that produces them.  Works on integer token ids (synthetic graphs)
or on whitespace-tokenized string labels.
"""

from __future__ import annotations

import numpy as np


def mid_df_tokens(index: "InvertedIndex", lo: int = 2,
                  hi: int = 200) -> list:
    """df-sorted vocabulary slice with ``lo <= df <= hi`` — the pool the
    CLIs auto-pick query keywords from (paper Sec. 7.1 samples across the
    df spectrum).  Falls back to the full df-sorted vocabulary when the
    band is empty, so tiny test graphs still yield queries.  Uses the
    bulk :meth:`InvertedIndex.token_dfs` enumeration (one pass; on a
    lazy artifact index, no per-token binary searches)."""
    pairs = sorted(index.token_dfs(), key=lambda p: p[1])
    mid = [t for t, d in pairs if lo <= d <= hi]
    return mid or [t for t, _ in pairs]


class InvertedIndex:
    def __init__(self) -> None:
        self._post: dict[object, list[int]] = {}
        self._frozen: dict[object, np.ndarray] = {}

    @classmethod
    def from_token_matrix(cls, tokens: np.ndarray) -> "InvertedIndex":
        """tokens: int[V, L] token ids per node."""
        idx = cls()
        v, l = tokens.shape
        flat = tokens.reshape(-1)
        nodes = np.repeat(np.arange(v, dtype=np.int64), l)
        order = np.argsort(flat, kind="stable")
        flat, nodes = flat[order], nodes[order]
        bounds = np.flatnonzero(np.diff(flat)) + 1
        starts = np.concatenate([[0], bounds])
        ends = np.concatenate([bounds, [len(flat)]])
        for s, e in zip(starts, ends):
            idx._frozen[int(flat[s])] = np.unique(nodes[s:e]).astype(np.int32)
        return idx

    @classmethod
    def from_labels(cls, labels: list[str]) -> "InvertedIndex":
        idx = cls()
        for node, text in enumerate(labels):
            for tok in text.lower().split():
                idx._post.setdefault(tok, []).append(node)
        for tok, nodes in idx._post.items():
            idx._frozen[tok] = np.unique(np.asarray(nodes, np.int32))
        idx._post.clear()
        return idx

    def lookup(self, token) -> np.ndarray:
        return self._frozen.get(token, np.zeros(0, np.int32))

    def missing_tokens(self, query: list) -> list:
        """Tokens of ``query`` that match no node (the single definition of
        "unmatched" — keyword_masks and the engine both use it)."""
        return [tok for tok in query if len(self.lookup(tok)) == 0]

    def keyword_masks(
        self, query: list, n_nodes: int, v_pad: int | None = None,
        on_missing: str = "raise",
    ) -> np.ndarray:
        """bool[m, v_pad or n_nodes] — keyword-node masks for a query.

        ``v_pad``: pad the node axis out to the device graph's padded node
        count, so the masks feed the DKS executors directly (keyword nodes
        only ever land in the first ``n_nodes`` columns).

        ``on_missing``: a token absent from the index produces an all-False
        row, which makes the query burn its full superstep budget and
        return INF with no diagnosis — so ``"raise"`` (the default) raises
        :class:`KeyError` naming the missing tokens up front.  Pass
        ``"ignore"`` for best-effort masks (callers should then surface the
        missing tokens themselves, e.g. ``QueryResult.unmatched``).
        """
        if on_missing not in ("raise", "ignore"):
            raise ValueError(f"unknown on_missing={on_missing!r}")
        width = n_nodes if v_pad is None else v_pad
        if width < n_nodes:
            raise ValueError(f"v_pad={v_pad} smaller than n_nodes={n_nodes}")
        if on_missing == "raise":
            missing = self.missing_tokens(query)
            if missing:
                raise KeyError(
                    f"query keywords match no node in the index: {missing!r} "
                    "(pass on_missing='ignore' for best-effort masks)")
        masks = np.zeros((len(query), width), bool)
        for i, tok in enumerate(query):
            masks[i, self.lookup(tok)] = True
        return masks

    def vocabulary(self) -> list:
        return list(self._frozen)

    def df(self, token) -> int:
        return len(self.lookup(token))

    def token_dfs(self) -> list[tuple]:
        """All ``(token, df)`` pairs in one pass — the bulk form callers
        enumerating the vocabulary should use instead of a per-token
        ``df()`` loop (the artifact-backed lazy index overrides this to
        read posting lengths straight off the offsets table, where a
        per-token ``df()`` would be a binary search each)."""
        return [(tok, len(post)) for tok, post in self._frozen.items()]

    # ------------------------------------------------------------------
    # Persistence (repro.store artifact hooks)
    # ------------------------------------------------------------------

    def to_postings(self) -> tuple[list, np.ndarray, np.ndarray]:
        """Frozen postings as flat arrays: ``(tokens, offsets, nodes)``.

        ``tokens`` is the vocabulary in deterministic (sorted) order;
        token ``i``'s posting list is ``nodes[offsets[i]:offsets[i+1]]``
        (int32 node ids, sorted unique).  This is the layout
        :mod:`repro.store` persists — and the one :meth:`from_postings`
        rebuilds from without re-tokenizing anything.  The *sorted* token
        order is load-bearing: the artifact reader
        (``repro.store.LazyArtifactIndex``) resolves tokens by binary
        search over the persisted table, so artifact open stays O(1) in
        vocabulary size.
        """
        tokens = sorted(self._frozen)
        offsets = np.zeros(len(tokens) + 1, np.int64)
        for i, tok in enumerate(tokens):
            offsets[i + 1] = offsets[i] + len(self._frozen[tok])
        nodes = (np.concatenate([self._frozen[t] for t in tokens])
                 if tokens else np.zeros(0, np.int32))
        return tokens, offsets, nodes.astype(np.int32, copy=False)

    @classmethod
    def from_postings(cls, tokens: list, offsets: np.ndarray,
                      nodes: np.ndarray) -> "InvertedIndex":
        """Rebuild an index from :meth:`to_postings` arrays.

        Posting lists are *views* into ``nodes`` — with a memory-mapped
        ``nodes`` the postings stay on disk until a token is looked up
        (zero-copy open; see :mod:`repro.store.artifact`).
        """
        if len(offsets) != len(tokens) + 1:
            raise ValueError(
                f"offsets length {len(offsets)} != n_tokens+1 "
                f"({len(tokens) + 1})")
        idx = cls()
        for i, tok in enumerate(tokens):
            idx._frozen[tok] = nodes[offsets[i]:offsets[i + 1]]
        return idx
