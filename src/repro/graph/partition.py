"""Node partitioning for the mesh (the Pregel worker hash map).

The device engine consumes globally-indexed arrays sharded by the mesh, so
partitioning is a *relabeling*: nodes are permuted so that contiguous
blocks of size V/P land on each shard, edges are regrouped by destination
shard (messages to a shard are then a contiguous segment — the layout both
XLA SPMD and the Pallas scatter kernel want).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.structure import Graph, build_graph


@dataclasses.dataclass
class Partition:
    n_shards: int
    perm: np.ndarray       # new id -> old id
    inv_perm: np.ndarray   # old id -> new id
    shard_of: np.ndarray   # new id -> shard

    def relabel(self, node_ids: np.ndarray) -> np.ndarray:
        return self.inv_perm[node_ids]


def hash_partition(n_nodes: int, n_shards: int, seed: int = 0) -> Partition:
    """Pregel-style hash partition: random permutation, contiguous blocks."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_nodes)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(n_nodes)
    block = -(-n_nodes // n_shards)
    shard_of = np.arange(n_nodes) // block
    return Partition(n_shards=n_shards, perm=perm, inv_perm=inv,
                     shard_of=shard_of.astype(np.int32))


def edge_cut(g: Graph, part: Partition) -> float:
    """Fraction of symmetric edges crossing shards (drives the collective
    term of the DKS roofline)."""
    deg = np.diff(g.indptr)
    src = np.repeat(np.arange(g.n_nodes), deg)
    dst = g.indices
    s_src = part.shard_of[part.inv_perm[src]]
    s_dst = part.shard_of[part.inv_perm[dst]]
    if len(src) == 0:
        return 0.0
    return float(np.mean(s_src != s_dst))


def apply_partition(g: Graph, part: Partition) -> Graph:
    """Relabel a host graph so device sharding = partition blocks."""
    new_src = part.inv_perm[g.src]
    new_dst = part.inv_perm[g.dst]
    labels = None
    if g.labels is not None:
        labels = [g.labels[part.perm[i]] for i in range(g.n_nodes)]
    return build_graph(new_src, new_dst, g.n_nodes, w=g.w, labels=labels)
