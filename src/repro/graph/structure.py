"""Graph storage.

Two views of a graph:

- :class:`Graph` — host-side container (numpy): CSR for the neighbor sampler
  and the inverted index, node text labels, raw directed edges.
- :class:`DeviceGraph` — device pytree (jnp): symmetrized, padded edge list
  sorted by destination, exactly what the DKS relaxation and the GNN message
  passing consume.  Edges sorted by ``dst`` double as the layout the Pallas
  ``segment_minplus`` kernel requires.

Edge weights follow the paper (Sec. 7.1): ``w(e) = int(log10(d_in(dst)))``
clipped to >= 1 below a degree threshold tau, and "infinite" (the INF
sentinel) above it — high-degree hub nodes are effectively disconnected,
which is what keeps relationship queries meaningful on LOD data.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import INF


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """Symmetrized padded edge-list graph living on device.

    Attributes:
      src, dst: int32[E_pad] endpoints (padded entries point at node 0).
      w:        float32[E_pad] edge lengths (INF on padded entries).
      valid:    bool[E_pad] real-edge mask.
      out_degree: int32[V_pad] symmetric degree (0 on padded nodes).
      node_valid: bool[V_pad].
      n_nodes / n_edges: static true counts (pre-padding).
    """

    src: jax.Array
    dst: jax.Array
    w: jax.Array
    valid: jax.Array
    out_degree: jax.Array
    node_valid: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def v_pad(self) -> int:
        return self.out_degree.shape[0]

    @property
    def e_pad(self) -> int:
        return self.src.shape[0]

    def e_min(self) -> jax.Array:
        """Smallest real edge length (the paper's ``e_min``)."""
        return jnp.min(jnp.where(self.valid, self.w, INF))


@dataclasses.dataclass
class Graph:
    """Host-side graph: directed raw edges + CSR over the symmetrized graph."""

    n_nodes: int
    # Raw directed edges.
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray
    # Symmetrized CSR (host): indptr[V+1], indices[E_sym], ew[E_sym].
    indptr: np.ndarray
    indices: np.ndarray
    ew: np.ndarray
    labels: list[str] | None = None
    # Optional dst-sorted symmetric edge list (src, dst, w) — the exact
    # device layout.  Set by the repro.store artifact loader (mmap views;
    # to_device then skips the argsort); None on in-memory graphs, where
    # retaining a second edge-list copy would cost real host memory.
    sym_sorted: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None

    @property
    def n_edges_directed(self) -> int:
        return len(self.src)

    @property
    def n_edges_sym(self) -> int:
        return len(self.indices)

    def neighbors(self, v: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[v], self.indptr[v + 1]
        return self.indices[s:e], self.ew[s:e]

    def sym_sorted_edges(
        self, cache: bool = False,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Dst-sorted symmetric edge list ``(src, dst, w)`` — the device
        layout (and the layout :mod:`repro.store` persists).

        ``cache=True`` retains the triple on ``sym_sorted`` — three extra
        E_sym-length host arrays, so only the artifact writer (which is
        about to persist them anyway) opts in; ``to_device`` computes
        transiently unless the loader already populated ``sym_sorted``
        with mmap views (then it is reused for free)."""
        if self.sym_sorted is not None:
            return self.sym_sorted
        deg = np.diff(self.indptr)
        src = np.repeat(np.arange(self.n_nodes, dtype=np.int32), deg)
        dst = self.indices.astype(np.int32)
        w = self.ew.astype(np.float32)
        order = np.argsort(dst, kind="stable")
        triple = (src[order], dst[order], w[order])
        if cache:
            self.sym_sorted = triple
        return triple

    def to_device(
        self,
        pad_nodes_to: int | None = None,
        pad_edges_to: int | None = None,
    ) -> DeviceGraph:
        """Build the padded, dst-sorted device edge list."""
        v = self.n_nodes
        deg = np.diff(self.indptr)
        src, dst, w = self.sym_sorted_edges()
        src = src.astype(np.int32, copy=False)
        dst = dst.astype(np.int32, copy=False)
        w = w.astype(np.float32, copy=False)

        e = len(src)
        v_pad = pad_nodes_to or v
        e_pad = pad_edges_to or e
        if v_pad < v or e_pad < e:
            raise ValueError("padding smaller than graph")
        pad_e = e_pad - e
        src = np.concatenate([src, np.zeros(pad_e, np.int32)])
        dst = np.concatenate([dst, np.zeros(pad_e, np.int32)])
        w = np.concatenate([w, np.full(pad_e, INF, np.float32)])
        valid = np.concatenate([np.ones(e, bool), np.zeros(pad_e, bool)])
        out_degree = np.zeros(v_pad, np.int32)
        out_degree[:v] = deg
        node_valid = np.zeros(v_pad, bool)
        node_valid[:v] = True
        return DeviceGraph(
            src=jnp.asarray(src), dst=jnp.asarray(dst), w=jnp.asarray(w),
            valid=jnp.asarray(valid), out_degree=jnp.asarray(out_degree),
            node_valid=jnp.asarray(node_valid),
            n_nodes=v, n_edges=e,
        )


def degree_weights(
    dst: np.ndarray, n_nodes: int, tau: int = 1001
) -> np.ndarray:
    """Paper Sec. 7.1 edge-length model: step function of target in-degree.

    ``w = max(1, int(log10 d_in(dst)))`` for ``d_in < tau``; INF otherwise.
    (The paper uses ``int(log10 d)`` which is 0 for d < 10; positive weights
    are required by Theorem 1, so we clip at 1 — same step structure.)
    """
    d_in = np.bincount(dst, minlength=n_nodes)
    wd = np.maximum(1, np.log10(np.maximum(d_in, 1)).astype(np.int64))
    wd = np.where(d_in >= tau, np.int64(INF), wd)
    return wd[dst].astype(np.float32)


def build_graph(
    src: Sequence[int] | np.ndarray,
    dst: Sequence[int] | np.ndarray,
    n_nodes: int,
    w: np.ndarray | None = None,
    labels: list[str] | None = None,
    tau: int = 1001,
) -> Graph:
    """Build a host Graph from directed edges; symmetrize; CSR-index.

    If ``w`` is None, weights follow the paper's degree model. Reverse edges
    get the same weight as the forward edge (paper Sec. 4: "we also include
    the reverse edges with the same edge-weight").
    """
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    if w is None:
        w = degree_weights(dst, n_nodes, tau=tau)
    w = np.asarray(w, np.float32)
    if len(src) and (w <= 0).any():
        raise ValueError("edge weights must be positive (paper requires w>0)")

    # Symmetrize: forward + reverse with equal weight; drop exact duplicates
    # keeping the minimum weight per (u, v).
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    ww = np.concatenate([w, w])
    # Remove self loops (contribute nothing to trees).
    keep = u != v
    u, v, ww = u[keep], v[keep], ww[keep]
    if len(u):
        key = u.astype(np.int64) * n_nodes + v.astype(np.int64)
        order = np.lexsort((ww, key))
        key, u, v, ww = key[order], u[order], v[order], ww[order]
        first = np.ones(len(key), bool)
        first[1:] = key[1:] != key[:-1]
        u, v, ww = u[first], v[first], ww[first]

    order = np.argsort(u, kind="stable")
    u, v, ww = u[order], v[order], ww[order]
    counts = np.bincount(u, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return Graph(
        n_nodes=n_nodes, src=src, dst=dst, w=w,
        indptr=indptr, indices=v.astype(np.int32), ew=ww.astype(np.float32),
        labels=labels,
    )
