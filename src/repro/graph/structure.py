"""Graph storage.

Two views of a graph:

- :class:`Graph` — host-side container (numpy): CSR for the neighbor sampler
  and the inverted index, node text labels, raw directed edges.
- :class:`DeviceGraph` — device pytree (jnp): symmetrized, padded edge list
  sorted by destination, exactly what the DKS relaxation and the GNN message
  passing consume.  Edges sorted by ``dst`` double as the layout the Pallas
  ``segment_minplus`` kernel requires.

Edge weights follow the paper (Sec. 7.1): ``w(e) = int(log10(d_in(dst)))``
clipped to >= 1 below a degree threshold tau, and "infinite" (the INF
sentinel) above it — high-degree hub nodes are effectively disconnected,
which is what keeps relationship queries meaningful on LOD data.

Both views optionally carry a *typed channel*: per-edge ``(pred, conf)``
where ``pred`` is an id into ``pred_names`` and ``conf`` a positive
provenance score.  The channel never enters the semiring directly — a
:class:`repro.graph.weights.WeightPolicy` folds it into the effective
weight vector before device packing, so the relaxation kernels stay
single-weight.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import INF

# Floor for effective edge weights.  Theorem 1 needs w > 0; confidence
# scaling (``w / conf**blend``) can push a weight arbitrarily close to 0,
# and float32 provenance scores can even round it *to* 0 — instead of
# raising mid-ingest, weights in [0, MIN_EDGE_WEIGHT) clamp up to this
# floor (negative weights still raise: they are caller bugs, not rounding).
MIN_EDGE_WEIGHT = 1e-3


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceGraph:
    """Symmetrized padded edge-list graph living on device.

    Attributes:
      src, dst: int32[E_pad] endpoints (padded entries point at node 0).
      w:        float32[E_pad] edge lengths (INF on padded entries).
      valid:    bool[E_pad] real-edge mask.
      out_degree: int32[V_pad] symmetric degree (0 on padded nodes).
      node_valid: bool[V_pad].
      n_nodes / n_edges: static true counts (pre-padding).
      pred / conf: optional typed channel, int32[E_pad] predicate ids
        (-1 on padded entries) and float32[E_pad] confidences (1.0 on
        padded entries); None on untyped graphs.  ``w`` is always the
        *effective* weight the relaxation consumes — the channel rides
        along for provenance-aware consumers, not for the kernels.
    """

    src: jax.Array
    dst: jax.Array
    w: jax.Array
    valid: jax.Array
    out_degree: jax.Array
    node_valid: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))
    pred: jax.Array | None = None
    conf: jax.Array | None = None

    @property
    def v_pad(self) -> int:
        return self.out_degree.shape[0]

    @property
    def e_pad(self) -> int:
        return self.src.shape[0]

    def e_min(self) -> jax.Array:
        """Smallest real edge length (the paper's ``e_min``)."""
        return jnp.min(jnp.where(self.valid, self.w, INF))


@dataclasses.dataclass
class Graph:
    """Host-side graph: directed raw edges + CSR over the symmetrized graph."""

    n_nodes: int
    # Raw directed edges.
    src: np.ndarray
    dst: np.ndarray
    w: np.ndarray
    # Symmetrized CSR (host): indptr[V+1], indices[E_sym], ew[E_sym].
    indptr: np.ndarray
    indices: np.ndarray
    ew: np.ndarray
    labels: list[str] | None = None
    # Optional dst-sorted symmetric edge list (src, dst, w) — the exact
    # device layout.  Set by the repro.store artifact loader (mmap views;
    # to_device then skips the argsort); None on in-memory graphs, where
    # retaining a second edge-list copy would cost real host memory.
    sym_sorted: tuple[np.ndarray, np.ndarray, np.ndarray] | None = None
    # Optional typed channel.  pred/conf align with the raw directed
    # edges (src/dst/w); csr_pred/csr_conf align with indices/ew;
    # sym_typed = (pred, conf) aligns with sym_sorted.  A graph is
    # "typed" iff csr_pred is not None (the CSR channel is what answer
    # reconstruction and weight policies consume).
    pred: np.ndarray | None = None
    conf: np.ndarray | None = None
    csr_pred: np.ndarray | None = None
    csr_conf: np.ndarray | None = None
    sym_typed: tuple[np.ndarray, np.ndarray] | None = None
    pred_names: list[str] | None = None

    @property
    def n_edges_directed(self) -> int:
        return len(self.src)

    @property
    def n_edges_sym(self) -> int:
        return len(self.indices)

    @property
    def typed(self) -> bool:
        return self.csr_pred is not None

    def neighbors(self, v: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[v], self.indptr[v + 1]
        return self.indices[s:e], self.ew[s:e]

    def edge_channel(self, u: int, v: int) -> tuple[str | None, float] | None:
        """``(predicate_name, confidence)`` of the *cheapest* parallel
        edge between ``u`` and ``v`` — the entry ``_edge_weight`` (and so
        backtrace / rendering) resolves to.  None on untyped graphs or
        when no such edge exists."""
        if self.csr_pred is None:
            return None
        s, e = self.indptr[u], self.indptr[u + 1]
        hits = np.nonzero(self.indices[s:e] == v)[0]
        if not len(hits):
            return None
        j = int(hits[int(np.argmin(self.ew[s:e][hits]))])
        pid = int(self.csr_pred[s:e][j])
        name = None
        if self.pred_names is not None and 0 <= pid < len(self.pred_names):
            name = self.pred_names[pid]
        return name, float(self.csr_conf[s:e][j])

    def sym_sorted_edges(
        self, cache: bool = False,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Dst-sorted symmetric edge list ``(src, dst, w)`` — the device
        layout (and the layout :mod:`repro.store` persists).

        ``cache=True`` retains the triple on ``sym_sorted`` — three extra
        E_sym-length host arrays, so only the artifact writer (which is
        about to persist them anyway) opts in; ``to_device`` computes
        transiently unless the loader already populated ``sym_sorted``
        with mmap views (then it is reused for free)."""
        if self.sym_sorted is not None:
            return self.sym_sorted
        deg = np.diff(self.indptr)
        src = np.repeat(np.arange(self.n_nodes, dtype=np.int32), deg)
        dst = self.indices.astype(np.int32)
        w = self.ew.astype(np.float32)
        order = np.argsort(dst, kind="stable")
        triple = (src[order], dst[order], w[order])
        if cache:
            self.sym_sorted = triple
        return triple

    def sym_typed_edges(
        self, cache: bool = False,
    ) -> tuple[np.ndarray, np.ndarray] | None:
        """Typed channel aligned with :meth:`sym_sorted_edges` — the same
        stable dst-argsort of the CSR arrays, so ``sym_pred[i]`` describes
        the edge ``(sym_src[i], sym_dst[i])``.  None on untyped graphs."""
        if self.csr_pred is None:
            return None
        if self.sym_typed is not None:
            return self.sym_typed
        order = np.argsort(self.indices.astype(np.int32), kind="stable")
        typed = (self.csr_pred[order].astype(np.int32, copy=False),
                 self.csr_conf[order].astype(np.float32, copy=False))
        if cache:
            self.sym_typed = typed
        return typed

    def to_device(
        self,
        pad_nodes_to: int | None = None,
        pad_edges_to: int | None = None,
    ) -> DeviceGraph:
        """Build the padded, dst-sorted device edge list."""
        v = self.n_nodes
        deg = np.diff(self.indptr)
        src, dst, w = self.sym_sorted_edges()
        src = src.astype(np.int32, copy=False)
        dst = dst.astype(np.int32, copy=False)
        w = w.astype(np.float32, copy=False)

        e = len(src)
        v_pad = pad_nodes_to or v
        e_pad = pad_edges_to or e
        if v_pad < v or e_pad < e:
            raise ValueError("padding smaller than graph")
        pad_e = e_pad - e
        src = np.concatenate([src, np.zeros(pad_e, np.int32)])
        dst = np.concatenate([dst, np.zeros(pad_e, np.int32)])
        w = np.concatenate([w, np.full(pad_e, INF, np.float32)])
        valid = np.concatenate([np.ones(e, bool), np.zeros(pad_e, bool)])
        out_degree = np.zeros(v_pad, np.int32)
        out_degree[:v] = deg
        node_valid = np.zeros(v_pad, bool)
        node_valid[:v] = True
        pred = conf = None
        typed = self.sym_typed_edges()
        if typed is not None:
            pred = jnp.asarray(np.concatenate(
                [typed[0], np.full(pad_e, -1, np.int32)]))
            conf = jnp.asarray(np.concatenate(
                [typed[1], np.ones(pad_e, np.float32)]))
        return DeviceGraph(
            src=jnp.asarray(src), dst=jnp.asarray(dst), w=jnp.asarray(w),
            valid=jnp.asarray(valid), out_degree=jnp.asarray(out_degree),
            node_valid=jnp.asarray(node_valid),
            n_nodes=v, n_edges=e, pred=pred, conf=conf,
        )


def degree_weights(
    dst: np.ndarray, n_nodes: int, tau: int = 1001
) -> np.ndarray:
    """Paper Sec. 7.1 edge-length model: step function of target in-degree.

    ``w = max(1, int(log10 d_in(dst)))`` for ``d_in < tau``; INF otherwise.
    (The paper uses ``int(log10 d)`` which is 0 for d < 10; positive weights
    are required by Theorem 1, so we clip at 1 — same step structure.)
    """
    d_in = np.bincount(dst, minlength=n_nodes)
    wd = np.maximum(1, np.log10(np.maximum(d_in, 1)).astype(np.int64))
    wd = np.where(d_in >= tau, np.int64(INF), wd)
    return wd[dst].astype(np.float32)


def build_graph(
    src: Sequence[int] | np.ndarray,
    dst: Sequence[int] | np.ndarray,
    n_nodes: int,
    w: np.ndarray | None = None,
    labels: list[str] | None = None,
    tau: int = 1001,
    pred: np.ndarray | None = None,
    conf: np.ndarray | None = None,
    pred_names: list[str] | None = None,
) -> Graph:
    """Build a host Graph from directed edges; symmetrize; CSR-index.

    If ``w`` is None, weights follow the paper's degree model. Reverse edges
    get the same weight as the forward edge (paper Sec. 4: "we also include
    the reverse edges with the same edge-weight").

    ``pred``/``conf`` attach the typed channel (per directed edge:
    predicate id into ``pred_names``, positive confidence).  Dedup is then
    *type-aware*: parallel edges with distinct predicates survive as
    parallel CSR entries (the untyped dedup keeps only the min weight per
    ``(u, v)``, which would silently collapse them); per ``(u, v, pred)``
    the min-weight (then max-confidence) entry wins.

    Weights in ``[0, MIN_EDGE_WEIGHT)`` clamp up to the floor rather than
    raising — confidence-scaled weights legitimately round to 0 in
    float32; negative weights are still an error.
    """
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    if w is None:
        w = degree_weights(dst, n_nodes, tau=tau)
    w = np.asarray(w, np.float32)
    if len(src) and (w < 0).any():
        raise ValueError("edge weights must be non-negative (paper requires w>0)")
    w = np.where(w < MIN_EDGE_WEIGHT, np.float32(MIN_EDGE_WEIGHT), w)
    if conf is not None and pred is None:
        raise ValueError("conf requires pred (readers synthesize a "
                         "predicate id when only confidences exist)")
    typed = pred is not None
    if typed:
        pred = np.asarray(pred, np.int32)
        conf = (np.ones(len(src), np.float32) if conf is None
                else np.asarray(conf, np.float32))
        if len(src) and (conf <= 0).any():
            raise ValueError("edge confidences must be positive")

    # Symmetrize: forward + reverse with equal weight; drop exact duplicates
    # keeping the minimum weight per (u, v) — per (u, v, pred) when typed.
    u = np.concatenate([src, dst])
    v = np.concatenate([dst, src])
    ww = np.concatenate([w, w])
    pp = np.concatenate([pred, pred]) if typed else None
    cc = np.concatenate([conf, conf]) if typed else None
    # Remove self loops (contribute nothing to trees).
    keep = u != v
    u, v, ww = u[keep], v[keep], ww[keep]
    if typed:
        pp, cc = pp[keep], cc[keep]
    if len(u):
        key = u.astype(np.int64) * n_nodes + v.astype(np.int64)
        if typed:
            # Sort by (u,v), then pred, then weight asc, then conf desc:
            # the first row of each (u, v, pred) group is the keeper.
            order = np.lexsort((-cc, ww, pp, key))
            key, u, v, ww = key[order], u[order], v[order], ww[order]
            pp, cc = pp[order], cc[order]
            first = np.ones(len(key), bool)
            first[1:] = (key[1:] != key[:-1]) | (pp[1:] != pp[:-1])
            u, v, ww, pp, cc = u[first], v[first], ww[first], pp[first], cc[first]
        else:
            order = np.lexsort((ww, key))
            key, u, v, ww = key[order], u[order], v[order], ww[order]
            first = np.ones(len(key), bool)
            first[1:] = key[1:] != key[:-1]
            u, v, ww = u[first], v[first], ww[first]

    order = np.argsort(u, kind="stable")
    u, v, ww = u[order], v[order], ww[order]
    if typed:
        pp, cc = pp[order], cc[order]
    counts = np.bincount(u, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return Graph(
        n_nodes=n_nodes, src=src, dst=dst, w=w.astype(np.float32, copy=False),
        indptr=indptr, indices=v.astype(np.int32), ew=ww.astype(np.float32),
        labels=labels,
        pred=pred, conf=conf,
        csr_pred=pp.astype(np.int32, copy=False) if typed else None,
        csr_conf=cc.astype(np.float32, copy=False) if typed else None,
        pred_names=list(pred_names) if pred_names is not None else None,
    )
