"""Weight policies: fold the typed edge channel into effective weights.

The paper's ranking framework (Sec. 4) only needs ``w(e) > 0``; where the
weight comes from is plumbing.  This module is that plumbing's single
switch point: a :class:`WeightPolicy` names a ranking semantics, and
:func:`apply_weight_policy` rewrites a typed :class:`~repro.graph.Graph`'s
weight vectors *once, on the host, before device packing* — the relax /
``lane_superstep`` kernels, the sharded packer, answer backtrace and
rendering all consume the same precomputed effective weights, so they
never re-derive weights (and can never disagree with each other).

Policies:

- ``degree`` (default) — the artifact's stored weights as-is (paper
  Sec. 7.1 degree model for ingested graphs).  Applying it is the
  identity, which is what keeps pre-typed (format v1) artifacts
  bit-identical.
- ``confidence`` — blend provenance into the length:
  ``w_eff = w / conf**blend`` clamped to ``MIN_EDGE_WEIGHT``.  Confidence
  is any positive score (probability, source count); higher confidence
  means a *shorter* edge, so trees rank by well-sourced relatedness.
  ``blend`` scales how hard provenance bites (0.0 ≈ degree, 1.0 = full).
- either policy may also carry ``predicates`` — an allow-list of
  predicate names; edges with any other predicate get INF weight
  (= disconnected, exactly like the paper's hub cutoff).

``WeightPolicy`` is frozen and hashable: it lives on
:class:`~repro.engine.ExecutionPolicy` and therefore inside every
``cache_token`` and serve shape key.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import INF
from repro.graph.structure import Graph, MIN_EDGE_WEIGHT

_KINDS = ("degree", "confidence")


@dataclasses.dataclass(frozen=True)
class WeightPolicy:
    """How per-edge provenance becomes the semiring's edge length.

    Attributes:
      kind: ``"degree"`` (stored weights as-is) or ``"confidence"``
        (``w / conf**blend``).
      blend: confidence exponent, > 0; only meaningful for
        ``kind="confidence"``.
      predicates: optional allow-list of predicate *names*; edges whose
        predicate is not listed become INF (disconnected).  Unknown
        names raise at apply time — a filter that silently matches
        nothing is a typo, not a policy.
    """

    kind: str = "degree"
    blend: float = 1.0
    predicates: tuple[str, ...] | None = None

    def __post_init__(self) -> None:
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {self.kind!r}")
        if not self.blend > 0:
            raise ValueError(f"blend must be > 0, got {self.blend!r}")
        if self.predicates is not None:
            preds = tuple(str(p) for p in self.predicates)
            if not preds:
                raise ValueError("predicates allow-list must be non-empty "
                                 "(use None for no filter)")
            object.__setattr__(self, "predicates", preds)

    @property
    def is_default(self) -> bool:
        """True iff applying this policy is the identity."""
        return self.kind == "degree" and self.predicates is None


def effective_weights(
    w: np.ndarray,
    pred: np.ndarray,
    conf: np.ndarray,
    policy: WeightPolicy,
    name_to_id: dict[str, int],
) -> np.ndarray:
    """Effective weight vector for one edge array (directed, CSR, or
    sym-sorted — any array whose ``pred``/``conf`` align with ``w``).

    INF entries (hub-cutoff edges) stay INF under every policy; finite
    results clamp to ``MIN_EDGE_WEIGHT`` so Theorem 1's ``w > 0`` holds
    even when a huge confidence drives ``w / conf**blend`` to zero.
    """
    w = np.asarray(w, np.float32)
    eff = w.copy()
    if policy.kind == "confidence":
        scaled = w / np.asarray(conf, np.float32) ** np.float32(policy.blend)
        eff = np.where(w >= INF, np.float32(INF),
                       np.maximum(scaled, np.float32(MIN_EDGE_WEIGHT)))
    if policy.predicates is not None:
        unknown = [p for p in policy.predicates if p not in name_to_id]
        if unknown:
            known = sorted(name_to_id)
            raise ValueError(
                f"unknown predicate(s) {unknown} in filter; "
                f"graph has {known}")
        ids = np.asarray(sorted(name_to_id[p] for p in policy.predicates),
                         np.int32)
        allowed = np.isin(np.asarray(pred, np.int32), ids)
        eff = np.where(allowed, eff, np.float32(INF))
    return eff.astype(np.float32, copy=False)


def apply_weight_policy(graph: Graph, policy: WeightPolicy | None) -> Graph:
    """Rewrite every weight vector of ``graph`` under ``policy``.

    Returns ``graph`` unchanged (same object) for the default policy —
    that identity is what guarantees pre-typed artifacts serve
    bit-identical results.  Non-default policies require a typed graph.
    The returned Graph shares node/edge-structure arrays (mmap views
    stay mmapped); only the weight vectors are fresh host arrays.
    """
    if policy is None or policy.is_default:
        return graph
    if not graph.typed:
        raise ValueError(
            f"weight policy {policy!r} needs a typed graph; this graph "
            "has no predicate channel (re-ingest with a typed reader)")
    name_to_id = {n: i for i, n in enumerate(graph.pred_names or [])}
    new_ew = effective_weights(
        graph.ew, graph.csr_pred, graph.csr_conf, policy, name_to_id)
    new_w = graph.w
    if graph.pred is not None:
        new_w = effective_weights(
            graph.w, graph.pred, graph.conf, policy, name_to_id)
    sym_sorted = None
    sym_typed = graph.sym_typed
    if graph.sym_sorted is not None:
        typed = graph.sym_typed_edges()
        if typed is not None:
            s_src, s_dst, s_w = graph.sym_sorted
            sym_sorted = (s_src, s_dst, effective_weights(
                s_w, typed[0], typed[1], policy, name_to_id))
            sym_typed = typed
        # else: drop the pre-sorted list; to_device re-sorts from the
        # (rewritten) CSR arrays — correctness over the saved argsort.
    return dataclasses.replace(
        graph, w=new_w, ew=new_ew,
        sym_sorted=sym_sorted, sym_typed=sym_typed)
