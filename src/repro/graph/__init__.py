"""Graph substrate: storage, partitioning, text index, sampling, generators."""

from repro.graph.structure import DeviceGraph, Graph, build_graph  # noqa: F401
