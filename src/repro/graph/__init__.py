"""Graph substrate: storage, partitioning, text index, sampling, generators."""

from repro.graph.structure import (  # noqa: F401
    DeviceGraph, Graph, MIN_EDGE_WEIGHT, build_graph,
)
from repro.graph.weights import (  # noqa: F401
    WeightPolicy, apply_weight_policy, effective_weights,
)
