"""Serving driver: batched prefill + greedy decode with a KV cache.

``python -m repro.launch.serve --arch <id> --smoke --prompt-len 16 --gen 8``
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import lm as lm_lib
from repro.models import transformer as tfm


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    entry = get_arch(args.arch)
    if entry.family != "lm":
        raise SystemExit("serve only applies to LM archs")
    cfg = entry.config.smoke() if args.smoke else entry.config
    b = tfm.build(cfg, tp=1 if args.smoke else 16)
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(key, b)

    prefill = jax.jit(lm_lib.make_prefill_step(b, attn_impl="naive"))
    decode = jax.jit(lm_lib.make_decode_step(b, attn_impl="naive"),
                     donate_argnums=1)

    max_seq = args.prompt_len + args.gen
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)

    t0 = time.time()
    logits_last, cache = prefill(params, prompts)
    # Grow cache to max_seq.
    pad = max_seq - cache["k"].shape[2]
    cache = {"k": jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
             "v": jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
             "pos": cache["pos"]}
    tok = jnp.argmax(logits_last[:, : cfg.vocab], axis=-1)[:, None].astype(jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        tok, cache = decode(params, cache, tok)
        out_tokens.append(tok)
    gen = jnp.concatenate(out_tokens, axis=1)
    gen = jax.block_until_ready(gen)
    t_decode = time.time() - t0

    print(f"prefill: {t_prefill*1e3:.1f} ms for {args.batch}x{args.prompt_len}")
    print(f"decode:  {t_decode*1e3:.1f} ms for {args.gen-1} steps "
          f"({(args.gen-1)*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample generations (token ids):")
    for row in np.asarray(gen)[:2]:
        print("  ", row[:16])
    assert np.all(np.asarray(gen) >= 0) and np.all(np.asarray(gen) < cfg.vocab)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
