import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Hillclimb helper: compile one cell and print the roofline breakdown —
top HBM-traffic ops, top collectives, loop multipliers — so each
hypothesis->change->measure iteration is one command:

    PYTHONPATH=src python -m repro.launch.analyze --cell command-r-plus-104b__train_4k
"""

import argparse
import re
from collections import defaultdict

import jax  # noqa: E402

from repro import shardmap
from repro.analysis import hlo as H
from repro.analysis import build_roofline
from repro.launch import cells as cells_mod
from repro.launch.mesh import make_production_mesh, sharding_tree


def compile_cell(cell_name: str, multi_pod: bool = False):
    n_shards = 512 if multi_pod else 256
    if cell_name.startswith("dks-"):
        ds = cell_name.split("__")[0][len("dks-"):]
        if "dense" in cell_name:
            cell = cells_mod.dks_cell_dense(ds)
        else:
            cell = cells_mod.dks_cell(ds, n_shards=n_shards)
    else:
        arch, shape = cell_name.split("__")
        cell = cells_mod.build_cell(arch, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    in_sh = tuple(sharding_tree(mesh, s) for s in cell.in_specs)
    with shardmap.mesh_scope(mesh):
        compiled = jax.jit(cell.fn, in_shardings=in_sh,
                           donate_argnums=cell.donate
                           ).lower(*cell.args).compile()
    return cell, mesh, compiled


def breakdown(compiled, top: int = 25):
    text = compiled.as_text()
    comps = H.parse_hlo(text)
    summary = H.analyze_hlo(text)

    entry = next(c for c in comps.values() if c.is_entry)
    inlined = set()
    for c in comps.values():
        for op in c.ops:
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", op.attrs):
                inlined.add(m.group(1))

    # Recompute multipliers (mirrors analyze_hlo).
    mult = defaultdict(float)
    mult[entry.name] = 1.0
    stack = [entry.name]
    seen_edges = set()
    loops = []
    while stack:
        cn = stack.pop()
        c = comps.get(cn)
        if c is None:
            continue
        for op in c.ops:
            if op.opcode == "while":
                mc = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                mb = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                if not (mc and mb):
                    continue
                tc = H._trip_count(comps, mc.group(1)) or 1
                loops.append((cn, mb.group(1), tc, mult[cn]))
                for child in (mb.group(1), mc.group(1)):
                    e = (cn, child, op.name)
                    if e not in seen_edges:
                        seen_edges.add(e)
                        mult[child] += mult[cn] * tc
                        stack.append(child)
            else:
                for m in re.finditer(
                        r"(?:calls|to_apply|true_computation|false_computation"
                        r")=%?([\w\.\-]+)", op.attrs):
                    e = (cn, m.group(1), op.name)
                    if e not in seen_edges:
                        seen_edges.add(e)
                        mult[m.group(1)] += mult[cn]
                        stack.append(m.group(1))

    rows = []
    colls = []
    for c in comps.values():
        m_here = mult.get(c.name, 0.0)
        if m_here == 0:
            continue
        for op in c.ops:
            base = op.opcode.replace("-start", "")
            if base in H.COLLECTIVES:
                nbytes = (H._shape_bytes(op.result_type) if base == "all-gather"
                          else sum(H._shape_bytes(c.types.get(o, ""))
                                   for o in op.operands))
                colls.append((m_here * nbytes, m_here, base, op.result_type[:60],
                              c.name[:40]))
            if c.name in inlined or op.opcode in H._SKIP_TRAFFIC:
                continue
            t = H._op_traffic(op, c, comps) * m_here
            rows.append((t, m_here, op.opcode, op.result_type[:60], c.name[:40]))
    rows.sort(reverse=True)
    colls.sort(reverse=True)
    return summary, loops, rows[:top], colls[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    args = ap.parse_args()

    cell, mesh, compiled = compile_cell(args.cell, args.multi_pod)
    mem = compiled.memory_analysis()
    summary, loops, rows, colls = breakdown(compiled, args.top)
    chips = mesh.devices.size
    terms = build_roofline(cell.arch_id, cell.shape_name,
                           "multi" if args.multi_pod else "single",
                           chips, summary, cell.model_flops)
    gib = 2**30
    print(f"== {cell.name}  ({cell.notes}) ==")
    print(f"mem: arg={mem.argument_size_in_bytes/gib:.2f} "
          f"temp={mem.temp_size_in_bytes/gib:.2f} "
          f"out={mem.output_size_in_bytes/gib:.2f} "
          f"alias={mem.alias_size_in_bytes/gib:.2f} GiB/dev")
    print(f"t_compute={terms.t_compute:.3e}s t_memory={terms.t_memory:.3e}s "
          f"t_collective={terms.t_collective:.3e}s -> {terms.bottleneck}")
    print(f"HLO dot TFLOP/dev={summary.dot_flops/1e12:.2f} "
          f"traffic TB/dev={summary.traffic_bytes/1e12:.3f} "
          f"wire GB/dev={summary.total_collective_bytes()/1e9:.2f} "
          f"useful={100*terms.useful_flops_frac:.1f}%")
    print(f"\nloops (parent, body, trip, parent_mult):")
    for l in loops[:12]:
        print(f"  {l[0][:36]:36s} -> {l[1][:36]:36s} trip={l[2]:<6d} m={l[3]:.0f}")
    print(f"\ntop HBM-traffic ops (GiB/dev, mult, opcode, shape, comp):")
    for t, m, opc, ty, cn in rows:
        print(f"  {t/gib:9.2f}  x{m:<7.0f} {opc:22s} {ty:44s} {cn}")
    print(f"\ntop collectives (GiB/dev, mult, type, shape, comp):")
    for t, m, base, ty, cn in colls:
        print(f"  {t/gib:9.2f}  x{m:<7.0f} {base:20s} {ty:44s} {cn}")


if __name__ == "__main__":
    main()
