import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any jax import so the host platform
exposes 512 placeholder devices for the production meshes.

Per cell it records:
  - memory_analysis (per-device bytes: args/outputs/temps) -> proves it fits
  - cost_analysis flops/bytes (XLA's own numbers, loop bodies counted once)
  - trip-count-corrected HLO flops / traffic / collective bytes (hlo.py)
  - the three roofline terms (roofline.py)
as JSON under experiments/dryrun/<mesh>/<cell>.json, which EXPERIMENTS.md
§Dry-run and §Roofline read.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax  # noqa: E402  (after XLA_FLAGS on purpose)

from repro import shardmap
from repro.analysis import analyze_hlo, build_roofline
from repro.launch import cells as cells_mod
from repro.launch.mesh import make_production_mesh, sharding_tree

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_cell(cell, mesh, mesh_name: str, out_dir: Path,
             save_hlo: bool = False) -> dict:
    t0 = time.time()
    in_shardings = tuple(sharding_tree(mesh, s) for s in cell.in_specs)
    with shardmap.mesh_scope(mesh):
        jitted = jax.jit(cell.fn, in_shardings=in_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        compiled = lowered.compile()
    t1 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    summary = analyze_hlo(text)
    chips = mesh.devices.size
    terms = build_roofline(cell.arch_id, cell.shape_name, mesh_name, chips,
                           summary, cell.model_flops)
    rec = {
        "cell": cell.name,
        "arch": cell.arch_id,
        "shape": cell.shape_name,
        "kind": cell.kind,
        "mesh": mesh_name,
        "chips": chips,
        "compile_s": round(t1 - t0, 2),
        "notes": cell.notes,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "total_nonaliased": int(mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        },
        "cost_analysis": {
            "flops": float(cost.get("flops", -1.0)),
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        },
        "hlo": {
            "dot_flops": summary.dot_flops,
            "traffic_bytes": summary.traffic_bytes,
            "collective_bytes": summary.collective_bytes,
            "collective_counts": summary.collective_counts,
            "wire_bytes": summary.total_collective_bytes(),
            "dynamic_loops": summary.dynamic_loops,
            "static_loops": summary.static_loops,
            "n_dots": summary.n_dots,
        },
        "roofline": terms.as_row(),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell.name}.json").write_text(json.dumps(rec, indent=1))
    if save_hlo:
        (out_dir / f"{cell.name}.hlo.txt").write_text(text)
    return rec


def iter_cells(only=None, dks=True, tp=16, n_shards=256):
    for arch_id, shape_name in cells_mod.all_assigned_cells():
        if only and only not in f"{arch_id}__{shape_name}":
            continue
        yield lambda a=arch_id, s=shape_name: cells_mod.build_cell(a, s, tp=tp)
    if dks and not only or (only and "dks" in only):
        for make in (lambda: cells_mod.dks_cell("sec-rdfabout",
                                                n_shards=n_shards),
                     lambda: cells_mod.dks_cell("bluk-bnb",
                                                n_shards=n_shards),
                     lambda: cells_mod.dks_cell_dense("bluk-bnb")):
            c_probe = make
            yield c_probe


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--only", default=None,
                    help="substring filter on <arch>__<shape>")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--stop-on-error", action="store_true")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multipod2x16x16", make_production_mesh(multi_pod=True)))

    failures = []
    n_ok = 0
    for mesh_name, mesh in meshes:
        out_dir = OUT_DIR / mesh_name
        for make in iter_cells(only=args.only, n_shards=mesh.devices.size):
            try:
                cell = make()
                if args.only and args.only not in cell.name:
                    continue
                rec = run_cell(cell, mesh, mesh_name, out_dir,
                               save_hlo=args.save_hlo)
                r = rec["roofline"]
                print(f"[OK] {mesh_name} {rec['cell']:<48s} "
                      f"compile={rec['compile_s']:7.1f}s "
                      f"mem={rec['memory']['total_nonaliased']/2**30:7.2f}GiB "
                      f"t_c={r['t_compute']:.3e} t_m={r['t_memory']:.3e} "
                      f"t_x={r['t_collective']:.3e} bott={r['bottleneck']}",
                      flush=True)
                n_ok += 1
            except Exception as e:  # noqa: BLE001
                name = getattr(locals().get("cell"), "name", "<build failed>")
                print(f"[FAIL] {mesh_name} {name}: {e}", flush=True)
                traceback.print_exc()
                failures.append((mesh_name, name, str(e)))
                if args.stop_on_error:
                    return 1
    print(f"\n{n_ok} cells OK, {len(failures)} failed")
    for f in failures:
        print("FAILED:", *f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
