"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS for 512 host devices *before* any jax
import and only then calls these.  Mesh construction goes through
:mod:`repro.shardmap` so the same code runs on jax 0.4.x and >= 0.7.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro import shardmap


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) for two."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shardmap.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally, as a 1D (data,) mesh (tests/CPU)."""
    n = len(jax.devices())
    return shardmap.make_mesh((n,), ("data",))


def filter_spec(spec: P, mesh) -> P:
    """Drop axis names not present in the mesh from a PartitionSpec."""
    names = set(mesh.axis_names)

    def f(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in names else None
        kept = tuple(a for a in entry if a in names)
        return kept if kept else None

    return P(*(f(e) for e in spec))


def named_sharding(mesh, spec: P):
    return jax.sharding.NamedSharding(mesh, filter_spec(spec, mesh))


def sharding_tree(mesh, spec_tree):
    """Map a pytree of PartitionSpecs to NamedShardings (P treated as leaf)."""
    return jax.tree_util.tree_map(
        lambda s: named_sharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
