"""Cell catalog: every (architecture x input-shape) pair as a lowerable unit.

A Cell bundles the step function (train_step / serve_step / retrieval /
DKS superstep), ShapeDtypeStruct arguments (weak-type-correct, shardable,
zero allocation) and PartitionSpec trees for jit in_shardings.  The dry-run
lowers + compiles each cell on the production meshes; the roofline reads
the compiled artifact.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, DKS_CONFIGS, get_arch
from repro.configs.base import GNNShape, LMShape, RecsysShape
from repro.core.dks import DKSConfig, DKSState
from repro.graph.structure import DeviceGraph
from repro.models import gnn as gnn_lib
from repro.models import lm as lm_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tfm
from repro.models.gnn import GraphBatch
from repro.optim import AdamWConfig, OptState
import repro.analysis.roofline as rl

DP = ("pod", "data")
TP = ("model",)
ALL = ("pod", "data", "model")


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def pad_to(x: int, m: int) -> int:
    return int(-(-x // m) * m)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    fn: Callable
    args: tuple
    in_specs: tuple          # pytree-of-P matching args
    donate: tuple = ()
    model_flops: float = 0.0
    static_argnums: tuple = ()
    notes: str = ""

    @property
    def name(self) -> str:
        return f"{self.arch_id}__{self.shape_name}"


def _tree_specs(tree, spec) -> Any:
    """Broadcast one P to every leaf of a pytree."""
    return jax.tree_util.tree_map(lambda _: spec, tree)


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------


def _lm_state_specs(b: tfm.BuiltLM):
    ps = tfm.param_specs(b)
    return lm_lib.TrainState(
        params=ps,
        opt=OptState(mu=ps, nu=ps, count=P()),
        step=P(),
    )


def _lm_grad_accum(cfg, shape: LMShape) -> int:
    """Activation-memory heuristic.  With sequence-parallel residual
    carries the saved stack shards over dp x tp (256-way), so ~2 GB of
    pre-SP-equivalent activations per chip keeps the measured temp
    footprint well inside 16 GiB while minimizing FSDP weight regathers."""
    tokens = shape.seq_len * shape.global_batch
    act_bytes = tokens * cfg.d_model * 2 * cfg.n_layers  # saved layer inputs
    per_chip = act_bytes / 256
    # >50B-param models carry f32 grad/optimizer transients of several GiB,
    # so their activation budget is tighter (measured; §Perf B7).
    budget = 0.5e9 if cfg.param_count_analytic() > 5e10 else 2e9
    accum = 1
    while per_chip / accum > budget and accum < shape.global_batch:
        accum *= 2
    return accum


def lm_cell(arch_id: str, shape: LMShape, tp: int = 16) -> Cell:
    cfg = get_arch(arch_id).config
    b = tfm.build(cfg, tp=tp)
    key = jax.random.PRNGKey(0)

    if shape.kind == "train":
        accum = _lm_grad_accum(cfg, shape)
        step = lm_lib.make_train_step(
            b, AdamWConfig(), attn_impl="flash_jax" if shape.seq_len > 2048
            else "naive", grad_accum=accum)
        state = jax.eval_shape(lambda k: lm_lib.init_train_state(k, b), key)
        batch = {
            "tokens": sds((shape.global_batch, shape.seq_len), jnp.int32),
            "labels": sds((shape.global_batch, shape.seq_len), jnp.int32),
        }
        in_specs = (_lm_state_specs(b),
                    {"tokens": P(DP, None), "labels": P(DP, None)})
        return Cell(arch_id, shape.name, "train", step, (state, batch),
                    in_specs, donate=(0,),
                    model_flops=rl.model_flops_lm(cfg, shape),
                    notes=f"grad_accum={accum}")

    if shape.kind == "prefill":
        fn = lm_lib.make_prefill_step(b, attn_impl="flash_jax")
        params = jax.eval_shape(lambda k: tfm.init_params(k, b), key)
        tokens = sds((shape.global_batch, shape.seq_len), jnp.int32)
        in_specs = (tfm.param_specs(b), P(DP, None))
        return Cell(arch_id, shape.name, "prefill", fn, (params, tokens),
                    in_specs, model_flops=rl.model_flops_lm(cfg, shape))

    # decode: one new token against a seq_len KV cache.
    fn = lm_lib.make_decode_step(b, attn_impl="naive")
    params = jax.eval_shape(lambda k: tfm.init_params(k, b), key)
    cache = jax.eval_shape(
        lambda: tfm.init_cache(b, shape.global_batch, shape.seq_len))
    tokens = sds((shape.global_batch, 1), jnp.int32)
    # Tiny batches (long_500k B=1) can't shard batch over data: put the KV
    # sequence axis over (data, model) instead and replicate batch.
    if shape.global_batch >= 32:
        batch_spec, seq_axes = DP, TP
    else:
        batch_spec, seq_axes = None, ("data", "model")
    cache_spec = {"k": P(None, batch_spec, seq_axes, None, None),
                  "v": P(None, batch_spec, seq_axes, None, None),
                  "pos": P()}
    in_specs = (tfm.param_specs(b), cache_spec, P(batch_spec, None))
    return Cell(arch_id, shape.name, "decode", fn, (params, cache, tokens),
                in_specs, donate=(1,),
                model_flops=rl.model_flops_lm(cfg, shape))


def lm_pp_cell(arch_id: str, shape_name: str = "train_4k", tp: int = 16,
               n_stages: int = 2, n_micro: int = 16) -> Cell:
    """Pipeline-parallel train cell: layers stage-sharded over "pod" with
    the GPipe schedule (models/pipeline.py).  Multi-pod mesh only — PP is
    the parallelism for the slow cross-pod hop."""
    from repro.models import pipeline as pp_lib

    cfg = get_arch(arch_id).config
    shape = next(s for s in get_arch(arch_id).shapes if s.name == shape_name)
    b = tfm.build(cfg, tp=tp)
    key = jax.random.PRNGKey(0)
    step = pp_lib.make_pp_train_step(
        b, AdamWConfig(), n_stages=n_stages, n_micro=n_micro,
        attn_impl="flash_jax")
    state = jax.eval_shape(lambda k: lm_lib.init_train_state(k, b), key)
    batch = {
        "tokens": sds((shape.global_batch, shape.seq_len), jnp.int32),
        "labels": sds((shape.global_batch, shape.seq_len), jnp.int32),
    }
    ps = pp_lib.stage_layer_specs(b)
    state_spec = lm_lib.TrainState(
        params=ps, opt=OptState(mu=ps, nu=ps, count=P()), step=P())
    in_specs = (state_spec, {"tokens": P(("data",), None),
                             "labels": P(("data",), None)})
    return Cell(arch_id, f"{shape_name}_pp{n_stages}", "train", step,
                (state, batch), in_specs, donate=(0,),
                model_flops=rl.model_flops_lm(cfg, shape),
                notes=f"GPipe n_stages={n_stages} n_micro={n_micro}")


def lm_decode_quant_cell(arch_id: str, shape_name: str, tp: int = 16) -> Cell:
    """Decode cell variant with the int8 KV cache (beyond-paper lever for
    the decode cells whose bf16 cache exceeds 16 GiB; EXPERIMENTS §Perf)."""
    from repro.models import kvcache

    cfg = get_arch(arch_id).config
    shape = next(s for s in get_arch(arch_id).shapes if s.name == shape_name)
    b = tfm.build(cfg, tp=tp)
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: tfm.init_params(k, b), key)
    cache = jax.eval_shape(
        lambda: kvcache.init_cache_quant(b, shape.global_batch,
                                         shape.seq_len))
    tokens = sds((shape.global_batch, 1), jnp.int32)
    if shape.global_batch >= 32:
        batch_spec, seq_axes = DP, TP
    else:
        batch_spec, seq_axes = None, ("data", "model")
    cspec = {k: P(None, batch_spec, seq_axes, None, None)
             for k in ("k_q", "k_s", "v_q", "v_s")}
    cspec["pos"] = P()

    def fn(params, cache, tokens):
        logits, cache = tfm.decode_step_quant(params, cache, tokens, b)
        nxt = jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1)
        return nxt.astype(jnp.int32)[:, None], cache

    return Cell(arch_id, f"{shape_name}_int8kv", "decode", fn,
                (params, cache, tokens),
                (tfm.param_specs(b), cspec, P(batch_spec, None)),
                donate=(1,), model_flops=rl.model_flops_lm(cfg, shape),
                notes="int8 KV cache")


# --------------------------------------------------------------------------
# GNN cells
# --------------------------------------------------------------------------


def _gnn_train_step(cfg, opt_cfg: AdamWConfig):
    from repro.optim import adamw_update

    def step(state: lm_lib.TrainState, batch: GraphBatch):
        loss, grads = jax.value_and_grad(
            lambda p: gnn_lib.gnn_loss(p, batch, cfg))(state.params)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params)
        return (lm_lib.TrainState(params=new_params, opt=new_opt,
                                  step=state.step + 1),
                {"loss": loss, **metrics})

    return step


def gnn_cell(arch_id: str, shape: GNNShape, mesh_divisor: int = 512) -> Cell:
    cfg = get_arch(arch_id).config
    # Production cells run bf16 message passing (halves edge-gather wire
    # and HBM bytes; accumulation in f32 — §Perf hillclimb).
    cfg = dataclasses.replace(cfg, mp_dtype="bfloat16")
    key = jax.random.PRNGKey(0)

    if shape.kind == "minibatch":
        from repro.graph.sampler import plan_sizes
        n_nodes, n_edges = plan_sizes(shape.batch_nodes, list(shape.fanout))
        n_graphs = 1
    elif shape.kind == "molecule":
        n_nodes = shape.n_nodes * shape.batch_graphs
        n_edges = shape.n_edges * shape.batch_graphs
        n_graphs = shape.batch_graphs
    else:
        n_nodes, n_edges, n_graphs = shape.n_nodes, shape.n_edges, 1

    n_pad = pad_to(n_nodes, mesh_divisor)
    e_pad = pad_to(n_edges, mesh_divisor)
    d_feat = max(shape.d_feat, 1)

    graph_level = n_graphs > 1
    label_len = n_graphs if (graph_level or cfg.family == "schnet") else n_pad
    label_dtype = jnp.float32 if cfg.family == "schnet" else jnp.int32
    batch = GraphBatch(
        x=sds((n_pad, d_feat), jnp.float32),
        edge_src=sds((e_pad,), jnp.int32),
        edge_dst=sds((e_pad,), jnp.int32),
        node_mask=sds((n_pad,), jnp.bool_),
        edge_mask=sds((e_pad,), jnp.bool_),
        labels=sds((max(label_len, 1),), label_dtype),
        graph_ids=sds((n_pad,), jnp.int32),
        positions=sds((n_pad, 3), jnp.float32),
        n_graphs=n_graphs,
    )
    params = jax.eval_shape(
        lambda k: gnn_lib.init_gnn(k, cfg, d_in=d_feat), key)
    state = lm_lib.TrainState(
        params=params,
        opt=OptState(
            mu=jax.tree_util.tree_map(
                lambda p: sds(p.shape, jnp.float32), params),
            nu=jax.tree_util.tree_map(
                lambda p: sds(p.shape, jnp.float32), params),
            count=sds((), jnp.int32)),
        step=sds((), jnp.int32))

    step = _gnn_train_step(cfg, AdamWConfig())
    label_spec = P(None) if label_len < 4096 else P(ALL)
    batch_specs = GraphBatch(
        x=P(ALL, None), edge_src=P(ALL), edge_dst=P(ALL),
        node_mask=P(ALL), edge_mask=P(ALL), labels=label_spec,
        graph_ids=P(ALL), positions=P(ALL, None), n_graphs=n_graphs)
    param_spec = _tree_specs(params, P())
    state_spec = lm_lib.TrainState(
        params=param_spec,
        opt=OptState(mu=param_spec, nu=param_spec, count=P()),
        step=P())
    return Cell(arch_id, shape.name, "gnn_train", step, (state, batch),
                (state_spec, batch_specs), donate=(0,),
                model_flops=rl.model_flops_gnn(cfg, shape, n_nodes, n_edges),
                notes=f"n_pad={n_pad} e_pad={e_pad}")


# --------------------------------------------------------------------------
# Recsys cells
# --------------------------------------------------------------------------


def _rec_train_step(cfg, opt_cfg: AdamWConfig):
    from repro.optim import adamw_update

    def step(state: lm_lib.TrainState, batch):
        loss, grads = jax.value_and_grad(
            lambda p: rec_lib.dcn_loss(p, batch, cfg))(state.params)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params)
        return (lm_lib.TrainState(params=new_params, opt=new_opt,
                                  step=state.step + 1),
                {"loss": loss, **metrics})

    return step


def recsys_cell(arch_id: str, shape: RecsysShape) -> Cell:
    cfg = get_arch(arch_id).config
    key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda k: rec_lib.init_dcn(k, cfg), key)
    pspec = rec_lib.param_specs(cfg)

    if shape.kind == "train":
        batch = {
            "dense": sds((shape.batch, cfg.n_dense), jnp.float32),
            "sparse": sds((shape.batch, cfg.n_sparse), jnp.int32),
            "label": sds((shape.batch,), jnp.int32),
        }
        state = lm_lib.TrainState(
            params=params,
            opt=OptState(
                mu=jax.tree_util.tree_map(
                    lambda p: sds(p.shape, jnp.float32), params),
                nu=jax.tree_util.tree_map(
                    lambda p: sds(p.shape, jnp.float32), params),
                count=sds((), jnp.int32)),
            step=sds((), jnp.int32))
        state_spec = lm_lib.TrainState(
            params=pspec, opt=OptState(mu=pspec, nu=pspec, count=P()),
            step=P())
        bspec = {"dense": P(DP, None), "sparse": P(DP, None),
                 "label": P(DP)}
        step = _rec_train_step(cfg, AdamWConfig())
        return Cell(arch_id, shape.name, "rec_train", step, (state, batch),
                    (state_spec, bspec), donate=(0,),
                    model_flops=rl.model_flops_recsys(cfg, shape))

    if shape.kind == "serve":
        fn = functools.partial(rec_lib.dcn_forward, cfg=cfg)
        dense = sds((shape.batch, cfg.n_dense), jnp.float32)
        sparse = sds((shape.batch, cfg.n_sparse), jnp.int32)
        return Cell(arch_id, shape.name, "rec_serve", fn,
                    (params, dense, sparse),
                    (pspec, P(DP, None), P(DP, None)),
                    model_flops=rl.model_flops_recsys(cfg, shape))

    # retrieval: 1 query vs 1M candidates (padded to the mesh).
    n_cand = pad_to(shape.n_candidates, 512)
    fn = functools.partial(rec_lib.retrieval_scores, cfg=cfg, top_k=100)
    dense = sds((shape.batch, cfg.n_dense), jnp.float32)
    sparse = sds((shape.batch, cfg.n_sparse), jnp.int32)
    cand = sds((n_cand,), jnp.int32)
    return Cell(arch_id, shape.name, "rec_retrieval", fn,
                (params, dense, sparse, cand),
                (pspec, P(None, None), P(None, None), P(ALL)),
                model_flops=rl.model_flops_recsys(cfg, shape),
                notes=f"n_cand_pad={n_cand}")


# --------------------------------------------------------------------------
# DKS cells (the paper's technique on the production mesh)
# --------------------------------------------------------------------------


def dks_cell(ds_name: str, m: int = 4, k: int = 2,
             n_shards: int = 256) -> Cell:
    """DKS superstep with frontier-compressed relax (post-hillclimb; the
    dense-relax baseline is dks_cell_dense)."""
    from repro.core import dks_sharded

    ds = DKS_CONFIGS[ds_name]
    v_pad = pad_to(ds.n_nodes, max(512, n_shards))
    e_sym = 2 * ds.n_edges
    n_sets = 1 << m
    e_cap = pad_to(int(e_sym / n_shards * 1.2), 8)
    graph = dks_sharded.FrontierGraph(
        edge_src=sds((n_shards, e_cap), jnp.int32),
        edge_dst_l=sds((n_shards, e_cap), jnp.int32),
        edge_w=sds((n_shards, e_cap), jnp.float32),
        out_degree=sds((v_pad,), jnp.int32),
        node_valid=sds((v_pad,), jnp.bool_),
        n_nodes=ds.n_nodes, n_edges=e_sym, n_shards=n_shards)
    state = DKSState(
        S=sds((v_pad, n_sets, k), jnp.float32),
        changed=sds((v_pad,), jnp.bool_),
        first_fire=sds((v_pad,), jnp.bool_),
        visited=sds((v_pad,), jnp.bool_),
        g=sds((n_sets,), jnp.float32),
        s_front=sds((n_sets,), jnp.float32),
        topk_w=sds((k,), jnp.float32),
        topk_root=sds((k,), jnp.int32),
        msgs_bfs=sds((), jnp.float32), msgs_deep=sds((), jnp.float32),
        step=sds((), jnp.int32), done=sds((), jnp.bool_),
        budget_hit=sds((), jnp.bool_), capped=sds((), jnp.bool_))
    cfg = DKSConfig(m=m, k=k, max_supersteps=64)
    fn = functools.partial(dks_sharded.superstep_frontier, cfg=cfg)

    # Sharding (post-hillclimb, EXPERIMENTS.md §Perf): node axis over ALL
    # mesh axes, keyword-set axis replicated -> subset-combine is fully
    # node-local; relax exchanges only the packed frontier.
    gspec = dks_sharded.FrontierGraph(
        edge_src=P(ALL, None), edge_dst_l=P(ALL, None),
        edge_w=P(ALL, None),
        out_degree=P(ALL), node_valid=P(ALL),
        n_nodes=ds.n_nodes, n_edges=e_sym, n_shards=n_shards)
    sspec = DKSState(
        S=P(ALL, None, None), changed=P(ALL), first_fire=P(ALL),
        visited=P(ALL),
        g=P(None), s_front=P(None), topk_w=P(None), topk_root=P(None),
        msgs_bfs=P(), msgs_deep=P(), step=P(), done=P(), budget_hit=P(),
        capped=P())
    return Cell(f"dks-{ds_name}", f"superstep_m{m}_k{k}", "dks", fn,
                (graph, state), (gspec, sspec), donate=(1,),
                model_flops=rl.model_flops_dks(ds.n_nodes, e_sym, m, k),
                notes=f"V={ds.n_nodes} E_sym={e_sym} shards={n_shards}")


def dks_cell_dense(ds_name: str, m: int = 4, k: int = 2) -> Cell:
    """Baseline dense-relax DKS cell (nodes over DP, keyword-sets over TP)
    — kept for the §Perf before/after comparison."""
    from repro.core import dks as dks_mod

    ds = DKS_CONFIGS[ds_name]
    v_pad = pad_to(ds.n_nodes, 512)
    e_sym = 2 * ds.n_edges
    e_pad = pad_to(e_sym, 512)
    n_sets = 1 << m
    graph = DeviceGraph(
        src=sds((e_pad,), jnp.int32), dst=sds((e_pad,), jnp.int32),
        w=sds((e_pad,), jnp.float32), valid=sds((e_pad,), jnp.bool_),
        out_degree=sds((v_pad,), jnp.int32),
        node_valid=sds((v_pad,), jnp.bool_),
        n_nodes=ds.n_nodes, n_edges=e_sym)
    state = DKSState(
        S=sds((v_pad, n_sets, k), jnp.float32),
        changed=sds((v_pad,), jnp.bool_),
        first_fire=sds((v_pad,), jnp.bool_),
        visited=sds((v_pad,), jnp.bool_),
        g=sds((n_sets,), jnp.float32),
        s_front=sds((n_sets,), jnp.float32),
        topk_w=sds((k,), jnp.float32),
        topk_root=sds((k,), jnp.int32),
        msgs_bfs=sds((), jnp.float32), msgs_deep=sds((), jnp.float32),
        step=sds((), jnp.int32), done=sds((), jnp.bool_),
        budget_hit=sds((), jnp.bool_), capped=sds((), jnp.bool_))
    cfg = DKSConfig(m=m, k=k, max_supersteps=64)
    fn = functools.partial(dks_mod.superstep, cfg=cfg)
    gspec = DeviceGraph(
        src=P(DP), dst=P(DP), w=P(DP), valid=P(DP),
        out_degree=P(DP), node_valid=P(DP),
        n_nodes=ds.n_nodes, n_edges=e_sym)
    sspec = DKSState(
        S=P(DP, TP, None), changed=P(DP), first_fire=P(DP), visited=P(DP),
        g=P(None), s_front=P(None), topk_w=P(None), topk_root=P(None),
        msgs_bfs=P(), msgs_deep=P(), step=P(), done=P(), budget_hit=P(),
        capped=P())
    return Cell(f"dks-{ds_name}", f"superstep_dense_m{m}_k{k}", "dks", fn,
                (graph, state), (gspec, sspec), donate=(1,),
                model_flops=rl.model_flops_dks(ds.n_nodes, e_sym, m, k),
                notes=f"V={ds.n_nodes} E_sym={e_sym} dense-relax baseline")


# --------------------------------------------------------------------------
# Catalog
# --------------------------------------------------------------------------


def build_cell(arch_id: str, shape_name: str, tp: int = 16) -> Cell:
    entry = get_arch(arch_id)
    shape = next(s for s in entry.shapes if s.name == shape_name)
    if entry.family == "lm":
        return lm_cell(arch_id, shape, tp=tp)
    if entry.family == "gnn":
        return gnn_cell(arch_id, shape)
    return recsys_cell(arch_id, shape)


def all_assigned_cells(tp: int = 16) -> list[tuple[str, str]]:
    return [(a.arch_id, s.name) for a in ARCHS.values() for s in a.shapes]


def dks_cells(n_shards: int = 256) -> list[Cell]:
    return [dks_cell("sec-rdfabout", n_shards=n_shards),
            dks_cell("bluk-bnb", n_shards=n_shards),
            dks_cell_dense("bluk-bnb")]
