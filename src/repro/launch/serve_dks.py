"""DKS serving CLI: load-replay a synthetic request trace against
:class:`repro.serve.DKSService` with concurrent closed-loop clients, then
print the :class:`ServeStats` report and verify every served answer
against the direct single-query engine.

    python -m repro.launch.serve_dks --dataset sec-rdfabout-cpu \
        --clients 8 --requests 32 --max-batch 8 --max-wait-ms 25

``--smoke`` shrinks the run to CI size and *asserts* the serving
invariants: mean batch-fill > 1 (the micro-batcher coalesced concurrent
clients), warm cache-hit rate > 0 (the trace repeats, the cache caught
it), at least one multi-lane deadline bucket (same-budget requests rode
one stepwise lane driver and shared supersteps), every served answer
either matches the direct engine result or carries ``approximate=True``
with a valid SPA lower bound, and answer trees are servable end-to-end:
a ``return_trees=True`` query yields >= k distinct keyword-covering
trees and an identical follow-up is served warm from the tree-pool
cache.  The smoke also scrapes its own ``/metrics`` over HTTP
(ephemeral port) and asserts the exposition parses, the request/dispatch
counters match ``ServeStats``, and the recent traces carry dispatch
spans.

``--metrics-port`` serves Prometheus ``/metrics``, ``/healthz``, and
recent traces as ``/traces`` JSONL for the duration of the replay;
``--trace-sample`` / ``--trace-log`` control span sampling and the
structured JSONL event log.

Live graphs: ``--live DIR`` serves the delta chain in a
:class:`repro.live.LiveDir` (engine version = the chained hash);
``--watch WATCH_DIR`` additionally tails a fragment directory for the
duration of the replay, hot-swapping the engine on every published
delta.  ``--smoke --swap-mid-run`` appends the swap-under-load leg:
open-ended client load over a live ring graph, a fragment dropped
mid-run, and hard asserts that zero requests fail, in-flight requests
finish on their admitting build, post-swap requests see the new chained
version (a shortcut edge collapses the probe weight, a post-delta-only
keyword resolves), traces stay complete (begun == finished, ``dks.swap``
carries build/warm/swap spans), and the swap counters land on
``/metrics``.
"""

from __future__ import annotations

import argparse
import json
import time
import urllib.request

import numpy as np

from repro.engine import ExecutionPolicy
from repro.launch.dks_query import (add_weight_policy_args, build_engine,
                                    weight_policy_from_args)
from repro.obs import MetricsServer, parse_prometheus
from repro.serve import DKSService, ServeConfig
from repro.serve.loadgen import latency_split, make_trace, replay


def verify_served(engine, trace, served, atol=1e-5):
    """Check every served answer against the direct engine.

    Exact results must match the single-query weights; approximate
    (deadline-terminated) results must bracket the optimum:
    ``sound_opt_lower_bound <= optimum <= best-so-far``.  (The *sound*
    bound is the one asserted — ``opt_lower_bound`` follows the paper's
    reporting convention, whose SPA component is an estimator and may in
    principle overestimate.)  Returns (n_exact, n_approx); raises
    AssertionError on any mismatch.
    """
    refs: dict = {}
    n_exact = n_approx = 0
    for req, srv in zip(trace, served):
        key = (req.keywords, req.k)
        if key not in refs:
            refs[key] = engine.query(list(req.keywords), k=req.k,
                                     extract=False)
        ref = refs[key]
        if srv.approximate:
            n_approx += 1
            assert srv.opt_lower_bound is not None, \
                "approximate result without a lower bound"
            assert srv.sound_opt_lower_bound is not None, \
                "approximate result without a sound lower bound"
            assert srv.sound_opt_lower_bound <= ref.best_weight + atol, (
                f"invalid sound bound for {req.keywords}: "
                f"{srv.sound_opt_lower_bound} > optimum {ref.best_weight}")
            assert srv.result.weights[0] >= ref.weights[0] - atol, (
                f"best-so-far beats the optimum for {req.keywords}")
        else:
            n_exact += 1
            np.testing.assert_allclose(
                srv.result.weights, ref.weights, rtol=1e-5, atol=atol,
                err_msg=f"served weights diverged for {req.keywords}")
    return n_exact, n_approx


def verify_trees(svc, engine, trace, k=2):
    """Smoke acceptance for served answer trees (``return_trees=True``).

    Walks the trace's unique keyword sets, asserting on the first one
    whose table holds >= k distinct trees: the served page carries >= k
    *distinct* tree keys, every tree's node set covers every query
    keyword (checked against the inverted index), and an identical
    follow-up request is served warm from the tree-pool cache — same
    page, no re-extraction.  Returns (keywords, n_distinct) for the
    query that passed; raises AssertionError if no unique query yields
    k trees or any invariant fails.
    """
    index = engine.index
    seen: set = set()
    for req in trace:
        if req.keywords in seen:
            continue
        seen.add(req.keywords)
        srv = svc.query(list(req.keywords), k=k, return_trees=True,
                        tree_page_size=k)
        page = srv.trees
        assert page is not None, "return_trees request served no TreePage"
        if page.total < k:
            continue  # thin table for this query; try the next one
        keys = {(t.root, tuple(sorted((e.u, e.v) for e in t.edges)))
                for t in page.items}
        assert len(keys) >= k, (
            f"served page for {req.keywords} repeats trees: "
            f"{len(keys)} distinct keys < k={k}")
        for t in page.items:
            nodes = set(t.nodes)
            for tok in req.keywords:
                hits = set(int(v) for v in index.lookup(tok))
                assert nodes & hits, (
                    f"tree rooted at {t.root} does not cover keyword "
                    f"{tok!r} for query {req.keywords}")
            assert len(t.node_labels) == len(t.nodes), (
                "tree served without a label per node")
        before = svc.stats().tree_cache_hits
        warm = svc.query(list(req.keywords), k=k, return_trees=True,
                         tree_page_size=k)
        assert warm.cache_hit, "identical tree request missed the cache"
        assert svc.stats().tree_cache_hits > before, (
            "warm tree request re-extracted instead of hitting the "
            "tree-pool cache")
        warm_keys = {(t.root, tuple(sorted((e.u, e.v) for e in t.edges)))
                     for t in warm.trees.items}
        assert warm_keys == keys, "warm tree page diverged from cold page"
        return req.keywords, len(keys)
    raise AssertionError(
        f"no unique trace query yielded k={k} distinct answer trees")


def verify_metrics_scrape(svc, server):
    """Smoke acceptance for the metrics surface: scrape ``/metrics`` over
    real HTTP, assert the exposition parses, the serving counters equal
    the ``ServeStats`` snapshot (the service is idle here, so the two
    reads see the same state), dispatch counters are nonzero, and the
    recent traces carry the dispatch spans.  Returns the parsed samples.
    """
    with urllib.request.urlopen(f"{server.url}/healthz", timeout=10) as r:
        assert r.read().decode().strip() == "ok", "healthz not ok"
    with urllib.request.urlopen(f"{server.url}/metrics", timeout=10) as r:
        text = r.read().decode()
    samples = parse_prometheus(text)  # malformed exposition raises
    stats = svc.stats()
    for name, want in [
            ("dks_requests_total", stats.requests),
            ("dks_batch_dispatches_total", stats.batch_dispatches),
            ("dks_deadline_dispatches_total", stats.deadline_dispatches),
            ("dks_cache_hits_total", stats.cache_hits),
            ("dks_single_flight_hits_total", stats.single_flight_hits)]:
        assert samples.get(name) == want, (
            f"/metrics {name}={samples.get(name)} != stats {want}")
    assert samples["dks_requests_total"] > 0, "no requests on /metrics"
    assert samples["dks_batch_dispatches_total"] > 0, (
        "no batch dispatches on /metrics")
    assert samples["dks_engine_execute_count_total"] > 0, (
        "engine execute counter never moved")
    assert samples["dks_request_latency_ms_count"] == stats.requests, (
        "latency histogram count diverged from requests")
    reasons = sum(samples[f"dks_dispatch_reason_{r}_total"]
                  for r in ("full", "window", "flush"))
    assert reasons == stats.batch_dispatches + stats.deadline_dispatches, (
        f"dispatch reasons {reasons} != total dispatches")
    with urllib.request.urlopen(f"{server.url}/traces?n=16",
                                timeout=10) as r:
        lines = [json.loads(ln) for ln in
                 r.read().decode().splitlines() if ln]
    assert lines, "no finished traces on /traces"
    span_names = {sp["name"] for tr in lines for sp in tr["spans"]}
    for want in ("admit", "queue_wait", "coalesce", "device_dispatch"):
        assert want in span_names, (
            f"span {want!r} missing from recent traces: {span_names}")
    return samples


def swap_smoke(args) -> None:
    """The swap-under-load leg: a live ring graph served under
    open-ended client load, one fragment dropped mid-run, one hot swap.

    The ring makes the swap *observable in the answers*: the probe pair
    sits 8 hops apart (tree weight 8.0) until the delta's shortcut edge
    collapses it to 1.0 — so asserting every served probe weight is in
    {8.0, 1.0} proves no request ever saw a half-swapped graph, and the
    post-swap probes returning 1.0 prove the swap actually landed.
    """
    import tempfile
    import threading
    from pathlib import Path

    from repro.engine import QueryEngine
    from repro.live import EngineSwapper, GraphWatcher, LiveDir
    from repro.store import ingest_tsv

    def wait_for(cond, timeout, what):
        deadline = time.monotonic() + timeout
        while not cond():
            assert time.monotonic() < deadline, f"timed out waiting: {what}"
            time.sleep(0.02)

    tmp = Path(tempfile.mkdtemp(prefix="repro-swap-smoke-"))
    n, groups = 32, 4
    lines = [f"e{i:03d} g{i % groups}\t"
             f"e{(i + 1) % n:03d} g{(i + 1) % n % groups}\tknows\t1.0"
             for i in range(n)]
    base = tmp / "base.tsv"
    base.write_text("\n".join(lines) + "\n")
    live = LiveDir.initialize(tmp / "live", ingest_tsv(base))
    watch_dir = tmp / "incoming"
    watch_dir.mkdir()

    policy = ExecutionPolicy(
        backend=args.backend, partition=args.partition,
        max_supersteps=max(args.max_supersteps, 12),
        weights=weight_policy_from_args(args))
    engine = QueryEngine.build(artifact=live.chain(), policy=policy)
    old_version = engine.version
    cfg = ServeConfig(max_batch=4, max_wait_ms=10.0, cache_size=64,
                      trace_seed=args.seed)

    probe = ["e000", "e008"]     # 8 hops apart until the shortcut lands
    pool = [probe, ["e004", "g1"], ["e010", "g2"], ["e020", "g3"]]
    probe_weights: list = []
    failures: list = []
    stop = threading.Event()

    def client(i: int) -> None:
        while not stop.is_set():
            q = pool[i % len(pool)]
            try:
                srv = svc.query(list(q), k=1)
                if q is probe:
                    probe_weights.append(float(srv.result.weights[0]))
            except BaseException as exc:
                failures.append((q, exc))
                return

    with DKSService(engine, cfg) as svc:
        swapper = EngineSwapper(svc)
        swapper.wire_metrics()
        watcher = GraphWatcher(live, watch_dir, poll_s=0.05,
                               on_delta=swapper.on_delta).start()
        threads = [threading.Thread(target=client, args=(i,), daemon=True)
                   for i in range(4)]
        try:
            for t in threads:
                t.start()
            wait_for(lambda: svc.stats().requests >= 12, 120,
                     "pre-swap load")

            # Drop the fragment atomically; the watcher publishes the
            # delta and the swapper rebuilds + swaps off the dispatcher.
            frag_tmp = tmp / "frag.tsv.part"
            frag_tmp.write_text(
                "e000 g0\te008 g0\tshortcut\t1.0\n"
                "zzz fresh\te000 g0\tmentions\t0.9\n")
            import os
            os.replace(frag_tmp, watch_dir / "frag-0001.tsv")
            wait_for(lambda: swapper.swaps >= 1, 120, "the hot swap")
            wait_for(lambda: len(failures) > 0 or
                     svc.stats().requests >= 24, 120, "post-swap load")
        finally:
            stop.set()
            for t in threads:
                t.join(30)
            watcher.stop()

        assert not failures, f"requests failed across the swap: {failures}"
        chain = live.chain()
        assert chain.depth == 1
        assert svc.engine.version == f"artifact:{chain.content_hash}", \
            "serving engine is not on the chained version"
        assert svc.engine.version != old_version

        # Post-swap answers: the shortcut collapsed the probe, and the
        # delta-only keyword resolves.
        post = svc.query(list(probe), k=1)
        assert float(post.result.weights[0]) == 1.0, \
            f"post-swap probe weight {post.result.weights[0]} != 1.0"
        fresh = svc.query(["fresh", "g0"], k=1)
        assert float(fresh.result.weights[0]) == 1.0, \
            f"post-delta keyword probe weight {fresh.result.weights[0]}"
        bad = [w for w in probe_weights if w not in (8.0, 1.0)]
        assert not bad, (
            f"probe weights outside {{8.0, 1.0}}: {sorted(set(bad))} — "
            "a request saw a half-swapped graph")

        stats = svc.stats()
        assert stats.engine_swaps >= 1, stats.engine_swaps
        samples = parse_prometheus(svc.registry.render())
        assert samples["dks_engine_swaps_total"] == stats.engine_swaps
        assert samples["dks_delta_applied_total"] >= 1
        assert "dks_graph_staleness_seconds" in samples
        assert samples["dks_graph_staleness_seconds"] == 0.0, \
            "staleness gauge nonzero after the swap landed"

        ts = svc.tracer.stats()
        assert ts["begun"] == ts["finished"], (
            f"trace completeness broke across the swap: {ts}")
        swaps = [t for t in svc.recent_traces() if t.name == "dks.swap"]
        assert swaps, "no dks.swap trace recorded"
        span_names = [sp.name for sp in swaps[-1].spans]
        for want in ("build", "warm", "swap"):
            assert want in span_names, (
                f"span {want!r} missing from dks.swap: {span_names}")
        n_probe = len(probe_weights)
    print(f"swap smoke invariants hold: {stats.requests} requests, 0 "
          f"failures across {stats.engine_swaps} hot swap(s); probe "
          f"weight 8.0 -> 1.0 ({n_probe} probes, no mixed-build "
          f"answers); version {old_version[:21]}… -> "
          f"{svc.engine.version[:21]}…; traces complete "
          f"({ts['begun']} begun == finished), dks.swap spans "
          f"{span_names}; warmed {len(swapper.last_warmed)} hot shapes")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sec-rdfabout-cpu")
    ap.add_argument("--artifact", default=None,
                    help="serve from a repro.store artifact (mmap-load; "
                         "the artifact content hash keys the result "
                         "cache, so answers can never cross graph builds)")
    ap.add_argument("--live", default=None, metavar="DIR",
                    help="serve a repro.live.LiveDir's delta chain "
                         "(engine version = the chained hash)")
    ap.add_argument("--watch", default=None, metavar="WATCH_DIR",
                    help="with --live: tail this fragment directory "
                         "during the replay, hot-swapping the engine on "
                         "every published delta")
    ap.add_argument("--swap-mid-run", action="store_true",
                    help="append the swap-under-load smoke leg (live "
                         "ring graph, fragment dropped mid-run, hard "
                         "asserts on zero failures + build isolation)")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--unique", type=int, default=8,
                    help="distinct queries in the trace (repeats warm the "
                         "cache)")
    ap.add_argument("--k", type=int, default=1)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait-ms", type=float, default=25.0)
    ap.add_argument("--cache-size", type=int, default=256)
    ap.add_argument("--deadline-frac", type=float, default=0.25,
                    help="fraction of requests carrying a latency budget")
    ap.add_argument("--deadline-ms", type=float, default=75.0)
    ap.add_argument("--max-supersteps", type=int, default=24)
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--partition", default="single",
                    choices=["single", "sharded"])
    add_weight_policy_args(ap)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus /metrics, /healthz, and "
                         "/traces on this port for the run (0 = "
                         "ephemeral; --smoke scrapes it either way)")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="fraction of requests whose trace records spans "
                         "(deterministic per seed)")
    ap.add_argument("--trace-log", default=None,
                    help="append finished sampled traces to this path as "
                         "JSONL (the structured event log)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the direct-engine parity pass")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run + hard asserts on coalescing, "
                         "cache hits, answer parity, and the /metrics "
                         "scrape")
    args = ap.parse_args()

    if args.smoke:
        args.requests = min(args.requests, 20)
        args.unique = min(args.unique, 5)
        args.max_batch = min(args.max_batch, 4)
        args.max_wait_ms = 50.0
        args.max_supersteps = min(args.max_supersteps, 12)

    if args.watch is not None and args.live is None:
        ap.error("--watch needs --live DIR")

    t0 = time.time()
    policy = ExecutionPolicy(
        backend=args.backend, partition=args.partition,
        max_supersteps=args.max_supersteps,
        weights=weight_policy_from_args(args))
    live = None
    if args.live is not None:
        from repro.engine import QueryEngine
        from repro.live import LiveDir
        live = LiveDir(args.live)
        engine = QueryEngine.build(artifact=live.chain(), policy=policy)
        source = f"{live!r}"
    else:
        ds, engine = build_engine(args.dataset, policy,
                                  artifact=args.artifact)
        source = args.artifact if args.artifact else ds.name
    print(f"loaded {source}: V={engine.n_nodes:,} E_sym={engine.n_edges:,} "
          f"({time.time()-t0:.1f}s)")
    if not policy.weights.is_default:
        print(f"weight policy: {policy.weights}")

    trace = make_trace(
        engine.index, args.requests, unique=args.unique, k=args.k,
        deadline_frac=args.deadline_frac, deadline_ms=args.deadline_ms,
        seed=args.seed)
    cfg = ServeConfig(max_batch=args.max_batch,
                      max_wait_ms=args.max_wait_ms,
                      cache_size=args.cache_size,
                      trace_sample=args.trace_sample,
                      trace_log=args.trace_log,
                      trace_seed=args.seed)
    print(f"replaying {len(trace)} requests ({args.unique} unique) through "
          f"{args.clients} clients; max_batch={cfg.max_batch} "
          f"max_wait_ms={cfg.max_wait_ms:g}")

    # The smoke always scrapes its own endpoint (ephemeral port unless
    # one was asked for), so CI exercises the HTTP surface end to end.
    metrics_port = args.metrics_port
    if args.smoke and metrics_port is None:
        metrics_port = 0

    t0 = time.perf_counter()
    tree_check = None
    scraped = None
    with DKSService(engine, cfg) as svc:
        server = None
        watcher = None
        if args.watch is not None:
            from repro.live import EngineSwapper, GraphWatcher
            swapper = EngineSwapper(svc)
            swapper.wire_metrics()
            watcher = GraphWatcher(live, args.watch,
                                   on_delta=swapper.on_delta).start()
            print(f"watching {args.watch} for fragments (hot swap on "
                  f"every delta)")
        if metrics_port is not None:
            server = MetricsServer(svc.registry, tracer=svc.tracer,
                                   port=metrics_port).start()
            print(f"metrics: {server.url}/metrics")
        try:
            served = replay(svc, trace, n_clients=args.clients)
            if args.smoke:
                tree_check = verify_trees(svc, engine, trace,
                                          k=max(2, args.k))
                scraped = verify_metrics_scrape(svc, server)
                print(f"metrics scrape verified: {len(scraped)} samples "
                      f"parsed, counters match ServeStats")
            stats = svc.stats()
        finally:
            if server is not None:
                server.stop()
            if watcher is not None:
                watcher.stop()
    wall = time.perf_counter() - t0

    print(f"\n--- ServeStats ({wall:.2f}s wall) ---")
    print(stats.summary())
    split = latency_split(served)
    print(f"latency split  queue p95={split['queue_p95_ms']:.1f}ms over "
          f"{split['n_queue']} dispatched; device "
          f"p95={split['device_p95_ms']:.1f}ms")

    if not args.no_verify:
        n_exact, n_approx = verify_served(engine, trace, served)
        print(f"\nverified: {n_exact} exact answers match the direct "
              f"engine, {n_approx} approximate answers carry valid SPA "
              f"bounds")

    if args.smoke:
        assert stats.mean_batch_fill > 1.0, (
            f"no coalescing: mean batch-fill {stats.mean_batch_fill}")
        warm = stats.cache_hits + stats.single_flight_hits
        assert warm > 0, "repeated queries neither hit the cache nor " \
            "attached to an in-flight run"
        if args.deadline_frac > 0:
            # The trace's same-budget deadline bursts must have ridden a
            # shared lane driver: mean fill > 1 implies at least one
            # multi-lane deadline bucket (every dispatch serves >= 1).
            assert stats.deadline_dispatches > 0, "no deadline dispatches"
            assert stats.mean_deadline_fill > 1.0, (
                f"deadline requests never coalesced: fill "
                f"{stats.mean_deadline_fill} over "
                f"{stats.deadline_dispatches} dispatches")
            assert stats.deadline_driver_supersteps <= \
                stats.deadline_lane_supersteps, "driver stepped more " \
                "than its lanes billed — freeze accounting is broken"
        assert stats.tree_requests > 0, "smoke never requested trees"
        assert stats.tree_cache_hits > 0, \
            "warm tree request missed the tree-pool cache"
        kw, n_keys = tree_check
        print("smoke invariants hold: batch-fill > 1, "
              f"warm reuse > 0 ({stats.cache_hits} cache hits + "
              f"{stats.single_flight_hits} single-flight), "
              f"deadline fill {stats.mean_deadline_fill:.2f} over "
              f"{stats.deadline_dispatches} shared drivers "
              f"({stats.deadline_driver_supersteps} driver vs "
              f"{stats.deadline_lane_supersteps} lane supersteps); "
              f"trees: {n_keys} distinct covering trees for {kw}, "
              f"{stats.tree_cache_hits}/{stats.tree_requests} warm")

    if args.swap_mid_run:
        swap_smoke(args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
