"""Ingestion CLI: generate-or-read -> stream-ingest -> write artifact ->
mmap reopen -> verify roundtrip query parity.

    # synthetic LOD stand-in -> artifact
    python -m repro.launch.ingest --dataset sec-rdfabout-cpu \
        --out artifacts/sec-rdfabout-cpu

    # real dumps (N-Triples or TSV edge list, .gz transparently)
    python -m repro.launch.ingest --input dump.nt.gz \
        --out artifacts/dump

    # live graph: initialize once, then append fragments as deltas
    python -m repro.launch.ingest --input dump.nt.gz --live live/
    python -m repro.launch.ingest --live live/ --append edits-0042.nt
    python -m repro.launch.ingest --live live/ --compact

    # CI smoke: tiny graph, temp dir, hard asserts on parity + checksums
    # (includes the delta leg: base -> append -> chain parity vs union)
    python -m repro.launch.ingest --smoke

The verification pass builds TWO engines — one from the reopened mmapped
artifact, one from the in-memory graph — and asserts bit-identical query
weights/supersteps on auto-picked queries: the artifact roundtrip must be
invisible to the engine.  The written artifact is then the input for
``python -m repro.launch.dks_query --artifact ...`` and
``python -m repro.launch.serve_dks --artifact ...``.
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.configs import DKS_CONFIGS
from repro.engine import ExecutionPolicy, QueryEngine
from repro.graph.generators import lod_like_graph
from repro.graph.index import mid_df_tokens
from repro.store import (
    from_graph,
    ingest_ntriples,
    ingest_tsv,
    open_artifact,
    open_chain,
    write_artifact,
)


def pick_queries(index, n: int = 3, ms: tuple = (2, 3)) -> list[list]:
    """Auto-pick verification queries from the shared mid-df pool
    (:func:`repro.graph.index.mid_df_tokens` — the same pool the query
    CLI auto-picks from)."""
    mid = mid_df_tokens(index)
    queries = []
    for i in range(n):
        m = ms[i % len(ms)]
        step = max(1, len(mid) // (m * (i + 2)))
        q = mid[i::step][:m]
        if len(q) == m:
            queries.append(q)
    return queries


def verify_roundtrip(result, artifact, *, n_queries: int = 3,
                     max_supersteps: int = 16,
                     partition: str = "single") -> int:
    """Assert mmap-loaded artifact queries == in-memory build queries,
    bit-identical.  Returns the number of queries checked."""
    policy = ExecutionPolicy(max_supersteps=max_supersteps,
                             partition=partition,
                             n_shards=1 if partition == "sharded" else None)
    e_mem = QueryEngine.build(result.graph, index=result.index,
                              policy=policy)
    e_art = QueryEngine.build(artifact=artifact, policy=policy)
    assert e_art.graph_hash == artifact.content_hash
    queries = pick_queries(e_mem.index, n=n_queries)
    assert queries, "no usable verification queries in the vocabulary"
    for q in queries:
        r_mem = e_mem.query(q, k=2, extract=False)
        r_art = e_art.query(q, k=2, extract=False)
        np.testing.assert_array_equal(
            r_mem.weights, r_art.weights,
            err_msg=f"artifact parity broke for query {q!r}")
        assert r_mem.supersteps == r_art.supersteps, q
        assert r_mem.spa == r_art.spa and r_mem.spa_ratio == r_art.spa_ratio
    return len(queries)


def _typed_fixture_lines() -> list[str]:
    """A small typed N-Triples fixture: a ``knows`` backbone (so a
    predicate-filtered engine stays connected), ``cites``/``funds`` cross
    edges, and N-Quads-style numeric 4th terms on some statements (the
    reader's per-statement confidence convention)."""
    def uri(i: int) -> str:
        return f"<http://x.example/e{i}>"

    lines = []
    n = 24
    for i in range(n - 1):   # knows backbone, alternating confidences
        conf = " 0.9" if i % 2 else ""
        lines.append(f"{uri(i)} <http://p.example/knows> {uri(i+1)}{conf} .")
    for i in range(0, n - 6, 3):   # cites cross edges, explicit confidence
        lines.append(f"{uri(i)} <http://p.example/cites> {uri(i+6)} "
                     f"\"0.5\"^^<http://www.w3.org/2001/XMLSchema#double> .")
    for i in range(0, n - 9, 4):   # funds long-range edges, high confidence
        lines.append(f"{uri(i)} <http://p.example/funds> {uri(i+9)} 4 .")
    return lines


def typed_smoke(tmp: Path, *, max_supersteps: int = 16) -> None:
    """Smoke leg for the typed edge channel: ingest a confidence-annotated
    N-Triples fixture, persist + reopen the v2 artifact, and assert (a)
    the predicate dictionary survives into the manifest, (b) default and
    predicate-filtered queries are bit-identical between the in-memory
    build and the mmapped artifact engine, and (c) a filtered engine's
    rendered trees carry only allowed predicates."""
    from repro.answers import render_tree
    from repro.graph import WeightPolicy

    fixture = tmp / "typed-fixture.nt"
    fixture.write_text("\n".join(_typed_fixture_lines()) + "\n",
                       encoding="utf-8")
    result = ingest_ntriples(fixture)
    assert result.stats.n_predicates == 3, result.stats.n_predicates
    assert result.graph.typed

    out = tmp / "typed-artifact"
    artifact = write_artifact(out, result.graph, result.index,
                              tau=result.tau,
                              stats=result.stats.as_dict(),
                              names=result.names, overwrite=True)
    reopened = open_artifact(out, verify="full")
    assert reopened.format_version == 2, reopened.format_version
    assert reopened.typed
    assert set(reopened.predicates) == {"knows", "cites", "funds"}, \
        reopened.predicates

    queries = [["e3", "e7"], ["e2", "e10"], ["e1", "e5", "e9"]]
    policies = [
        ExecutionPolicy(max_supersteps=max_supersteps),
        ExecutionPolicy(max_supersteps=max_supersteps,
                        weights=WeightPolicy(predicates=("knows",))),
        ExecutionPolicy(max_supersteps=max_supersteps,
                        weights=WeightPolicy(kind="confidence", blend=1.0)),
    ]
    for policy in policies:
        e_mem = QueryEngine.build(result.graph, index=result.index,
                                  policy=policy)
        e_art = QueryEngine.build(artifact=reopened, policy=policy)
        for q in queries:
            r_mem = e_mem.query(q, k=2, extract=False)
            r_art = e_art.query(q, k=2, extract=False)
            np.testing.assert_array_equal(
                r_mem.weights, r_art.weights,
                err_msg=f"typed artifact parity broke for {q!r} "
                        f"under {policy.weights}")
            assert r_mem.supersteps == r_art.supersteps, (q, policy.weights)

    # Predicate-filtered end-to-end: every rendered edge of every answer
    # tree must carry an allowed predicate.
    filt = QueryEngine.build(
        artifact=reopened,
        policy=ExecutionPolicy(max_supersteps=max_supersteps,
                               weights=WeightPolicy(predicates=("knows",))))
    res = filt.query(["e3", "e7"], k=2)
    assert res.answers, "filtered query returned no answer trees"
    for a in res.answers:
        rt = render_tree(a, label_fn=filt.node_label, graph=filt.graph)
        for e in rt.edges:
            assert e.predicate == "knows", (
                f"filtered tree served a {e.predicate!r} edge: "
                f"{rt.describe()}")
    print(f"typed smoke invariants hold: {result.stats.n_predicates} "
          f"predicates persisted in a format-v{reopened.format_version} "
          f"artifact; default/filtered/confidence parity on "
          f"{len(queries)} queries; filtered trees carry only 'knows' "
          f"edges ({len(res.answers)} trees checked)")


def delta_smoke(tmp: Path, *, max_supersteps: int = 16) -> None:
    """Smoke leg for live graphs: initialize a live dir from the typed
    fixture, append TWO delta fragments (dictionary growth across
    deltas: the second references entities only the first introduced),
    and assert (a) the chain engine is bit-identical to a full union
    re-ingest, (b) a post-delta-only keyword resolves through the lazy
    chain index, (c) compaction reproduces the union artifact's
    ``content_hash`` exactly, and (d) a mis-stacked delta fails loudly,
    naming both hashes."""
    from repro.live import LiveDir
    from repro.store import ArtifactError, ChainIndex, LazyArtifactIndex

    base_lines = _typed_fixture_lines()
    frag1_lines = [
        f"<http://x.example/e{i}> <http://p.example/mentions> "
        f"<http://x.example/fresh{j}> 0.8 ."
        for j, i in enumerate((0, 5, 11))]
    frag2_lines = [   # fresh0 resolves to its delta-1 id; fresh3 is new
        "<http://x.example/fresh0> <http://p.example/knows> "
        "<http://x.example/fresh3> .",
        "<http://x.example/fresh3> <http://p.example/cites> "
        "<http://x.example/e2> 0.6 .",
    ]
    base_nt = tmp / "live-base.nt"
    base_nt.write_text("\n".join(base_lines) + "\n", encoding="utf-8")
    (tmp / "frag1.nt").write_text("\n".join(frag1_lines) + "\n",
                                  encoding="utf-8")
    (tmp / "frag2.nt").write_text("\n".join(frag2_lines) + "\n",
                                  encoding="utf-8")
    union_nt = tmp / "live-union.nt"
    union_nt.write_text(
        "\n".join(base_lines + frag1_lines + frag2_lines) + "\n",
        encoding="utf-8")

    live = LiveDir.initialize(tmp / "live-smoke", ingest_ntriples(base_nt))
    d1 = live.append([tmp / "frag1.nt"])
    d2 = live.append([tmp / "frag2.nt"])
    assert d1 is not None and d2 is not None
    assert d2.base_content_hash != d1.base_content_hash  # stacks on chain
    chain = live.chain()
    assert chain.depth == 2

    union = ingest_ntriples(union_nt)
    policy = ExecutionPolicy(max_supersteps=max_supersteps)
    e_chain = QueryEngine.build(artifact=chain, policy=policy)
    e_union = QueryEngine.build(union.graph, index=union.index,
                                policy=policy)
    queries = pick_queries(e_union.index) + [["fresh0", "e3"],
                                             ["fresh3", "e10"]]
    for q in queries:
        r_c = e_chain.query(q, k=2, extract=False)
        r_u = e_union.query(q, k=2, extract=False)
        np.testing.assert_array_equal(
            r_c.weights, r_u.weights,
            err_msg=f"chain/union parity broke for query {q!r}")
        assert r_c.supersteps == r_u.supersteps, q

    # Post-delta-only keywords resolve through the lazy chain index.
    assert isinstance(e_chain.index, ChainIndex)
    assert isinstance(e_chain.index.base_index, LazyArtifactIndex)
    assert e_chain.index.df("fresh3") == 1

    # Compaction == union re-ingest, down to the content hash.
    compacted = live.compact()
    union_art = write_artifact(tmp / "live-union-artifact", union.graph,
                               union.index, tau=union.tau,
                               stats=union.stats.as_dict(),
                               names=union.names)
    assert compacted.content_hash == union_art.content_hash, \
        "compacted chain is not bit-identical to the union re-ingest"

    # Mis-stacked chains fail loudly, naming both hashes.
    try:
        open_chain(live.path / "base-000000", d2.path)
    except ArtifactError as exc:
        assert "mis-stacked" in str(exc), exc
    else:
        raise AssertionError("mis-stacked chain opened without error")
    print(f"delta smoke invariants hold: 2 stacked deltas "
          f"(+V={d1.n_new_nodes + d2.n_new_nodes}, "
          f"+E={d1.n_new_edges + d2.n_new_edges}) bit-identical to the "
          f"union re-ingest on {len(queries)} queries; post-delta "
          f"keywords resolve lazily; compaction reproduced the union "
          f"content hash {union_art.content_hash[:12]}…; mis-stacking "
          f"rejected")


def main() -> int:
    ap = argparse.ArgumentParser()
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--dataset", default=None,
                     choices=sorted(DKS_CONFIGS),
                     help="synthetic LOD stand-in to generate+ingest "
                          "(default: sec-rdfabout-cpu)")
    src.add_argument("--input", default=None,
                     help="path to an N-Triples or TSV dump (.gz ok)")
    ap.add_argument("--format", default="auto",
                    choices=["auto", "ntriples", "tsv"],
                    help="--input format; auto sniffs the suffix")
    ap.add_argument("--out", default=None,
                    help="artifact directory to write (default: "
                         "experiments/artifacts/<name>)")
    ap.add_argument("--tau", type=int, default=1001,
                    help="hub cutoff for the degree weight model")
    ap.add_argument("--chunk-edges", type=int, default=1 << 20)
    ap.add_argument("--overwrite", action="store_true")
    ap.add_argument("--verify-queries", type=int, default=3,
                    help="roundtrip parity queries (0 skips verification)")
    ap.add_argument("--max-supersteps", type=int, default=16)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: tiny synthetic graph into a temp "
                         "dir, full-checksum reopen, hard parity asserts")
    ap.add_argument("--live", default=None, metavar="DIR",
                    help="live-graph directory: with --input, initialize "
                         "it; with --append/--compact, grow/fold it")
    ap.add_argument("--append", nargs="+", default=None, metavar="FRAG",
                    help="fragment files to fold into ONE delta on the "
                         "--live chain")
    ap.add_argument("--compact", action="store_true",
                    help="fold the --live chain into a fresh base "
                         "artifact")
    ap.add_argument("--gc", action="store_true",
                    help="after any --append/--compact, delete "
                         "base-*/delta-* directories CHAIN.json no "
                         "longer references")
    ap.add_argument("--gc-keep", type=int, default=1, metavar="N",
                    help="unreferenced directories to retain as an "
                         "in-flight-reader grace window (default 1; "
                         "0 deletes all)")
    args = ap.parse_args()

    if args.append or args.compact or args.gc:
        if args.live is None:
            ap.error("--append/--compact/--gc need --live DIR")
        return _live_update(args)

    tmp_ctx = None
    if args.smoke:
        tmp_ctx = tempfile.TemporaryDirectory(prefix="repro-ingest-smoke-")
        if args.out is None:
            args.out = str(Path(tmp_ctx.name) / "artifact")

    # ---- generate-or-read -> ingest ---------------------------------
    t0 = time.perf_counter()
    if args.input is not None:
        fmt = args.format
        if fmt == "auto":
            stem = args.input[:-3] if args.input.endswith(".gz") else \
                args.input
            fmt = "ntriples" if stem.endswith((".nt", ".ntriples")) else \
                "tsv"
        reader = ingest_ntriples if fmt == "ntriples" else ingest_tsv
        result = reader(args.input, tau=args.tau,
                        chunk_edges=args.chunk_edges)
        name = Path(args.input).name.split(".")[0]
    else:
        if args.smoke:
            n_nodes, n_edges, vocab, seed = 1500, 4500, 200, 11
            name = "smoke"
        else:
            ds = DKS_CONFIGS[args.dataset or "sec-rdfabout-cpu"]
            n_nodes, n_edges, vocab, seed = (ds.n_nodes, ds.n_edges,
                                             ds.vocab, ds.seed)
            name = ds.name
        g, tokens = lod_like_graph(n_nodes, n_edges, seed=seed,
                                   vocab=vocab, tau=args.tau)
        result = from_graph(g, tokens=tokens, tau=args.tau,
                            edges_requested=n_edges,
                            source=f"synthetic:{name}")
        result.stats.ingest_s = time.perf_counter() - t0
    st = result.stats
    print(f"ingested {st.source}: V={st.n_nodes:,} "
          f"E={st.edges_directed:,} directed "
          f"({st.edges_per_s:,.0f} edges/s"
          f"{f', {st.malformed_lines} malformed' if st.malformed_lines else ''}"
          f"{f', {st.self_loops_dropped} self-loops dropped' if st.self_loops_dropped else ''})")
    if st.edges_requested is not None:
        print(f"  requested {st.edges_requested:,} edges, produced "
              f"{st.edges_directed:,} (true counts)")

    # ---- live-dir initialization -------------------------------------
    if args.live is not None:
        from repro.live import LiveDir
        live = LiveDir.initialize(args.live, result,
                                  overwrite=args.overwrite)
        print(f"initialized {live}")
        if args.verify_queries > 0:
            n = verify_roundtrip(result, live.base(),
                                 n_queries=args.verify_queries,
                                 max_supersteps=args.max_supersteps)
            print(f"verified: {n} queries bit-identical between the live "
                  f"base artifact and the in-memory build")
        return 0

    # ---- write artifact (atomic) -------------------------------------
    out = Path(args.out or (Path("experiments") / "artifacts" / name))
    t0 = time.perf_counter()
    artifact = write_artifact(out, result.graph, result.index,
                              tau=result.tau, stats=st.as_dict(),
                              names=result.names,
                              overwrite=args.overwrite or args.smoke)
    t_write = time.perf_counter() - t0
    print(f"wrote {artifact} ({artifact.nbytes()/1e6:.1f} MB buffers, "
          f"{t_write:.2f}s)")

    # ---- reopen (mmap) + verify --------------------------------------
    t0 = time.perf_counter()
    reopened = open_artifact(out, verify="full" if args.smoke else "meta")
    t_open = time.perf_counter() - t0
    print(f"reopened with mmap in {t_open*1e3:.0f} ms "
          f"(content hash {reopened.content_hash[:12]}…)")

    if args.verify_queries > 0:
        n = verify_roundtrip(result, reopened,
                             n_queries=args.verify_queries,
                             max_supersteps=args.max_supersteps)
        print(f"verified: {n} queries bit-identical between the mmapped "
              f"artifact engine and the in-memory build")

    if args.smoke:
        assert st.edges_requested is None or st.edges_directed == \
            st.edges_requested, "generator undershot the requested edges"
        assert reopened.content_hash == artifact.content_hash
        print("ingest smoke invariants hold: checksum-verified reopen, "
              "query parity, true edge counts")
        typed_smoke(Path(tmp_ctx.name),
                    max_supersteps=args.max_supersteps)
        delta_smoke(Path(tmp_ctx.name),
                    max_supersteps=args.max_supersteps)
        tmp_ctx.cleanup()
    return 0


def _live_update(args) -> int:
    """``--live DIR --append frag…`` / ``--live DIR --compact``."""
    from repro.live import LiveDir

    live = LiveDir(args.live)
    if args.append:
        t0 = time.perf_counter()
        delta = live.append(args.append)
        dt = time.perf_counter() - t0
        if delta is None:
            print(f"no new statements in {len(args.append)} fragment(s) "
                  f"— marked consumed, nothing published")
        else:
            print(f"published {delta} in {dt:.2f}s")
            print(f"chain now: {live.chain()}")
    if args.compact:
        t0 = time.perf_counter()
        art = live.compact()
        dt = time.perf_counter() - t0
        print(f"compacted chain into {art} in {dt:.2f}s")
    if args.gc:
        deleted = live.gc(keep_last=args.gc_keep)
        if deleted:
            print(f"gc: deleted {len(deleted)} superseded "
                  f"director{'y' if len(deleted) == 1 else 'ies'}: "
                  f"{', '.join(deleted)}")
        else:
            print("gc: nothing to delete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
