"""Relationship-query driver (the paper's end-to-end flow, Fig. 2c):

index lookup -> keyword-node masks -> DKS supersteps (jitted while-loop)
-> aggregator-side answer-tree extraction.

``python -m repro.launch.dks_query --dataset bluk-bnb-cpu \
      --query 3,17,42 --k 2``
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import DKS_CONFIGS
from repro.core import DKSConfig, extract_answers, run_dks
from repro.core.spa import nu_lower_bound, spa_cover_dp, spa_ratio
from repro.graph.generators import lod_like_graph
from repro.graph.index import InvertedIndex


def load_dataset(name: str):
    ds = DKS_CONFIGS[name]
    g, tokens = lod_like_graph(ds.n_nodes, ds.n_edges, seed=ds.seed,
                               vocab=ds.vocab, tau=ds.tau)
    index = InvertedIndex.from_token_matrix(tokens)
    return ds, g, index


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sec-rdfabout-cpu",
                    choices=sorted(DKS_CONFIGS))
    ap.add_argument("--query", default=None,
                    help="comma-separated token ids (default: auto-pick)")
    ap.add_argument("--m", type=int, default=3,
                    help="number of keywords when auto-picking")
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--max-supersteps", type=int, default=32)
    ap.add_argument("--message-budget", type=float, default=float("inf"))
    ap.add_argument("--exit-mode", default="sound",
                    choices=["sound", "none"])
    args = ap.parse_args()

    t0 = time.time()
    ds, g, index = load_dataset(args.dataset)
    print(f"loaded {ds.name}: V={g.n_nodes:,} E_sym={g.n_edges_sym:,} "
          f"({time.time()-t0:.1f}s)")

    if args.query:
        query = [int(t) for t in args.query.split(",")]
    else:
        vocab = sorted(index.vocabulary(), key=index.df)
        mid = [t for t in vocab if 3 <= index.df(t) <= 200]
        query = mid[:: max(1, len(mid) // args.m)][: args.m]
    print("query tokens:", query, "df:", [index.df(t) for t in query])

    masks = index.keyword_masks(query, g.n_nodes)
    dg = g.to_device()
    if masks.shape[1] < dg.v_pad:
        masks = np.pad(masks, ((0, 0), (0, dg.v_pad - masks.shape[1])))
    cfg = DKSConfig(m=len(query), k=args.k,
                    max_supersteps=args.max_supersteps,
                    message_budget=args.message_budget,
                    exit_mode=args.exit_mode)
    t0 = time.time()
    state = run_dks(dg, jnp.asarray(masks), cfg)
    dt = time.time() - t0

    weights = np.asarray(state.topk_w)
    print(f"\nDKS finished in {int(state.step)} supersteps, {dt:.2f}s")
    print(f"messages: bfs={float(state.msgs_bfs):,.0f} "
          f"deep={float(state.msgs_deep):,.0f} "
          f"({100*(float(state.msgs_bfs)+float(state.msgs_deep))/max(dg.n_edges,1):.1f}% of |E|)")
    print(f"explored {100*float(jnp.mean(state.visited[:g.n_nodes])):.1f}% of nodes")
    if bool(state.budget_hit):
        nu = nu_lower_bound(state.g, dg.e_min(), cfg.m)
        spa = spa_cover_dp(state.s_front + dg.e_min(), cfg.m)
        print(f"budget hit: SPA-ratio={float(spa_ratio(state.topk_w[0], spa)):.3f}")

    print("\ntop answers (weights):", [w for w in weights if w < 1e8])
    answers = extract_answers(np.asarray(state.S), g, masks[:, : g.n_nodes],
                              k=args.k)
    for i, a in enumerate(answers):
        print(f"  #{i+1} weight={a.weight} root={a.root} "
              f"edges={list(a.edges)[:8]}{'...' if len(a.edges) > 8 else ''}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
