"""Relationship-query CLI (the paper's end-to-end flow, Fig. 2c), served by
:class:`repro.engine.QueryEngine`:

    engine = QueryEngine.build(graph, index=index, policy=policy)
    result = engine.query(tokens, k=k)      # ranked answer trees + stats

``python -m repro.launch.dks_query --dataset bluk-bnb-cpu \
      --query 3,17,42 --k 2``

``--stream`` prints per-superstep approximate answers with the paper's
early-termination bound (SPA ratio) instead of just the final result.

``--explain`` serves the query through a one-shot :class:`DKSService`
and prints the request's span tree (admit -> queue -> dispatch ->
extract, with durations) — the serving path's answer to "where did the
latency go".  ``--telemetry`` runs the fused executor with the
device-side per-superstep counters and prints the frontier/message
table (no host round-trips during the run — the counters ride the
while-loop carry; see :mod:`repro.obs.telemetry`).
"""

from __future__ import annotations

import argparse
import time

from repro import INF
from repro.configs import DKS_CONFIGS
from repro.engine import ExecutionPolicy, QueryEngine, WeightPolicy
from repro.graph.generators import lod_like_graph
from repro.graph.index import InvertedIndex, mid_df_tokens


def add_weight_policy_args(ap: argparse.ArgumentParser) -> None:
    """The shared --weight-policy / --blend / --predicate-filter flags
    (dks_query and serve_dks accept the same provenance-ranking knobs)."""
    ap.add_argument("--weight-policy", default="degree",
                    choices=["degree", "confidence"],
                    help="edge-weight semantics: 'degree' = the stored "
                         "(paper Sec. 7.1) weights; 'confidence' = blend "
                         "per-edge provenance into the length "
                         "(w / conf**blend) — needs a typed artifact")
    ap.add_argument("--blend", type=float, default=1.0,
                    help="confidence exponent for --weight-policy "
                         "confidence (higher = provenance bites harder)")
    ap.add_argument("--predicate-filter", default=None,
                    help="comma-separated predicate names to allow; edges "
                         "with any other predicate are disconnected (INF) "
                         "— needs a typed artifact")


def weight_policy_from_args(args) -> WeightPolicy:
    preds = None
    if args.predicate_filter:
        preds = tuple(p.strip() for p in args.predicate_filter.split(",")
                      if p.strip())
    return WeightPolicy(kind=args.weight_policy, blend=args.blend,
                        predicates=preds)


def load_dataset(name: str):
    ds = DKS_CONFIGS[name]
    g, tokens = lod_like_graph(ds.n_nodes, ds.n_edges, seed=ds.seed,
                               vocab=ds.vocab, tau=ds.tau)
    index = InvertedIndex.from_token_matrix(tokens)
    return ds, g, index


def build_engine(name: str, policy: ExecutionPolicy | None = None,
                 artifact: str | None = None):
    """Dataset name (or artifact path) -> (dataset config, ready engine).

    ``artifact``: path to a ``repro.store`` artifact — the graph and the
    persisted index mmap-load straight into the engine (seconds, no
    re-generation); ``name`` is then only used for the printed config.
    """
    if artifact is not None:
        from repro.store import open_artifact
        art = open_artifact(artifact)
        ds = DKS_CONFIGS.get(name)
        return ds, QueryEngine.build(artifact=art, policy=policy)
    ds, g, index = load_dataset(name)
    return ds, QueryEngine.build(g, index=index, policy=policy)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="sec-rdfabout-cpu",
                    choices=sorted(DKS_CONFIGS))
    ap.add_argument("--artifact", default=None,
                    help="path to a repro.store artifact: mmap-load the "
                         "graph + persisted index instead of generating "
                         "--dataset (python -m repro.launch.ingest writes "
                         "one)")
    ap.add_argument("--query", default=None,
                    help="comma-separated token ids (default: auto-pick)")
    ap.add_argument("--m", type=int, default=3,
                    help="number of keywords when auto-picking")
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--max-supersteps", type=int, default=32)
    ap.add_argument("--message-budget", type=float, default=float("inf"))
    ap.add_argument("--exit-mode", default="sound",
                    choices=["sound", "none"])
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--partition", default="single",
                    choices=["single", "sharded"],
                    help="sharded = frontier-compressed shard_map over the "
                         "local devices (runs on any jax via repro.shardmap)")
    add_weight_policy_args(ap)
    ap.add_argument("--stream", action="store_true",
                    help="print per-superstep answers with SPA bounds")
    ap.add_argument("--explain", action="store_true",
                    help="serve the query through a one-shot DKSService "
                         "and print its trace span tree with durations")
    ap.add_argument("--telemetry", action="store_true",
                    help="carry per-superstep counters in the fused "
                         "device loop and print the frontier/message "
                         "table (bit-identical answers)")
    ap.add_argument("--extract", action="store_true",
                    help="print label-rendered answer trees (entity "
                         "strings from the artifact's label blob when "
                         "--artifact is given; node:<id> otherwise) "
                         "instead of raw int ids")
    ap.add_argument("--parity", action="store_true",
                    help="with --backend pallas: build a jnp twin engine "
                         "and assert bit-identical top-K weights and "
                         "superstep count (the CI interpret-mode smoke)")
    args = ap.parse_args()
    if args.explain and args.stream:
        ap.error("--explain and --stream are mutually exclusive "
                 "(streaming runs outside the serving path)")
    if args.telemetry and args.stream:
        ap.error("--telemetry and --stream are mutually exclusive "
                 "(streaming is already per-superstep)")

    t0 = time.time()
    policy = ExecutionPolicy(
        backend=args.backend,
        partition=args.partition,
        exit_mode=args.exit_mode,
        max_supersteps=args.max_supersteps,
        message_budget=args.message_budget,
        weights=weight_policy_from_args(args),
        telemetry=args.telemetry,
    )
    ds, engine = build_engine(args.dataset, policy,
                              artifact=args.artifact)
    source = args.artifact if args.artifact else ds.name
    print(f"loaded {source}: V={engine.n_nodes:,} E_sym={engine.n_edges:,} "
          f"({time.time()-t0:.1f}s)")
    if not policy.weights.is_default:
        print(f"weight policy: {policy.weights}")

    index = engine.index
    if args.query:
        def parse_token(t: str):
            # Int ids for synthetic token-matrix vocabularies; fall back
            # to the literal string when only it is in the vocabulary
            # (ingested dumps index label text — including numeric
            # strings like SNAP node ids or year literals).
            if t.lstrip("-").isdigit():
                ti = int(t)
                if index.df(ti) == 0 and index.df(t) > 0:
                    return t
                return ti
            return t

        query = [parse_token(t) for t in args.query.split(",")]
    else:
        mid = mid_df_tokens(index)
        query = mid[:: max(1, len(mid) // args.m)][: args.m]
    print("query tokens:", query, "df:", [index.df(t) for t in query])

    if args.stream:
        def show(upd):
            best = "-" if upd.best_weight >= INF else f"{upd.best_weight:g}"
            ratio = ("inf" if upd.spa_ratio == float("inf")
                     else f"{upd.spa_ratio:.3f}")
            print(f"  step {upd.step:2d} frontier={upd.frontier:6d} "
                  f"best={best:>6} spa-ratio={ratio}"
                  f"{'  [exit]' if upd.done else ''}")

        res = engine.query_streamed(query, k=args.k, on_update=show)
    elif args.explain:
        # One-shot service: the query takes the REAL serving path
        # (admission, cache lookup, bucket dispatch, extraction), so the
        # printed span tree is the same anatomy production traces have.
        from repro.obs import render_span_tree
        from repro.serve import DKSService, ServeConfig
        with DKSService(engine, ServeConfig(
                max_batch=1, max_wait_ms=0.0)) as svc:
            served = svc.query(query, k=args.k)
            trace = svc.trace(served.trace_id)
        res = served.result
        print("\n--- request trace ---")
        print(render_span_tree(trace))
    else:
        res = engine.query(query, k=args.k)
    if res.telemetry is not None:
        tel = res.telemetry
        print(f"\n--- superstep telemetry ({tel.n_steps} steps"
              f"{', truncated' if tel.truncated else ''}) ---")
        print("  step  frontier  msgs_bfs     msgs_deep    frozen")
        for row in tel.rows():
            print(f"  {row['step']:4d}  {row['frontier']:8d}  "
                  f"{row['msgs_bfs']:11,.0f}  {row['msgs_deep']:11,.0f}  "
                  f"{int(tel.frozen[row['step'] - 1]):6d}")
    print(f"\nDKS finished in {res.supersteps} supersteps, "
          f"{res.wall_time_s:.2f}s")
    print(f"messages: bfs={res.msgs_bfs:,.0f} deep={res.msgs_deep:,.0f} "
          f"({100*res.msgs_total/max(engine.n_edges,1):.1f}% of |E|)")
    print(f"explored {100*res.explored_frac:.1f}% of nodes")
    if res.budget_hit:
        print(f"budget hit: SPA-ratio={res.spa_ratio:.3f}")
    elif res.capped:
        print(f"superstep cap hit: SPA-ratio={res.spa_ratio:.3f}")

    if args.parity:
        import dataclasses as _dc

        import numpy as np
        if args.backend != "pallas":
            ap.error("--parity needs --backend pallas (it builds the "
                     "jnp twin to compare against)")
        _, twin = build_engine(
            args.dataset, _dc.replace(policy, backend="jnp"),
            artifact=args.artifact)
        ref = twin.query(query, k=args.k)
        if not np.array_equal(np.asarray(res.weights),
                              np.asarray(ref.weights)):
            raise AssertionError(
                f"pallas/jnp weights diverged: {res.weights} "
                f"vs {ref.weights}")
        if res.supersteps != ref.supersteps:
            raise AssertionError(
                f"pallas/jnp superstep counts diverged: "
                f"{res.supersteps} vs {ref.supersteps}")
        print(f"\nparity: pallas == jnp bit-identical "
              f"(top-{args.k} weights, {res.supersteps} supersteps)")

    print("\ntop answers (weights):", [w for w in res.weights if w < 1e8])
    if args.extract:
        from repro.answers import render_tree
        if res.answers and res.answers_exhausted:
            print(f"(table holds fewer than k={args.k} distinct trees)")
        for i, a in enumerate(res.answers):
            rt = render_tree(a, label_fn=engine.node_label,
                             graph=engine.graph)
            print(f"  #{i+1} {rt.describe()}")
    else:
        for i, a in enumerate(res.answers):
            print(f"  #{i+1} weight={a.weight} root={a.root} "
                  f"edges={list(a.edges)[:8]}"
                  f"{'...' if len(a.edges) > 8 else ''}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
