"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Production loop with the full runnability stack wired in: mesh + sharded
state, prefetching data pipeline, per-step fault guard (retry + straggler
EMA), async checkpointing with crash-safe commit + auto-resume, optional
int8 gradient compression (``--compress``).

On this CPU container use ``--smoke`` (reduced config); on a pod the same
flags run the full architecture.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_arch
from repro.data import PrefetchIterator, lm_synthetic_stream, recsys_synthetic_stream
from repro.distributed.fault import StepGuard
from repro.launch.mesh import make_host_mesh, make_production_mesh, sharding_tree
from repro.models import gnn as gnn_lib
from repro.models import lm as lm_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_init, adamw_update


def train_lm(args) -> dict:
    entry = get_arch(args.arch)
    cfg = entry.config.smoke() if args.smoke else entry.config
    tp = 1 if args.smoke else 16
    b = tfm.build(cfg, tp=tp)
    key = jax.random.PRNGKey(args.seed)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20))
    state = lm_lib.init_train_state(key, b)
    step_fn = jax.jit(lm_lib.make_train_step(
        b, opt_cfg, attn_impl="naive" if args.smoke else "chunked",
        grad_accum=args.grad_accum), donate_argnums=0)

    ckpt = Checkpointer(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start = 0
    if ckpt is not None and ckpt.latest() is not None:
        state, start = ckpt.restore(state)
        print(f"resumed from step {start}")

    stream = PrefetchIterator(lm_synthetic_stream(
        cfg.vocab, args.batch, args.seq, seed=args.seed, skip=start))
    guard = StepGuard()
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(stream)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics, info = guard.run(step_fn, state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"t={info['step_time_s']*1e3:.0f}ms"
                  + (" [straggler]" if info["straggler"] else ""))
        if ckpt is not None and (step + 1) % args.ckpt_every == 0:
            ckpt.save(state, step + 1)
    if ckpt is not None:
        ckpt.save(state, args.steps)
        ckpt.wait()
    wall = time.time() - t0
    return {"first_loss": losses[0], "last_loss": losses[-1],
            "wall_s": wall, "guard_events": guard.events}


def train_recsys(args) -> dict:
    entry = get_arch(args.arch)
    cfg = entry.config.smoke() if args.smoke else entry.config
    key = jax.random.PRNGKey(args.seed)
    params = rec_lib.init_dcn(key, cfg)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20))
    opt = adamw_init(params)

    @jax.jit
    def step_fn(carry, batch):
        params, opt = carry
        loss, grads = jax.value_and_grad(
            lambda p: rec_lib.dcn_loss(p, batch, cfg))(params)
        params, opt, metrics = adamw_update(opt_cfg, grads, opt, params)
        return (params, opt), {"loss": loss, **metrics}

    stream = PrefetchIterator(
        recsys_synthetic_stream(cfg, args.batch, seed=args.seed))
    losses = []
    carry = (params, opt)
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        carry, metrics = step_fn(carry, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            print(f"step {step:5d} loss={losses[-1]:.4f}")
    return {"first_loss": losses[0], "last_loss": losses[-1]}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    family = get_arch(args.arch).family
    if family == "lm":
        out = train_lm(args)
    elif family == "recsys":
        out = train_recsys(args)
    else:
        raise SystemExit("use examples/gnn_train.py for GNN archs")
    print(out)
    ok = out["last_loss"] < out["first_loss"]
    print("TRAINING", "IMPROVED" if ok else "DID NOT IMPROVE")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
