"""Shared model utilities: sharding constraints, init, dtype policy."""

from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import shardmap

# Canonical logical axes: data-parallel dims ("pod","data"), tensor dim
# ("model").  constrain() drops axes missing from the ambient mesh, so the
# same model code runs on 1 CPU device, a 16x16 pod, or a 2x16x16 multi-pod.
DP = ("pod", "data")
TP = ("model",)
FSDP = ("pod", "data")


def _filter_axes(entry, mesh_axes: tuple[str, ...]):
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in mesh_axes else None
    kept = tuple(a for a in entry if a in mesh_axes)
    return kept if kept else None


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint against the ambient mesh; no-op when no mesh
    is installed (unit tests / single device); axes that are Manual in the
    current scope (e.g. "pod" inside the pipeline shard_map) are dropped
    from the spec (:func:`repro.shardmap.auto_axis_names`)."""
    am = shardmap.get_abstract_mesh()
    if am is None or not shardmap.constraints_supported_here():
        return x
    axes = shardmap.auto_axis_names(am)
    if not axes:
        return x
    clean = tuple(_filter_axes(s, axes) for s in spec)
    return jax.lax.with_sharding_constraint(x, P(*clean))


def mesh_axis_size(*names: str) -> int:
    """Product of the sizes of the given axes in the ambient mesh (1 if none)."""
    return shardmap.mesh_axis_size(shardmap.get_abstract_mesh(), *names)


def pad_to(x: int, multiple: int) -> int:
    return int(-(-x // multiple) * multiple)


def dense_init(key, shape: Sequence[int], dtype=jnp.bfloat16, scale: float | None = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def split_keys(key, names: Sequence[str]) -> dict[str, jax.Array]:
    keys = jax.random.split(key, len(names))
    return dict(zip(names, keys))


def param_count(params: Any) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def cast_tree(params: Any, dtype) -> Any:
    return jax.tree_util.tree_map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params,
    )
