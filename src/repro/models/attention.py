"""GQA attention: naive, chunked (flash-style online softmax in pure JAX,
used by the 512-device dry-run where Pallas cannot lower on the host
platform), and the Pallas flash kernel for real TPUs.

Layouts: q [B, Sq, Hq, Dh]; k/v [B, Skv, Hkv, Dh]; GQA groups G = Hq // Hkv.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.models.common import DP, TP, constrain

NEG_INF = -1e30


def rotary(x: jax.Array, positions: jax.Array, pct: float = 1.0,
           theta: float = 10000.0) -> jax.Array:
    """NeoX-style rotary embedding on the first ``pct`` of head dims.

    x: [B, S, H, Dh]; positions: [B, S] (absolute token positions).
    ``pct=0.5`` gives ChatGLM's 2d-RoPE (half the dims rotate).
    """
    dh = x.shape[-1]
    rot = int(dh * pct)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.concatenate([y1, y2, x_pass], axis=-1)
    return out.astype(x.dtype)


def _naive(q, k, v, causal: bool, q_offset) -> jax.Array:
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qr = q.reshape(b, sq, hkv, g, dh)
    scale = 1.0 / math.sqrt(dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)
        kpos = jnp.arange(skv)
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, dh)


def _chunked(q, k, v, causal: bool, q_offset, block: int,
             score_dtype=jnp.bfloat16) -> jax.Array:
    """Online-softmax over KV blocks: O(Sq·block) live memory, the same
    schedule the Pallas flash kernel implements on TPU.

    ``score_dtype``: the [.., Sq, block] score/probability tensors are the
    dominant HBM traffic of XLA attention (the Pallas kernel keeps them in
    VMEM; XLA materializes them).  bf16 scores with f32 running max/sum
    halve that traffic at ~4e-3 relative error (EXPERIMENTS.md §Perf it.2);
    pass jnp.float32 for the full-precision baseline."""
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    if skv % block:
        pad = block - skv % block
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kvalid = jnp.arange(skv + pad) < skv
    else:
        pad = 0
        kvalid = jnp.ones(skv, bool)
    skv_p = skv + pad
    nb = skv_p // block
    qr = q.reshape(b, sq, hkv, g, dh)
    scale = 1.0 / math.sqrt(dh)
    kb = k.reshape(b, nb, block, hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block, hkv, dh).transpose(1, 0, 2, 3, 4)
    kvalid_b = kvalid.reshape(nb, block)

    qpos = q_offset + jnp.arange(sq)

    neg_big = jnp.asarray(-3e38 if score_dtype == jnp.float32 else -3e38,
                          jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, valid, ib = xs
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qr, kblk,
                            preferred_element_type=score_dtype)
        logits = logits * jnp.asarray(scale, score_dtype)
        kpos = ib * block + jnp.arange(block)
        mask = valid[None, :]
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        else:
            mask = jnp.broadcast_to(mask, (sq, block))
        logits = jnp.where(mask[None, None, None],
                           logits, jnp.asarray(NEG_INF, score_dtype))
        # Running max/denominator stay f32; only the bulky [.., Sq, block]
        # tensors live in score_dtype.
        m_blk = jnp.max(logits, axis=-1).astype(jnp.float32)
        m_new = jnp.maximum(m, m_blk)
        p = jnp.exp((logits - m_new[..., None].astype(score_dtype)))
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk)
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (kb, vb, kvalid_b, jnp.arange(nb)),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)
    return out.astype(q.dtype)


def _bias_2d(sq, block, ib, kvalid_blk, causal, q_offset, dtype):
    """[Sq, block] additive mask (0 / -inf).  2D so the backward needs no
    broadcasted 6D pred residual (add transposes without a mask)."""
    kpos = ib * block + jnp.arange(block)
    mask = kvalid_blk[None, :]
    if causal:
        qpos = q_offset + jnp.arange(sq)
        mask = mask & (kpos[None, :] <= qpos[:, None])
    else:
        mask = jnp.broadcast_to(mask, (sq, block))
    return jnp.where(mask, 0.0, NEG_INF).astype(dtype)


def _flash_fwd_scan(q, k, v, causal, q_offset, block, score_dtype):
    """Forward online-softmax; returns (out f32, m, l) pre-normalization."""
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    pad = (-skv) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kvalid = jnp.arange(skv + pad) < skv
    nb = (skv + pad) // block
    qr = q.reshape(b, sq, hkv, g, dh)
    scale = 1.0 / math.sqrt(dh)
    kb = k.reshape(b, nb, block, hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, block, hkv, dh).transpose(1, 0, 2, 3, 4)
    kvalid_b = kvalid.reshape(nb, block)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, valid, ib = xs
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qr, kblk,
                            preferred_element_type=score_dtype)
        logits = logits * jnp.asarray(scale, score_dtype)
        bias = _bias_2d(sq, block, ib, valid, causal, q_offset, score_dtype)
        logits = logits + bias[None, None, None]
        m_blk = jnp.max(logits, axis=-1).astype(jnp.float32)
        m_new = jnp.maximum(m, m_blk)
        p = jnp.exp(logits - m_new[..., None].astype(score_dtype))
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk)
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (kb, vb, kvalid_b, jnp.arange(nb)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out, m, l  # out: [b, hkv, g, sq, dh] f32


def make_flash_jax(causal: bool, q_offset: int, block: int,
                   score_dtype=jnp.bfloat16):
    """Flash attention with a hand-written VJP (pure JAX).

    Autodiff of the chunked forward materializes f32 score cotangents and
    remat-replays the whole forward scan; this custom backward recomputes
    probabilities per block in ``score_dtype`` from (q, k, v, m, l) — the
    FlashAttention-2 backward — roughly halving attention HBM traffic in
    the compiled artifact (EXPERIMENTS.md §Perf it.3).
    """

    @jax.custom_vjp
    def flash(q, k, v):
        out, m, l = _flash_fwd_scan(q, k, v, causal, q_offset, block,
                                    score_dtype)
        b, hkv, g, sq, dh = out.shape
        return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hkv * g, dh
                                                    ).astype(q.dtype)

    def fwd(q, k, v):
        out, m, l = _flash_fwd_scan(q, k, v, causal, q_offset, block,
                                    score_dtype)
        b, hkv, g, sq, dh = out.shape
        o = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hkv * g, dh
                                                 ).astype(q.dtype)
        return o, (q, k, v, out, m, l)

    def bwd(res, d_o):
        q, k, v, out, m, l = res
        b, sq, hq, dh = q.shape
        _, skv, hkv, _ = k.shape
        g = hq // hkv
        scale = 1.0 / math.sqrt(dh)
        pad = (-skv) % block
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kvalid = jnp.arange(skv + pad) < skv
        nb = (skv + pad) // block
        qr = q.reshape(b, sq, hkv, g, dh)
        do = d_o.reshape(b, sq, hkv, g, dh).transpose(0, 2, 3, 1, 4)
        do = do.astype(jnp.float32)                     # [b,hkv,g,sq,dh]
        # delta = rowsum(dO * O); ``out`` in the residuals is already the
        # normalized output.
        delta = jnp.sum(do * out, axis=-1)              # [b,hkv,g,sq]
        kb = k.reshape(b, nb, block, hkv, dh).transpose(1, 0, 2, 3, 4)
        vb = v.reshape(b, nb, block, hkv, dh).transpose(1, 0, 2, 3, 4)
        kvalid_b = kvalid.reshape(nb, block)
        linv = 1.0 / jnp.maximum(l, 1e-30)

        def body(dq_acc, xs):
            kblk, vblk, valid, ib = xs
            logits = jnp.einsum("bqhgd,bkhd->bhgqk", qr, kblk,
                                preferred_element_type=score_dtype)
            logits = logits * jnp.asarray(scale, score_dtype)
            bias = _bias_2d(sq, block, ib, valid, causal, q_offset,
                            score_dtype)
            logits = logits + bias[None, None, None]
            p = jnp.exp(logits - m[..., None].astype(score_dtype))
            p = p * linv[..., None].astype(score_dtype)   # normalized probs
            do_c = do.astype(score_dtype)
            dv = jnp.einsum("bhgqk,bhgqd->bkhd", p, do_c,
                            preferred_element_type=jnp.float32
                            ).astype(v.dtype)
            # dp/ds stay in score_dtype: they are the other [.., Sq, block]
            # giants; the dq/dk reductions accumulate in f32 via the einsum
            # preferred type.
            dp = jnp.einsum("bhgqd,bkhd->bhgqk", do_c,
                            vblk.astype(score_dtype),
                            preferred_element_type=score_dtype)
            ds = p * (dp - delta[..., None].astype(score_dtype))
            ds = ds * jnp.asarray(scale, score_dtype)
            dq_blk = jnp.einsum("bhgqk,bkhd->bqhgd", ds, kblk,
                                preferred_element_type=jnp.float32)
            dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qr,
                            preferred_element_type=jnp.float32
                            ).astype(k.dtype)
            return dq_acc + dq_blk, (dk, dv)

        dq0 = jnp.zeros((b, sq, hkv, g, dh), jnp.float32)
        dq, (dks, dvs) = jax.lax.scan(body, dq0,
                                      (kb, vb, kvalid_b, jnp.arange(nb)))
        dq = dq.reshape(b, sq, hq, dh).astype(q.dtype)
        dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, skv + pad, hkv, dh)
        dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, skv + pad, hkv, dh)
        return dq, dk[:, :skv], dv[:, :skv]

    flash.defvjp(fwd, bwd)
    return flash


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, impl: str = "auto",
    q_offset: jax.Array | int = 0, block: int = 512,
    score_dtype=jnp.bfloat16,
) -> jax.Array:
    """Dispatch across attention implementations.

    impl="auto": decode (Sq small) -> naive einsum (linear in Skv, which is
    the flash-decoding layout XLA partitions across a sequence-sharded KV
    cache); long Sq -> chunked online-softmax; tiny -> naive.
    """
    sq, skv = q.shape[1], k.shape[1]
    if impl == "auto":
        if sq <= 16:
            impl = "naive"
        elif skv > 2048:
            impl = "chunked"
        else:
            impl = "naive"
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal,
                                      q_offset=q_offset, block=block)
    if impl == "flash_jax":
        fn = make_flash_jax(causal, int(q_offset), block, score_dtype)
        return fn(q, k, v)
    if impl == "chunked":
        return _chunked(q, k, v, causal, q_offset, block, score_dtype)
    if impl == "chunked_f32":
        return _chunked(q, k, v, causal, q_offset, block, jnp.float32)
    return _naive(q, k, v, causal, q_offset)
