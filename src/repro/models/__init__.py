"""Model zoo: LM transformers (dense + MoE), GNNs, recsys — pure-functional
JAX models with mesh-agnostic sharding constraints."""
