"""LM task heads: loss, train_step, prefill/decode serve steps.

These are the functions the dry-run lowers for every LM (arch x shape) cell
and the train/serve drivers execute for real.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import transformer as tfm
from repro.models.common import DP, TP, constrain
from repro.optim import AdamWConfig, OptState, adamw_init, adamw_update


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: OptState
    step: jax.Array


def init_train_state(key, b: tfm.BuiltLM) -> TrainState:
    params = tfm.init_params(key, b)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def chunked_ce(params, hidden, labels, b: tfm.BuiltLM,
               chunk: int = 512) -> jax.Array:
    """Cross entropy without materializing [B, S, vocab] logits.

    Scans over sequence chunks; each chunk's logits are rematerialized in
    the backward pass (jax.checkpoint), so live memory is O(B·chunk·vocab)
    instead of O(B·S·vocab) — the difference between 65 MB and 1 PB at
    command-r scale.
    """
    bsz, s, d = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    hc = hidden.reshape(bsz, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(bsz, nc, chunk).transpose(1, 0, 2)
    vocab_pad = jnp.arange(tfm_vocab_p(b)) >= b.cfg.vocab

    @jax.checkpoint
    def chunk_loss(carry, xs):
        h, lab = xs
        logits = tfm.unembed(params, h, b)
        logits = jnp.where(vocab_pad[None, None], -1e30, logits)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(chunk_loss, jnp.float32(0.0), (hc, lc))
    return total / (bsz * s)


def tfm_vocab_p(b: tfm.BuiltLM) -> int:
    return b.vocab_p


def lm_loss(params, batch, b: tfm.BuiltLM, attn_impl="auto",
            loss_chunk: int = 512):
    hidden, _, aux = tfm.forward(params, batch["tokens"], b,
                                 attn_impl=attn_impl)
    ce = chunked_ce(params, hidden, batch["labels"], b, chunk=loss_chunk)
    loss = ce
    if b.cfg.moe is not None:
        loss = (loss + b.cfg.moe.aux_loss_weight * aux["load_balance"]
                + b.cfg.moe.router_z_weight * aux["router_z"])
    return loss, {"ce": ce, **aux}


def make_train_step(b: tfm.BuiltLM, opt_cfg: AdamWConfig,
                    attn_impl: str = "auto", grad_accum: int = 1,
                    grad_transform=None):
    """Returns train_step(state, batch) -> (state, metrics).

    grad_accum > 1 splits the batch into microbatches accumulated in f32 —
    the standard activation-memory lever for the 100B+ dry-run cells.
    grad_transform(grads) -> grads optionally post-processes gradients
    (e.g. the int8 ring all-reduce in repro.distributed.compression).
    """

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: lm_loss(p, batch, b, attn_impl)[0])(params)

    def train_step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        params = state.params
        if grad_accum == 1:
            loss, grads = grads_of(params, batch)
        else:
            bsz = batch["tokens"].shape[0]
            mb = bsz // grad_accum
            resh = lambda x: x.reshape(grad_accum, mb, *x.shape[1:])
            micro = jax.tree_util.tree_map(resh, batch)

            def acc_body(carry, mb_batch):
                loss_acc, g_acc = carry
                loss, grads = grads_of(params, mb_batch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.float32(0.0), g0),
                                            micro)
            loss = loss / grad_accum
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, grads, state.opt, params)
        new_state = TrainState(params=new_params, opt=new_opt,
                               step=state.step + 1)
        return new_state, {"loss": loss, **metrics}

    return train_step


def make_prefill_step(b: tfm.BuiltLM, attn_impl: str = "auto"):
    """prefill(params, tokens) -> (logits_last, cache)."""

    def prefill(params, tokens):
        hidden, cache, _ = tfm.forward(params, tokens, b, return_cache=True,
                                       attn_impl=attn_impl)
        k, v = cache
        logits_last = tfm.unembed(params, hidden[:, -1], b)
        return logits_last, {"k": k, "v": v,
                             "pos": jnp.int32(tokens.shape[1])}

    return prefill


def make_decode_step(b: tfm.BuiltLM, attn_impl: str = "auto"):
    """serve_step(params, cache, tokens[B,1]) -> (next_token, cache)."""

    def decode(params, cache, tokens):
        logits, cache = tfm.decode_step(params, cache, tokens, b, attn_impl)
        # Greedy head (sampling lives in the serving driver).
        next_tok = jnp.argmax(logits[:, -1, : b.cfg.vocab], axis=-1)
        return next_tok.astype(jnp.int32)[:, None], cache

    return decode
