"""Quantized (int8) KV cache for long-context decode.

The decode_32k/long_500k cells are pure KV-streaming workloads; int8 halves
both the resident cache and the bytes-per-token read.  Symmetric per
(layer, batch, position, head) scales (KIVI-style per-token granularity);
attention dequantizes chunk-by-chunk inside an online-softmax scan so the
bf16 copy never materializes beyond one chunk.

On-TPU, the dequant fuses into the Pallas decode kernel; this module is the
XLA-measurable formulation.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import DP, TP, constrain


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x [..., Dh] -> (int8 [..., Dh], scale f32 [..., 1])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def init_cache_quant(b, batch: int, max_seq: int) -> dict:
    l = b.cfg.n_layers
    h, dh = b.n_kv_heads_p, b.cfg.head_dim
    return {
        "k_q": jnp.zeros((l, batch, max_seq, h, dh), jnp.int8),
        "k_s": jnp.zeros((l, batch, max_seq, h, 1), jnp.float32),
        "v_q": jnp.zeros((l, batch, max_seq, h, dh), jnp.int8),
        "v_s": jnp.zeros((l, batch, max_seq, h, 1), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_quant_specs(b, seq_axes=("model",)) -> dict:
    from jax.sharding import PartitionSpec as P
    sp = P(None, DP, seq_axes, None, None)
    return {"k_q": sp, "k_s": sp, "v_q": sp, "v_s": sp, "pos": P()}


def decode_attention_quant(q, k_q, k_s, v_q, v_s, pos, chunk: int = 2048):
    """One-token attention over an int8 cache, chunk-dequantized.

    q [B, 1, Hq, Dh]; k_q/v_q [B, S, Hkv, Dh] int8 (+ scales [B,S,Hkv,1]).
    Returns [B, 1, Hq, Dh].
    """
    bsz, _, hq, dh = q.shape
    _, s, hkv, _ = k_q.shape
    g = hq // hkv
    qr = q.reshape(bsz, hkv, g, dh)
    scale = 1.0 / math.sqrt(dh)
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)

    kc = k_q.reshape(bsz, nc, chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    ksc = k_s.reshape(bsz, nc, chunk, hkv, 1).transpose(1, 0, 2, 3, 4)
    vc = v_q.reshape(bsz, nc, chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    vsc = v_s.reshape(bsz, nc, chunk, hkv, 1).transpose(1, 0, 2, 3, 4)

    def body(carry, xs):
        m, l, acc = carry
        kq_blk, ks_blk, vq_blk, vs_blk, ic = xs
        k_blk = kq_blk.astype(jnp.bfloat16) * ks_blk.astype(jnp.bfloat16)
        logits = jnp.einsum("bhgd,bkhd->bhgk", qr, k_blk,
                            preferred_element_type=jnp.float32) * scale
        kpos = ic * chunk + jnp.arange(chunk)
        valid = kpos <= pos
        logits = jnp.where(valid[None, None, None], logits, -1e30)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        v_blk = vq_blk.astype(jnp.bfloat16) * vs_blk.astype(jnp.bfloat16)
        pv = jnp.einsum("bhgk,bkhd->bhgd", p.astype(jnp.bfloat16), v_blk,
                        preferred_element_type=jnp.float32)
        return (m_new, l_new, acc * corr[..., None] + pv), None

    m0 = jnp.full((bsz, hkv, g), -1e30, jnp.float32)
    l0 = jnp.zeros((bsz, hkv, g), jnp.float32)
    a0 = jnp.zeros((bsz, hkv, g, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kc, ksc, vc, vsc, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(bsz, 1, hq, dh).astype(q.dtype)
