"""DCN-v2 (arXiv:2008.13535): embedding tables + cross network + deep tower.

JAX has no native EmbeddingBag: :func:`embedding_bag` builds it from
``jnp.take`` + ``jax.ops.segment_sum`` (multi-hot fields), and single-hot
fields use plain row gathers.  Tables are row-sharded over "model"; the
lookup's cross-shard gather is the classic recsys all-to-all.

Serving paths: pointwise scoring (online p99 / offline bulk) and retrieval
(user tower vs. 1M candidate item vectors via sharded matmul).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RecsysConfig
from repro.models.common import DP, TP, constrain, dense_init, pad_to, split_keys


def embedding_bag(table: jax.Array, ids: jax.Array, weights: jax.Array | None,
                  mode: str = "sum", impl: str = "jnp") -> jax.Array:
    """EmbeddingBag: ids [B, nnz] (−1 = padding) -> [B, dim].

    Built from gather + segment-sum; ``impl="pallas"`` uses the TPU kernel.
    """
    if impl == "pallas":
        from repro.kernels.embedding_bag import ops as eb_ops
        return eb_ops.embedding_bag(table, ids, weights, mode=mode)
    b, nnz = ids.shape
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    rows = jnp.take(table, safe.reshape(-1), axis=0).reshape(b, nnz, -1)
    if weights is not None:
        rows = rows * weights[..., None]
    rows = jnp.where(valid[..., None], rows, 0.0)
    out = jnp.sum(rows, axis=1)
    if mode == "mean":
        out = out / jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
    return out


# Tables at or above this row count are sharded over "model"; smaller ones
# are replicated.  Sharded tables are row-padded to 512 (2-pod mesh size).
SHARD_VOCAB_MIN = 100_000


def _table_rows(vocab: int) -> int:
    if vocab >= SHARD_VOCAB_MIN:
        return pad_to(vocab, 512)
    return vocab


def init_dcn(key, cfg: RecsysConfig) -> dict:
    ks = split_keys(key, ["tables", "cross", "deep", "logit", "item"])
    d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    tkeys = jax.random.split(ks["tables"], cfg.n_sparse)
    tables = {
        f"table_{i}": dense_init(
            tk, (_table_rows(cfg.vocab_sizes[i]), cfg.embed_dim),
            jnp.float32, scale=0.02)
        for i, tk in enumerate(tkeys)
    }
    ckeys = jax.random.split(ks["cross"], cfg.n_cross_layers)
    cross = [{"w": dense_init(ck, (d0, d0), jnp.float32),
              "b": jnp.zeros((d0,), jnp.float32)} for ck in ckeys]
    dims = (d0,) + cfg.mlp_dims
    dkeys = jax.random.split(ks["deep"], len(cfg.mlp_dims))
    deep = [{"w": dense_init(dk, (dims[i], dims[i + 1]), jnp.float32),
             "b": jnp.zeros((dims[i + 1],), jnp.float32)}
            for i, dk in enumerate(dkeys)]
    logit_w = dense_init(ks["logit"], (d0 + cfg.mlp_dims[-1], 1), jnp.float32)
    # Item tower for retrieval: embed item id (table_0) -> mlp_dims[-1].
    item_w = dense_init(ks["item"], (cfg.embed_dim, cfg.mlp_dims[-1]),
                        jnp.float32)
    return {"tables": tables, "cross": cross, "deep": deep,
            "logit": logit_w, "item": item_w}


def param_specs(cfg: RecsysConfig) -> dict:
    tables = {
        f"table_{i}": (P(TP, None) if cfg.vocab_sizes[i] >= SHARD_VOCAB_MIN
                       else P(None, None))
        for i in range(cfg.n_sparse)
    }
    # Cross weights are [d0, d0] with d0 = 13 + 26*16 = 429 — not divisible
    # by the TP degree and tiny (<1 MB): replicate.
    cross = [{"w": P(None, None), "b": P(None)}] * cfg.n_cross_layers
    deep = [{"w": P(None, TP) if cfg.mlp_dims[i] % 16 == 0 else P(None, None),
             "b": P(None)} for i in range(len(cfg.mlp_dims))]
    return {"tables": tables, "cross": cross, "deep": deep,
            "logit": P(None, None), "item": P(None, TP)}


def _features(params, dense, sparse_ids, cfg: RecsysConfig) -> jax.Array:
    """dense [B, n_dense] f32; sparse_ids [B, n_sparse] i32 -> x0 [B, d0]."""
    embs = []
    for i in range(cfg.n_sparse):
        t = params["tables"][f"table_{i}"]
        ids = jnp.clip(sparse_ids[:, i], 0, t.shape[0] - 1)
        embs.append(jnp.take(t, ids, axis=0))
    x0 = jnp.concatenate([dense] + embs, axis=-1)
    return constrain(x0, DP, None)


def _cross_tower(params, x0):
    x = x0
    for lw in params["cross"]:
        x = x0 * (x @ lw["w"] + lw["b"]) + x
        x = constrain(x, DP, None)
    return x


def _deep_tower(params, x0):
    h = x0
    for lw in params["deep"]:
        h = jax.nn.relu(h @ lw["w"] + lw["b"])
        h = constrain(h, DP, None)
    return h


def dcn_forward(params: dict, dense: jax.Array, sparse_ids: jax.Array,
                cfg: RecsysConfig) -> jax.Array:
    """Pointwise CTR logit [B]."""
    x0 = _features(params, dense, sparse_ids, cfg)
    xc = _cross_tower(params, x0)
    xd = _deep_tower(params, x0)
    z = jnp.concatenate([xc, xd], axis=-1)
    return (z @ params["logit"])[:, 0]


def dcn_loss(params, batch, cfg: RecsysConfig) -> jax.Array:
    logits = dcn_forward(params, batch["dense"], batch["sparse"], cfg)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def user_vector(params, dense, sparse_ids, cfg: RecsysConfig) -> jax.Array:
    x0 = _features(params, dense, sparse_ids, cfg)
    return _deep_tower(params, x0)          # [B, mlp_dims[-1]]


def retrieval_scores(params, dense, sparse_ids, cand_ids, cfg: RecsysConfig,
                     top_k: int = 100) -> tuple[jax.Array, jax.Array]:
    """Score one query against n_candidates item ids; return top-k.

    cand_ids: i32[n_cand] into table_0; batched dot, never a loop.
    """
    u = user_vector(params, dense, sparse_ids, cfg)        # [B, Dv]
    t0 = params["tables"]["table_0"]
    cand_emb = jnp.take(t0, jnp.clip(cand_ids, 0, t0.shape[0] - 1), axis=0)
    item_vecs = cand_emb @ params["item"]                  # [n_cand, Dv]
    item_vecs = constrain(item_vecs, TP, None)
    scores = u @ item_vecs.T                               # [B, n_cand]
    scores = constrain(scores, DP, TP)
    return jax.lax.top_k(scores, top_k)
