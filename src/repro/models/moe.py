"""Mixture-of-Experts FFN (dbrx 16e top-4, granite 40e top-8).

Three dispatch regimes, chosen by token count and ambient mesh:

1. ``_moe_sharded`` (train/prefill on a mesh): the production path —
   shard_map with *local* top-k + cumsum ranking + local scatter into
   per-expert buffers, then ``all_to_all`` over the "model" (expert) axis,
   FSDP all-gather of expert weights, grouped einsum, reverse all_to_all,
   local combine.  This is the GShard/DeepSpeed schedule; a naive global
   scatter would make XLA replicate the dispatch buffers and all-reduce
   ~15 GiB per layer (measured — see EXPERIMENTS.md §Perf).
2. ``_moe_dense_all`` (decode on a mesh): token counts are tiny; computing
   every expert for every token and masking is cheaper than an all-to-all
   and partitions trivially (experts sharded over "model", psum combine).
3. ``_moe_local`` (no mesh / unit tests): plain cumsum+scatter on one
   device.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import shardmap
from repro.configs.base import MoESpec
from repro.models.common import dense_init, pad_to, split_keys


def init_moe(key, d_model: int, spec: MoESpec, e_pad: int, dtype) -> dict:
    ks = split_keys(key, ["router", "w_gate", "w_up", "w_down"])
    f = spec.d_ff_expert
    return {
        "router": dense_init(ks["router"], (d_model, e_pad), jnp.float32),
        "w_gate": dense_init(ks["w_gate"], (e_pad, d_model, f), dtype),
        "w_up": dense_init(ks["w_up"], (e_pad, d_model, f), dtype),
        "w_down": dense_init(ks["w_down"], (e_pad, f, d_model), dtype),
    }


def capacity(n_tokens: int, spec: MoESpec, e_pad: int) -> int:
    c = int(n_tokens * spec.top_k * spec.capacity_factor / e_pad) + 1
    return max(4, pad_to(c, 4))


def _route(router, x, spec: MoESpec, n_real: int, e_pad: int):
    """Shared router: returns (gate [T,k], ids [T,k], probs [T,E], logits)."""
    logits = x.astype(jnp.float32) @ router
    if n_real < e_pad:
        pad_mask = jnp.arange(e_pad) >= n_real
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, spec.top_k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    return gate, ids, probs, logits


def _aux(probs, ids, logits, e_pad, keep=None):
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(ids, e_pad, dtype=jnp.float32), axis=(0, 1))
    out = {
        "load_balance": jnp.sum(me * ce) * e_pad,
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    out["dropped_frac"] = (1.0 - jnp.mean(keep.astype(jnp.float32))
                           if keep is not None else jnp.float32(0.0))
    return out


def _dispatch_local(x, gate, ids, spec: MoESpec, e_pad: int, c: int):
    """cumsum-ranked capacity assignment; returns (buf [E,C,D], slot, keep,
    tok_of)."""
    t, d = x.shape
    k = spec.top_k
    flat_ids = ids.reshape(-1)
    oh = jax.nn.one_hot(flat_ids, e_pad, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh
    my_pos = jnp.take_along_axis(pos, flat_ids[:, None], axis=1)[:, 0]
    keep = my_pos < c
    slot = jnp.where(keep, flat_ids * c + my_pos, e_pad * c)
    tok_of = jnp.arange(t * k) // k
    x_rep = jnp.take(x, tok_of, axis=0)
    buf = jnp.zeros((e_pad * c, d), x.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], x_rep, 0), mode="drop")
    return buf.reshape(e_pad, c, d), slot, keep, tok_of


def _combine_local(y_buf, slot, keep, tok_of, gate, t: int):
    e_pad_c, d = y_buf.shape[0] * y_buf.shape[1], y_buf.shape[2]
    y_flat = y_buf.reshape(e_pad_c, d)
    y_rep = jnp.take(y_flat, jnp.minimum(slot, e_pad_c - 1), axis=0)
    y_rep = jnp.where(keep[:, None], y_rep, 0)
    y_rep = y_rep * gate.reshape(-1)[:, None].astype(y_rep.dtype)
    return jax.ops.segment_sum(y_rep, tok_of, num_segments=t)


def _expert_mlp(buf, wg, wu, wd):
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
         * jnp.einsum("ecd,edf->ecf", buf, wu))
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _moe_local(params, x, spec: MoESpec, n_real: int):
    t, d = x.shape
    e_pad = params["router"].shape[1]
    c = capacity(t, spec, e_pad)
    gate, ids, probs, logits = _route(params["router"], x, spec, n_real, e_pad)
    buf, slot, keep, tok_of = _dispatch_local(x, gate, ids, spec, e_pad, c)
    y_buf = _expert_mlp(buf, params["w_gate"], params["w_up"], params["w_down"])
    y = _combine_local(y_buf, slot, keep, tok_of, gate, t)
    return y.astype(x.dtype), _aux(probs, ids, logits, e_pad, keep)


def _moe_dense_all(params, x, spec: MoESpec, n_real: int):
    """Decode path: all experts for all tokens, masked combine (psum over
    the expert-sharded axis is derived by XLA SPMD)."""
    t, d = x.shape
    e_pad = params["router"].shape[1]
    gate, ids, probs, logits = _route(params["router"], x, spec, n_real, e_pad)
    # combine weights [T, E]
    w_te = jnp.zeros((t, e_pad), jnp.float32)
    w_te = jnp.sum(jax.nn.one_hot(ids, e_pad) * gate[..., None], axis=1)
    h = (jax.nn.silu(jnp.einsum("td,edf->tef", x, params["w_gate"]))
         * jnp.einsum("td,edf->tef", x, params["w_up"]))
    y_e = jnp.einsum("tef,efd->ted", h, params["w_down"])
    y = jnp.einsum("ted,te->td", y_e, w_te.astype(y_e.dtype))
    return y.astype(x.dtype), _aux(probs, ids, logits, e_pad)


def _moe_sharded(params, x3d, spec: MoESpec, n_real: int, am):
    """x3d: [B, S, D].  The shard_map boundary uses sequence parallelism —
    batch over (pod, data), sequence over "model" — so tokens split
    256/512-way for dispatch without any merged-axis resharding (a naive
    [B*S, D] boundary makes the backward cotangent reshard degenerate to a
    full global-activation all-gather; measured in EXPERIMENTS.md §Perf)."""
    mesh_axes = am.axis_names
    tp = tuple(a for a in ("model",) if a in mesh_axes)
    fsdp = tuple(a for a in ("pod", "data") if a in mesh_axes)
    e_pad = params["router"].shape[1]

    def block(router, wg, wu, wd, x_loc3):
        b_loc, s_loc, d = x_loc3.shape
        x_loc = x_loc3.reshape(b_loc * s_loc, d)
        t_loc = x_loc.shape[0]
        c_loc = capacity(t_loc, spec, e_pad)
        if fsdp:
            wg = jax.lax.all_gather(wg, fsdp, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp, axis=2, tiled=True)
        gate, ids, probs, logits = _route(router, x_loc, spec, n_real, e_pad)
        buf, slot, keep, tok_of = _dispatch_local(
            x_loc, gate, ids, spec, e_pad, c_loc)
        if tp:
            # MoE all-to-all: experts to their owners. [E, C, D] ->
            # [E/tp, C*tp, D]
            buf = jax.lax.all_to_all(buf, tp, split_axis=0, concat_axis=1,
                                     tiled=True)
        y_buf = _expert_mlp(buf, wg, wu, wd)
        if tp:
            y_buf = jax.lax.all_to_all(y_buf, tp, split_axis=1, concat_axis=0,
                                       tiled=True)
        y = _combine_local(y_buf, slot, keep, tok_of, gate, t_loc)
        aux = _aux(probs, ids, logits, e_pad, keep)
        aux = {k: jax.lax.pmean(v, fsdp + tp) for k, v in aux.items()}
        return (y.reshape(b_loc, s_loc, d).astype(x_loc3.dtype),
                aux["load_balance"], aux["router_z"], aux["dropped_frac"])

    in_specs = (
        P(None, None),                       # router (replicated)
        P(tp, fsdp, None),                   # w_gate [E, D, F]
        P(tp, fsdp, None),                   # w_up
        P(tp, None, fsdp),                   # w_down [E, F, D]
        P(fsdp, tp, None),                   # x [B, S, D] sequence-parallel
    )
    out_specs = (P(fsdp, tp, None), P(), P(), P())
    y, lb, rz, dropped = shardmap.shard_map(
        block, mesh=am, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"],
      x3d)
    return y, {"load_balance": lb, "router_z": rz, "dropped_frac": dropped}


def moe_ffn(params: dict, x: jax.Array, spec: MoESpec,
            n_experts_real: int) -> tuple[jax.Array, dict]:
    """x: [B, S, D] -> ([B, S, D], aux metrics)."""
    b, s, d = x.shape
    am = shardmap.get_abstract_mesh()
    if am is None:
        y, aux = _moe_local(params, x.reshape(b * s, d), spec, n_experts_real)
        return y.reshape(b, s, d), aux
    fsdp = math.prod(am.shape[a] for a in ("pod", "data")
                     if a in am.axis_names)
    tp = math.prod(am.shape[a] for a in ("model",) if a in am.axis_names)
    if b * s >= 4096 and b % fsdp == 0 and s % tp == 0:
        return _moe_sharded(params, x, spec, n_experts_real, am)
    y, aux = _moe_dense_all(params, x.reshape(b * s, d), spec, n_experts_real)
    return y.reshape(b, s, d), aux
