"""Decoder-only transformer (dense + MoE) with scan-over-layers + remat.

Covers the five assigned LM architectures: qwen1.5-4b (QKV bias, MHA),
chatglm3-6b (GQA kv=2, 2d/partial RoPE), command-r-plus-104b (GQA kv=8),
dbrx-132b (MoE 16e top-4), granite-moe-3b-a800m (MoE 40e top-8, head_dim 64).

Heads/vocab/experts are padded to the tensor-parallel degree at build time
(padded weights zero-initialized; padded vocab masked in the loss; padded
experts masked in routing) — the honest cost shows up in the
MODEL_FLOPS/HLO_FLOPs roofline ratio.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import shardmap
from repro.configs.base import LMConfig
from repro.models import moe as moe_lib
from repro.models.attention import attention, rotary
from repro.models.common import (
    DP, FSDP, TP, constrain, dense_init, pad_to, split_keys,
)


@dataclasses.dataclass(frozen=True)
class BuiltLM:
    """Config + mesh-dependent padded dimensions."""

    cfg: LMConfig
    tp: int
    n_heads_p: int
    n_kv_heads_p: int
    vocab_p: int
    e_pad: int  # padded experts (0 if dense)

    @property
    def kv_sharded(self) -> bool:
        return self.n_kv_heads_p % self.tp == 0 and self.n_kv_heads_p >= self.tp


def build(cfg: LMConfig, tp: int = 1) -> BuiltLM:
    n_heads_p = pad_to(cfg.n_heads, tp)
    # KV heads: shard when >= tp (pad up), replicate when smaller.
    n_kv_p = pad_to(cfg.n_kv_heads, tp) if cfg.n_kv_heads >= tp else cfg.n_kv_heads
    # Query grouping must divide padded kv heads evenly.
    while n_heads_p % n_kv_p:
        n_heads_p += tp if n_heads_p % tp == 0 else 1
    vocab_p = pad_to(cfg.vocab, tp)
    e_pad = pad_to(cfg.moe.n_experts, tp) if cfg.moe else 0
    return BuiltLM(cfg=cfg, tp=tp, n_heads_p=n_heads_p, n_kv_heads_p=n_kv_p,
                   vocab_p=vocab_p, e_pad=e_pad)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def init_params(key, b: BuiltLM) -> dict:
    cfg = b.cfg
    dtype = jnp.dtype(cfg.param_dtype)
    d, dh = cfg.d_model, cfg.head_dim
    l = cfg.n_layers
    ks = split_keys(key, ["embed", "head", "wq", "wk", "wv", "wo",
                          "ffn", "moe"])

    def zpad(arr, target, axis):
        pad = [(0, 0)] * arr.ndim
        pad[axis] = (0, target - arr.shape[axis])
        return jnp.pad(arr, pad)

    wq = dense_init(ks["wq"], (l, d, cfg.n_heads * dh), dtype)
    wq = zpad(wq, b.n_heads_p * dh, 2)
    wk = dense_init(ks["wk"], (l, d, cfg.n_kv_heads * dh), dtype)
    wk = zpad(wk, b.n_kv_heads_p * dh, 2)
    wv = dense_init(ks["wv"], (l, d, cfg.n_kv_heads * dh), dtype)
    wv = zpad(wv, b.n_kv_heads_p * dh, 2)
    wo = dense_init(ks["wo"], (l, cfg.n_heads * dh, d), dtype)
    wo = zpad(wo, b.n_heads_p * dh, 1)

    layers: dict[str, Any] = {
        "attn_norm": jnp.ones((l, d), dtype),
        "ffn_norm": jnp.ones((l, d), dtype),
        "wq": wq, "wk": wk, "wv": wv, "wo": wo,
    }
    if cfg.qkv_bias:
        layers["bq"] = jnp.zeros((l, b.n_heads_p * dh), dtype)
        layers["bk"] = jnp.zeros((l, b.n_kv_heads_p * dh), dtype)
        layers["bv"] = jnp.zeros((l, b.n_kv_heads_p * dh), dtype)
    if cfg.moe is not None:
        moe_keys = jax.random.split(ks["moe"], l)
        per_layer = [moe_lib.init_moe(mk, d, cfg.moe, b.e_pad, dtype)
                     for mk in moe_keys]
        layers["moe"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *per_layer)
    else:
        kg, ku, kd = jax.random.split(ks["ffn"], 3)
        layers["w_gate"] = dense_init(kg, (l, d, cfg.d_ff), dtype)
        layers["w_up"] = dense_init(ku, (l, d, cfg.d_ff), dtype)
        layers["w_down"] = dense_init(kd, (l, cfg.d_ff, d), dtype)

    params = {
        "embed": dense_init(ks["embed"], (b.vocab_p, d), dtype, scale=0.02),
        "final_norm": jnp.ones((d,), dtype),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(ks["head"], (d, b.vocab_p), dtype)
    return params


def param_specs(b: BuiltLM) -> dict:
    """PartitionSpecs (FSDP over data axes x TP over model) per parameter."""
    cfg = b.cfg
    specs: dict[str, Any] = {
        "embed": P(TP, FSDP),
        "final_norm": P(None),
        "layers": {
            "attn_norm": P(None, None),
            "ffn_norm": P(None, None),
            "wq": P(None, FSDP, TP),
            "wk": P(None, FSDP, TP if b.kv_sharded else None),
            "wv": P(None, FSDP, TP if b.kv_sharded else None),
            "wo": P(None, TP, FSDP),
        },
    }
    if cfg.qkv_bias:
        specs["layers"]["bq"] = P(None, TP)
        specs["layers"]["bk"] = P(None, TP if b.kv_sharded else None)
        specs["layers"]["bv"] = P(None, TP if b.kv_sharded else None)
    if cfg.moe is not None:
        specs["layers"]["moe"] = {
            "router": P(None, None, None),
            "w_gate": P(None, TP, FSDP, None),
            "w_up": P(None, TP, FSDP, None),
            "w_down": P(None, TP, None, FSDP),
        }
    else:
        specs["layers"]["w_gate"] = P(None, FSDP, TP)
        specs["layers"]["w_up"] = P(None, FSDP, TP)
        specs["layers"]["w_down"] = P(None, TP, FSDP)
    if not cfg.tie_embeddings:
        specs["head"] = P(FSDP, TP)
    return specs


def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------


def _attn_block(x, lw, b: BuiltLM, positions, cache_kv=None, cache_pos=None,
                attn_impl: str = "auto"):
    """Returns (attn_out, (new_k, new_v)); cache_kv is (k_cache, v_cache)
    for decode (k_cache: [B, Smax, Hkv, Dh])."""
    cfg = b.cfg
    bsz, s, d = x.shape
    dh = cfg.head_dim
    q = x @ lw["wq"]
    k = x @ lw["wk"]
    v = x @ lw["wv"]
    if cfg.qkv_bias:
        q = q + lw["bq"]
        k = k + lw["bk"]
        v = v + lw["bv"]
    q = q.reshape(bsz, s, b.n_heads_p, dh)
    k = k.reshape(bsz, s, b.n_kv_heads_p, dh)
    v = v.reshape(bsz, s, b.n_kv_heads_p, dh)
    q = constrain(q, DP, None, TP, None)
    kv_tp = TP if b.kv_sharded else None
    k = constrain(k, DP, None, kv_tp, None)
    v = constrain(v, DP, None, kv_tp, None)
    q = rotary(q, positions, cfg.rotary_pct, cfg.rope_theta)
    k = rotary(k, positions, cfg.rotary_pct, cfg.rope_theta)

    if cache_kv is not None:
        k_cache, v_cache = cache_kv
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, cache_pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, cache_pos, 0, 0))
        # Mask beyond current position via q_offset causal masking.
        out = attention(q, k_cache, v_cache, causal=True,
                        q_offset=cache_pos, impl=attn_impl)
        new_kv = (k_cache, v_cache)
    else:
        out = attention(q, k, v, causal=True, q_offset=0, impl=attn_impl)
        new_kv = (k, v)
    out = constrain(out, DP, None, TP, None)
    out = out.reshape(bsz, s, b.n_heads_p * dh) @ lw["wo"]
    return constrain(out, DP, None, None), new_kv


def _ffn_block(x, lw, b: BuiltLM):
    cfg = b.cfg
    if cfg.moe is not None:
        return moe_lib.moe_ffn(lw["moe"], x, cfg.moe, cfg.moe.n_experts)
    h = jax.nn.silu(x @ lw["w_gate"]) * (x @ lw["w_up"])
    h = constrain(h, DP, None, TP)
    return h @ lw["w_down"], {}


def _layer(x, lw, b: BuiltLM, positions, cache_kv=None, cache_pos=None,
           attn_impl="auto"):
    cfg = b.cfg
    # Sequence parallelism on the residual stream: the carry (and therefore
    # the remat-saved layer input) is sharded over "model" along the
    # sequence axis — without this, a microbatch with B_loc=1 stacks
    # [L, 1, S, D] activations that can shard over nothing (measured
    # 6.4 GiB/chip on command-r; EXPERIMENTS.md §Perf B6).  Attention/FFN
    # entry norms gather the sequence; outputs reduce-scatter back via the
    # residual add (Megatron-SP schedule, derived by SPMD from the
    # constraints).
    seq_sp = x.shape[1] % max(1, _tp_size()) == 0 and x.shape[1] > 1
    sp = (DP, TP, None) if seq_sp else (DP, None, None)
    h = rms_norm(x, lw["attn_norm"], cfg.norm_eps)
    h = constrain(h, DP, None, None)
    attn_out, new_kv = _attn_block(h, lw, b, positions, cache_kv, cache_pos,
                                   attn_impl)
    x = x + attn_out
    x = constrain(x, *sp)
    h = rms_norm(x, lw["ffn_norm"], cfg.norm_eps)
    h = constrain(h, DP, None, None)
    ffn_out, aux = _ffn_block(h, lw, b)
    x = x + ffn_out
    x = constrain(x, *sp)
    return x, new_kv, aux


def _tp_size() -> int:
    am = shardmap.get_abstract_mesh()
    if am is None or "model" not in am.axis_names:
        return 1
    return am.shape["model"]


def forward(params: dict, tokens: jax.Array, b: BuiltLM, *,
            positions: jax.Array | None = None,
            return_cache: bool = False,
            attn_impl: str = "auto") -> tuple[jax.Array, Any, dict]:
    """Train/prefill forward. tokens [B, S] -> final hidden [B, S, D].

    Returns (hidden, cache | None, aux) where cache = (k [L,B,S,H,Dh], v).
    Logits are *not* materialized here: at 256k vocab x 1M tokens that
    tensor is petabyte-scale — use :func:`unembed` (last position) or the
    chunked CE in lm.py.
    """
    cfg = b.cfg
    bsz, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (bsz, s))
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, DP, None, None)

    def body(x, lw):
        x, new_kv, aux = _layer(x, lw, b, positions, attn_impl=attn_impl)
        ys = (new_kv if return_cache else None,
              aux.get("load_balance", jnp.float32(0.0)),
              aux.get("router_z", jnp.float32(0.0)))
        return x, ys

    scan_body = jax.checkpoint(body) if cfg.remat else body
    x, (cache, lb, rz) = jax.lax.scan(scan_body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    aux = {"load_balance": jnp.mean(lb), "router_z": jnp.mean(rz)}
    return x, cache, aux


def unembed(params: dict, x: jax.Array, b: BuiltLM) -> jax.Array:
    """hidden [..., D] -> f32 logits [..., vocab_p]."""
    head = params["embed"].T if b.cfg.tie_embeddings else params["head"]
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    return constrain(logits, DP, None, TP) if logits.ndim == 3 else logits


def init_cache(b: BuiltLM, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> dict:
    shape = (b.cfg.n_layers, batch, max_seq, b.n_kv_heads_p, b.cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


def cache_specs(b: BuiltLM, decode_seq_shard: bool = True) -> dict:
    """KV cache shardings: decode shapes shard the sequence axis over
    "model" (flash-decoding layout) since kv heads are few."""
    seq = TP if decode_seq_shard else None
    kv_heads = None if decode_seq_shard else (TP if b.kv_sharded else None)
    sp = P(None, DP, seq, kv_heads, None)
    return {"k": sp, "v": sp, "pos": P()}


def decode_step_quant(params: dict, cache: dict, tokens: jax.Array,
                      b: BuiltLM, chunk: int = 2048) -> tuple[jax.Array, dict]:
    """One-token decode against an int8 KV cache (kvcache.py)."""
    from repro.models import kvcache

    cfg = b.cfg
    bsz = tokens.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (bsz, 1))
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, DP, None, None)

    def body(x, xs):
        lw, k_q, k_s, v_q, v_s = xs
        h = rms_norm(x, lw["attn_norm"], cfg.norm_eps)
        q = h @ lw["wq"]
        k = h @ lw["wk"]
        v = h @ lw["wv"]
        if cfg.qkv_bias:
            q, k, v = q + lw["bq"], k + lw["bk"], v + lw["bv"]
        dh = cfg.head_dim
        q = q.reshape(bsz, 1, b.n_heads_p, dh)
        k = k.reshape(bsz, 1, b.n_kv_heads_p, dh)
        v = v.reshape(bsz, 1, b.n_kv_heads_p, dh)
        q = rotary(q, positions, cfg.rotary_pct, cfg.rope_theta)
        k = rotary(k, positions, cfg.rotary_pct, cfg.rope_theta)
        kq, ks = kvcache.quantize_kv(k)
        vq, vs = kvcache.quantize_kv(v)
        k_q = jax.lax.dynamic_update_slice(k_q, kq, (0, pos, 0, 0))
        k_s = jax.lax.dynamic_update_slice(k_s, ks, (0, pos, 0, 0))
        v_q = jax.lax.dynamic_update_slice(v_q, vq, (0, pos, 0, 0))
        v_s = jax.lax.dynamic_update_slice(v_s, vs, (0, pos, 0, 0))
        attn = kvcache.decode_attention_quant(q, k_q, k_s, v_q, v_s, pos,
                                              chunk=chunk)
        attn = attn.reshape(bsz, 1, b.n_heads_p * dh) @ lw["wo"]
        x = x + attn
        h2 = rms_norm(x, lw["ffn_norm"], cfg.norm_eps)
        ffn_out, _ = _ffn_block(h2, lw, b)
        return x + ffn_out, (k_q, k_s, v_q, v_s)

    x, (k_q, k_s, v_q, v_s) = jax.lax.scan(
        body, x, (params["layers"], cache["k_q"], cache["k_s"],
                  cache["v_q"], cache["v_s"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, b)
    return logits, {"k_q": k_q, "k_s": k_s, "v_q": v_q, "v_s": v_s,
                    "pos": pos + 1}


def decode_step(params: dict, cache: dict, tokens: jax.Array, b: BuiltLM,
                attn_impl: str = "auto") -> tuple[jax.Array, dict]:
    """One-token decode: tokens [B, 1] + cache -> (logits [B, 1, V], cache)."""
    cfg = b.cfg
    bsz = tokens.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos[None, None], (bsz, 1))
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, DP, None, None)

    def body(x, xs):
        lw, k_c, v_c = xs
        x, (k_c, v_c), _ = _layer(x, lw, b, positions, cache_kv=(k_c, v_c),
                                  cache_pos=pos, attn_impl=attn_impl)
        return x, (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x, b)
    new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}
    return logits, new_cache
