"""Inter-pod pipeline parallelism (GPipe schedule over the "pod" axis).

The 2-pod mesh's cross-pod hop is the scarcest link (DCI, not ICI).  PP
sends ONE activation tensor per microbatch per boundary instead of
FSDP/TP traffic for every layer — the right parallelism for the slow axis.

Implementation: shard_map (via :mod:`repro.shardmap`) manual over *only*
`"pod"` (data/model
axes stay auto, so each stage's layer math keeps its TP/FSDP shardings).
Layers are stage-sharded at rest (`P("pod", ...)` on the stacked layer
axis); microbatches stream through a `lax.scan` of length
`n_micro + n_stages - 1`, with `ppermute` shifting activations to the next
stage each tick.  The schedule is differentiable (scan + ppermute
transpose), so `jax.grad` through it gives GPipe training; per-stage
bodies are `jax.checkpoint`ed.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import shardmap
from repro.models import transformer as tfm
from repro.models.common import constrain


def stage_layer_specs(b: tfm.BuiltLM) -> Any:
    """Param specs for PP: stacked layer axis sharded over "pod" (stages
    at rest), FSDP restricted to "data" (the pod axis is the pipe)."""
    specs = tfm.param_specs(b)

    def repl_pod(spec: P) -> P:
        def fix(entry):
            if isinstance(entry, tuple):
                kept = tuple(a for a in entry if a != "pod")
                return kept if kept else None
            return entry
        parts = [fix(e) for e in spec]
        parts[0] = "pod"   # layer-stack axis -> stage-sharded
        return P(*parts)

    specs["layers"] = jax.tree_util.tree_map(
        repl_pod, specs["layers"], is_leaf=lambda x: isinstance(x, P))

    def drop_pod(spec: P) -> P:
        def fix(entry):
            if isinstance(entry, tuple):
                kept = tuple(a for a in entry if a != "pod")
                return kept if kept else None
            return entry
        return P(*(fix(e) for e in spec))

    for k in ("embed", "head", "final_norm"):
        if k in specs:
            specs[k] = drop_pod(specs[k])
    return specs


def pp_hidden_forward(params: dict, tokens: jax.Array, b: tfm.BuiltLM, *,
                      n_stages: int, n_micro: int,
                      attn_impl: str = "flash_jax") -> jax.Array:
    """Pipelined forward to final hidden states [B, S, D]."""
    cfg = b.cfg
    assert cfg.n_layers % n_stages == 0
    lps = cfg.n_layers // n_stages
    bsz, s = tokens.shape
    assert bsz % n_micro == 0
    mb = bsz // n_micro
    positions = jnp.broadcast_to(jnp.arange(s)[None], (mb, s))

    x = jnp.take(params["embed"], tokens, axis=0)
    x_mb = x.reshape(n_micro, mb, s, cfg.d_model)

    layers_st = jax.tree_util.tree_map(
        lambda a: a.reshape(n_stages, lps, *a.shape[1:]), params["layers"])

    def stage_fn(stage_layers, h):
        def body(h, lw):
            h, _, _ = tfm._layer(h, lw, b, positions, attn_impl=attn_impl)
            return h, None
        h, _ = jax.lax.scan(jax.checkpoint(body), h, stage_layers)
        return h

    n_ticks = n_micro + n_stages - 1
    assert n_micro % n_stages == 0

    def block(layers_loc, x_stream):
        # x_stream: [1, n_micro/n_stages, mb, S, D] — microbatch t lives on
        # pod t % n_stages, local slot t // n_stages.
        stage = jax.lax.axis_index("pod")
        layers_loc = jax.tree_util.tree_map(lambda a: a[0], layers_loc)
        x_stream = x_stream[0]
        shift = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        h = jnp.zeros((mb, s, cfg.d_model), x_stream.dtype)
        collected = []
        # Ticks are UNROLLED: per-tick permutes are static, the microbatch
        # stream stays pod-sharded, and no transpose needs a pod-psum —
        # the three things the XLA partial-manual partitioner chokes on
        # with the scan-based formulation ("invalid binary opcode copy").
        for t in range(n_ticks):
            if t < n_micro:
                owner = t % n_stages
                inj = x_stream[t // n_stages]
                if owner != 0:
                    inj = jax.lax.ppermute(inj, "pod", [(owner, 0)])
                m_inj = (stage == 0).astype(h.dtype)
                h = inj.astype(h.dtype) * m_inj + h * (1 - m_inj)
            h = stage_fn(layers_loc, h)
            h = constrain(h, ("data",), None, None)
            if t >= n_stages - 1:
                # Completed microbatch: park it on its owner pod (zero on
                # the others) so outputs stay pod-sharded.
                oidx = t - (n_stages - 1)
                dest = oidx % n_stages
                out_t = h
                if n_stages - 1 != dest:
                    out_t = jax.lax.ppermute(out_t, "pod",
                                             [(n_stages - 1, dest)])
                m_out = (stage == dest).astype(h.dtype)
                collected.append(out_t * m_out)
            if t < n_ticks - 1:
                h = jax.lax.ppermute(h, "pod", shift)
        # collected[oidx] is nonzero only on pod oidx%n_stages: summing each
        # local group of n_stages entries collapses, per pod, to exactly its
        # own microbatch -> local slot j holds microbatch j*n_stages+stage.
        local = [sum(collected[j * n_stages:(j + 1) * n_stages])
                 for j in range(n_micro // n_stages)]
        return jnp.stack(local, axis=0)[None]  # [1, n_micro/ns, mb, S, D]

    am = shardmap.get_abstract_mesh()
    x_sharded = jax.lax.with_sharding_constraint(
        x_mb.reshape(n_micro // n_stages, n_stages, mb, s, cfg.d_model)
        .swapaxes(0, 1), P("pod"))
    # x_sharded: [n_stages, n_micro/n_stages, mb, S, D]; row p = microbatches
    # with t % n_stages == p.
    outs = shardmap.shard_map(
        block, mesh=am,
        in_specs=(jax.tree_util.tree_map(
            lambda _: P("pod"), layers_st,
            is_leaf=lambda v: hasattr(v, "shape")), P("pod")),
        out_specs=P("pod"),
        axis_names={"pod"}, check_vma=False,
    )(layers_st, x_sharded)

    # outs: [n_stages, n_micro/ns, mb, S, D] with [p, j] = microbatch
    # j*n_stages + p; invert the input reordering.
    hidden = outs.swapaxes(0, 1).reshape(bsz, s, cfg.d_model)
    return tfm.rms_norm(hidden, params["final_norm"], cfg.norm_eps)


def make_pp_train_step(b: tfm.BuiltLM, opt_cfg, *, n_stages: int,
                       n_micro: int, attn_impl: str = "flash_jax"):
    """GPipe train step: grads via autodiff through the pipeline scan."""
    from repro.models import lm as lm_lib
    from repro.optim import adamw_update

    def loss_fn(params, batch):
        hidden = pp_hidden_forward(params, batch["tokens"], b,
                                   n_stages=n_stages, n_micro=n_micro,
                                   attn_impl=attn_impl)
        return lm_lib.chunked_ce(params, hidden, batch["labels"], b)

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, grads, state.opt, state.params)
        return (lm_lib.TrainState(params=new_params, opt=new_opt,
                                  step=state.step + 1),
                {"loss": loss, **metrics})

    return train_step
