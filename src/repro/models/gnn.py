"""GNN zoo: GAT, SchNet, GIN, PNA — segment-op message passing.

JAX has no CSR/CSC sparse: message passing is gather (edge src) ->
edge-compute -> ``segment_sum``/``segment_max`` scatter (edge dst), which is
the same machinery the DKS relaxation uses (one shared substrate, per the
paper's Pregel framing).  Node/edge axes shard over all mesh axes.

Batch container works for all four shape regimes: full graphs (cora,
ogb-products), fanout-sampled subgraphs (reddit minibatch) and batched
molecules (graph_ids + graph-level readout).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro import shardmap
from repro.configs.base import GNNConfig
from repro.models.common import constrain, dense_init, split_keys

ALL_AXES = ("pod", "data", "model")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class GraphBatch:
    x: jax.Array            # f32[N, F] node features (or embedded atoms)
    edge_src: jax.Array     # i32[E]
    edge_dst: jax.Array     # i32[E]
    node_mask: jax.Array    # bool[N]
    edge_mask: jax.Array    # bool[E]
    labels: jax.Array       # i32[N] (node tasks) or f32/i32[G] (graph tasks)
    graph_ids: jax.Array    # i32[N] graph id per node (0 for single graph)
    positions: jax.Array    # f32[N, 3] (schnet; zeros otherwise)
    n_graphs: int = dataclasses.field(metadata=dict(static=True), default=1)


def _seg_sum(vals, seg, n):
    return jax.ops.segment_sum(vals, seg, num_segments=n)


def _seg_max(vals, seg, n):
    return jax.ops.segment_max(vals, seg, num_segments=n)


def _seg_min(vals, seg, n):
    return jax.ops.segment_min(vals, seg, num_segments=n)


def _degree(batch: GraphBatch, n: int) -> jax.Array:
    ones = batch.edge_mask.astype(jnp.float32)
    return _seg_sum(ones, batch.edge_dst, n)


def _mp_dtype(cfg: GNNConfig):
    return jnp.bfloat16 if cfg.mp_dtype == "bfloat16" else jnp.float32


def _gather_rows(h: jax.Array, idx: jax.Array, mpd) -> jax.Array:
    """h[idx] across node shards with the node table cast to the
    message-passing dtype BEFORE it crosses the wire.

    Under plain pjit, XLA replicates the f32 table for the edge gather
    (and f32 cotangments on the way back); this shard_map pins an explicit
    bf16 all_gather, halving the GNN's dominant collective.  The backward
    is the transpose (bf16 reduce-scatter of message cotangents)."""
    am = shardmap.get_abstract_mesh()
    axes = tuple(a for a in ALL_AXES if am is not None and a in am.axis_names)
    if not axes:
        return h.astype(mpd)[idx]
    trailing = (None,) * (h.ndim - 1)

    def block(h_loc, idx_loc):
        h_all = jax.lax.all_gather(h_loc.astype(mpd), axes, axis=0,
                                   tiled=True)
        return h_all[idx_loc]

    from jax.sharding import PartitionSpec as P
    return shardmap.shard_map(
        block, mesh=am,
        in_specs=(P(axes, *trailing), P(axes)),
        out_specs=P(axes, *trailing),
        check_vma=False,
    )(h, idx)


def _edge_softmax(scores, dst, edge_mask, n):
    """Segment softmax over incoming edges (GAT); f32 for stability."""
    scores = scores.astype(jnp.float32)
    scores = jnp.where(edge_mask[..., None] if scores.ndim > 1 else edge_mask,
                       scores, -1e30)
    mx = _seg_max(scores, dst, n)
    ex = jnp.exp(scores - mx[dst])
    ex = jnp.where(edge_mask[..., None] if scores.ndim > 1 else edge_mask,
                   ex, 0.0)
    den = _seg_sum(ex, dst, n)
    return ex / jnp.maximum(den[dst], 1e-16)


# --------------------------------------------------------------------------
# GAT (arXiv:1710.10903): SDDMM edge scores -> segment softmax -> SpMM.
# --------------------------------------------------------------------------


def init_gat(key, cfg: GNNConfig, d_in: int) -> dict:
    layers = []
    keys = jax.random.split(key, cfg.n_layers)
    d_prev = d_in
    for li, k in enumerate(keys):
        last = li == cfg.n_layers - 1
        d_out = cfg.n_classes if last else cfg.d_hidden
        heads = 1 if last else cfg.n_heads
        ks = split_keys(k, ["w", "a_src", "a_dst"])
        layers.append({
            "w": dense_init(ks["w"], (d_prev, heads * d_out), jnp.float32),
            "a_src": dense_init(ks["a_src"], (heads, d_out), jnp.float32),
            "a_dst": dense_init(ks["a_dst"], (heads, d_out), jnp.float32),
        })
        d_prev = d_out * (heads if not last else 1)
    return {"layers": layers}


def gat_forward(params: dict, batch: GraphBatch, cfg: GNNConfig) -> jax.Array:
    x = constrain(batch.x, ALL_AXES, None)
    n = x.shape[0]
    n_layers = len(params["layers"])
    for li, lw in enumerate(params["layers"]):
        last = li == n_layers - 1
        heads = 1 if last else cfg.n_heads
        d_out = lw["w"].shape[1] // heads
        h = (x @ lw["w"]).reshape(n, heads, d_out)
        s_src = jnp.sum(h * lw["a_src"][None], axis=-1)   # [N, H]
        s_dst = jnp.sum(h * lw["a_dst"][None], axis=-1)
        e = jax.nn.leaky_relu(
            s_src[batch.edge_src] + s_dst[batch.edge_dst], 0.2)  # [E, H]
        alpha = _edge_softmax(e, batch.edge_dst, batch.edge_mask, n)
        mpd = _mp_dtype(cfg)
        h_src = _gather_rows(h.reshape(n, heads * d_out), batch.edge_src,
                             mpd).reshape(-1, heads, d_out)
        msg = h_src * alpha.astype(mpd)[..., None]        # [E, H, D]
        agg = _seg_sum(msg, batch.edge_dst, n)            # stays mp_dtype
        x = agg.reshape(n, heads * d_out) if not last else agg.mean(axis=1)
        if not last:
            x = jax.nn.elu(x)
        x = constrain(x, ALL_AXES, None)
    return x  # [N, n_classes] logits


# --------------------------------------------------------------------------
# GIN (arXiv:1810.00826): sum aggregation + MLP, learnable eps.
# --------------------------------------------------------------------------


def init_gin(key, cfg: GNNConfig, d_in: int) -> dict:
    layers = []
    keys = jax.random.split(key, cfg.n_layers + 1)
    d_prev = d_in
    for k in keys[:-1]:
        ks = split_keys(k, ["w1", "w2"])
        layers.append({
            "w1": dense_init(ks["w1"], (d_prev, cfg.d_hidden), jnp.float32),
            "b1": jnp.zeros((cfg.d_hidden,), jnp.float32),
            "w2": dense_init(ks["w2"], (cfg.d_hidden, cfg.d_hidden), jnp.float32),
            "b2": jnp.zeros((cfg.d_hidden,), jnp.float32),
            "eps": jnp.zeros((), jnp.float32),
        })
        d_prev = cfg.d_hidden
    out = dense_init(keys[-1], (cfg.d_hidden, cfg.n_classes), jnp.float32)
    return {"layers": layers, "out": out}


def gin_forward(params: dict, batch: GraphBatch, cfg: GNNConfig,
                graph_level: bool = False) -> jax.Array:
    x = constrain(batch.x, ALL_AXES, None)
    n = x.shape[0]
    mpd = _mp_dtype(cfg)
    for lw in params["layers"]:
        msg = jnp.where(batch.edge_mask[:, None],
                        x.astype(mpd)[batch.edge_src], jnp.asarray(0, mpd))
        agg = _seg_sum(msg, batch.edge_dst, n)            # stays mp_dtype
        h = (1.0 + lw["eps"]) * x.astype(mpd) + agg
        h = jax.nn.relu(h @ lw["w1"] + lw["b1"])
        x = jax.nn.relu(h @ lw["w2"] + lw["b2"])
        x = constrain(x, ALL_AXES, None)
    if graph_level:
        pooled = _seg_sum(jnp.where(batch.node_mask[:, None], x, 0.0),
                          batch.graph_ids, batch.n_graphs)
        return pooled @ params["out"]                    # [G, classes]
    return x @ params["out"]                             # [N, classes]


# --------------------------------------------------------------------------
# PNA (arXiv:2004.05718): mean/max/min/std aggregators x id/amp/atten scalers.
# --------------------------------------------------------------------------


def init_pna(key, cfg: GNNConfig, d_in: int, delta: float = 2.5) -> dict:
    layers = []
    keys = jax.random.split(key, cfg.n_layers + 1)
    d_prev = d_in
    n_agg = len(cfg.aggregators) * len(cfg.scalers)
    for k in keys[:-1]:
        ks = split_keys(k, ["pre", "post"])
        layers.append({
            "pre": dense_init(ks["pre"], (d_prev, cfg.d_hidden), jnp.float32),
            "post": dense_init(
                ks["post"], (n_agg * cfg.d_hidden + d_prev, cfg.d_hidden),
                jnp.float32),
        })
        d_prev = cfg.d_hidden
    out = dense_init(keys[-1], (cfg.d_hidden, cfg.n_classes), jnp.float32)
    return {"layers": layers, "out": out, "delta": jnp.float32(delta)}


def _pna_aggregate(h, batch: GraphBatch, n: int,
                   chunk_edges: int = 16_000_000):
    """(sum, sumsq, max, min) per destination — edge-CHUNKED when the edge
    set is large: the four [E, d] message tensors at ogb-products scale are
    26 GiB/chip live (measured); sum/sumsq/max/min are decomposable, so a
    checkpointed scan over edge chunks caps the live set at [chunk, d]."""
    e = batch.edge_src.shape[0]
    nc = max(1, -(-e // chunk_edges))
    if nc == 1 or e % nc:
        msg = jnp.where(batch.edge_mask[:, None], h[batch.edge_src], 0.0)
        s = _seg_sum(msg, batch.edge_dst, n)
        sq = _seg_sum(msg * msg, batch.edge_dst, n)
        mx = _seg_max(jnp.where(batch.edge_mask[:, None], h[batch.edge_src],
                                -1e30), batch.edge_dst, n)
        mn = _seg_min(jnp.where(batch.edge_mask[:, None], h[batch.edge_src],
                                1e30), batch.edge_dst, n)
        return s, sq, mx, mn
    ec = e // nc
    resh = lambda a: a.reshape(nc, ec, *a.shape[1:])
    src_c, dst_c, msk_c = (resh(batch.edge_src), resh(batch.edge_dst),
                           resh(batch.edge_mask))

    @jax.checkpoint
    def body(carry, xs):
        s, sq, mx, mn = carry
        src, dst, mask = xs
        m = jnp.where(mask[:, None], h[src], 0.0)
        s = s + _seg_sum(m, dst, n)
        sq = sq + _seg_sum(m * m, dst, n)
        mx = jnp.maximum(mx, _seg_max(
            jnp.where(mask[:, None], h[src], -1e30), dst, n))
        mn = jnp.minimum(mn, _seg_min(
            jnp.where(mask[:, None], h[src], 1e30), dst, n))
        return (s, sq, mx, mn), None

    d = h.shape[1]
    init = (jnp.zeros((n, d), h.dtype), jnp.zeros((n, d), h.dtype),
            jnp.full((n, d), -1e30, h.dtype), jnp.full((n, d), 1e30, h.dtype))
    (s, sq, mx, mn), _ = jax.lax.scan(body, init, (src_c, dst_c, msk_c))
    return s, sq, mx, mn


def pna_forward(params: dict, batch: GraphBatch, cfg: GNNConfig) -> jax.Array:
    x = constrain(batch.x, ALL_AXES, None)
    n = x.shape[0]
    deg = _degree(batch, n)
    log_deg = jnp.log(deg + 1.0)
    delta = params["delta"]
    for lw in params["layers"]:
        h = jax.nn.relu(x @ lw["pre"])
        s, sq, mmax, mmin = _pna_aggregate(h, batch, n)
        mean = s / jnp.maximum(deg[:, None], 1.0)
        mmax = jnp.where(deg[:, None] > 0, jnp.maximum(mmax, -1e30), 0.0)
        mmin = jnp.where(deg[:, None] > 0, jnp.minimum(mmin, 1e30), 0.0)
        var = (sq.astype(jnp.float32) / jnp.maximum(deg[:, None], 1.0)
               - mean.astype(jnp.float32) ** 2)
        std = jnp.sqrt(jnp.maximum(var, 0.0) + 1e-5).astype(h.dtype)
        aggs = {"mean": mean, "max": mmax, "min": mmin, "std": std,
                "sum": s}
        feats = []
        for agg_name in cfg.aggregators:
            a = aggs[agg_name]
            for sc in cfg.scalers:
                if sc == "identity":
                    feats.append(a)
                elif sc == "amplification":
                    feats.append(a * (log_deg / delta)[:, None])
                elif sc == "attenuation":
                    feats.append(a * (delta / jnp.maximum(log_deg, 1e-2))[:, None])
        z = jnp.concatenate(feats + [x], axis=-1)
        x = jax.nn.relu(z @ lw["post"])
        x = constrain(x, ALL_AXES, None)
    return x @ params["out"]


# --------------------------------------------------------------------------
# SchNet (arXiv:1706.08566): RBF expansion + continuous-filter convolution.
# --------------------------------------------------------------------------


def shifted_softplus(x):
    return jax.nn.softplus(x) - jnp.log(2.0)


def init_schnet(key, cfg: GNNConfig, n_atom_types: int = 100) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    inter = []
    for k in keys[:-2]:
        ks = split_keys(k, ["filt1", "filt2", "in", "out1", "out2"])
        inter.append({
            "filt1": dense_init(ks["filt1"], (cfg.rbf, d), jnp.float32),
            "filt2": dense_init(ks["filt2"], (d, d), jnp.float32),
            "w_in": dense_init(ks["in"], (d, d), jnp.float32),
            "w_out1": dense_init(ks["out1"], (d, d), jnp.float32),
            "w_out2": dense_init(ks["out2"], (d, d), jnp.float32),
        })
    ks = split_keys(keys[-2], ["o1", "o2"])
    return {
        "embed": dense_init(keys[-1], (n_atom_types, d), jnp.float32, scale=1.0),
        "interactions": inter,
        "out1": dense_init(ks["o1"], (d, d // 2), jnp.float32),
        "out2": dense_init(ks["o2"], (d // 2, 1), jnp.float32),
    }


def schnet_forward(params: dict, batch: GraphBatch, cfg: GNNConfig) -> jax.Array:
    """Per-graph energy [G]. batch.x[:, 0] holds integer atom types."""
    n = batch.x.shape[0]
    z = batch.x[:, 0].astype(jnp.int32)
    x = jnp.take(params["embed"], jnp.clip(z, 0, params["embed"].shape[0] - 1),
                 axis=0)
    x = constrain(x, ALL_AXES, None)
    diff = batch.positions[batch.edge_src] - batch.positions[batch.edge_dst]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1) + 1e-12)
    centers = jnp.linspace(0.0, cfg.cutoff, cfg.rbf)
    gamma = 10.0
    rbf = jnp.exp(-gamma * (dist[:, None] - centers[None]) ** 2)  # [E, rbf]
    # Smooth cosine cutoff.
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cfg.cutoff, 0, 1)) + 1.0)
    for lw in params["interactions"]:
        filt = shifted_softplus(rbf @ lw["filt1"])
        filt = shifted_softplus(filt @ lw["filt2"]) * env[:, None]
        h = x @ lw["w_in"]
        msg = h[batch.edge_src] * filt
        msg = jnp.where(batch.edge_mask[:, None], msg, 0.0)
        agg = _seg_sum(msg, batch.edge_dst, n)
        v = shifted_softplus(agg @ lw["w_out1"]) @ lw["w_out2"]
        x = x + v
        x = constrain(x, ALL_AXES, None)
    e_atom = shifted_softplus(x @ params["out1"]) @ params["out2"]  # [N, 1]
    e_atom = jnp.where(batch.node_mask[:, None], e_atom, 0.0)
    return _seg_sum(e_atom[:, 0], batch.graph_ids, batch.n_graphs)   # [G]


# --------------------------------------------------------------------------
# Dispatch + task losses
# --------------------------------------------------------------------------


def init_gnn(key, cfg: GNNConfig, d_in: int) -> dict:
    if cfg.family == "gat":
        return init_gat(key, cfg, d_in)
    if cfg.family == "gin":
        return init_gin(key, cfg, d_in)
    if cfg.family == "pna":
        return init_pna(key, cfg, d_in)
    if cfg.family == "schnet":
        return init_schnet(key, cfg)
    raise ValueError(cfg.family)


def gnn_forward(params: dict, batch: GraphBatch, cfg: GNNConfig,
                graph_level: bool = False) -> jax.Array:
    if cfg.mp_dtype == "bfloat16":
        # bf16 across the whole message-passing path (params, features,
        # edge gathers AND their cotangents); softmax/losses stay f32.
        params = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.bfloat16)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
        batch = dataclasses.replace(batch, x=batch.x.astype(jnp.bfloat16))
    if cfg.family == "gat":
        out = gat_forward(params, batch, cfg)
    elif cfg.family == "gin":
        out = gin_forward(params, batch, cfg, graph_level)
    elif cfg.family == "pna":
        out = pna_forward(params, batch, cfg)
    elif cfg.family == "schnet":
        out = schnet_forward(params, batch, cfg)
    else:
        raise ValueError(cfg.family)
    return out.astype(jnp.float32)


def gnn_loss(params: dict, batch: GraphBatch, cfg: GNNConfig) -> jax.Array:
    if cfg.family == "schnet":
        energy = schnet_forward(params, batch, cfg)
        target = batch.labels.astype(jnp.float32)
        return jnp.mean((energy - target) ** 2)
    graph_level = batch.n_graphs > 1
    logits = gnn_forward(params, batch, cfg, graph_level)
    if graph_level:
        if logits.shape[0] != batch.n_graphs:
            # Node-level heads (GAT/PNA): mean-pool per graph.
            ones = batch.node_mask.astype(jnp.float32)
            cnt = _seg_sum(ones, batch.graph_ids, batch.n_graphs)
            pooled = _seg_sum(
                jnp.where(batch.node_mask[:, None], logits, 0.0),
                batch.graph_ids, batch.n_graphs)
            logits = pooled / jnp.maximum(cnt[:, None], 1.0)
        labels = jnp.clip(batch.labels.astype(jnp.int32), 0,
                          logits.shape[-1] - 1)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
        return jnp.mean(logz - gold)
    labels = jnp.clip(batch.labels.astype(jnp.int32), 0,
                      logits.shape[-1] - 1)
    mask = batch.node_mask.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
