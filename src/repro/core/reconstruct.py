"""Aggregator-side answer-tree reconstruction (the paper's ``V_K`` role).

The device loop produces the final table ``S[V, 2^m, K]``; answer *weights*
and *roots* are known on-device.  Recovering the actual answer-trees — and
deduplicating / re-ranking them exactly like the paper's ``A_A`` aggregator —
is the only genuinely ragged computation in DKS, so it runs on the host
(= Pregel master) against the final table:

  backtrace(v, ks, val):
    - singleton at a keyword node with val==0        -> leaf
    - val == S[u, ks, j] + w(u,v) for a neighbor u   -> tree edge (u,v)
    - val == S[v, a, i] + S[v, b, j], a ⊎ b = ks     -> split at v

Backtraced trees may be non-minimal (a branch's keyword may already be
covered elsewhere, paper Def. 2.1); :func:`prune_non_minimal` removes
redundant branches, the true weight is recomputed over the deduped edge set,
and identical trees found at different roots collapse.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro import INF
from repro.graph.structure import Graph

_TOL = 1e-3


@dataclasses.dataclass(frozen=True)
class AnswerTree:
    root: int
    edges: tuple[tuple[int, int], ...]   # undirected, (min,max)-normalized
    weight: float
    raw_value: float                     # DP value before dedupe/prune
    nodes: tuple[int, ...]

    def key(self) -> tuple:
        return self.edges if self.edges else (("node", self.nodes),)


def _edge_weight(g: Graph, u: int, v: int) -> float:
    nbrs, ws = g.neighbors(u)
    hits = ws[nbrs == v]
    return float(hits.min()) if len(hits) else float(INF)


def backtrace(
    S: np.ndarray,
    g: Graph,
    kw_masks: np.ndarray,
    root: int,
    ks: int,
    val: float,
    _depth: int = 0,
) -> list[tuple[int, int]] | None:
    """Recover one tree achieving DP value ``val`` for keyword-set ``ks`` at
    ``root``.  Returns a list of undirected edges, or None if no exact
    decomposition exists (can happen for K>1 slots whose value is a walk
    artifact — callers simply drop those candidates)."""
    if _depth > 10_000:
        return None
    m = kw_masks.shape[0]
    if val <= _TOL and all(
        kw_masks[i, root] for i in range(m) if ks >> i & 1
    ):
        return []
    # Split decompositions at the root.
    a = (ks - 1) & ks
    while a:
        b = ks ^ a
        if a <= b:
            for i in range(S.shape[2]):
                va = S[root, a, i]
                if va > val + _TOL or va >= INF:
                    break
                for j in range(S.shape[2]):
                    vb = S[root, b, j]
                    if vb >= INF:
                        break
                    if abs(va + vb - val) <= _TOL:
                        left = backtrace(S, g, kw_masks, root, a, float(va), _depth + 1)
                        if left is None:
                            continue
                        right = backtrace(S, g, kw_masks, root, b, float(vb), _depth + 1)
                        if right is None:
                            continue
                        return left + right
        a = (a - 1) & ks
    # Edge decompositions.
    nbrs, ws = g.neighbors(root)
    for u, w in zip(nbrs, ws):
        if w >= INF or w > val + _TOL:
            continue
        target = val - float(w)
        for j in range(S.shape[2]):
            vu = S[int(u), ks, j]
            if vu >= INF:
                break
            if abs(vu - target) <= _TOL:
                sub = backtrace(S, g, kw_masks, int(u), ks, float(vu), _depth + 1)
                if sub is not None:
                    e = (min(root, int(u)), max(root, int(u)))
                    return sub + [e]
    return None


def prune_non_minimal(
    edges: Sequence[tuple[int, int]],
    kw_masks: np.ndarray,
    root: int,
) -> list[tuple[int, int]]:
    """Iteratively remove leaf branches not needed for keyword coverage
    (paper Def. 2.1 minimality).  The root is *not* exempt: a root that is
    itself a redundant leaf makes the tree non-minimal — after pruning it,
    the answer collapses onto the tree it contained (and dedupes there)."""
    edges = list(dict.fromkeys(edges))  # dedupe, keep order
    m = kw_masks.shape[0]
    while True:
        if not edges:
            return edges
        deg: dict[int, int] = {}
        for u, v in edges:
            deg[u] = deg.get(u, 0) + 1
            deg[v] = deg.get(v, 0) + 1
        nodes = set(deg)
        removed = False
        for leaf in [n for n, d in deg.items() if d == 1]:
            rest = nodes - {leaf}
            if all(any(kw_masks[i, n] for n in rest) for i in range(m)):
                edges = [e for e in edges if leaf not in e]
                removed = True
                break
        if not removed:
            return edges


def _spanning_tree(edges: list[tuple[int, int]], g: Graph) -> list[tuple[int, int]]:
    """Kruskal MST over the (possibly cyclic) union subgraph.

    Backtraced walk-unions can contain cycles; any answer tree inside the
    union with pruned leaves is a valid minimal answer, so we take the MST
    (cheapest spanning structure) and let the caller re-prune."""
    weighted = sorted(((_edge_weight(g, u, v), u, v) for u, v in edges))
    parent: dict[int, int] = {}

    def find(x: int) -> int:
        while parent.setdefault(x, x) != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    out = []
    for w, u, v in weighted:
        ru, rv = find(u), find(v)
        if ru != rv:
            parent[ru] = rv
            out.append((u, v))
    return out


def finish_tree(
    edges: list[tuple[int, int]],
    g: Graph,
    kw_masks: np.ndarray,
    root: int,
    raw_value: float,
) -> AnswerTree:
    """Backtraced edge list -> finished :class:`AnswerTree`: prune to
    minimal, cycle-repair, recompute the true weight over the deduped edge
    set, re-root if the root itself was pruned."""
    orig_nodes = {n for e in edges for n in e}
    edges = prune_non_minimal(edges, kw_masks, root)
    # A walk-union may contain cycles: reduce to a spanning tree of the
    # union and re-prune (paper's V_K-based extraction never produces
    # cycles; this is our equivalent repair at the aggregator).
    if len({n for e in edges for n in e}) != len(edges) + (1 if edges else 0):
        edges = _spanning_tree(list(dict.fromkeys(edges)), g)
        edges = prune_non_minimal(edges, kw_masks, root)
    m = kw_masks.shape[0]
    if not edges and orig_nodes and not all(kw_masks[i, root]
                                            for i in range(m)):
        # Pruning collapsed the whole tree: the last prune left a single
        # node covering every keyword.  Re-root onto (a deterministic)
        # such survivor — keeping the original root would report a
        # zero-weight "tree" that covers nothing.
        root = min(c for c in orig_nodes
                   if all(kw_masks[i, c] for i in range(m)))
    weight = sum(_edge_weight(g, u, v) for u, v in edges)
    tree_nodes = {n for e in edges for n in e}
    if edges and root not in tree_nodes:
        # Root pruned away as a redundant leaf: re-root at the highest
        # degree remaining node (the connection node of what is left).
        degc: dict[int, int] = {}
        for u, v in edges:
            degc[u] = degc.get(u, 0) + 1
            degc[v] = degc.get(v, 0) + 1
        root = max(degc, key=degc.get)
    nodes = tuple(sorted(tree_nodes | {root}))
    return AnswerTree(
        root=root, edges=tuple(sorted(edges)), weight=round(weight, 6),
        raw_value=raw_value, nodes=nodes,
    )


def collect_answers(
    S: np.ndarray,
    g: Graph,
    kw_masks: np.ndarray,
    k: int,
    candidate_factor: int = 4,
    backtrace_fn=None,
) -> tuple[list[AnswerTree], bool]:
    """Global top-K minimal answer-trees from the final DP table, with an
    exhaustion flag.

    Mirrors the paper's aggregator A_A: collect candidate (root, value)
    pairs in a *stable* value-ascending order (ties broken by cell index,
    so host and device candidate selection agree bit-for-bit),
    reconstruct, prune to minimal, recompute true weights over the deduped
    edge set, drop duplicates, re-rank.

    Every candidate of the initial ``k * candidate_factor`` window is
    processed (recomputed weights can re-rank past the k-th tree).  When
    dedup / failed backtraces collapse that pool below ``k`` distinct
    trees, the scan *refills*: it keeps walking the value-ordered table
    until ``k`` distinct trees exist or the finite candidates run out.
    Returns ``(ranked[:k], exhausted)`` — ``exhausted`` is True when the
    table holds fewer than ``k`` distinct trees in total.

    ``backtrace_fn(pos, root, val)``: optional override returning an edge
    list (or None) for the candidate at scan position ``pos`` — the hook
    the device-batched backtracer (:mod:`repro.answers`) plugs in; the
    default is the host :func:`backtrace`.
    """
    m = kw_masks.shape[0]
    full = (1 << m) - 1
    K = S.shape[2]
    flat = S[:, full, :].reshape(-1)
    # Stable: equal values scan in cell-index order (argpartition would
    # pick an arbitrary representative set at the window boundary).
    order = np.argsort(flat, kind="stable")
    if backtrace_fn is None:
        def backtrace_fn(pos: int, root: int, val: float):
            return backtrace(S, g, kw_masks, root, full, val)
    window = min(len(order), max(k, 1) * candidate_factor)
    answers: dict[tuple, AnswerTree] = {}
    pos = 0
    while pos < len(order):
        if pos >= window and len(answers) >= k:
            break
        fi = int(order[pos])
        val = float(flat[fi])
        if val >= INF:
            break
        root = fi // K
        edges = backtrace_fn(pos, root, val)
        pos += 1
        if edges is None:
            continue
        tree = finish_tree(edges, g, kw_masks, root, val)
        answers.setdefault(tree.key(), tree)
    ranked = sorted(answers.values(), key=lambda t: (t.weight, t.root))
    return ranked[:k], len(answers) < k


def extract_answers(
    S: np.ndarray,
    g: Graph,
    kw_masks: np.ndarray,
    k: int,
    candidate_factor: int = 4,
) -> list[AnswerTree]:
    """:func:`collect_answers` without the exhaustion flag (the original
    aggregator surface; kept for callers that only want the trees)."""
    answers, _ = collect_answers(S, g, kw_masks, k, candidate_factor)
    return answers
