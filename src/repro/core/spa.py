"""Smallest-Possible-Answer (SPA) estimation and sound exit bounds.

Paper Sec. 5.4: when traversal is stopped early (message budget), a dynamic
program over keyword-set *covers* estimates the smallest answer weight that
could still be discovered; the ratio best-found / SPA is the reported
SPA-ratio.  Paper Sec. 6 (Theorem 1) stops BFS via Fagin's argument once the
estimated next-superstep path-lengths exceed those in the current top-K.

This module provides:

- ``spa_cover_dp``   — the paper's cover DP over estimated path-lengths.
- ``nu_lower_bound`` — a *provably sound* per-keyword-set lower bound on any
  value that can newly appear at any node in a future superstep, for the
  dense re-fire semantics of this engine (see DESIGN.md Sec. 5).  A new
  answer is a newly-appearing full-set value, so BFS may stop once
  ``nu[full] >= W_K``.

All DPs are over the 2^m keyword-set lattice (m <= ~6), so they are
unrolled statically and cost nothing next to the graph-sized work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import INF


@functools.lru_cache(maxsize=None)
def split_pairs(m: int) -> tuple[tuple[int, int, int], ...]:
    """All (t, a, b) with a ⊎ b = t, a < b, nonempty — in popcount(t) order."""
    pairs = []
    masks = sorted(range(1, 1 << m), key=lambda t: (bin(t).count("1"), t))
    for t in masks:
        a = (t - 1) & t
        while a:
            b = t ^ a
            if a < b:
                pairs.append((t, a, b))
            a = (a - 1) & t
    return tuple(pairs)


@functools.lru_cache(maxsize=None)
def submasks(u: int) -> tuple[int, ...]:
    """All nonempty submasks of u."""
    out, s = [], u
    while s:
        out.append(s)
        s = (s - 1) & u
    return tuple(out)


def nu_lower_bound(
    g: jax.Array, e_min: jax.Array, m: int
) -> jax.Array:
    """Lower bound ``nu[t]`` on any value for keyword-set ``t`` that first
    appears at some node in a superstep after the current one.

    ``g[t]``: global minimum value for ``t`` seen anywhere so far (INF if
    never seen).  New values arise by (i) arrival over an edge — at least
    ``g[t] + e_min`` — or (ii) a combine with at least one locally-new input
    — at least ``min(nu[a]+g[b], g[a]+nu[b], nu[a]+nu[b])`` over splits.
    """
    nu = jnp.minimum(g + e_min, INF)
    nu = nu.at[0].set(INF)
    for t, a, b in split_pairs(m):
        cand = jnp.minimum(
            jnp.minimum(nu[a] + g[b], g[a] + nu[b]), nu[a] + nu[b]
        )
        nu = nu.at[t].min(jnp.minimum(cand, INF))
    return nu


def spa_cover_dp(shat: jax.Array, m: int) -> jax.Array:
    """Paper Sec. 5.4 DP: cheapest cover of the full keyword set by
    keyword-sets priced at ``shat`` (estimated next-superstep path-lengths).

    ``cost[U] = min(shat[U], min_{T ⊂ U} shat[T] + cost[U \\ T])``; returns
    ``cost[full]`` — the smallest possible answer weight by further traversal.
    """
    n = 1 << m
    cost = jnp.minimum(shat, INF)
    cost = cost.at[0].set(0.0)
    # Popcount-ordered relaxation: covers may overlap in the paper's wording
    # ("collectively contain all keywords"), so U \ T with T any submask.
    order = sorted(range(1, n), key=lambda t: (bin(t).count("1"), t))
    for u in order:
        best = cost[u]
        for t in submasks(u):
            if t == u:
                continue
            best = jnp.minimum(best, jnp.minimum(shat[t], INF) + cost[u ^ t])
        cost = cost.at[u].set(jnp.minimum(best, INF))
    return cost[(1 << m) - 1]


def spa_ratio(best_found: jax.Array, spa: jax.Array) -> jax.Array:
    """Paper Fig. 12: degree of approximation on forced early exit.

    Returns best_found / spa (>= 1 when optimality is unproven).  Per the
    paper's convention, returns 0 when the answer is proven optimal —
    including the case spa >= best_found, where further traversal cannot
    beat the best answer already found.
    """
    return jnp.where(
        (best_found >= INF) | (spa <= 0.0) | (spa >= INF),
        jnp.float32(jnp.inf),
        jnp.where(spa >= best_found, 0.0, best_found / spa),
    )
