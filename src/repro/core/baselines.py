"""Baselines the paper compares against.

- :func:`vanilla_parallel_bfs` — plain frontier BFS touching the whole
  graph (the paper's Sec. 7.2 reference point: DKS should stay within a
  small factor of it while doing exponentially more per-node work).
- :func:`dks_no_early_exit` — DKS with the exit criterion disabled
  (ablation for the "effectiveness of early exit" experiments).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.graph.structure import DeviceGraph


@functools.partial(jax.jit, static_argnames=("max_steps",))
def vanilla_parallel_bfs(graph: DeviceGraph, sources: jax.Array,
                         max_steps: int = 64):
    """Frontier BFS from source mask; returns (hops[V], n_supersteps)."""
    v = graph.v_pad
    dist = jnp.where(sources & graph.node_valid, 0, jnp.int32(2**30))

    def cond(carry):
        dist, frontier, step = carry
        return jnp.any(frontier) & (step < max_steps)

    def body(carry):
        dist, frontier, step = carry
        send = frontier[graph.src] & graph.valid
        cand = jnp.where(send, dist[graph.src] + 1, 2**30)
        new = jax.ops.segment_min(cand, graph.dst, num_segments=v)
        improved = new < dist
        dist = jnp.minimum(dist, new)
        return dist, improved & graph.node_valid, step + 1

    frontier = sources & graph.node_valid
    dist, _, steps = jax.lax.while_loop(cond, body,
                                        (dist, frontier, jnp.int32(0)))
    return dist, steps


def dks_no_early_exit(graph, kw_masks, cfg):
    import dataclasses

    from repro.core.dks import DKSConfig, run_dks
    cfg2 = dataclasses.replace(cfg, exit_mode="none")
    return run_dks(graph, kw_masks, cfg2)
