"""Fagin-style exit criteria (paper Sec. 6 / Theorem 1).

The paper's literal Eq. 2 needs, per keyword-set, the largest *constituent*
path-length among the global top-K answers (``L_n``) — which requires
decomposing each answer tree.  In Giraph this runs in the master between
supersteps; here it is a host-side ``exit_hook`` for
:func:`repro.core.dks.run_dks_instrumented`.

The fully-jitted production path instead uses the sound on-device bound in
``spa.nu_lower_bound`` (see DESIGN.md §5); tests verify neither criterion
ever misses an optimum.
"""

from __future__ import annotations

import numpy as np

from repro import INF
from repro.core import reconstruct
from repro.core.dks import DKSConfig, DKSState
from repro.graph.structure import Graph


def constituent_lengths(
    S: np.ndarray,
    g: Graph,
    kw_masks: np.ndarray,
    root: int,
    val: float,
) -> dict[int, float]:
    """Top-level decomposition of an answer at ``root`` into constituent
    keyword-sets and their path-lengths (the ``L`` set of Step 3)."""
    m = kw_masks.shape[0]
    full = (1 << m) - 1
    out: dict[int, float] = {}

    def walk(ks: int, v: float):
        # Prefer splits at the root: constituents are the split leaves.
        a = (ks - 1) & ks
        while a:
            b = ks ^ a
            if a <= b:
                for i in range(S.shape[2]):
                    va = float(S[root, a, i])
                    if va >= INF or va > v + 1e-3:
                        break
                    for j in range(S.shape[2]):
                        vb = float(S[root, b, j])
                        if vb >= INF:
                            break
                        if abs(va + vb - v) <= 1e-3:
                            walk(a, va)
                            walk(b, vb)
                            return
            a = (a - 1) & ks
        out[ks] = max(out.get(ks, 0.0), v)

    walk(full, val)
    return out


def paper_exit_hook(g: Graph, kw_masks: np.ndarray, cfg: DKSConfig, e_min: float):
    """Literal paper Eq. 2: exit when for every keyword-set with an entry in
    L_n, the estimated next-superstep frontier minimum exceeds it."""

    def hook(state: DKSState) -> bool:
        topk_w = np.asarray(state.topk_w)
        topk_root = np.asarray(state.topk_root)
        if np.sum(topk_w < INF) < cfg.k:
            return False
        S = np.asarray(state.S)
        L: dict[int, float] = {}
        for w, r in zip(topk_w, topk_root):
            if w >= INF or r < 0:
                continue
            for ks, ln in constituent_lengths(S, g, kw_masks, int(r), float(w)).items():
                L[ks] = max(L.get(ks, 0.0), ln)
        s_front = np.asarray(state.s_front)
        shat = np.minimum(s_front + e_min, INF)
        return all(shat[ks] > ln for ks, ln in L.items())

    return hook
