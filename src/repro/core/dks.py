"""DKS — Distributed Keyword Search (the paper's core algorithm) in JAX.

Vertex state is the dense table ``S[V, 2^m, K]`` (top-K distinct partial
answer weights per keyword-set — the paper's ``S_K``).  One superstep is:

  1. *Send/Receive* — min-plus edge relaxation from every node whose table
     changed last superstep (BFS messages; re-fires of previously visited
     nodes are exactly the paper's deep messages — see DESIGN.md §2),
     reduced per destination with an exact segment-top-K.
  2. *Combine* — per-node min-plus subset convolution over keyword-sets
     (the paper's local-tree S_K/V_K computation, Sec. 5.1), batched over
     ``ceil(log2 m)`` closure passes so it is one dense TPU-friendly op.
  3. *Aggregate* — frontier minima per keyword-set (aggregator ``A_S``) and
     the global top-K answer weights (aggregator ``A_A``).
  4. *Exit check* — sound on-device criterion ``nu[full] >= W_K`` (see
     spa.py), plus frontier exhaustion and the paper's message budget
     (Sec. 5.4 "system hangs at ~1M messages" — here a first-class config).

``run_dks`` executes the loop as a single jitted ``lax.while_loop`` and is
the unit that shards over the production mesh (node axis over data axes).
``run_dks_instrumented`` is a host loop around the same jitted phases with
per-phase wall times (paper Table 1) and literal Eq. 2 "paper" exit mode.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import INF
from repro.core import semiring, spa
from repro.graph.structure import DeviceGraph


# --------------------------------------------------------------------------
# Config / state
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DKSConfig:
    """Static configuration of a DKS run."""

    m: int                      # number of query keywords
    k: int = 1                  # top-K answers
    max_supersteps: int = 64
    message_budget: float = float("inf")  # paper: ~1e6 before Giraph hangs
    exit_mode: str = "sound"    # "sound" | "none" (run to frontier exhaustion)
    combine_impl: str = "jnp"   # "jnp" | "pallas"
    relax_impl: str = "jnp"     # "jnp" | "pallas"
    combine_passes: int | None = None  # default ceil(log2 m)
    frontier_frac: float = 0.25  # per-shard frontier cap (frontier relax);
    # overflow marks budget_hit — the paper's Sec. 5.4 forced-stop + SPA.

    @property
    def n_sets(self) -> int:
        return 1 << self.m

    @property
    def full(self) -> int:
        return (1 << self.m) - 1

    def n_combine_passes(self) -> int:
        if self.combine_passes is not None:
            return self.combine_passes
        if self.m <= 1:
            return 0
        return int(np.ceil(np.log2(self.m)))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DKSState:
    """Per-superstep state (a pytree; node axis shards over the mesh).

    Shapes below are the un-batched single-query layout.  The lane-batched
    driver (:mod:`repro.core.driver`) runs the same pytree with an explicit
    leading **lane** axis on every field (``S[L, V, 2^m, K]``,
    ``done[L]``, ...): one lane per concurrent query, with per-lane
    freeze/exit flags (``done`` / ``budget_hit`` / ``capped``) so lanes
    stop individually while the driver keeps stepping the rest."""

    S: jax.Array            # f32[V, 2^m, K] top-K distinct partial weights
    changed: jax.Array      # bool[V] — Pregel "active" vertices
    first_fire: jax.Array   # bool[V] — active for the first time (BFS
                            # frontier; re-fires are deep messages, Fig. 11)
    visited: jax.Array      # bool[V] — ever active (paper Fig. 13)
    g: jax.Array            # f32[2^m] global running min per keyword-set
    s_front: jax.Array      # f32[2^m] min over current frontier (A_S aggr.)
    topk_w: jax.Array       # f32[K] global top-K answer weights (A_A aggr.)
    topk_root: jax.Array    # i32[K] their root nodes
    msgs_bfs: jax.Array     # f32[] cumulative BFS messages (first visits)
    msgs_deep: jax.Array    # f32[] cumulative deep messages (re-fires)
    step: jax.Array         # i32[]
    done: jax.Array         # bool[]
    budget_hit: jax.Array   # bool[] — stopped by message budget (Sec. 5.4)
    capped: jax.Array       # bool[] — stopped ONLY by the superstep cap
                            # (truncated: the answer is unproven)


# --------------------------------------------------------------------------
# Phases
# --------------------------------------------------------------------------


def init_state(graph: DeviceGraph, kw_masks: jax.Array, cfg: DKSConfig) -> DKSState:
    """Superstep 0: keyword-nodes hold weight-0 singletons and are active."""
    v_pad = graph.v_pad
    n, k = cfg.n_sets, cfg.k
    S = jnp.full((v_pad, n, k), INF, jnp.float32)
    for i in range(cfg.m):
        S = S.at[:, 1 << i, 0].set(jnp.where(kw_masks[i], 0.0, INF))
    changed = jnp.any(kw_masks, axis=0) & graph.node_valid
    S = combine(S, cfg)  # nodes holding several keywords already combine
    state = DKSState(
        S=S,
        changed=changed,
        first_fire=changed,
        visited=changed,
        g=jnp.full((n,), INF, jnp.float32),
        s_front=jnp.full((n,), INF, jnp.float32),
        topk_w=jnp.full((k,), INF, jnp.float32),
        topk_root=jnp.full((k,), -1, jnp.int32),
        msgs_bfs=jnp.float32(0.0),
        msgs_deep=jnp.float32(0.0),
        step=jnp.int32(0),
        done=jnp.bool_(False),
        budget_hit=jnp.bool_(False),
        capped=jnp.bool_(False),
    )
    return aggregate(graph, state, cfg)


def relax(graph: DeviceGraph, S: jax.Array, changed: jax.Array,
          cfg: DKSConfig) -> jax.Array:
    """Messages: every active node sends its table along every incident edge;
    destinations take the per-keyword-set top-K of what arrives.

    Returns R[V, 2^m, K] (INF where nothing arrived).
    """
    if cfg.relax_impl == "pallas":
        from repro.kernels.segment_minplus import ops as sm_ops
        return sm_ops.segment_minplus(
            S, graph.src, graph.dst, graph.w,
            changed, graph.v_pad, cfg.k,
        )
    send = changed[graph.src] & graph.valid
    # cand[e, ks, k] = S[src(e), ks, k] + w(e)
    cand = S[graph.src] + graph.w[:, None, None]
    cand = jnp.where(send[:, None, None], cand, INF)
    cand = semiring.bump_to_inf(cand)
    e_pad, n, k = cand.shape
    # Candidate axis = (edge, slot); segment by destination.
    vals = cand.transpose(0, 2, 1).reshape(e_pad * k, n)
    seg = jnp.repeat(graph.dst, k)
    return semiring.segment_topk_min(vals, seg, graph.v_pad, cfg.k)  # [V, 2^m, K]


def combine(S: jax.Array, cfg: DKSConfig) -> jax.Array:
    """Per-node min-plus subset convolution:
    ``S[v, a|b] <- topk(S[v, a|b] ∪ (S[v,a] ⊕ S[v,b]))`` for disjoint a,b.

    Batched over all split pairs at once; ``ceil(log2 m)`` passes reach the
    popcount-doubling closure (DESIGN.md §3.1).
    """
    if cfg.m <= 1:
        return S
    if cfg.combine_impl == "pallas":
        from repro.kernels.subset_combine import ops as sc_ops
        return sc_ops.subset_combine(S, cfg.m, cfg.n_combine_passes())
    pairs = spa.split_pairs(cfg.m)
    t_ids = jnp.asarray([p[0] for p in pairs], jnp.int32)
    a_ids = jnp.asarray([p[1] for p in pairs], jnp.int32)
    b_ids = jnp.asarray([p[2] for p in pairs], jnp.int32)
    k = cfg.k
    n_pairs = len(pairs)

    def one_pass(S, _):
        a = jnp.take(S, a_ids, axis=1)          # [V, P, K]
        b = jnp.take(S, b_ids, axis=1)          # [V, P, K]
        cand = semiring.outer_combine(a, b)     # [V, P, K]
        #

        # Reduce candidates into their target keyword-sets: segment over the
        # pair axis, feature axes (V,) after folding K into the candidate
        # axis: rows (p, kslot) -> segment t_ids[p].
        vals = cand.transpose(1, 2, 0).reshape(n_pairs * k, -1)  # [(P K), V]
        seg = jnp.repeat(t_ids, k)
        red = semiring.segment_topk_min(vals, seg, cfg.n_sets, k)  # [2^m, V, K]
        red = red.transpose(1, 0, 2)            # [V, 2^m, K]
        return semiring.topk_merge(S, red), None

    S, _ = jax.lax.scan(one_pass, S, None, length=cfg.n_combine_passes())
    return S


def aggregate(graph: DeviceGraph, state: DKSState, cfg: DKSConfig) -> DKSState:
    """Aggregators A_S (frontier minima per keyword-set) and A_A (global
    top-K answers: smallest full-set values across all nodes)."""
    S, changed = state.S, state.changed
    masked = jnp.where(changed[:, None], S[:, :, 0], INF)  # [V, 2^m]
    s_front = jnp.min(masked, axis=0)
    g = jnp.minimum(state.g, jnp.min(S[:, :, 0], axis=0))
    full_vals = S[:, cfg.full, :].reshape(-1)               # [V*K]
    neg_top, idx = jax.lax.top_k(-full_vals, cfg.k)
    topk_w = -neg_top
    topk_root = (idx // cfg.k).astype(jnp.int32)
    topk_root = jnp.where(topk_w >= INF, -1, topk_root)
    return dataclasses.replace(
        state, s_front=s_front, g=g, topk_w=topk_w, topk_root=topk_root
    )


def exit_check(graph: DeviceGraph, state: DKSState, cfg: DKSConfig) -> DKSState:
    """Sound exit: stop when no future superstep can produce a new full-set
    value better than the current K-th best (nu[full] >= W_K), when the
    frontier is empty, or when the message budget is exhausted.  A run that
    stops for none of these reasons but hits ``max_supersteps`` is flagged
    ``capped`` — truncated, its answer unproven."""
    frontier_empty = ~jnp.any(state.changed)
    done = frontier_empty
    budget_hit = jnp.bool_(False)
    if cfg.exit_mode == "sound":
        nu = spa.nu_lower_bound(state.g, graph.e_min(), cfg.m)
        w_k = state.topk_w[cfg.k - 1]
        done = done | (nu[cfg.full] >= jnp.minimum(w_k, INF))
    msgs = state.msgs_bfs + state.msgs_deep
    if np.isfinite(cfg.message_budget):
        budget_hit = msgs > cfg.message_budget
        done = done | budget_hit
    capped = (state.step >= cfg.max_supersteps) & ~done
    done = done | capped
    return dataclasses.replace(state, done=done, budget_hit=budget_hit,
                               capped=capped)


def freeze_finished(old: DKSState, new: DKSState) -> DKSState:
    """Keep ``old`` wherever its exit criterion has already fired.

    Batched loops (the lane driver, :mod:`repro.core.driver`) keep
    stepping every lane until the whole batch finishes.  The lattice makes
    the extra steps idempotent on ``S``, but
    ``msgs_bfs``/``msgs_deep``/``step`` are counters, not lattice values —
    without this select, finished lanes keep accumulating them (and could
    even flip ``budget_hit``).  ``old.done`` may be any rank: a scalar
    under a per-lane vmap, or ``[L]`` on a state with an explicit lane
    axis — it broadcasts against each field from the left.  A single
    query's while-loop never runs the body once done, so the select only
    ever fires when some lanes finish before others.
    """
    done = old.done

    def sel(o, n):
        d = done.reshape(done.shape + (1,) * (o.ndim - done.ndim))
        return jnp.where(d, o, n)

    return jax.tree_util.tree_map(sel, old, new)


def finish_superstep(graph: Any, S0: jax.Array, state: DKSState,
                     cfg: DKSConfig, overflow: jax.Array | None = None,
                     ) -> DKSState:
    """The post-combine tail shared by every superstep flavor (dense,
    frontier-sharded, and their instrumented hosts): recompute the active
    set from the table delta, fold visit tracking, run the aggregators,
    and apply the exit check.  ``state.S`` must already hold the combined
    table; ``S0`` is the pre-relax table; counters/step are the caller's.

    ``overflow``: the frontier-sharded paths pass their frontier-overflow
    flag — it folds into ``budget_hit``/``done`` (frontier overflow == the
    paper's Sec. 5.4 message-budget forced stop).
    """
    changed = jnp.any(state.S < S0, axis=(1, 2)) & graph.node_valid
    st = dataclasses.replace(
        state,
        changed=changed,
        first_fire=changed & ~state.visited,
        visited=state.visited | changed,
    )
    st = aggregate(graph, st, cfg)
    st = exit_check(graph, st, cfg)
    if overflow is not None:
        st = dataclasses.replace(
            st, budget_hit=st.budget_hit | overflow,
            done=st.done | overflow)
    return st


def superstep(graph: DeviceGraph, state: DKSState, cfg: DKSConfig) -> DKSState:
    """One Pregel superstep (phases 1-4 above)."""
    S0 = state.S
    deg = graph.out_degree.astype(jnp.float32)
    # First-time fires are BFS messages; re-fires of visited vertices are
    # the deep messages (paper Fig. 11).
    n_bfs = jnp.sum(jnp.where(state.first_fire, deg, 0.0))
    n_deep = jnp.sum(jnp.where(state.changed & ~state.first_fire, deg, 0.0))

    R = relax(graph, S0, state.changed, cfg)
    S1 = semiring.topk_merge(S0, R)
    S1 = combine(S1, cfg)
    nxt = dataclasses.replace(
        state,
        S=S1,
        msgs_bfs=state.msgs_bfs + n_bfs,
        msgs_deep=state.msgs_deep + n_deep,
        step=state.step + 1,
    )
    return finish_superstep(graph, S0, nxt, cfg)


# --------------------------------------------------------------------------
# Drivers
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=())
def run_dks(graph: DeviceGraph, kw_masks: jax.Array, cfg: DKSConfig) -> DKSState:
    """Full DKS run as one jitted while-loop (the production path)."""
    state = init_state(graph, kw_masks, cfg)

    def cond(st: DKSState):
        return ~st.done

    def body(st: DKSState):
        return superstep(graph, st, cfg)

    return jax.lax.while_loop(cond, body, state)


def run_dks_batched(graph: DeviceGraph, kw_masks_batch: jax.Array,
                    cfg: DKSConfig) -> DKSState:
    """Serve a BATCH of queries in one device program.

    kw_masks_batch: bool[Q, m, V].  A thin alias for the lane-batched
    driver (:func:`repro.core.driver.run_lanes`): the query axis is the
    driver's lane axis, the fused while-loop steps until every lane's exit
    criterion fires, and finished lanes are frozen
    (:func:`freeze_finished`) so their counters stop with them.  Amortizes
    graph residency and kernel launches across the paper's 100-query
    workloads.
    """
    from repro.core.driver import run_lanes

    return run_lanes(graph, kw_masks_batch, cfg)


def run_dks_instrumented(
    graph: DeviceGraph,
    kw_masks: jax.Array,
    cfg: DKSConfig,
    exit_hook: Callable[[DKSState], bool] | None = None,
) -> tuple[DKSState, dict[str, Any]]:
    """Host-driven superstep loop with per-phase wall times (paper Table 1).

    A 1-lane instance of the driver's instrumented host loop
    (:func:`repro.core.driver.host_instrumented_loop`) over lane-batched
    phase kernels.  Phases timed: send_bfs (gather+add candidates),
    receive (segment top-K + merge), evaluate (subset combine = local-tree
    S_K computation), send_agg (aggregators + exit).  Deep messages share
    the relax kernel (DESIGN.md §2), so their share is attributed by
    message counts.

    ``exit_hook``: optional host-side exit criterion (e.g. the literal paper
    Eq. 2 check, fagin.paper_exit_hook) evaluated between supersteps.
    """
    from repro.core.driver import host_instrumented_loop

    def _relax_one(S, changed):
        send = changed[graph.src] & graph.valid
        cand = S[graph.src] + graph.w[:, None, None]
        cand = jnp.where(send[:, None, None], cand, INF)
        return semiring.bump_to_inf(cand)

    def _receive_one(S, cand):
        e_pad, n, k = cand.shape
        vals = cand.transpose(0, 2, 1).reshape(e_pad * k, n)
        seg = jnp.repeat(graph.dst, k)
        r = semiring.segment_topk_min(vals, seg, graph.v_pad, cfg.k)
        return semiring.topk_merge(S, r)

    @jax.jit
    def _phase_relax(S, changed):
        return jax.vmap(_relax_one)(S, changed)

    @jax.jit
    def _phase_receive(S, cand):
        return jax.vmap(_receive_one)(S, cand)

    @jax.jit
    def _phase_combine(S):
        return jax.vmap(lambda s: combine(s, cfg))(S)

    @jax.jit
    def _phase_agg(S0, state, _aux):
        return jax.vmap(
            lambda s0, st: finish_superstep(graph, s0, st, cfg))(S0, state)

    return host_instrumented_loop(
        graph, kw_masks, cfg, exit_hook,
        _phase_relax, _phase_receive, _phase_combine, _phase_agg)


def extract_answer_weights(state: DKSState, cfg: DKSConfig) -> np.ndarray:
    """Global top-K distinct answer weights (INF-padded)."""
    return np.asarray(state.topk_w)
