"""The paper's primary contribution: DKS — distributed keyword search
(top-K Group Steiner Trees) in the Pregel model, as dense JAX tensor algebra.

Public API:
  DKSConfig, DKSState                       — static config / superstep state
  run_dks                                   — jitted while-loop, one query
  run_lanes, lane_init, lane_superstep      — the lane-batched driver (one
                                              step kernel, L concurrent
                                              queries, both partitionings)
  run_dks_batched                           — lane-driver alias (query axis
                                              = lane axis)
  run_dks_instrumented                      — host loop w/ per-phase timings
  init_state, superstep, freeze_finished    — the loop's building blocks
  lane_view, freeze_lanes                   — lane-batch helpers
  extract_answers, AnswerTree               — aggregator-side answer trees
  collect_answers, finish_tree              — the same aggregator with the
                                              exhaustion flag + pluggable
                                              backtrace (repro.answers)
  extract_answer_weights                    — top-K weights only (no trees)
  dreyfus_wagner, brute_force_topk          — exact oracles (tests)

Most callers should not drive these directly: :class:`repro.engine.QueryEngine`
(re-exported here as ``QueryEngine`` / ``ExecutionPolicy`` / ``QueryResult`` /
``StreamUpdate``) wraps index lookup, mask padding, device residency, and
executable caching behind one facade.
"""

from repro.core.dks import (  # noqa: F401
    DKSConfig,
    DKSState,
    extract_answer_weights,
    freeze_finished,
    init_state,
    run_dks,
    run_dks_batched,
    run_dks_instrumented,
    superstep,
)
from repro.core.driver import (  # noqa: F401
    freeze_lanes,
    lane_init,
    lane_superstep,
    lane_view,
    run_lanes,
)
from repro.core.reconstruct import (  # noqa: F401
    AnswerTree,
    collect_answers,
    extract_answers,
    finish_tree,
)
from repro.core.steiner_ref import brute_force_topk, dreyfus_wagner  # noqa: F401

_ENGINE_EXPORTS = ("QueryEngine", "ExecutionPolicy", "QueryResult",
                   "StreamUpdate")


def __getattr__(name):
    # Lazy re-export: repro.engine imports from repro.core submodules, so an
    # eager import here would be circular.
    if name in _ENGINE_EXPORTS:
        import repro.engine as _engine
        return getattr(_engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
