"""The paper's primary contribution: DKS — distributed keyword search
(top-K Group Steiner Trees) in the Pregel model, as dense JAX tensor algebra.

Public API:
  DKSConfig, DKSState, run_dks, run_dks_instrumented  — the engine
  extract_answers                                      — aggregator-side trees
  dreyfus_wagner, brute_force_topk                     — exact oracles (tests)
"""

from repro.core.dks import (  # noqa: F401
    DKSConfig,
    DKSState,
    init_state,
    run_dks,
    run_dks_batched,
    run_dks_instrumented,
    superstep,
)
from repro.core.reconstruct import AnswerTree, extract_answers  # noqa: F401
from repro.core.steiner_ref import brute_force_topk, dreyfus_wagner  # noqa: F401
