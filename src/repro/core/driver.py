"""The lane-batched superstep driver — ONE step kernel for every executor.

The paper's DKS algorithm is one Pregel superstep loop, and Pregel-style
systems win by running many concurrent computations through a single
synchronized step loop (Malewicz et al.; Giraph in the paper's own
experiments).  This module is that structure: a :class:`DKSState` whose
every field carries an explicit leading **lane** axis (``L`` concurrent
queries), and one ``lane_superstep(graph, state, cfg) -> state`` kernel
that advances all lanes together and is correct for both partitionings:

- **dense** (:class:`~repro.graph.structure.DeviceGraph`): the dense
  :func:`~repro.core.dks.superstep` vmapped over the lane axis;
- **sharded** (:class:`~repro.core.dks_sharded.FrontierGraph`): the lane
  axis lives *inside* the ``shard_map`` body (lanes-per-shard,
  :func:`~repro.core.dks_sharded.relax_frontier_lanes`), so batching no
  longer needs vmap-over-shard_map — one device program relaxes every
  lane's frontier in one collective exchange.

Per-lane exit flags (``done`` / ``budget_hit`` / ``capped``) freeze lanes
individually (:func:`freeze_lanes`): a lane that proves its exit stops
accumulating counters while the driver keeps stepping the rest.  Every
engine surface is a thin loop over this driver — ``query`` is the
degenerate 1-lane case, ``query_batch`` a fused while-loop over a bucket
of lanes, streaming/deadline surfaces host-step it — so there is exactly
one superstep formulation to test, shard, and optimize.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.dks import (
    DKSConfig,
    DKSState,
    freeze_finished,
    init_state,
    superstep,
)
from repro.obs.telemetry import (
    HostTelemetryCollector,
    N_COLS as TELEMETRY_COLS,
    TELEMETRY_MAX_SUPERSTEPS,
)


def is_frontier_graph(graph: Any) -> bool:
    """Sharded (FrontierGraph) vs dense (DeviceGraph) residency, without
    importing dks_sharded at module load (it imports from dks)."""
    return hasattr(graph, "edge_dst_l")


def lane_view(state: DKSState, i: int) -> DKSState:
    """One lane of a lane-batched state, as an unbatched DKSState."""
    return jax.tree_util.tree_map(lambda x: x[i], state)


def lane_init(graph: Any, kw_masks: jax.Array, cfg: DKSConfig) -> DKSState:
    """Superstep 0 for a batch of lanes.  ``kw_masks``: bool[L, m, V]."""
    return jax.vmap(lambda m: init_state(graph, m, cfg))(kw_masks)


# Per-lane freeze: lanes whose exit criterion fired keep their state and
# counters while the driver steps the rest (rank-aware select on ``done``).
freeze_lanes = freeze_finished


def lane_superstep(graph: Any, state: DKSState, cfg: DKSConfig,
                   csr: Any = None) -> DKSState:
    """One Pregel superstep for every lane at once, finished lanes frozen.

    The single kernel behind every engine executor: dense lanes ride a
    vmapped :func:`~repro.core.dks.superstep`; sharded lanes share one
    frontier exchange inside the ``shard_map``
    (:func:`~repro.core.dks_sharded.relax_frontier_lanes`) with the
    node-local tail vmapped over lanes.

    ``csr``: a :class:`~repro.kernels.lane_superstep.LaneCSR` layout makes
    this the real ``backend="pallas"`` path on dense graphs — the whole
    inner loop (relax + hub merge + receive + combine + per-lane freeze)
    runs as ONE fused kernel launch over the lane axis
    (:func:`~repro.kernels.lane_superstep.fused_lane_superstep`),
    bit-identical to the vmapped jnp superstep.  The engine builds the
    layout once per graph (``QueryEngine.build``) and threads it here.
    Sharded graphs never take the fused path: the shard_map body keeps
    jnp (``ExecutionPolicy`` rejects the combination up front; see
    NotImplementedError there — fusing the sharded body is the remaining
    ROADMAP item).
    """
    if is_frontier_graph(graph):
        from repro.core.dks_sharded import frontier_tail, relax_frontier_lanes

        R, overflow = relax_frontier_lanes(graph, state.S, state.changed, cfg)
        nxt = jax.vmap(
            lambda st, r, ov: frontier_tail(graph, st, r, ov, cfg)
        )(state, R, overflow)
    elif csr is not None and cfg.relax_impl == "pallas":
        from repro.kernels.lane_superstep import fused_lane_superstep

        nxt = fused_lane_superstep(graph, csr, state, cfg)
    else:
        nxt = jax.vmap(lambda st: superstep(graph, st, cfg))(state)
    if state.done.shape[0] == 1:
        # Degenerate 1-lane case (engine.query, streams): every driving
        # loop stops at done, so the body never runs on a finished lane —
        # the freeze select would be a pure full-state where() per
        # superstep that XLA cannot fold (done is dynamic).  Lane count
        # is static at trace time, so this branch costs nothing.
        return nxt
    return freeze_lanes(state, nxt)


@functools.partial(jax.jit, static_argnames=("cfg",))
def run_lanes(graph: Any, kw_masks: jax.Array, cfg: DKSConfig) -> DKSState:
    """Full lane-batched DKS run as one jitted while-loop (the fused
    driver): steps until every lane's exit criterion fires.  Works on both
    partitionings; 1 lane is the single-query production path."""
    state = lane_init(graph, kw_masks, cfg)
    return jax.lax.while_loop(
        lambda st: ~jnp.all(st.done),
        lambda st: lane_superstep(graph, st, cfg),
        state)


# --------------------------------------------------------------------------
# Production superstep telemetry (paper §6's per-superstep curves, from
# the FUSED loop — no drop to the stepwise instrumented path)
# --------------------------------------------------------------------------


def telemetry_capacity(cfg: DKSConfig) -> int:
    """Device-buffer row count for a config: one row per superstep, capped
    at TELEMETRY_MAX_SUPERSTEPS (a capped run sets ``done`` anyway, so the
    cap only matters for configs with a larger max_supersteps)."""
    return max(1, min(int(cfg.max_supersteps), TELEMETRY_MAX_SUPERSTEPS))


def telemetry_row(state: DKSState) -> jax.Array:
    """One lane-summed counter row for the post-step state: ``[frontier,
    msgs_bfs (cumulative), msgs_deep (cumulative), frozen lanes]`` — the
    column order repro.obs.telemetry decodes.  Pure reads: computing the
    row cannot perturb the state, which is what makes telemetry-on
    bit-identical to telemetry-off."""
    return jnp.stack([
        jnp.sum(state.changed).astype(jnp.float32),
        jnp.sum(state.msgs_bfs).astype(jnp.float32),
        jnp.sum(state.msgs_deep).astype(jnp.float32),
        jnp.sum(state.done).astype(jnp.float32),
    ])


def run_lanes_telemetry(
    graph: Any, kw_masks: jax.Array, cfg: DKSConfig, csr: Any = None,
) -> tuple[DKSState, jax.Array, jax.Array]:
    """The fused driver with a telemetry carry: the while-loop threads
    ``(state, buf, i)`` and writes one :func:`telemetry_row` per superstep
    into a bounded ``[T, 4]`` f32 buffer (rows past T overwrite the last
    slot — the decoder flags truncation).  Returns ``(final state, buffer,
    supersteps run)``; same exit condition, same superstep kernel, so the
    state trajectory is exactly :func:`run_lanes`'s.

    Meant to be jitted by the caller (the engine caches it per config,
    like the plain fused executable).
    """
    T = telemetry_capacity(cfg)
    init = (lane_init(graph, kw_masks, cfg),
            jnp.zeros((T, TELEMETRY_COLS), jnp.float32),
            jnp.int32(0))

    def cond(carry):
        st, _, _ = carry
        return ~jnp.all(st.done)

    def body(carry):
        st, buf, i = carry
        nxt = lane_superstep(graph, st, cfg, csr=csr)
        buf = buf.at[jnp.minimum(i, T - 1)].set(telemetry_row(nxt))
        return nxt, buf, i + 1

    return jax.lax.while_loop(cond, body, init)


# --------------------------------------------------------------------------
# Instrumented host loop (per-phase wall times, paper Table 1)
# --------------------------------------------------------------------------


def host_instrumented_loop(
    graph: Any,
    kw_masks: jax.Array,
    cfg: DKSConfig,
    exit_hook: Callable[[DKSState], bool] | None,
    phase_relax: Callable,
    phase_receive: Callable,
    phase_combine: Callable,
    phase_agg: Callable,
) -> tuple[DKSState, dict[str, Any]]:
    """The host-driven per-phase superstep loop shared by the dense and
    sharded instrumented runners — one copy of the timing buckets, message
    accounting, history rows, and ``exit_hook`` contract.  The phases are
    the driver's lane-batched kernels run at ``L = 1`` (``kw_masks``:
    bool[m, V], un-batched; the final state is returned un-batched too).

    Phase signatures (each jitted by the caller, timed here; all on
    lane-batched arrays):
      phase_relax(S, changed) -> aux           "send_bfs"
      phase_receive(S, aux) -> S1              "receive"
      phase_combine(S1) -> S1                  "evaluate"
      phase_agg(S0, state, aux) -> state       "send_agg"
    ``aux`` is whatever relax must hand forward (per-edge candidates on the
    dense path; (R, overflow) on the sharded path).

    ``exit_hook`` sees an *un-batched* :class:`DKSState` (lane 0), so
    host-side criteria like ``fagin.paper_exit_hook`` keep working.
    """
    timings = {"send_bfs": 0.0, "receive": 0.0, "evaluate": 0.0,
               "send_agg": 0.0}
    state = jax.block_until_ready(lane_init(graph, kw_masks[None], cfg))
    deg = graph.out_degree.astype(jnp.float32)
    # One source of per-superstep truth: rows accumulate on the shared
    # collector (repro.obs) and the legacy ``history`` dicts are derived
    # from it — the fused telemetry path decodes the same columns.
    collector = HostTelemetryCollector()
    while not bool(state.done[0]):
        n_bfs = jnp.sum(jnp.where(state.first_fire, deg, 0.0), axis=1)
        n_deep = jnp.sum(
            jnp.where(state.changed & ~state.first_fire, deg, 0.0), axis=1)

        t0 = time.perf_counter()
        aux = jax.block_until_ready(phase_relax(state.S, state.changed))
        t1 = time.perf_counter()
        S1 = jax.block_until_ready(phase_receive(state.S, aux))
        t2 = time.perf_counter()
        S1 = jax.block_until_ready(phase_combine(S1))
        t3 = time.perf_counter()
        S0 = state.S
        state = dataclasses.replace(
            state,
            S=S1,
            msgs_bfs=state.msgs_bfs + n_bfs,
            msgs_deep=state.msgs_deep + n_deep,
            step=state.step + 1,
        )
        state = jax.block_until_ready(phase_agg(S0, state, aux))
        t4 = time.perf_counter()

        timings["send_bfs"] += t1 - t0
        timings["receive"] += t2 - t1
        timings["evaluate"] += t3 - t2
        timings["send_agg"] += t4 - t3
        lane = lane_view(state, 0)
        collector.record(
            frontier=int(jnp.sum(lane.changed)),
            msgs_bfs=float(lane.msgs_bfs),
            msgs_deep=float(lane.msgs_deep),
            frozen=int(jnp.sum(state.done)),
            best=float(lane.topk_w[0]),
        )
        if exit_hook is not None and exit_hook(lane):
            state = dataclasses.replace(
                state, done=jnp.ones_like(state.done))
    telemetry = collector.build()
    info = dict(timings=timings, history=telemetry.rows(),
                telemetry=telemetry)
    return lane_view(state, 0), info
