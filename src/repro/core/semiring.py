"""Top-K min-plus lattice operations.

The paper keeps, at every node and for every keyword-set ``k ⊆ Q``, the top-K
best partial-answer path-lengths (the ``S_K`` structure, Sec. 4/5.1).  On TPU
we realize ``S_K`` as a dense tensor ``S[V, 2^m, K]`` whose last axis is a
*sorted, duplicate-free, INF-padded* K-vector.  All DKS dataflow is then
algebra over this lattice:

- ``topk_merge``      — join of two K-vectors (Pregel "receive messages")
- ``outer_combine``   — min-plus product of two K-vectors (local-tree combine)
- ``segment_topk_min``— top-K min-reduce by segment id (message scatter)

Duplicate-free matters: Pregel vertices resend their whole table whenever
active, so the merge must be *idempotent* (merging the same table twice is a
no-op).  We therefore keep top-K **distinct weights** — this also implements
the paper's duplicate-answer removal at the aggregator (Sec. 4, Step 5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import INF


def sorted_unique_k(x: jax.Array, k: int) -> jax.Array:
    """Sort ascending along the last axis, drop duplicate values, pad with INF,
    and keep the first ``k`` entries.

    ``x``: (..., n) with n >= k.  Returns (..., k).
    """
    x = jnp.sort(x, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(x[..., :1], dtype=bool), x[..., 1:] == x[..., :-1]],
        axis=-1,
    )
    x = jnp.where(dup, INF, x)
    x = jnp.sort(x, axis=-1)
    return x[..., :k]


def topk_merge(a: jax.Array, b: jax.Array) -> jax.Array:
    """Merge two sorted-unique K-vectors into one (idempotent lattice join)."""
    k = a.shape[-1]
    return sorted_unique_k(jnp.concatenate([a, b], axis=-1), k)


def outer_combine(a: jax.Array, b: jax.Array) -> jax.Array:
    """Min-plus product: all pairwise sums of two K-vectors, reduced to the
    top-K distinct sums.  This is the paper's combination of two disjoint
    keyword-set partial answers at a node ((1+2K)^m analysis, Sec. 5.1).

    ``a``, ``b``: (..., K) -> (..., K).
    """
    k = a.shape[-1]
    s = a[..., :, None] + b[..., None, :]
    s = jnp.minimum(s, INF)  # saturate so INF+x does not overflow usefully
    return sorted_unique_k(s.reshape(*s.shape[:-2], k * k), k)


def segment_topk_min(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    k: int,
) -> jax.Array:
    """Exact per-segment top-K smallest *distinct* values.

    ``values``: (N, ...F) candidate values; ``segment_ids``: (N,) int32.
    Returns (num_segments, ...F, k), sorted-unique-INF-padded.

    Implementation: K rounds of (segment-min -> winner masking).  Each round
    extracts one distinct minimum per (segment, feature) cell; every candidate
    equal to the extracted minimum is masked (distinct-weight semantics), so
    K rounds suffice and the result is duplicate-free by construction.
    """
    vals = values
    outs = []
    for _ in range(k):
        cur = jax.ops.segment_min(
            vals, segment_ids, num_segments=num_segments,
            indices_are_sorted=False, unique_indices=False,
        )
        cur = jnp.minimum(cur, INF)
        outs.append(cur)
        # Mask every candidate equal to its segment's extracted minimum.
        vals = jnp.where(vals <= cur[segment_ids], INF, vals)
    out = jnp.stack(outs, axis=-1)
    return out


def bump_to_inf(x: jax.Array, thresh: float = INF * 0.5) -> jax.Array:
    """Saturate any value that drifted past thresh back to exactly INF."""
    return jnp.where(x >= thresh, INF, x)
