"""Exact Group-Steiner-Tree oracles (host-side, small graphs) for tests.

- :func:`dreyfus_wagner` — textbook exact optimum (Dijkstra-based DW DP),
  independent of the DKS engine's tensor formulation.
- :func:`brute_force_topk` — enumerates *all minimal answer-trees* on tiny
  graphs (paper Def. 2.1/2.2) and returns the top-K distinct weights.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Sequence

import numpy as np

from repro import INF
from repro.graph.structure import Graph


def _multi_source_dijkstra(g: Graph, sources: Sequence[int]) -> np.ndarray:
    dist = np.full(g.n_nodes, INF, np.float64)
    heap = []
    for s in sources:
        dist[s] = 0.0
        heap.append((0.0, int(s)))
    heapq.heapify(heap)
    while heap:
        d, v = heapq.heappop(heap)
        if d > dist[v]:
            continue
        nbrs, ws = g.neighbors(v)
        for u, w in zip(nbrs, ws):
            if w >= INF:
                continue
            nd = d + float(w)
            if nd < dist[u]:
                dist[u] = nd
                heapq.heappush(heap, (nd, int(u)))
    return dist


def _dijkstra_settle(g: Graph, init: np.ndarray) -> np.ndarray:
    """Settle arbitrary initial labels to shortest-path closure."""
    dist = init.copy()
    heap = [(float(d), int(v)) for v, d in enumerate(dist) if d < INF]
    heapq.heapify(heap)
    while heap:
        d, v = heapq.heappop(heap)
        if d > dist[v]:
            continue
        nbrs, ws = g.neighbors(v)
        for u, w in zip(nbrs, ws):
            if w >= INF:
                continue
            nd = d + float(w)
            if nd < dist[u]:
                dist[u] = nd
                heapq.heappush(heap, (nd, int(u)))
    return dist


def dreyfus_wagner(g: Graph, groups: Sequence[Sequence[int]]) -> float:
    """Exact minimum Group Steiner Tree weight (INF if infeasible)."""
    m = len(groups)
    full = (1 << m) - 1
    dp = np.full((full + 1, g.n_nodes), INF, np.float64)
    for i, grp in enumerate(groups):
        if len(grp) == 0:
            return float(INF)
        dp[1 << i] = _multi_source_dijkstra(g, grp)
    masks = sorted(range(1, full + 1), key=lambda t: bin(t).count("1"))
    for t in masks:
        if bin(t).count("1") == 1:
            continue
        a = (t - 1) & t
        while a:
            b = t ^ a
            if a <= b:
                dp[t] = np.minimum(dp[t], dp[a] + dp[b])
            a = (a - 1) & t
        dp[t] = _dijkstra_settle(g, np.minimum(dp[t], INF))
    best = dp[full].min()
    return float(best if best < INF else INF)


def _is_tree(n_nodes_in_tree: int, edges: list[tuple[int, int]]) -> bool:
    if len(edges) != n_nodes_in_tree - 1:
        return False
    # Connectivity via union-find.
    parent: dict[int, int] = {}

    def find(x):
        while parent.setdefault(x, x) != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in edges:
        ru, rv = find(u), find(v)
        if ru == rv:
            return False
        parent[ru] = rv
    return True


def brute_force_topk(
    g: Graph, groups: Sequence[Sequence[int]], k: int,
    max_edges: int | None = None,
) -> list[float]:
    """Top-K distinct weights over all *minimal* answer-trees (tiny graphs).

    Enumerates every subset of the symmetrized unique undirected edges whose
    induced subgraph is a tree covering all groups and is minimal (every leaf
    is required for coverage).
    """
    # Unique undirected edges with min weight.
    seen: dict[tuple[int, int], float] = {}
    for v in range(g.n_nodes):
        nbrs, ws = g.neighbors(v)
        for u, w in zip(nbrs, ws):
            if w >= INF:
                continue
            key = (min(v, int(u)), max(v, int(u)))
            if key not in seen or w < seen[key]:
                seen[key] = float(w)
    edges = list(seen.items())
    if max_edges is not None and len(edges) > max_edges:
        raise ValueError(f"graph too large for brute force: {len(edges)} edges")

    group_sets = [set(map(int, grp)) for grp in groups]
    weights: set[float] = set()

    # Single-node answers (a node containing every keyword).
    common = set(range(g.n_nodes))
    for gs in group_sets:
        common &= gs
    if common:
        weights.add(0.0)

    for r in range(1, len(edges) + 1):
        for combo in itertools.combinations(edges, r):
            es = [e for e, _ in combo]
            nodes = set()
            for u, v in es:
                nodes.add(u)
                nodes.add(v)
            if not _is_tree(len(nodes), es):
                continue
            if not all(nodes & gs for gs in group_sets):
                continue
            # Minimality: every leaf must be essential for coverage.
            deg: dict[int, int] = {}
            for u, v in es:
                deg[u] = deg.get(u, 0) + 1
                deg[v] = deg.get(v, 0) + 1
            minimal = True
            for leaf in [n for n, d in deg.items() if d == 1]:
                rest = nodes - {leaf}
                if all(rest & gs for gs in group_sets):
                    minimal = False
                    break
            if minimal:
                weights.add(round(sum(w for _, w in combo), 6))
    out = sorted(weights)[:k]
    return out + [float(INF)] * (k - len(out))
