"""Frontier-compressed sharded DKS (the production multi-pod path).

The dense relax under plain pjit makes XLA replicate the whole ``S`` table
for the edge gather (measured 1.93 GiB/device/superstep on bluk-bnb — see
EXPERIMENTS.md §Perf).  But Pregel semantics only need the tables of
*active* vertices on the wire.  This module is that observation as a
shard_map:

  1. each shard packs (global id, table) for up to ``f_cap`` changed nodes;
  2. one all-gather moves only the packed frontier;
  3. edges are pre-partitioned by destination owner (host-side), so each
     shard relaxes its own edges against the gathered frontier via a
     sorted-id binary search, reducing locally with the K-round
     segment-top-K.

Frontier overflow (> f_cap active nodes on some shard) raises the
``budget_hit`` flag — precisely the paper's Sec. 5.4 forced stop: the run
finishes with the SPA bound instead of silently dropping messages.

The relax kernel is **lane-batched** (:func:`relax_frontier_lanes`): the
lane axis of the driver (:mod:`repro.core.driver`) lives *inside* the
shard_map body, so a whole bucket of concurrent queries shares one
frontier all-gather per superstep — shard_map under vmap (unsupported in
jax) is never needed.  The single-query entry points are its 1-lane case.

Combine stays node-local (node axis sharded over ALL mesh axes, keyword-set
axis replicated), so it needs no collectives at all.

The mesh is *explicit*: :func:`pack_frontier_graph` records it on the
:class:`FrontierGraph` (a static pytree field), and every executor reads it
from there — no ambient ``get_abstract_mesh()`` state.  All shard_map/mesh
API calls go through :mod:`repro.shardmap`, so this path runs on both jax
0.4.x and >= 0.7.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import INF, shardmap
from repro.core import semiring
from repro.core.dks import (
    DKSConfig,
    DKSState,
    combine,
    finish_superstep,
)
from repro.graph.structure import Graph

MESH_AXES = ("pod", "data", "model")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FrontierGraph:
    """Edges partitioned by destination owner; node arrays over all axes.

    edge_src:   i32[n_shards, e_cap]  global source ids (-1 pad)
    edge_dst_l: i32[n_shards, e_cap]  destination LOCAL index on its shard
    edge_w:     f32[n_shards, e_cap]  (INF pad)
    out_degree: i32[V_pad]; node_valid: bool[V_pad]
    mesh:       the device mesh the shards live on (static; executors read
                it from here instead of ambient ``get_abstract_mesh`` state)
    """

    edge_src: jax.Array
    edge_dst_l: jax.Array
    edge_w: jax.Array
    out_degree: jax.Array
    node_valid: jax.Array
    n_nodes: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))
    n_shards: int = dataclasses.field(metadata=dict(static=True))
    mesh: Any = dataclasses.field(default=None, metadata=dict(static=True))

    @property
    def v_pad(self) -> int:
        return self.node_valid.shape[0]

    @property
    def n_loc(self) -> int:
        return self.v_pad // self.n_shards

    def e_min(self) -> jax.Array:
        return jnp.min(jnp.where(self.edge_w < INF, self.edge_w, INF))


def pack_frontier_graph(g: Graph, n_shards: int | None = None,
                        e_slack: float = 1.2,
                        mesh: Any = None) -> FrontierGraph:
    """Host-side: symmetrized edges grouped by dst owner, padded rows.

    ``mesh``: the mesh the shards will execute on; recorded on the result so
    the executors need no ambient mesh state.  ``n_shards`` defaults to the
    mesh's device count when a mesh is given.
    """
    if n_shards is None:
        if mesh is None:
            raise ValueError("pack_frontier_graph needs n_shards= or mesh=")
        n_shards = int(math.prod(mesh.shape.values()))
    v_pad = int(-(-g.n_nodes // n_shards) * n_shards)
    n_loc = v_pad // n_shards
    deg = np.diff(g.indptr)
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int32), deg)
    dst = g.indices.astype(np.int32)
    w = g.ew.astype(np.float32)
    owner = dst // n_loc
    counts = np.bincount(owner, minlength=n_shards)
    e_cap = int(max(8, -(-int(counts.max() * 1.0) // 8) * 8))
    edge_src = np.full((n_shards, e_cap), -1, np.int32)
    edge_dst_l = np.zeros((n_shards, e_cap), np.int32)
    edge_w = np.full((n_shards, e_cap), INF, np.float32)
    order = np.argsort(owner, kind="stable")
    src, dst, w, owner = src[order], dst[order], w[order], owner[order]
    starts = np.concatenate([[0], np.cumsum(counts)])
    for s in range(n_shards):
        lo, hi = starts[s], starts[s + 1]
        n = hi - lo
        edge_src[s, :n] = src[lo:hi]
        edge_dst_l[s, :n] = dst[lo:hi] - s * n_loc
        edge_w[s, :n] = w[lo:hi]
    out_degree = np.zeros(v_pad, np.int32)
    out_degree[: g.n_nodes] = deg
    node_valid = np.zeros(v_pad, bool)
    node_valid[: g.n_nodes] = True
    return FrontierGraph(
        edge_src=jnp.asarray(edge_src), edge_dst_l=jnp.asarray(edge_dst_l),
        edge_w=jnp.asarray(edge_w), out_degree=jnp.asarray(out_degree),
        node_valid=jnp.asarray(node_valid),
        n_nodes=g.n_nodes, n_edges=len(src), n_shards=n_shards, mesh=mesh)


def _mesh_axes(am) -> tuple[str, ...]:
    return tuple(a for a in MESH_AXES if a in am.axis_names)


def _graph_mesh(graph: FrontierGraph):
    """The graph's recorded mesh; ambient mesh_scope only as a legacy
    fallback for FrontierGraphs packed without one."""
    mesh = graph.mesh if graph.mesh is not None else shardmap.get_abstract_mesh()
    if mesh is None:
        raise ValueError(
            "sharded DKS needs a mesh: pack_frontier_graph(..., mesh=...) "
            "(or run under repro.shardmap.mesh_scope)")
    return mesh


def relax_frontier_lanes(graph: FrontierGraph, S: jax.Array,
                         changed: jax.Array, cfg: DKSConfig,
                         ) -> tuple[jax.Array, jax.Array]:
    """Lane-batched frontier-compressed relax — THE sharded relax kernel.

    ``S``: f32[L, V, 2^m, K]; ``changed``: bool[L, V].  The lane axis
    lives *inside* the ``shard_map`` body (lanes-per-shard): every lane's
    frontier is packed per shard and exchanged in ONE all-gather, so a
    batch of queries costs one device program and one collective per
    superstep instead of vmap-over-shard_map (which jax does not
    support).  Returns ``(R[L, V, 2^m, K], overflow bool[L])``.
    """
    am = _graph_mesh(graph)
    axes = _mesh_axes(am)
    n_shards = graph.n_shards
    n_loc = graph.n_loc
    f_cap = min(n_loc, max(1, int(n_loc * cfg.frontier_frac)))
    n_sets, k = S.shape[2], S.shape[3]
    f_tot = n_shards * f_cap

    def block(S_loc, changed_loc, src_g, dst_l, w, shard_arange):
        # S_loc: [L, n_loc, n_sets, k]; changed_loc: [L, n_loc]
        src_g = src_g[0]
        dst_l = dst_l[0]
        w = w[0]
        shard_id = shard_arange[0]
        offset = shard_id * n_loc
        # Pack each lane's local frontier (ids ascending; invalid slots
        # OOB-marked).  sort-of-keyed-arange == nonzero(size=f_cap,
        # fill_value=n_loc), but lane-batched without a vmapped nonzero.
        arange = jnp.arange(n_loc, dtype=jnp.int32)
        key = jnp.where(changed_loc, arange[None, :], jnp.int32(n_loc))
        idx = jnp.sort(key, axis=1)[:, :f_cap]              # [L, f_cap]
        fvalid = idx < n_loc
        tab = jnp.take_along_axis(
            S_loc, jnp.minimum(idx, n_loc - 1)[:, :, None, None], axis=1)
        tab = jnp.where(fvalid[:, :, None, None], tab, INF)
        gids = jnp.where(fvalid, idx + offset, jnp.int32(2**30) + idx)
        overflow = jnp.sum(changed_loc, axis=1) > f_cap     # [L]
        # Exchange only the frontiers — one collective for all lanes.
        all_gids = jax.lax.all_gather(
            gids, axes, tiled=True, axis=1)                 # [L, F_tot]
        all_tab = jax.lax.all_gather(
            tab, axes, tiled=True, axis=1)                  # [L,F_tot,S,K]

        def relax_lane(gids_l, tab_l):
            # Relax local edges against one lane's gathered frontier.
            order = jnp.argsort(gids_l)
            sg = gids_l[order]
            st = tab_l[order]
            pos = jnp.clip(jnp.searchsorted(sg, src_g), 0, f_tot - 1)
            hit = (sg[pos] == src_g) & (src_g >= 0)
            cand = st[pos] + w[:, None, None]
            cand = jnp.where(hit[:, None, None], cand, INF)
            cand = semiring.bump_to_inf(cand)
            e_cap = cand.shape[0]
            vals = cand.transpose(0, 2, 1).reshape(e_cap * k, n_sets)
            seg = jnp.repeat(dst_l, k)
            return semiring.segment_topk_min(vals, seg, n_loc, k)

        r_loc = jax.vmap(relax_lane)(all_gids, all_tab)  # [L,n_loc,S,K]
        ov = jax.lax.pmax(overflow.astype(jnp.int32), axes)
        return r_loc, ov

    in_specs = (
        P(None, axes, None, None),  # S (node axis over all mesh axes)
        P(None, axes),              # changed
        P(axes, None),              # edge_src [n_shards, e_cap]
        P(axes, None),              # edge_dst_l
        P(axes, None),              # edge_w
        P(axes),                    # shard ids
    )
    out_specs = (P(None, axes, None, None), P(None))
    shard_arange = jnp.arange(n_shards, dtype=jnp.int32)
    r, ov = shardmap.shard_map(
        block, mesh=am, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )(S, changed, graph.edge_src, graph.edge_dst_l, graph.edge_w,
      shard_arange)
    return r, ov > 0


def relax_frontier(graph: FrontierGraph, S: jax.Array, changed: jax.Array,
                   cfg: DKSConfig) -> tuple[jax.Array, jax.Array]:
    """Frontier-compressed relax, single-query: the 1-lane case of
    :func:`relax_frontier_lanes`.  Returns (R[V, 2^m, K], overflow bool)."""
    r, ov = relax_frontier_lanes(graph, S[None], changed[None], cfg)
    return r[0], ov[0]


def frontier_tail(graph: FrontierGraph, state: DKSState, R: jax.Array,
                  overflow: jax.Array, cfg: DKSConfig) -> DKSState:
    """Everything after the frontier relax, per lane: message accounting,
    top-K merge, subset combine, and the shared superstep finish (node
    axis sharded over the mesh, keyword-set axis replicated — no
    collectives).  The lane driver vmaps this over its lane axis."""
    S0 = state.S
    deg = graph.out_degree.astype(jnp.float32)
    n_bfs = jnp.sum(jnp.where(state.first_fire, deg, 0.0))
    n_deep = jnp.sum(jnp.where(state.changed & ~state.first_fire, deg, 0.0))

    S1 = semiring.topk_merge(S0, R)
    S1 = combine(S1, cfg)
    nxt = dataclasses.replace(
        state, S=S1,
        msgs_bfs=state.msgs_bfs + n_bfs, msgs_deep=state.msgs_deep + n_deep,
        step=state.step + 1,
    )
    return finish_superstep(graph, S0, nxt, cfg, overflow=overflow)


def superstep_frontier(graph: FrontierGraph, state: DKSState,
                       cfg: DKSConfig) -> DKSState:
    """One superstep with frontier-compressed communication (1 lane)."""
    R, overflow = relax_frontier(graph, state.S, state.changed, cfg)
    return frontier_tail(graph, state, R, overflow, cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def run_dks_frontier(graph: FrontierGraph, kw_masks: jax.Array,
                     cfg: DKSConfig) -> DKSState:
    """Full frontier-sharded DKS run (jitted while-loop)."""
    from repro.core.dks import init_state

    state = init_state(graph, kw_masks, cfg)
    return jax.lax.while_loop(
        lambda st: ~st.done,
        lambda st: superstep_frontier(graph, st, cfg),
        state)


def run_dks_frontier_instrumented(
    graph: FrontierGraph,
    kw_masks: jax.Array,
    cfg: DKSConfig,
    exit_hook: Callable[[DKSState], bool] | None = None,
) -> tuple[DKSState, dict[str, Any]]:
    """Host-driven frontier-sharded loop with per-phase wall times — the
    sharded counterpart of :func:`repro.core.dks.run_dks_instrumented`
    (same ``timings`` keys, same ``history`` rows, same ``exit_hook``
    contract), so ``QueryEngine.query_instrumented`` serves both
    partitionings.

    Phase attribution differs from the dense path where the sharded
    dataflow forces it to: the frontier pack + all-gather + edge relax are
    fused inside one shard_map (:func:`relax_frontier_lanes`) and cannot
    be timed apart, so that whole exchange lands in "send_bfs"; "receive"
    is the per-node top-K merge of what arrived; "evaluate" (subset
    combine) and "send_agg" (aggregators + exit check) match the dense
    buckets.  Like the dense runner this is a 1-lane instance of the
    driver's instrumented host loop over the lane-batched phase kernels.
    """
    from repro.core.driver import host_instrumented_loop

    @jax.jit
    def _phase_relax(S, changed):
        return relax_frontier_lanes(graph, S, changed, cfg)

    @jax.jit
    def _phase_receive(S, aux):
        R, _overflow = aux
        return semiring.topk_merge(S, R)

    @jax.jit
    def _phase_combine(S):
        return jax.vmap(lambda s: combine(s, cfg))(S)

    @jax.jit
    def _phase_agg(S0, state, aux):
        _R, overflow = aux
        return jax.vmap(
            lambda s0, st, ov: finish_superstep(graph, s0, st, cfg,
                                                overflow=ov)
        )(S0, state, overflow)

    return host_instrumented_loop(
        graph, kw_masks, cfg, exit_hook,
        _phase_relax, _phase_receive, _phase_combine, _phase_agg)
