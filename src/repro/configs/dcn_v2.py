"""dcn-v2 [recsys] — 13 dense + 26 sparse fields, embed_dim=16,
3 cross layers, MLP 1024-1024-512, cross interaction. [arXiv:2008.13535]

Vocab sizes follow the Criteo-1TB hashed regime: a few huge fields
(10^7), a tail of small ones.
"""

from repro.configs.base import RecsysConfig

_VOCABS = (
    10_000_000, 10_000_000, 5_000_000,           # 3 huge id-like fields
    1_000_000, 1_000_000, 1_000_000, 500_000, 500_000,   # 5 large
    100_000, 100_000, 100_000, 50_000, 50_000, 50_000, 10_000, 10_000,  # mid
    10_000, 5_000, 5_000, 1_000, 1_000, 1_000, 500, 100, 100, 50,       # small
)

CONFIG = RecsysConfig(
    name="dcn-v2",
    n_dense=13,
    n_sparse=26,
    embed_dim=16,
    n_cross_layers=3,
    mlp_dims=(1024, 1024, 512),
    vocab_sizes=_VOCABS,
)

assert len(_VOCABS) == CONFIG.n_sparse
