"""schnet [gnn] — 3 interactions d_hidden=64 rbf=300 cutoff=10.
[arXiv:1706.08566]"""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="schnet",
    family="schnet",
    n_layers=3,          # n_interactions
    d_hidden=64,
    rbf=300,
    cutoff=10.0,
)
