"""Architecture registry: ``--arch <id>`` resolves here.

10 assigned architectures (5 LM, 4 GNN, 1 recsys), each paired with its
family's shape set, plus the paper's own DKS benchmark configs.
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.configs import (
    chatglm3_6b, command_r_plus_104b, dbrx_132b, dcn_v2, dks_paper,
    gat_cora, gin_tu, granite_moe_3b_a800m, pna, qwen15_4b, schnet,
)
from repro.configs.base import (
    GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES, GNNConfig, GNNShape, LMConfig,
    LMShape, RecsysConfig, RecsysShape,
)


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    family: str          # "lm" | "gnn" | "recsys"
    config: Any
    shapes: tuple


ARCHS: dict[str, ArchEntry] = {
    "qwen1.5-4b": ArchEntry("qwen1.5-4b", "lm", qwen15_4b.CONFIG, LM_SHAPES),
    "chatglm3-6b": ArchEntry("chatglm3-6b", "lm", chatglm3_6b.CONFIG, LM_SHAPES),
    "command-r-plus-104b": ArchEntry(
        "command-r-plus-104b", "lm", command_r_plus_104b.CONFIG, LM_SHAPES),
    "dbrx-132b": ArchEntry("dbrx-132b", "lm", dbrx_132b.CONFIG, LM_SHAPES),
    "granite-moe-3b-a800m": ArchEntry(
        "granite-moe-3b-a800m", "lm", granite_moe_3b_a800m.CONFIG, LM_SHAPES),
    "gat-cora": ArchEntry("gat-cora", "gnn", gat_cora.CONFIG, GNN_SHAPES),
    "schnet": ArchEntry("schnet", "gnn", schnet.CONFIG, GNN_SHAPES),
    "gin-tu": ArchEntry("gin-tu", "gnn", gin_tu.CONFIG, GNN_SHAPES),
    "pna": ArchEntry("pna", "gnn", pna.CONFIG, GNN_SHAPES),
    "dcn-v2": ArchEntry("dcn-v2", "recsys", dcn_v2.CONFIG, RECSYS_SHAPES),
}

DKS_CONFIGS = {
    "sec-rdfabout": dks_paper.SEC_RDFABOUT,
    "bluk-bnb": dks_paper.BLUK_BNB,
    "sec-rdfabout-cpu": dks_paper.SEC_RDFABOUT_CPU,
    "bluk-bnb-cpu": dks_paper.BLUK_BNB_CPU,
}


def get_arch(arch_id: str) -> ArchEntry:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_cells() -> list[tuple[str, str]]:
    """Every assigned (arch, shape) pair — 40 cells."""
    return [(a.arch_id, s.name) for a in ARCHS.values() for s in a.shapes]
