"""chatglm3-6b [dense] — 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024, RoPE 2d (partial rotary), GQA. [arXiv:2406.12793; hf]"""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="chatglm3-6b",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    head_dim=128,
    rotary_pct=0.5,  # ChatGLM 2d-RoPE: half the head dims rotate
)
