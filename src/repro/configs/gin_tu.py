"""gin-tu [gnn] — 5 layers d_hidden=64 sum aggregator, learnable eps.
[arXiv:1810.00826]"""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gin-tu",
    family="gin",
    n_layers=5,
    d_hidden=64,
    aggregators=("sum",),
    learnable_eps=True,
    n_classes=2,
)
