"""Config dataclasses for the assigned architectures and input shapes."""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    rotary_pct: float = 1.0
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    moe: MoESpec | None = None
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    remat: bool = True

    def scaled(self, **kw) -> "LMConfig":
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "LMConfig":
        """Reduced config: same family/topology, tiny dims (CPU smoke tests)."""
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
            )
        return dataclasses.replace(
            self, n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128, vocab=256, head_dim=16, moe=moe,
        )

    def param_count_analytic(self) -> int:
        """6·N·D MODEL_FLOPS uses this N (embeddings included once)."""
        d, l = self.d_model, self.n_layers
        attn = d * self.n_heads * self.head_dim * 2  # q + o
        attn += d * self.n_kv_heads * self.head_dim * 2  # k + v
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
        if self.moe is not None:
            ffn = 3 * d * self.moe.d_ff_expert * self.moe.n_experts
            ffn += d * self.moe.n_experts  # router
        else:
            ffn = 3 * d * self.d_ff
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ffn + 2 * d) + embed + d

    def active_param_count(self) -> int:
        if self.moe is None:
            return self.param_count_analytic()
        d, l = self.d_model, self.n_layers
        attn = d * self.n_heads * self.head_dim * 2
        attn += d * self.n_kv_heads * self.head_dim * 2
        ffn = 3 * d * self.moe.d_ff_expert * self.moe.top_k + d * self.moe.n_experts
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ffn + 2 * d) + embed + d


@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


LM_SHAPES = (
    LMShape("train_4k", "train", 4096, 256),
    LMShape("prefill_32k", "prefill", 32768, 32),
    LMShape("decode_32k", "decode", 32768, 128),
    LMShape("long_500k", "decode", 524288, 1),
)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    family: str               # "gat" | "schnet" | "gin" | "pna"
    n_layers: int
    d_hidden: int
    n_heads: int = 1
    aggregators: tuple[str, ...] = ("sum",)
    scalers: tuple[str, ...] = ("identity",)
    rbf: int = 0              # schnet radial basis size
    cutoff: float = 0.0
    learnable_eps: bool = False
    n_classes: int = 16
    param_dtype: str = "float32"
    mp_dtype: str = "float32"   # message-passing dtype: "bfloat16" halves
    # edge-gather traffic/wire bytes (production cells; see §Perf)

    def smoke(self) -> "GNNConfig":
        return dataclasses.replace(self, d_hidden=min(self.d_hidden, 16),
                                   rbf=min(self.rbf, 16) if self.rbf else 0)


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str                # "full_graph" | "minibatch" | "molecule"
    n_nodes: int
    n_edges: int
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple[int, ...] = ()
    batch_graphs: int = 0


GNN_SHAPES = (
    GNNShape("full_graph_sm", "full_graph", 2_708, 10_556, d_feat=1_433),
    GNNShape("minibatch_lg", "minibatch", 232_965, 114_615_892,
             d_feat=602, batch_nodes=1_024, fanout=(15, 10)),
    GNNShape("ogb_products", "full_graph", 2_449_029, 61_859_140, d_feat=100),
    GNNShape("molecule", "molecule", 30, 64, d_feat=16, batch_graphs=128),
)


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    n_dense: int
    n_sparse: int
    embed_dim: int
    n_cross_layers: int
    mlp_dims: tuple[int, ...]
    vocab_sizes: tuple[int, ...]  # one per sparse field
    param_dtype: str = "float32"

    def smoke(self) -> "RecsysConfig":
        return dataclasses.replace(
            self, embed_dim=8, mlp_dims=(32, 16),
            vocab_sizes=tuple(min(v, 100) for v in self.vocab_sizes),
        )


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    kind: str                 # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


RECSYS_SHAPES = (
    RecsysShape("train_batch", "train", 65_536),
    RecsysShape("serve_p99", "serve", 512),
    RecsysShape("serve_bulk", "serve", 262_144),
    RecsysShape("retrieval_cand", "retrieval", 1, n_candidates=1_000_000),
)


@dataclasses.dataclass(frozen=True)
class DKSBenchConfig:
    """The paper's own experiment configuration (synthetic LOD stand-ins)."""

    name: str
    n_nodes: int
    n_edges: int
    vocab: int
    tau: int = 1001
    seed: int = 7
