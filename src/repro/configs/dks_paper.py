"""The paper's own experiment configurations (Sec. 7.1).

The two LOD datasets are not redistributable; these are synthetic
structurally-similar stand-ins (power-law degree, Zipf labels) at the
paper's node/edge scales for the dry-run, plus CPU-scaled variants the
benchmarks actually execute.
"""

from repro.configs.base import DKSBenchConfig

# Paper-scale (dry-run / roofline only — ShapeDtypeStructs, no allocation).
SEC_RDFABOUT = DKSBenchConfig(
    name="sec-rdfabout", n_nodes=460_451, n_edges=500_384, vocab=50_000)
BLUK_BNB = DKSBenchConfig(
    name="bluk-bnb", n_nodes=16_100_000, n_edges=46_600_000, vocab=500_000)

# CPU-scaled stand-ins (benchmarks execute these end-to-end).
SEC_RDFABOUT_CPU = DKSBenchConfig(
    name="sec-rdfabout-cpu", n_nodes=46_000, n_edges=50_000, vocab=5_000)
BLUK_BNB_CPU = DKSBenchConfig(
    name="bluk-bnb-cpu", n_nodes=80_000, n_edges=230_000, vocab=8_000)
