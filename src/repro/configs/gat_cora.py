"""gat-cora [gnn] — 2 layers d_hidden=8 8 heads, attention aggregator.
[arXiv:1710.10903]"""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gat-cora",
    family="gat",
    n_layers=2,
    d_hidden=8,
    n_heads=8,
    aggregators=("attn",),
    n_classes=7,
)
