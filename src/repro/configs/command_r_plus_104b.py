"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000, no-bias. [hf:CohereForAI/c4ai-command-r-v01]"""

from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="command-r-plus-104b",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab=256000,
    head_dim=128,
)
