"""pna [gnn] — 4 layers d_hidden=75, aggregators mean-max-min-std,
scalers identity-amplification-attenuation. [arXiv:2004.05718]"""

from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="pna",
    family="pna",
    n_layers=4,
    d_hidden=75,
    aggregators=("mean", "max", "min", "std"),
    scalers=("identity", "amplification", "attenuation"),
    n_classes=16,
)
