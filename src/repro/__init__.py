"""repro: Distributed Keyword Search (DKS) — relationship queries on large
graphs using the Pregel model, built as a production JAX/TPU framework.

Paper: "Relationship Queries on Large graphs using Pregel"
       (Agarwal, Ramanath, Shroff; 2016).
"""

__version__ = "0.1.0"

INF = 1e9  # finite +infinity sentinel: keeps the min-plus algebra total
