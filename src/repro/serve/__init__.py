"""repro.serve — the serving subsystem on top of :class:`QueryEngine`.

Turns the engine's one-blocking-call-at-a-time query surface into an
online answer-ranking service (the workload EMBANKS/KlusTree frame, and
the ROADMAP's heavy-traffic north star):

    from repro.serve import DKSService, ServeConfig

    with DKSService(engine, ServeConfig(max_batch=8, max_wait_ms=5.0)) as svc:
        served = svc.query(["paris", "piano"], k=3, deadline_ms=50.0)
    print(svc.stats().summary())

Public API:
  DKSService    — admission + dynamic micro-batching (shape-bucketed
                  through the engine's vmapped executors), LRU result
                  cache, cross-request single-flight (concurrent
                  identical misses execute once), and deadline-bounded
                  best-so-far answers with SPA lower bounds (paper
                  Sec. 5.4 as a serving feature).
  ServeConfig   — max_batch / max_wait_ms / cache_size / padding / tree
                  serving knobs.
  ServedResult  — QueryResult + cache_hit / approximate / opt_lower_bound
                  / batch_size / latency_ms / trees (a TreePage when the
                  request asked with return_trees=True: label-rendered,
                  diversity- or weight-ranked, cursor-paginated answer
                  trees backed by a tree-pool LRU keyed on cache_token).
  ServeStats    — p50/p95 latency (end-to-end plus queue-wait/device-time
                  splits), throughput, batch-fill, cache-hit rate,
                  tree-request counters.
  ResultCache   — the LRU (exposed for direct use and tests).
  TreePage / RenderedTree / RenderedEdge — the served tree payloads
                  (re-exported from repro.answers).
  loadgen       — synthetic traces + concurrent replay clients
                  (make_trace / replay / TraceRequest / latency_split).

Observability (:mod:`repro.obs`): every admitted request carries a trace
(``ServedResult.trace_id`` -> ``svc.trace(id)``), and ``svc.registry``
exposes the ServeStats counters, engine executor/extraction counters,
and latency histograms in Prometheus text format (``serve_dks
--metrics-port`` serves it over HTTP).
"""

from repro.answers import RenderedEdge, RenderedTree, TreePage  # noqa: F401
from repro.serve.cache import ResultCache  # noqa: F401
from repro.serve.service import (  # noqa: F401
    DKSService,
    ServeConfig,
    ServedResult,
)
from repro.serve.stats import ServeStats  # noqa: F401
