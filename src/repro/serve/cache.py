"""LRU result cache for served queries.

Keys come from :meth:`repro.engine.QueryEngine.cache_token` — the
normalized keyword multiset plus ``(k, effective policy, engine build
version)`` — so permuted queries hit the same entry, any policy override
misses, and results computed against a previous graph build can never be
served (a rebuilt engine carries a fresh version).  Values are the full
:class:`~repro.engine.QueryResult` (answers are host objects; ``state`` is
dropped by default at query time, so entries don't pin device memory).

Only *exact* results belong here: a deadline-terminated best-so-far answer
is a property of that request's budget, not of the query, and
:class:`~repro.serve.service.DKSService` never inserts one.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Hashable


class ResultCache:
    """Thread-safe LRU with hit/miss/eviction counters.

    ``capacity <= 0`` disables the cache entirely: gets return None without
    counting, puts are dropped — so a cache-less service reports a 0/0
    counter line instead of a fake 100% miss rate.
    """

    def __init__(self, capacity: int) -> None:
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._entries: OrderedDict[Hashable, Any] = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def get(self, key: Hashable, *, count_miss: bool = True) -> Any | None:
        """Lookup; hits always count.  ``count_miss=False`` defers the
        miss counter to an explicit :meth:`count_miss` — for callers that
        only know after admission whether the miss will actually be
        served (a rejected request must not skew the miss rate)."""
        if not self.enabled:
            return None
        with self._lock:
            if key in self._entries:
                self._entries.move_to_end(key)
                self._hits += 1
                return self._entries[key]
            if count_miss:
                self._misses += 1
            return None

    def count_miss(self) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._misses += 1

    def put(self, key: Hashable, value: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self._evictions += 1

    def invalidate(self) -> int:
        """Drop every entry (graph rebuild, explicit flush).  Returns how
        many entries were dropped; they are not counted as evictions."""
        with self._lock:
            n = len(self._entries)
            self._entries.clear()
            return n

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {
                "size": len(self._entries),
                "capacity": self.capacity,
                "hits": self._hits,
                "misses": self._misses,
                "evictions": self._evictions,
            }
