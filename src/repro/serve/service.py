"""DKSService — the serving layer in front of :class:`QueryEngine`.

The paper's headline guarantee (Sec. 5.4 / Fig. 12) — a DKS run stopped
early still yields ranked answers with a sound lower bound — is exactly
the contract a latency-budgeted query service needs.  This module turns
the engine into that service:

- **admission + dynamic micro-batching** — concurrent requests coalesce
  into ``(m, k)``-shape buckets and dispatch through the engine's vmapped
  batch executors, amortizing device dispatch (and, via shape-padded
  buckets, compilation) across clients;
- **a result cache** — LRU keyed on the engine's normalized cache token
  (keyword multiset + ``(k, policy)`` + engine build version), with
  hit/miss/eviction stats and explicit invalidation on graph rebuild;
- **cross-request single-flight** — a cache miss identical to a request
  already executing (same cache token) attaches to the in-flight future
  instead of dispatching again: N concurrent identical misses cost one
  device execution (``ServedResult.coalesced`` marks the attached ones);
- **deadline-bounded answers, coalesced** — a per-request latency budget
  routes the query through the engine's stepwise lane driver; same-shape
  same-budget requests ride ONE driver (``engine.query_deadline_batch``),
  lanes freeze individually as they prove exits, and on expiry every
  lane gets its own best-so-far answer *with* its per-lane SPA lower
  bound and ``approximate=True``.  Deadline throughput therefore stops
  scaling 1:1 with concurrency: N coalesced requests cost ~max
  supersteps, not the sum (``ServeStats.deadline_driver_supersteps`` vs
  ``deadline_lane_supersteps`` shows the sharing).

Usage::

    with DKSService(engine, ServeConfig(max_batch=8)) as svc:
        fut = svc.submit(["paris", "piano"], k=3)          # non-blocking
        served = svc.query(query, k=1, deadline_ms=50.0)   # blocking
        if served.approximate:
            print(served.result.weights, ">=", served.opt_lower_bound)
    print(svc.stats().summary())

All device work happens on the service's single dispatcher thread; client
threads only touch the cache, the admission queue, and their futures.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import CancelledError, Future
from typing import Hashable, Sequence

from repro.answers import TreePage, diversified_order, paginate
from repro.engine import QueryEngine, QueryResult
from repro.serve.batcher import MicroBatcher, Request
from repro.serve.cache import ResultCache
from repro.serve.stats import ServeStats, StatsCollector


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving knobs, fixed at service construction.

    Attributes:
      max_batch:   most requests coalesced into one device dispatch.
      max_wait_ms: admission window — a partial bucket dispatches once its
                   oldest request has waited this long.  The classic
                   trade: higher = better fill, worse p50.
      cache_size:  LRU entries; 0 disables the result cache.
      extract:     reconstruct AnswerTrees on served results (skip for
                   weight-only serving).
      strict:      reject queries with unmatched keywords at admission
                   (KeyError on the future) instead of poisoning a whole
                   co-batched dispatch.
      pad_batches: pad partial buckets up to a fixed lane count by
                   repeating the last query, so the lane driver sees few
                   distinct lane counts (each new count re-traces):
                   "pow2" (next power of two, the default), "max" (always
                   ``max_batch`` lanes), or "none".  Padding lanes burn
                   device FLOPs only — the engine skips host-side result
                   construction for them (``n_real=``) — and batch-fill
                   stats count real requests only.  Applies on both
                   partitionings (sharded lanes live inside the
                   shard_map, so a padding lane is a free-ish extra lane
                   there too) and to deadline buckets.
      default_deadline_ms: deadline applied when a request sets none.
                   Deadline requests coalesce with same-shape same-budget
                   requests onto one stepwise lane driver, but they are
                   host-stepped (per-superstep deadline checks) and
                   exempt from the result cache and single-flight — so a
                   blanket default still costs more than deadline-less
                   serving; set it only when every request truly has that
                   budget.
      tree_cache_size: tree-pool LRU entries (``return_trees`` serving);
                   0 disables the tree cache.  Keyed on the engine's
                   cache token, so it is exact-only and version-safe by
                   construction (a rebuilt graph keys differently).
      tree_page_size: default trees per :class:`TreePage` (a request can
                   override per call).
      tree_pool_factor: tree requests extract a pool of
                   ``k * tree_pool_factor`` distinct trees, so diversified
                   re-ranking and pagination have material beyond the
                   top-k.
      diversify_lambda: the MMR relevance/diversity trade-off for
                   ``tree_ranking="diverse"`` (1 = pure weight order,
                   0 = pure diversification).
    """

    max_batch: int = 8
    max_wait_ms: float = 5.0
    cache_size: int = 1024
    extract: bool = True
    strict: bool = True
    pad_batches: str = "pow2"   # "pow2" | "max" | "none"
    default_deadline_ms: float | None = None
    tree_cache_size: int = 256
    tree_page_size: int = 5
    tree_pool_factor: int = 3
    diversify_lambda: float = 0.5

    def __post_init__(self) -> None:
        if self.pad_batches not in ("pow2", "max", "none"):
            raise ValueError(f"unknown pad_batches {self.pad_batches!r}")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.tree_page_size < 1:
            raise ValueError("tree_page_size must be >= 1")
        if self.tree_pool_factor < 1:
            raise ValueError("tree_pool_factor must be >= 1")
        if not 0.0 <= self.diversify_lambda <= 1.0:
            raise ValueError("diversify_lambda must be in [0, 1]")


@dataclasses.dataclass(frozen=True)
class ServedResult:
    """One served request: the engine's answer plus serving metadata.

    Attributes:
      result:      the :class:`QueryResult` (for ``approximate`` results:
                   best-so-far weights/answers, ``done=False``, and the
                   forced-stop SPA bound on ``result.spa``).
      cache_hit:   served from the result cache (no device work).
      coalesced:   served by attaching to an identical request already in
                   flight (cross-request single-flight — no device work;
                   ``batch_size`` is the leader dispatch's).
      approximate: the deadline expired before the run's exit criterion —
                   the answer is best-so-far, bounded below by
                   ``opt_lower_bound`` (the paper's early-termination
                   guarantee as a serving feature).
      opt_lower_bound: the *reported* lower bound on the optimum from the
                   last streamed update (deadline-routed requests only) —
                   the paper's Sec. 5.4 convention, mixing the provably
                   sound ``nu`` bound with the SPA estimator, which can in
                   principle overestimate.
      sound_opt_lower_bound: the provably sound lower bound (``nu`` /
                   exhausted-frontier facts only).  This is the value a
                   client may rely on: optimum >= sound_opt_lower_bound,
                   always.
      batch_size:  real requests that shared this dispatch (deadline
                   buckets count their coalesced lanes too; 0 for cache
                   hits).
      latency_ms:  end-to-end submit -> resolve latency.
      trees:       one :class:`TreePage` of label-rendered, ranked answer
                   trees (``return_trees=True`` requests only; None
                   otherwise).  For approximate results these are the
                   best-so-far trees, bounded by ``opt_lower_bound``.
    """

    result: QueryResult
    cache_hit: bool
    approximate: bool
    batch_size: int
    latency_ms: float
    opt_lower_bound: float | None = None
    sound_opt_lower_bound: float | None = None
    coalesced: bool = False
    trees: TreePage | None = None

    @property
    def weights(self):
        return self.result.weights

    @property
    def found(self) -> bool:
        return self.result.found

    @property
    def best_weight(self) -> float:
        return self.result.best_weight


class DKSService:
    """Micro-batching, caching, deadline-aware front end over one engine.

    Lifecycle: ``start()``/``stop()`` or use as a context manager.  Safe
    for any number of client threads; all device execution is serialized
    on the internal dispatcher thread.
    """

    def __init__(self, engine: QueryEngine,
                 config: ServeConfig | None = None) -> None:
        self.engine = engine
        self.config = config or ServeConfig()
        self._cache = ResultCache(self.config.cache_size)
        # Tree-pool LRU: cache_token -> (ranked AnswerTree pool,
        # exhausted).  Exact-only and version-safe for the same reason the
        # result cache is — the token carries the engine build version.
        # Ranking/pagination is computed per request FROM the pool, so one
        # entry serves every cursor/page-size/ranking combination.
        self._tree_cache = ResultCache(self.config.tree_cache_size)
        self._stats = StatsCollector()
        self._batcher = MicroBatcher(
            self._dispatch, max_batch=self.config.max_batch,
            max_wait_ms=self.config.max_wait_ms)
        # Cross-request single-flight: cache_token -> follower list of an
        # identical request currently in flight.  A second identical miss
        # attaches here instead of executing again; the leader's done
        # callback fans its result out (and by then the leader's result
        # is already in the ResultCache, so there is no window where an
        # identical request re-executes).  Deadline requests never
        # participate — a best-so-far answer is budget-specific.
        self._inflight: dict[Hashable, list[tuple[Future, float]]] = {}
        self._inflight_lock = threading.Lock()

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------

    def start(self) -> "DKSService":
        self._batcher.start()
        return self

    def stop(self) -> None:
        self._batcher.stop()

    def __enter__(self) -> "DKSService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------

    def submit(self, keywords: Sequence, k: int = 1, *,
               deadline_ms: float | None = None,
               return_trees: bool = False,
               tree_ranking: str = "diverse",
               tree_cursor: int = 0,
               tree_page_size: int | None = None,
               **overrides) -> "Future[ServedResult]":
        """Admit one query; returns a future resolving to a
        :class:`ServedResult`.

        ``deadline_ms``: per-request latency budget.  Queue wait counts
        against it; when it expires mid-run the request resolves with the
        best-so-far answer, ``approximate=True``, and its SPA lower bound.
        Same-shape requests with the SAME budget coalesce onto one lane
        driver and share supersteps (a conservative group deadline — the
        earliest lane's — guarantees no lane overshoots its own budget).
        Deadline-less requests run to their exit criterion.
        ``overrides``: per-call policy overrides, forwarded to the engine
        (they key both the result cache and the shape bucket).

        ``return_trees``: serve a :class:`TreePage` of label-rendered
        answer trees on ``ServedResult.trees``.  ``tree_ranking`` picks
        the cursor order — "diverse" (MMR duplication-free, the default)
        or "weight" (plain rank) — and ``tree_cursor``/``tree_page_size``
        paginate over it; pass the page's ``next_cursor`` back to get the
        following page (served from the tree cache, no device work).
        Tree requests are exempt from single-flight (the in-flight twin
        may not be extracting a tree pool).

        Identical concurrent misses are single-flighted: the first one
        executes, later ones attach to its in-flight future and resolve
        from its result (``coalesced=True``) — including its failure, if
        it fails.  Deadline-bounded requests are exempt (their best-so-far
        answers are budget-specific, like the cache exemption).
        """
        t_submit = time.perf_counter()
        keywords = tuple(keywords)
        if deadline_ms is None:
            deadline_ms = self.config.default_deadline_ms
        future: Future = Future()
        if not self._batcher.running:
            raise RuntimeError("service is not running")
        if tree_ranking not in ("diverse", "weight"):
            future.set_exception(ValueError(
                f"unknown tree_ranking {tree_ranking!r} "
                "(expected 'diverse' or 'weight')"))
            return future
        engine = self.engine  # snapshot: set_engine must not swap mid-flight
        if self.config.strict:
            missing = engine.index.missing_tokens(list(keywords))
            if missing:
                # Admission-time validation: fail this request alone, not
                # the co-batched dispatch it would have poisoned.
                future.set_exception(KeyError(
                    f"keywords matched no node in the index: {missing}"))
                return future
        if overrides:
            # Normalize: an override equal to the engine's policy value is
            # no override at all — dropping it lets the request coalesce
            # with no-override requests (the batcher buckets on these) and
            # matches how cache_token's effective-policy key behaves.
            # Unknown override names fail this request's future at
            # admission, like every other admission error.
            try:
                overrides = {name: value
                             for name, value in overrides.items()
                             if getattr(engine.policy, name) != value}
            except AttributeError as exc:
                future.set_exception(TypeError(
                    f"unknown policy override: {exc}"))
                return future
        # Counters only move for requests that will actually be served: a
        # hit counts on the spot (its serving is the set_result below); a
        # miss counts only after durable admission to the batcher, so a
        # submit racing stop() skews neither the stats window nor the
        # miss rate.
        cache_key = engine.cache_token(keywords, k, **overrides)
        try:
            hash(cache_key)
        except TypeError as exc:
            # An unhashable keyword or override value would otherwise blow
            # up on the dispatcher thread; fail this request alone.
            future.set_exception(TypeError(
                f"unhashable query or override value: {exc}"))
            return future
        hit = self._cache.get(cache_key, count_miss=False)
        if hit is not None:
            if not return_trees:
                self._resolve_cache_hit(future, hit, t_submit)
                return future
            # A tree request needs the pool too: both caches must hit —
            # a result without its pool re-dispatches (the dense table is
            # long gone, so re-extraction means re-running the query).
            pool_entry = self._tree_cache.get((cache_key, "trees"))
            if pool_entry is not None:
                self._stats.record_tree_request(cache_hit=True)
                page = self._render_page(
                    pool_entry, engine, ranking=tree_ranking,
                    cursor=tree_cursor, page_size=tree_page_size)
                self._resolve_cache_hit(future, hit, t_submit, trees=page)
                return future
        single_flight = deadline_ms is None and not return_trees
        if single_flight:
            # Cross-request single-flight: an identical request is already
            # executing (same cache_token, so same engine build / k /
            # effective policy) — attach to its result instead of
            # dispatching a second run.  The follower resolves from the
            # leader's ServedResult with ``coalesced=True``; if the leader
            # fails or is cancelled, followers inherit that outcome.
            with self._inflight_lock:
                followers = self._inflight.get(cache_key)
                if followers is not None:
                    followers.append((future, t_submit))
                    return future
                self._inflight[cache_key] = []
            # Leadership won — but the PREVIOUS leader may have resolved
            # between our cache check and the registration above (its
            # result cached, its inflight entry popped).  Re-check the
            # cache so a just-finished run is served instead of
            # re-executed; any follower that raced onto our short-lived
            # entry is served from the same hit.
            hit = self._cache.get(cache_key, count_miss=False)
            if hit is not None:
                with self._inflight_lock:
                    followers = self._inflight.pop(cache_key, [])
                self._resolve_cache_hit(future, hit, t_submit)
                for fut, t_sub in followers:
                    if fut.set_running_or_notify_cancel():
                        self._resolve_cache_hit(fut, hit, t_sub)
                return future
        try:
            self._batcher.submit(Request(
                keywords=keywords, k=k,
                overrides=tuple(sorted(overrides.items())),
                future=future, t_submit=t_submit, engine=engine,
                deadline_t=(t_submit + deadline_ms / 1e3
                            if deadline_ms is not None else None),
                deadline_ms=deadline_ms,
                cache_key=cache_key,
                return_trees=return_trees,
                tree_ranking=tree_ranking,
                tree_cursor=tree_cursor,
                tree_page_size=tree_page_size))
        except BaseException as exc:
            if single_flight:
                self._abort_single_flight(cache_key, exc)
            raise
        if single_flight:
            # The callback runs when the dispatcher resolves the leader —
            # by then the result already sits in the ResultCache (put
            # happens before set_result), so an identical submit landing
            # after the pop is caught by the cache (the leadership
            # re-check above closes the remaining pre-put window).
            future.add_done_callback(
                lambda fut: self._finish_single_flight(cache_key, fut))
        self._cache.count_miss()
        return future

    def query(self, keywords: Sequence, k: int = 1, *,
              deadline_ms: float | None = None, timeout: float | None = None,
              return_trees: bool = False, tree_ranking: str = "diverse",
              tree_cursor: int = 0, tree_page_size: int | None = None,
              **overrides) -> ServedResult:
        """Blocking :meth:`submit` — one served answer."""
        return self.submit(keywords, k,
                           deadline_ms=deadline_ms,
                           return_trees=return_trees,
                           tree_ranking=tree_ranking,
                           tree_cursor=tree_cursor,
                           tree_page_size=tree_page_size, **overrides
                           ).result(timeout)

    def _resolve_cache_hit(self, future: Future, hit: QueryResult,
                           t_submit: float,
                           trees: TreePage | None = None) -> None:
        """Resolve one future from a cached result (stats recorded)."""
        t_done = time.perf_counter()
        self._stats.record_request(t_submit, t_done)
        future.set_result(ServedResult(
            result=hit, cache_hit=True, approximate=False,
            batch_size=0, latency_ms=(t_done - t_submit) * 1e3,
            trees=trees))

    # ------------------------------------------------------------------
    # Single-flight bookkeeping
    # ------------------------------------------------------------------

    def _finish_single_flight(self, cache_key: Hashable,
                              leader: "Future[ServedResult]") -> None:
        """Leader resolved: fan its outcome out to attached followers."""
        with self._inflight_lock:
            followers = self._inflight.pop(cache_key, None)
        if not followers:
            return
        exc: BaseException | None
        if leader.cancelled():
            exc = CancelledError()
        else:
            exc = leader.exception()
        for fut, t_sub in followers:
            if not fut.set_running_or_notify_cancel():
                continue
            if exc is not None:
                self._stats.record_failure(1)
                fut.set_exception(exc)
                continue
            t_done = time.perf_counter()
            self._stats.record_request(t_sub, t_done)
            self._stats.record_single_flight()
            fut.set_result(dataclasses.replace(
                leader.result(), coalesced=True,
                latency_ms=(t_done - t_sub) * 1e3))

    def _abort_single_flight(self, cache_key: Hashable,
                             exc: BaseException) -> None:
        """Leader never reached the batcher: fail any follower that raced
        in and free the key."""
        with self._inflight_lock:
            followers = self._inflight.pop(cache_key, None)
        for fut, _t_sub in followers or ():
            if fut.set_running_or_notify_cancel():
                fut.set_exception(exc)

    # ------------------------------------------------------------------
    # Cache control / introspection
    # ------------------------------------------------------------------

    def invalidate_cache(self) -> int:
        """Drop every cached result and tree pool (call on graph
        rebuild).  Returns the number of entries dropped."""
        return self._cache.invalidate() + self._tree_cache.invalidate()

    def _render_page(self, pool_entry: tuple, engine: QueryEngine, *,
                     ranking: str, cursor: int,
                     page_size: int | None) -> TreePage:
        """One :class:`TreePage` from a ``(ranked pool, exhausted)``
        entry: rank order or MMR permutation, cut at the cursor, labels
        from the engine (artifact label blob for ingested graphs)."""
        pool, exhausted = pool_entry
        pool = list(pool)
        if ranking == "diverse":
            order = diversified_order(pool, self.config.diversify_lambda)
        else:
            order = list(range(len(pool)))
        return paginate(
            pool, order, cursor,
            page_size if page_size is not None
            else self.config.tree_page_size,
            ranking, exhausted,
            label_fn=engine.node_label, graph=engine.graph)

    def set_engine(self, engine: QueryEngine) -> None:
        """Swap in a rebuilt engine (graph update) and invalidate the
        cache.  In-flight requests snapshot their admitting engine, so
        they are answered by the previous build (its version rides on the
        batcher shape key — a dispatch never mixes builds); their results
        are keyed under that version and can never be served to post-swap
        clients."""
        self.engine = engine
        self.invalidate_cache()

    def stats(self) -> ServeStats:
        """Aggregate :class:`ServeStats` snapshot (p50/p95 latency,
        throughput, batch-fill, cache-hit rate)."""
        return self._stats.report(self._cache.stats())

    # ------------------------------------------------------------------
    # Dispatcher-thread execution
    # ------------------------------------------------------------------

    def _dispatch(self, group: list[Request]) -> None:
        # Move every future to RUNNING before touching the device: a
        # client that cancelled while queued drops out here (saving its
        # lanes), and set_result below can no longer race a cancel —
        # which would poison the co-batched futures with InvalidStateError.
        group = [req for req in group
                 if req.future.set_running_or_notify_cancel()]
        if not group:
            return
        try:
            if group[0].deadline_t is not None:
                self._serve_deadline_batch(group)
            else:
                self._serve_batch(group)
        except BaseException:
            # The batcher resolves the still-pending futures with this
            # exception; count only those, so requests + failures equals
            # admitted load even if some of the group already resolved.
            self._stats.record_failure(
                sum(1 for req in group if not req.future.done()))
            raise

    def _padded_len(self, n: int) -> int:
        mode = self.config.pad_batches
        if mode == "none" or n >= self.config.max_batch:
            return n
        if mode == "max":
            return self.config.max_batch
        p = 1
        while p < n:
            p *= 2
        return min(p, self.config.max_batch)

    def _serve_batch(self, group: list[Request]) -> None:
        cfg = self.config
        # The admitting engine build serves the group (a group never mixes
        # builds — the build version is part of the batcher's shape key).
        engine = group[0].engine
        queries = [list(req.keywords) for req in group]
        n_real = len(queries)
        queries += [queries[-1]] * (self._padded_len(n_real) - n_real)
        # Tree requests widen extraction to a ranked pool for the WHOLE
        # bucket (extraction is per-lane host work; the pool rides the
        # same device-batched backtrace pass either way) and force
        # extraction on even for weight-only configs.
        want_trees = any(req.return_trees for req in group)
        pool_n = group[0].k * cfg.tree_pool_factor if want_trees else None
        # n_real: padding lanes ride the device program for shape reuse
        # but skip host-side result construction in the engine.
        results = engine.query_batch(
            queries, k=group[0].k, extract=cfg.extract or want_trees,
            extract_pool=pool_n, strict=cfg.strict,
            n_real=n_real, **dict(group[0].overrides))
        t_done = time.perf_counter()
        self._stats.record_dispatch(n_real, deadline=False)
        # After a set_engine swap, results of the old build are keyed
        # under its version — unreachable to every future lookup, so
        # caching them would only evict live entries.
        cacheable = engine is self.engine
        for req, res in zip(group, results):
            if cacheable:
                self._cache.put(req.cache_key, res)
                if want_trees and res.answer_pool is not None:
                    self._tree_cache.put(
                        (req.cache_key, "trees"),
                        (res.answer_pool, res.pool_exhausted))
            trees = None
            if req.return_trees:
                self._stats.record_tree_request(cache_hit=False)
                trees = self._render_page(
                    (res.answer_pool or [], res.pool_exhausted), engine,
                    ranking=req.tree_ranking, cursor=req.tree_cursor,
                    page_size=req.tree_page_size)
            self._stats.record_request(req.t_submit, t_done)
            req.future.set_result(ServedResult(
                result=res, cache_hit=False, approximate=False,
                batch_size=n_real,
                latency_ms=(t_done - req.t_submit) * 1e3,
                trees=trees))

    def _serve_deadline_batch(self, group: list[Request]) -> None:
        cfg = self.config
        engine = group[0].engine
        queries = [list(req.keywords) for req in group]
        n_real = len(queries)
        queries += [queries[-1]] * (self._padded_len(n_real) - n_real)
        # One lane driver for the whole bucket.  The group deadline is the
        # EARLIEST lane's (conservative: requests with the same budget
        # admitted within one window differ by at most that window, and
        # no lane may overshoot its own deadline).  query_deadline_batch
        # spends the budget on supersteps, not on per-superstep bound
        # computation (the SPA cover DP can cost many times a superstep);
        # per-lane bounds are computed once, at the end.  Queue wait
        # already counted against the deadline.
        deadline_t = min(req.deadline_t for req in group)
        want_trees = any(req.return_trees for req in group)
        pool_n = group[0].k * cfg.tree_pool_factor if want_trees else None
        out = engine.query_deadline_batch(
            queries, k=group[0].k, extract=cfg.extract or want_trees,
            extract_pool=pool_n, strict=cfg.strict,
            deadline_s=deadline_t - time.perf_counter(), n_real=n_real,
            **dict(group[0].overrides))
        t_done = time.perf_counter()
        driver_steps = out[0][1]["driver_supersteps"] if out else 0
        lane_steps = sum(res.supersteps for res, _ in out[:n_real])
        self._stats.record_dispatch(n_real, deadline=True,
                                    driver_steps=driver_steps,
                                    lane_steps=lane_steps)
        cacheable = engine is self.engine
        for req, (res, info) in zip(group, out):
            approximate = info["interrupted"]
            if not approximate and cacheable:
                # Finished inside its budget: an exact answer, cacheable
                # like any other (unless the build was swapped while in
                # flight — the old-version key would be unreachable).
                # Best-so-far results are budget-specific — never cached,
                # and neither are their tree pools.
                self._cache.put(req.cache_key, res)
                if want_trees and res.answer_pool is not None:
                    self._tree_cache.put(
                        (req.cache_key, "trees"),
                        (res.answer_pool, res.pool_exhausted))
            trees = None
            if req.return_trees:
                self._stats.record_tree_request(cache_hit=False)
                # For interrupted lanes these are the BEST-SO-FAR trees,
                # served alongside their lower bound — the paper's
                # early-termination answer, now with explanations.
                trees = self._render_page(
                    (res.answer_pool or [], res.pool_exhausted), engine,
                    ranking=req.tree_ranking, cursor=req.tree_cursor,
                    page_size=req.tree_page_size)
            self._stats.record_request(req.t_submit, t_done,
                                       approximate=approximate)
            req.future.set_result(ServedResult(
                result=res, cache_hit=False, approximate=approximate,
                batch_size=n_real,
                latency_ms=(t_done - req.t_submit) * 1e3,
                opt_lower_bound=info["opt_lower_bound"],
                sound_opt_lower_bound=info["sound_opt_lower_bound"],
                trees=trees))
