"""DKSService — the serving layer in front of :class:`QueryEngine`.

The paper's headline guarantee (Sec. 5.4 / Fig. 12) — a DKS run stopped
early still yields ranked answers with a sound lower bound — is exactly
the contract a latency-budgeted query service needs.  This module turns
the engine into that service:

- **admission + dynamic micro-batching** — concurrent requests coalesce
  into ``(m, k)``-shape buckets and dispatch through the engine's vmapped
  batch executors, amortizing device dispatch (and, via shape-padded
  buckets, compilation) across clients;
- **a result cache** — LRU keyed on the engine's normalized cache token
  (keyword multiset + ``(k, policy)`` + engine build version), with
  hit/miss/eviction stats and explicit invalidation on graph rebuild;
- **cross-request single-flight** — a cache miss identical to a request
  already executing (same cache token) attaches to the in-flight future
  instead of dispatching again: N concurrent identical misses cost one
  device execution (``ServedResult.coalesced`` marks the attached ones);
- **deadline-bounded answers, coalesced** — a per-request latency budget
  routes the query through the engine's stepwise lane driver; same-shape
  same-budget requests ride ONE driver (``engine.query_deadline_batch``),
  lanes freeze individually as they prove exits, and on expiry every
  lane gets its own best-so-far answer *with* its per-lane SPA lower
  bound and ``approximate=True``.  Deadline throughput therefore stops
  scaling 1:1 with concurrency: N coalesced requests cost ~max
  supersteps, not the sum (``ServeStats.deadline_driver_supersteps`` vs
  ``deadline_lane_supersteps`` shows the sharing).

Usage::

    with DKSService(engine, ServeConfig(max_batch=8)) as svc:
        fut = svc.submit(["paris", "piano"], k=3)          # non-blocking
        served = svc.query(query, k=1, deadline_ms=50.0)   # blocking
        if served.approximate:
            print(served.result.weights, ">=", served.opt_lower_bound)
    print(svc.stats().summary())

All device work happens on the service's single dispatcher thread; client
threads only touch the cache, the admission queue, and their futures.

**Observability** (:mod:`repro.obs`): every admitted request gets a trace
(``ServedResult.trace_id``) whose spans walk the request's actual path —
admit (with the cache lookup), queue wait, bucket coalesce (shape / fill /
dispatch reason / deadline budget), device dispatch (compile-vs-warm,
detected via the engine's trace counter), extraction (device-resolved vs
host-fallback split), render/paginate, cache store.  Micro-batch riders
and single-flight followers get their own trace with a ``coalesced_into``
link to the bucket leader.  ``svc.registry`` exposes every ``ServeStats``
counter (derived from the same snapshot at scrape time, so ``/metrics``
can never drift from ``stats()``), engine executor counters, and
latency/queue/device histograms in Prometheus text format —
``serve_dks --metrics-port`` serves it over HTTP.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from concurrent.futures import CancelledError, Future
from typing import Hashable, Sequence

from repro.answers import TreePage, diversified_order, paginate
from repro.engine import AdaptiveLanePolicy, QueryEngine, QueryResult
from repro.obs import MetricsRegistry, Tracer
from repro.serve.batcher import MicroBatcher, Request
from repro.serve.cache import ResultCache
from repro.serve.stats import ServeStats, StatsCollector

# Stand-in context manager for unsampled/traceless span sites (entering
# it any number of times is safe — nullcontext keeps no state).
_NULL_SPAN = contextlib.nullcontext()


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving knobs, fixed at service construction.

    Attributes:
      max_batch:   most requests coalesced into one device dispatch.
      max_wait_ms: admission window — a partial bucket dispatches once its
                   oldest request has waited this long.  The classic
                   trade: higher = better fill, worse p50.
      cache_size:  LRU entries; 0 disables the result cache.
      extract:     reconstruct AnswerTrees on served results (skip for
                   weight-only serving).
      strict:      reject queries with unmatched keywords at admission
                   (KeyError on the future) instead of poisoning a whole
                   co-batched dispatch.
      pad_batches: pad partial buckets up to a fixed lane count by
                   repeating the last query, so the lane driver sees few
                   distinct lane counts (each new count re-traces):
                   "pow2" (next power of two, the default), "max" (always
                   ``max_batch`` lanes), "none", or "adaptive" — an
                   :class:`~repro.engine.AdaptiveLanePolicy` that scores
                   candidate lane counts from MEASURED per-dispatch device
                   time and the ``ServeStats.hot_shapes`` histogram
                   instead of blind rounding (it degrades to exactly
                   "pow2" until the first measurement lands; decisions
                   are exported as ``dks_lane_policy_*`` metrics).
                   Padding lanes burn device FLOPs only — the engine
                   skips host-side result construction for them
                   (``n_real=``) — and batch-fill stats count real
                   requests only.  Applies on both partitionings (sharded
                   lanes live inside the shard_map, so a padding lane is
                   a free-ish extra lane there too) and to deadline
                   buckets.
      default_deadline_ms: deadline applied when a request sets none.
                   Deadline requests coalesce with same-shape same-budget
                   requests onto one stepwise lane driver, but they are
                   host-stepped (per-superstep deadline checks) and
                   exempt from the result cache and single-flight — so a
                   blanket default still costs more than deadline-less
                   serving; set it only when every request truly has that
                   budget.
      tree_cache_size: tree-pool LRU entries (``return_trees`` serving);
                   0 disables the tree cache.  Keyed on the engine's
                   cache token, so it is exact-only and version-safe by
                   construction (a rebuilt graph keys differently).
      tree_page_size: default trees per :class:`TreePage` (a request can
                   override per call).
      tree_pool_factor: tree requests extract a pool of
                   ``k * tree_pool_factor`` distinct trees, so diversified
                   re-ranking and pagination have material beyond the
                   top-k.
      diversify_lambda: the MMR relevance/diversity trade-off for
                   ``tree_ranking="diverse"`` (1 = pure weight order,
                   0 = pure diversification).
      trace_sample: fraction of requests whose trace records spans
                   (deterministic per ``(trace_seed, trace_id)`` — see
                   :class:`repro.obs.Tracer`).  Unsampled requests still
                   get a trace id on their :class:`ServedResult`.
      trace_capacity: finished sampled traces kept in the in-memory ring
                   (the ``/traces`` endpoint and ``recent_traces()``).
      trace_seed:  seed for the sampling hash — the same seed samples the
                   same trace ids on every run.
      trace_log:   path to append finished sampled traces as JSONL (the
                   structured event log); None disables.
    """

    max_batch: int = 8
    max_wait_ms: float = 5.0
    cache_size: int = 1024
    extract: bool = True
    strict: bool = True
    pad_batches: str = "pow2"   # "pow2" | "max" | "none" | "adaptive"
    default_deadline_ms: float | None = None
    tree_cache_size: int = 256
    tree_page_size: int = 5
    tree_pool_factor: int = 3
    diversify_lambda: float = 0.5
    trace_sample: float = 1.0
    trace_capacity: int = 256
    trace_seed: int = 0
    trace_log: str | None = None

    def __post_init__(self) -> None:
        if self.pad_batches not in ("pow2", "max", "none", "adaptive"):
            raise ValueError(f"unknown pad_batches {self.pad_batches!r}")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.tree_page_size < 1:
            raise ValueError("tree_page_size must be >= 1")
        if self.tree_pool_factor < 1:
            raise ValueError("tree_pool_factor must be >= 1")
        if not 0.0 <= self.diversify_lambda <= 1.0:
            raise ValueError("diversify_lambda must be in [0, 1]")
        if not 0.0 <= self.trace_sample <= 1.0:
            raise ValueError("trace_sample must be in [0, 1]")
        if self.trace_capacity < 1:
            raise ValueError("trace_capacity must be >= 1")


@dataclasses.dataclass(frozen=True)
class ServedResult:
    """One served request: the engine's answer plus serving metadata.

    Attributes:
      result:      the :class:`QueryResult` (for ``approximate`` results:
                   best-so-far weights/answers, ``done=False``, and the
                   forced-stop SPA bound on ``result.spa``).
      cache_hit:   served from the result cache (no device work).
      coalesced:   served by attaching to an identical request already in
                   flight (cross-request single-flight — no device work;
                   ``batch_size`` is the leader dispatch's).
      approximate: the deadline expired before the run's exit criterion —
                   the answer is best-so-far, bounded below by
                   ``opt_lower_bound`` (the paper's early-termination
                   guarantee as a serving feature).
      opt_lower_bound: the *reported* lower bound on the optimum from the
                   last streamed update (deadline-routed requests only) —
                   the paper's Sec. 5.4 convention, mixing the provably
                   sound ``nu`` bound with the SPA estimator, which can in
                   principle overestimate.
      sound_opt_lower_bound: the provably sound lower bound (``nu`` /
                   exhausted-frontier facts only).  This is the value a
                   client may rely on: optimum >= sound_opt_lower_bound,
                   always.
      batch_size:  real requests that shared this dispatch (deadline
                   buckets count their coalesced lanes too; 0 for cache
                   hits).
      latency_ms:  end-to-end submit -> resolve latency.
      trees:       one :class:`TreePage` of label-rendered, ranked answer
                   trees (``return_trees=True`` requests only; None
                   otherwise).  For approximate results these are the
                   best-so-far trees, bounded by ``opt_lower_bound``.
      trace_id:    id of this request's trace (every admitted request has
                   one; whether spans were recorded depends on
                   ``ServeConfig.trace_sample``).  Fetch the span tree
                   with ``svc.trace(trace_id)`` while it is in the ring.
      queue_wait_ms: time this request sat in the admission queue before
                   its bucket dispatched (ms); None on resolve paths that
                   never queue (cache hits, single-flight followers).
      device_ms:   the compiled superstep program's wall time for the
                   dispatch that served this request (ms; a shared bucket
                   bills the same number to every rider); None when no
                   device work happened.
    """

    result: QueryResult
    cache_hit: bool
    approximate: bool
    batch_size: int
    latency_ms: float
    opt_lower_bound: float | None = None
    sound_opt_lower_bound: float | None = None
    coalesced: bool = False
    trees: TreePage | None = None
    trace_id: int | None = None
    queue_wait_ms: float | None = None
    device_ms: float | None = None

    @property
    def weights(self):
        return self.result.weights

    @property
    def found(self) -> bool:
        return self.result.found

    @property
    def best_weight(self) -> float:
        return self.result.best_weight


class DKSService:
    """Micro-batching, caching, deadline-aware front end over one engine.

    Lifecycle: ``start()``/``stop()`` or use as a context manager.  Safe
    for any number of client threads; all device execution is serialized
    on the internal dispatcher thread.
    """

    def __init__(self, engine: QueryEngine,
                 config: ServeConfig | None = None) -> None:
        self.engine = engine
        self.config = config or ServeConfig()
        self._cache = ResultCache(self.config.cache_size)
        # Tree-pool LRU: cache_token -> (ranked AnswerTree pool,
        # exhausted).  Exact-only and version-safe for the same reason the
        # result cache is — the token carries the engine build version.
        # Ranking/pagination is computed per request FROM the pool, so one
        # entry serves every cursor/page-size/ranking combination.
        self._tree_cache = ResultCache(self.config.tree_cache_size)
        self._stats = StatsCollector()
        # Lane-occupancy policy: always constructed (its snapshot feeds
        # the metrics surface either way) but consulted for padding
        # decisions only under pad_batches="adaptive".  Both dispatch
        # paths feed it per-dispatch device time.
        self.lane_policy = AdaptiveLanePolicy(self.config.max_batch)
        self._batcher = MicroBatcher(
            self._dispatch, max_batch=self.config.max_batch,
            max_wait_ms=self.config.max_wait_ms,
            max_batch_for=(self.lane_policy.target_fill
                           if self.config.pad_batches == "adaptive"
                           else None))
        # Cross-request single-flight: cache_token -> follower list of an
        # identical request currently in flight.  A second identical miss
        # attaches here instead of executing again; the leader's done
        # callback fans its result out (and by then the leader's result
        # is already in the ResultCache, so there is no window where an
        # identical request re-executes).  Deadline requests never
        # participate — a best-so-far answer is budget-specific.
        # Follower tuples are (future, t_submit, trace); _inflight_traces
        # remembers the leader's trace id so followers can link to it.
        self._inflight: dict[Hashable, list] = {}
        self._inflight_traces: dict[Hashable, int] = {}
        self._inflight_lock = threading.Lock()
        # Observability: one trace per admitted request (the span trees
        # behind ``--explain`` and ``/traces``) and a metrics registry
        # whose serving counters are DERIVED from ``self.stats()`` at
        # scrape time — /metrics equals ServeStats by construction.
        self.tracer = Tracer(
            capacity=self.config.trace_capacity,
            sample=self.config.trace_sample,
            seed=self.config.trace_seed,
            log_path=self.config.trace_log)
        self.registry = MetricsRegistry()
        self._wire_metrics()

    # ------------------------------------------------------------------
    # Metrics
    # ------------------------------------------------------------------

    def _wire_metrics(self) -> None:
        """Expose serving state on ``self.registry``.

        Counters and gauges are scrape-time collectors over the SAME
        snapshots ``stats()`` / ``engine.*`` / ``tracer.stats()`` serve,
        so ``/metrics`` cannot drift from the Python-side reports.  Only
        the latency histograms are direct instruments (a percentile
        cannot be reconstructed at scrape time)."""
        reg = self.registry
        self._h_latency = reg.histogram(
            "dks_request_latency_ms",
            "End-to-end request latency (submit -> resolved future), ms.")
        self._h_queue = reg.histogram(
            "dks_queue_wait_ms",
            "Admission-queue wait before bucket dispatch, ms "
            "(dispatched requests only).")
        self._h_device = reg.histogram(
            "dks_device_time_ms",
            "Compiled superstep program wall time billed to each "
            "dispatched request, ms.")

        _C, _G = "counter", "gauge"
        serve_kinds = {
            "dks_requests_total": _C,
            "dks_failures_total": _C,
            "dks_batch_dispatches_total": _C,
            "dks_deadline_dispatches_total": _C,
            "dks_batched_requests_total": _C,
            "dks_deadline_batched_requests_total": _C,
            "dks_deadline_driver_supersteps_total": _C,
            "dks_deadline_lane_supersteps_total": _C,
            "dks_cache_hits_total": _C,
            "dks_cache_misses_total": _C,
            "dks_cache_evictions_total": _C,
            "dks_single_flight_hits_total": _C,
            "dks_approximate_total": _C,
            "dks_tree_requests_total": _C,
            "dks_tree_cache_hits_total": _C,
            "dks_mean_batch_fill": _G,
            "dks_cache_hit_rate": _G,
            "dks_throughput_rps": _G,
            "dks_latency_p50_ms": _G,
            "dks_latency_p95_ms": _G,
            "dks_queue_p50_ms": _G,
            "dks_queue_p95_ms": _G,
            "dks_device_p50_ms": _G,
            "dks_device_p95_ms": _G,
            "dks_engine_swaps_total": _C,
        }

        def collect_serve() -> dict[str, float]:
            s = self.stats()
            return {
                "dks_requests_total": s.requests,
                "dks_failures_total": s.failures,
                "dks_batch_dispatches_total": s.batch_dispatches,
                "dks_deadline_dispatches_total": s.deadline_dispatches,
                "dks_batched_requests_total": s.batched_requests,
                "dks_deadline_batched_requests_total":
                    s.deadline_batched_requests,
                "dks_deadline_driver_supersteps_total":
                    s.deadline_driver_supersteps,
                "dks_deadline_lane_supersteps_total":
                    s.deadline_lane_supersteps,
                "dks_cache_hits_total": s.cache_hits,
                "dks_cache_misses_total": s.cache_misses,
                "dks_cache_evictions_total": s.cache_evictions,
                "dks_single_flight_hits_total": s.single_flight_hits,
                "dks_approximate_total": s.approximate,
                "dks_tree_requests_total": s.tree_requests,
                "dks_tree_cache_hits_total": s.tree_cache_hits,
                "dks_mean_batch_fill": s.mean_batch_fill,
                "dks_cache_hit_rate": s.cache_hit_rate,
                "dks_throughput_rps": s.throughput_rps,
                "dks_latency_p50_ms": s.p50_ms,
                "dks_latency_p95_ms": s.p95_ms,
                "dks_queue_p50_ms": s.queue_p50_ms,
                "dks_queue_p95_ms": s.queue_p95_ms,
                "dks_device_p50_ms": s.device_p50_ms,
                "dks_device_p95_ms": s.device_p95_ms,
                "dks_engine_swaps_total": s.engine_swaps,
            }

        reg.register_collector(collect_serve, kinds=serve_kinds, helps={
            "dks_requests_total": "Requests served (cache hits included).",
            "dks_failures_total": "Dispatched requests whose run raised.",
        })

        def collect_engine() -> dict[str, float]:
            eng = self.engine  # follow set_engine swaps
            extract = eng.extraction_stats
            return {
                "dks_engine_execute_count_total": eng.execute_count,
                "dks_engine_traces_total": eng.cache_stats["traces"],
                "dks_engine_executables": eng.cache_stats["executables"],
                "dks_extract_device_resolved_total":
                    extract["device_resolved"],
                "dks_extract_host_fallbacks_total":
                    extract["host_fallbacks"],
            }

        reg.register_collector(collect_engine, kinds={
            "dks_engine_execute_count_total": _C,
            "dks_engine_traces_total": _C,
            "dks_engine_executables": _G,
            "dks_extract_device_resolved_total": _C,
            "dks_extract_host_fallbacks_total": _C,
        }, helps={
            "dks_engine_execute_count_total":
                "Device dispatches through the compiled-executable cache.",
            "dks_engine_traces_total":
                "Executable compilations (jit traces) — warm serving "
                "means this stays flat while execute_count climbs.",
            "dks_extract_device_resolved_total":
                "Lanes whose answer trees the batched device backtracer "
                "reconstructed.",
            "dks_extract_host_fallbacks_total":
                "Ragged lanes re-run through the host tree search.",
        })

        def collect_tracer() -> dict[str, float]:
            t = self.tracer.stats()
            return {
                "dks_traces_begun_total": t["begun"],
                "dks_traces_finished_total": t["finished"],
                "dks_traces_sampled_total": t["sampled"],
                "dks_traces_buffered": t["buffered"],
            }

        reg.register_collector(collect_tracer, kinds={
            "dks_traces_begun_total": _C,
            "dks_traces_finished_total": _C,
            "dks_traces_sampled_total": _C,
            "dks_traces_buffered": _G,
        }, helps={
            "dks_traces_begun_total":
                "Traces begun (one per admitted request); equal to "
                "finished once the service drains.",
        })

        def collect_lane_policy() -> dict[str, float]:
            snap = self.lane_policy.snapshot()
            out = {
                "dks_lane_policy_last_lanes": snap["last_lanes"],
                "dks_lane_policy_target_fill":
                    self.lane_policy.target_fill(),
            }
            for reason in ("exact", "warm", "pow2", "cap"):
                out[f"dks_lane_policy_decision_{reason}_total"] = (
                    snap["decisions"].get(reason, 0))
            return out

        reg.register_collector(collect_lane_policy, kinds=dict(
            {"dks_lane_policy_last_lanes": _G,
             "dks_lane_policy_target_fill": _G},
            **{f"dks_lane_policy_decision_{r}_total": _C
               for r in ("exact", "warm", "pow2", "cap")},
        ), helps={
            "dks_lane_policy_last_lanes":
                "Lane count of the most recent padding decision "
                "(pad_batches='adaptive').",
            "dks_lane_policy_target_fill":
                "Bucket size the adaptive policy considers worth waiting "
                "for (most-dispatched warm lane count).",
            "dks_lane_policy_decision_exact_total":
                "Decisions that dispatched at the real request count "
                "(zero padding lanes).",
            "dks_lane_policy_decision_warm_total":
                "Decisions that padded up to an already-measured lane "
                "count (compiled executable, no retrace).",
        })

        def collect_batcher() -> dict[str, float]:
            counts = dict(self._batcher.dispatch_counts)
            return {f"dks_dispatch_reason_{reason}_total": n
                    for reason, n in counts.items()}

        reg.register_collector(collect_batcher, kinds={
            f"dks_dispatch_reason_{r}_total": _C
            for r in ("full", "window", "flush")
        }, helps={
            "dks_dispatch_reason_full_total":
                "Buckets dispatched because they reached max_batch.",
            "dks_dispatch_reason_window_total":
                "Buckets dispatched on admission-window expiry.",
            "dks_dispatch_reason_flush_total":
                "Buckets flushed at service stop.",
        })

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------

    def start(self) -> "DKSService":
        self._batcher.start()
        return self

    def stop(self) -> None:
        self._batcher.stop()

    def __enter__(self) -> "DKSService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------

    def submit(self, keywords: Sequence, k: int = 1, *,
               deadline_ms: float | None = None,
               return_trees: bool = False,
               tree_ranking: str = "diverse",
               tree_cursor: int = 0,
               tree_page_size: int | None = None,
               **overrides) -> "Future[ServedResult]":
        """Admit one query; returns a future resolving to a
        :class:`ServedResult`.

        ``deadline_ms``: per-request latency budget.  Queue wait counts
        against it; when it expires mid-run the request resolves with the
        best-so-far answer, ``approximate=True``, and its SPA lower bound.
        Same-shape requests with the SAME budget coalesce onto one lane
        driver and share supersteps (a conservative group deadline — the
        earliest lane's — guarantees no lane overshoots its own budget).
        Deadline-less requests run to their exit criterion.
        ``overrides``: per-call policy overrides, forwarded to the engine
        (they key both the result cache and the shape bucket).

        ``return_trees``: serve a :class:`TreePage` of label-rendered
        answer trees on ``ServedResult.trees``.  ``tree_ranking`` picks
        the cursor order — "diverse" (MMR duplication-free, the default)
        or "weight" (plain rank) — and ``tree_cursor``/``tree_page_size``
        paginate over it; pass the page's ``next_cursor`` back to get the
        following page (served from the tree cache, no device work).
        Tree requests are exempt from single-flight (the in-flight twin
        may not be extracting a tree pool).

        Identical concurrent misses are single-flighted: the first one
        executes, later ones attach to its in-flight future and resolve
        from its result (``coalesced=True``) — including its failure, if
        it fails.  Deadline-bounded requests are exempt (their best-so-far
        answers are budget-specific, like the cache exemption).
        """
        t_submit = time.perf_counter()
        keywords = tuple(keywords)
        if deadline_ms is None:
            deadline_ms = self.config.default_deadline_ms
        future: Future = Future()
        if not self._batcher.running:
            raise RuntimeError("service is not running")
        # One trace per admitted request, finished on EVERY resolve path
        # (finish() is idempotent) — the tracer's begun == finished
        # counters are the completeness invariant the tests assert.
        trace = self.tracer.begin(
            "dks.request", m=len(keywords), k=k,
            deadline_ms=deadline_ms, trees=return_trees)

        def _reject(exc: BaseException) -> "Future[ServedResult]":
            trace.add_span("admit", t_submit, time.perf_counter(),
                           outcome="rejected")
            trace.set(outcome="rejected", error=repr(exc))
            trace.finish()
            future.set_exception(exc)
            return future

        if tree_ranking not in ("diverse", "weight"):
            return _reject(ValueError(
                f"unknown tree_ranking {tree_ranking!r} "
                "(expected 'diverse' or 'weight')"))
        engine = self.engine  # snapshot: set_engine must not swap mid-flight
        if self.config.strict:
            missing = engine.index.missing_tokens(list(keywords))
            if missing:
                # Admission-time validation: fail this request alone, not
                # the co-batched dispatch it would have poisoned.
                return _reject(KeyError(
                    f"keywords matched no node in the index: {missing}"))
        if overrides:
            # Normalize: an override equal to the engine's policy value is
            # no override at all — dropping it lets the request coalesce
            # with no-override requests (the batcher buckets on these) and
            # matches how cache_token's effective-policy key behaves.
            # Unknown override names fail this request's future at
            # admission, like every other admission error.
            try:
                overrides = {name: value
                             for name, value in overrides.items()
                             if getattr(engine.policy, name) != value}
            except AttributeError as exc:
                return _reject(TypeError(
                    f"unknown policy override: {exc}"))
        # Counters only move for requests that will actually be served: a
        # hit counts on the spot (its serving is the set_result below); a
        # miss counts only after durable admission to the batcher, so a
        # submit racing stop() skews neither the stats window nor the
        # miss rate.
        cache_key = engine.cache_token(keywords, k, **overrides)
        try:
            hash(cache_key)
        except TypeError as exc:
            # An unhashable keyword or override value would otherwise blow
            # up on the dispatcher thread; fail this request alone.
            return _reject(TypeError(
                f"unhashable query or override value: {exc}"))
        with trace.span("cache_lookup") as lookup:
            hit = self._cache.get(cache_key, count_miss=False)
            lookup.set(hit=hit is not None)
        if hit is not None:
            if not return_trees:
                trace.add_span("admit", t_submit, time.perf_counter(),
                               outcome="cache_hit")
                self._resolve_cache_hit(future, hit, t_submit, trace=trace)
                return future
            # A tree request needs the pool too: both caches must hit —
            # a result without its pool re-dispatches (the dense table is
            # long gone, so re-extraction means re-running the query).
            pool_entry = self._tree_cache.get((cache_key, "trees"))
            if pool_entry is not None:
                self._stats.record_tree_request(cache_hit=True)
                trace.add_span("admit", t_submit, time.perf_counter(),
                               outcome="tree_cache_hit")
                with trace.span("render", ranking=tree_ranking,
                                cursor=tree_cursor):
                    page = self._render_page(
                        pool_entry, engine, ranking=tree_ranking,
                        cursor=tree_cursor, page_size=tree_page_size)
                self._resolve_cache_hit(future, hit, t_submit, trees=page,
                                        trace=trace)
                return future
        single_flight = deadline_ms is None and not return_trees
        if single_flight:
            # Cross-request single-flight: an identical request is already
            # executing (same cache_token, so same engine build / k /
            # effective policy) — attach to its result instead of
            # dispatching a second run.  The follower resolves from the
            # leader's ServedResult with ``coalesced=True``; if the leader
            # fails or is cancelled, followers inherit that outcome.
            with self._inflight_lock:
                followers = self._inflight.get(cache_key)
                if followers is not None:
                    leader_id = self._inflight_traces.get(cache_key)
                    if leader_id is not None:
                        trace.link(coalesced_into=leader_id)
                    trace.add_span("admit", t_submit, time.perf_counter(),
                                   outcome="attached")
                    followers.append((future, t_submit, trace))
                    return future
                # The follower LIST OBJECT is captured by this leader's
                # closures below: resolution paths pop the dict entry only
                # if it is still this exact list (identity guard), so a
                # set_engine swap can retire pre-swap entries wholesale
                # without a stale leader later adopting (and answering
                # with the OLD build) followers who attached post-swap.
                entry: list = []
                self._inflight[cache_key] = entry
                self._inflight_traces[cache_key] = trace.trace_id
            # Leadership won — but the PREVIOUS leader may have resolved
            # between our cache check and the registration above (its
            # result cached, its inflight entry popped).  Re-check the
            # cache so a just-finished run is served instead of
            # re-executed; any follower that raced onto our short-lived
            # entry is served from the same hit.
            hit = self._cache.get(cache_key, count_miss=False)
            if hit is not None:
                with self._inflight_lock:
                    if self._inflight.get(cache_key) is entry:
                        self._inflight.pop(cache_key)
                        self._inflight_traces.pop(cache_key, None)
                trace.add_span("admit", t_submit, time.perf_counter(),
                               outcome="cache_hit")
                self._resolve_cache_hit(future, hit, t_submit, trace=trace)
                for fut, t_sub, f_trace in entry:
                    if fut.set_running_or_notify_cancel():
                        self._resolve_cache_hit(fut, hit, t_sub,
                                                trace=f_trace)
                    elif f_trace is not None:
                        f_trace.set(outcome="cancelled")
                        f_trace.finish()
                return future
        trace.add_span("admit", t_submit, time.perf_counter(),
                       outcome="queued")
        try:
            self._batcher.submit(Request(
                keywords=keywords, k=k,
                overrides=tuple(sorted(overrides.items())),
                future=future, t_submit=t_submit, engine=engine,
                deadline_t=(t_submit + deadline_ms / 1e3
                            if deadline_ms is not None else None),
                deadline_ms=deadline_ms,
                cache_key=cache_key,
                trace=trace,
                return_trees=return_trees,
                tree_ranking=tree_ranking,
                tree_cursor=tree_cursor,
                tree_page_size=tree_page_size))
        except BaseException as exc:
            trace.set(outcome="error", error=repr(exc))
            trace.finish()
            if single_flight:
                self._abort_single_flight(cache_key, entry, exc)
            raise
        if single_flight:
            # The callback runs when the dispatcher resolves the leader —
            # by then the result already sits in the ResultCache (put
            # happens before set_result), so an identical submit landing
            # after the pop is caught by the cache (the leadership
            # re-check above closes the remaining pre-put window).
            future.add_done_callback(
                lambda fut: self._finish_single_flight(cache_key, entry,
                                                       fut))
        self._cache.count_miss()
        return future

    def query(self, keywords: Sequence, k: int = 1, *,
              deadline_ms: float | None = None, timeout: float | None = None,
              return_trees: bool = False, tree_ranking: str = "diverse",
              tree_cursor: int = 0, tree_page_size: int | None = None,
              **overrides) -> ServedResult:
        """Blocking :meth:`submit` — one served answer."""
        return self.submit(keywords, k,
                           deadline_ms=deadline_ms,
                           return_trees=return_trees,
                           tree_ranking=tree_ranking,
                           tree_cursor=tree_cursor,
                           tree_page_size=tree_page_size, **overrides
                           ).result(timeout)

    def _resolve_cache_hit(self, future: Future, hit: QueryResult,
                           t_submit: float,
                           trees: TreePage | None = None,
                           trace=None) -> None:
        """Resolve one future from a cached result (stats recorded)."""
        t_done = time.perf_counter()
        self._stats.record_request(t_submit, t_done)
        self._h_latency.observe((t_done - t_submit) * 1e3)
        trace_id = None
        if trace is not None:
            trace_id = trace.trace_id
            trace.set(outcome="cache_hit")
            trace.finish()
        future.set_result(ServedResult(
            result=hit, cache_hit=True, approximate=False,
            batch_size=0, latency_ms=(t_done - t_submit) * 1e3,
            trees=trees, trace_id=trace_id))

    # ------------------------------------------------------------------
    # Single-flight bookkeeping
    # ------------------------------------------------------------------

    def _finish_single_flight(self, cache_key: Hashable, entry: list,
                              leader: "Future[ServedResult]") -> None:
        """Leader resolved: fan its outcome out to attached followers.

        ``entry`` is the leader's own follower list (captured at
        registration).  The dict entry is popped only if it is still that
        exact list — after a ``set_engine`` swap retired it (or a newer
        leader registered), the current entry belongs to someone else and
        must not be touched.  Either way no new follower can attach to
        ``entry`` once this runs: it is out of the dict, so the local
        fan-out below is complete."""
        with self._inflight_lock:
            if self._inflight.get(cache_key) is entry:
                self._inflight.pop(cache_key)
                self._inflight_traces.pop(cache_key, None)
        followers = entry
        if not followers:
            return
        exc: BaseException | None
        if leader.cancelled():
            exc = CancelledError()
        else:
            exc = leader.exception()
        for fut, t_sub, f_trace in followers:
            if not fut.set_running_or_notify_cancel():
                if f_trace is not None:
                    f_trace.set(outcome="cancelled")
                    f_trace.finish()
                continue
            if exc is not None:
                self._stats.record_failure(1)
                if f_trace is not None:
                    f_trace.set(outcome="error", error=repr(exc))
                    f_trace.finish()
                fut.set_exception(exc)
                continue
            t_done = time.perf_counter()
            self._stats.record_request(t_sub, t_done)
            self._stats.record_single_flight()
            self._h_latency.observe((t_done - t_sub) * 1e3)
            trace_id = None
            if f_trace is not None:
                trace_id = f_trace.trace_id
                f_trace.set(outcome="attached")
                f_trace.finish()
            fut.set_result(dataclasses.replace(
                leader.result(), coalesced=True, trace_id=trace_id,
                queue_wait_ms=None, device_ms=None,
                latency_ms=(t_done - t_sub) * 1e3))

    def _abort_single_flight(self, cache_key: Hashable, entry: list,
                             exc: BaseException) -> None:
        """Leader never reached the batcher: fail any follower that raced
        in and free the key (same identity guard as
        :meth:`_finish_single_flight`)."""
        with self._inflight_lock:
            if self._inflight.get(cache_key) is entry:
                self._inflight.pop(cache_key)
                self._inflight_traces.pop(cache_key, None)
        for fut, _t_sub, f_trace in entry:
            if f_trace is not None:
                f_trace.set(outcome="error", error=repr(exc))
                f_trace.finish()
            if fut.set_running_or_notify_cancel():
                fut.set_exception(exc)

    # ------------------------------------------------------------------
    # Cache control / introspection
    # ------------------------------------------------------------------

    def invalidate_cache(self) -> int:
        """Drop every cached result and tree pool (call on graph
        rebuild).  Returns the number of entries dropped."""
        return self._cache.invalidate() + self._tree_cache.invalidate()

    def _render_page(self, pool_entry: tuple, engine: QueryEngine, *,
                     ranking: str, cursor: int,
                     page_size: int | None) -> TreePage:
        """One :class:`TreePage` from a ``(ranked pool, exhausted)``
        entry: rank order or MMR permutation, cut at the cursor, labels
        from the engine (artifact label blob for ingested graphs)."""
        pool, exhausted = pool_entry
        pool = list(pool)
        if ranking == "diverse":
            order = diversified_order(pool, self.config.diversify_lambda)
        else:
            order = list(range(len(pool)))
        return paginate(
            pool, order, cursor,
            page_size if page_size is not None
            else self.config.tree_page_size,
            ranking, exhausted,
            label_fn=engine.node_label, graph=engine.graph)

    def set_engine(self, engine: QueryEngine) -> None:
        """Swap in a rebuilt engine (graph update) — zero-downtime.

        In-flight requests snapshot their admitting engine, so they are
        answered by the previous build (its version rides on the batcher
        shape key — a dispatch never mixes builds).  The swap then:

        - invalidates the result cache AND the tree-pool LRU (both keyed
          under the outgoing version; version-keyed lookups would miss
          anyway, but retiring them frees the memory immediately);
        - retires every in-flight single-flight entry, so a pre-swap
          leader can no longer adopt post-swap followers — post-swap
          submits of the same query become their own leaders on the new
          build, while retired leaders still resolve their already-
          attached followers through the list object captured in their
          closures (identity-guarded, see ``_finish_single_flight``);
        - counts the swap in ``ServeStats.engine_swaps`` (exported as
          ``dks_engine_swaps_total``).
        """
        self.engine = engine
        self.invalidate_cache()
        with self._inflight_lock:
            self._inflight.clear()
            self._inflight_traces.clear()
        self._stats.record_engine_swap()

    def stats(self) -> ServeStats:
        """Aggregate :class:`ServeStats` snapshot (p50/p95 latency,
        throughput, batch-fill, cache-hit rate)."""
        return self._stats.report(self._cache.stats())

    def trace(self, trace_id: int):
        """The finished :class:`repro.obs.Trace` for a served request's
        ``ServedResult.trace_id``, while it is still in the tracer ring
        (None if evicted or unsampled)."""
        return self.tracer.get(trace_id)

    def recent_traces(self, n: int | None = None):
        """Most recent finished sampled traces, newest last."""
        return self.tracer.recent(n)

    # ------------------------------------------------------------------
    # Dispatcher-thread execution
    # ------------------------------------------------------------------

    def _dispatch(self, group: list[Request]) -> None:
        # Move every future to RUNNING before touching the device: a
        # client that cancelled while queued drops out here (saving its
        # lanes), and set_result below can no longer race a cancel —
        # which would poison the co-batched futures with InvalidStateError.
        alive = []
        for req in group:
            if req.future.set_running_or_notify_cancel():
                alive.append(req)
            elif req.trace is not None:
                req.trace.set(outcome="cancelled")
                req.trace.finish()
        group = alive
        if not group:
            return
        try:
            if group[0].deadline_t is not None:
                self._serve_deadline_batch(group)
            else:
                self._serve_batch(group)
        except BaseException as exc:
            # The batcher resolves the still-pending futures with this
            # exception; count only those, so requests + failures equals
            # admitted load even if some of the group already resolved.
            pending = [req for req in group if not req.future.done()]
            self._stats.record_failure(len(pending))
            for req in pending:
                if req.trace is not None:
                    req.trace.set(outcome="error", error=repr(exc))
                    req.trace.finish()
            raise

    def _padded_len(self, n: int) -> int:
        mode = self.config.pad_batches
        if mode == "none" or n >= self.config.max_batch:
            return n
        if mode == "max":
            return self.config.max_batch
        if mode == "adaptive":
            return self.lane_policy.lanes_for(
                n, hot_shapes=self.stats().hot_shapes).lanes
        p = 1
        while p < n:
            p *= 2
        return min(p, self.config.max_batch)

    def _observe_dispatch(self, group: list[Request], n_lanes: int,
                          t_dispatch: float, *,
                          deadline_budget_ms: float | None = None) -> None:
        """Queue-wait spans for every rider, a ``coalesce`` span +
        ``coalesced_into`` links under the bucket leader (group[0])."""
        for req in group:
            if req.trace is not None:
                req.trace.add_span("queue_wait", req.t_submit, t_dispatch)
        leader = group[0].trace
        if leader is not None:
            attrs = dict(shape=f"m{len(group[0].keywords)}k{group[0].k}",
                         fill=len(group), lanes=n_lanes,
                         reason=self._batcher.current_reason)
            if deadline_budget_ms is not None:
                attrs["deadline_budget_ms"] = round(deadline_budget_ms, 3)
            leader.add_span("coalesce", group[0].t_submit, t_dispatch,
                            **attrs)
            for req in group[1:]:
                if req.trace is not None:
                    req.trace.link(coalesced_into=leader.trace_id)

    def _serve_batch(self, group: list[Request]) -> None:
        cfg = self.config
        # The admitting engine build serves the group (a group never mixes
        # builds — the build version is part of the batcher's shape key).
        engine = group[0].engine
        queries = [list(req.keywords) for req in group]
        n_real = len(queries)
        queries += [queries[-1]] * (self._padded_len(n_real) - n_real)
        t_dispatch = time.perf_counter()
        self._observe_dispatch(group, len(queries), t_dispatch)
        leader = group[0].trace
        # Tree requests widen extraction to a ranked pool for the WHOLE
        # bucket (extraction is per-lane host work; the pool rides the
        # same device-batched backtrace pass either way) and force
        # extraction on even for weight-only configs.
        want_trees = any(req.return_trees for req in group)
        pool_n = group[0].k * cfg.tree_pool_factor if want_trees else None
        # Compile-vs-warm split: the engine's trace counter moves exactly
        # when this dispatch compiled a new executable for the shape.
        overrides = dict(group[0].overrides)
        m, k = len(group[0].keywords), group[0].k
        traces_before = engine.trace_count(m, k, **overrides)
        extract_before = engine.extraction_stats
        # n_real: padding lanes ride the device program for shape reuse
        # but skip host-side result construction in the engine.
        results = engine.query_batch(
            queries, k=k, extract=cfg.extract or want_trees,
            extract_pool=pool_n, strict=cfg.strict,
            n_real=n_real, **overrides)
        t_done = time.perf_counter()
        compiled = engine.trace_count(m, k, **overrides) > traces_before
        extract_after = engine.extraction_stats
        # The engine's wall_time_s times the superstep loop alone; the
        # rest of the dispatch interval is host-side extraction + result
        # construction.  Splitting the interval at that boundary gives
        # every rider an honest device span without a second clock read
        # inside the engine.
        device_ms = results[0].wall_time_s * 1e3 if results else 0.0
        t_device_end = min(t_done, t_dispatch + device_ms / 1e3)
        if leader is not None:
            leader.add_span("device_dispatch", t_dispatch, t_device_end,
                            compiled=compiled, lanes=len(queries))
            leader.add_span(
                "extract", t_device_end, t_done,
                mode="device" if cfg.extract or want_trees else "skipped",
                device_resolved=(extract_after["device_resolved"]
                                 - extract_before["device_resolved"]),
                host_fallbacks=(extract_after["host_fallbacks"]
                                - extract_before["host_fallbacks"]))
        self.lane_policy.observe(len(queries), device_ms)
        self._stats.record_dispatch(n_real, deadline=False,
                                    shape=(m, k, len(queries)))
        # After a set_engine swap, results of the old build are keyed
        # under its version — unreachable to every future lookup, so
        # caching them would only evict live entries.
        cacheable = engine is self.engine
        for req, res in zip(group, results):
            if cacheable:
                with (req.trace.span("cache_store") if req.trace is not None
                      else _NULL_SPAN):
                    self._cache.put(req.cache_key, res)
                    if want_trees and res.answer_pool is not None:
                        self._tree_cache.put(
                            (req.cache_key, "trees"),
                            (res.answer_pool, res.pool_exhausted))
            trees = None
            if req.return_trees:
                self._stats.record_tree_request(cache_hit=False)
                with (req.trace.span("render", ranking=req.tree_ranking,
                                     cursor=req.tree_cursor)
                      if req.trace is not None else _NULL_SPAN):
                    trees = self._render_page(
                        (res.answer_pool or [], res.pool_exhausted), engine,
                        ranking=req.tree_ranking, cursor=req.tree_cursor,
                        page_size=req.tree_page_size)
            t_res = time.perf_counter()
            queue_ms = (t_dispatch - req.t_submit) * 1e3
            self._stats.record_request(req.t_submit, t_res,
                                       queue_wait_ms=queue_ms,
                                       device_ms=device_ms)
            self._h_latency.observe((t_res - req.t_submit) * 1e3)
            self._h_queue.observe(queue_ms)
            self._h_device.observe(device_ms)
            trace_id = None
            if req.trace is not None:
                trace_id = req.trace.trace_id
                req.trace.set(outcome="served", compiled=compiled)
                req.trace.finish()
            req.future.set_result(ServedResult(
                result=res, cache_hit=False, approximate=False,
                batch_size=n_real,
                latency_ms=(t_res - req.t_submit) * 1e3,
                trees=trees, trace_id=trace_id,
                queue_wait_ms=queue_ms, device_ms=device_ms))

    def _serve_deadline_batch(self, group: list[Request]) -> None:
        cfg = self.config
        engine = group[0].engine
        queries = [list(req.keywords) for req in group]
        n_real = len(queries)
        queries += [queries[-1]] * (self._padded_len(n_real) - n_real)
        # One lane driver for the whole bucket.  The group deadline is the
        # EARLIEST lane's (conservative: requests with the same budget
        # admitted within one window differ by at most that window, and
        # no lane may overshoot its own deadline).  query_deadline_batch
        # spends the budget on supersteps, not on per-superstep bound
        # computation (the SPA cover DP can cost many times a superstep);
        # per-lane bounds are computed once, at the end.  Queue wait
        # already counted against the deadline.
        deadline_t = min(req.deadline_t for req in group)
        t_dispatch = time.perf_counter()
        self._observe_dispatch(
            group, len(queries), t_dispatch,
            deadline_budget_ms=(deadline_t - t_dispatch) * 1e3)
        leader = group[0].trace
        want_trees = any(req.return_trees for req in group)
        pool_n = group[0].k * cfg.tree_pool_factor if want_trees else None
        overrides = dict(group[0].overrides)
        m, k = len(group[0].keywords), group[0].k
        traces_before = engine.trace_count(m, k, kind="stepwise",
                                           **overrides)
        out = engine.query_deadline_batch(
            queries, k=k, extract=cfg.extract or want_trees,
            extract_pool=pool_n, strict=cfg.strict,
            deadline_s=deadline_t - time.perf_counter(), n_real=n_real,
            **overrides)
        t_done = time.perf_counter()
        compiled = engine.trace_count(m, k, kind="stepwise",
                                      **overrides) > traces_before
        driver_steps = out[0][1]["driver_supersteps"] if out else 0
        lane_steps = sum(res.supersteps for res, _ in out[:n_real])
        device_ms = out[0][0].wall_time_s * 1e3 if out else 0.0
        t_device_end = min(t_done, t_dispatch + device_ms / 1e3)
        if leader is not None:
            leader.add_span("device_dispatch", t_dispatch, t_device_end,
                            compiled=compiled, lanes=len(queries),
                            driver_supersteps=driver_steps)
            extraction = (out[0][1].get("extraction", {})
                          if out else {})
            leader.add_span(
                "extract", t_device_end, t_done,
                mode="overlapped" if extraction else "inline",
                **extraction)
        self.lane_policy.observe(len(queries), device_ms)
        self._stats.record_dispatch(n_real, deadline=True,
                                    driver_steps=driver_steps,
                                    lane_steps=lane_steps,
                                    shape=(m, k, len(queries)))
        cacheable = engine is self.engine
        for req, (res, info) in zip(group, out):
            approximate = info["interrupted"]
            if not approximate and cacheable:
                # Finished inside its budget: an exact answer, cacheable
                # like any other (unless the build was swapped while in
                # flight — the old-version key would be unreachable).
                # Best-so-far results are budget-specific — never cached,
                # and neither are their tree pools.
                with (req.trace.span("cache_store") if req.trace is not None
                      else _NULL_SPAN):
                    self._cache.put(req.cache_key, res)
                    if want_trees and res.answer_pool is not None:
                        self._tree_cache.put(
                            (req.cache_key, "trees"),
                            (res.answer_pool, res.pool_exhausted))
            trees = None
            if req.return_trees:
                self._stats.record_tree_request(cache_hit=False)
                # For interrupted lanes these are the BEST-SO-FAR trees,
                # served alongside their lower bound — the paper's
                # early-termination answer, now with explanations.
                with (req.trace.span("render", ranking=req.tree_ranking,
                                     cursor=req.tree_cursor)
                      if req.trace is not None else _NULL_SPAN):
                    trees = self._render_page(
                        (res.answer_pool or [], res.pool_exhausted), engine,
                        ranking=req.tree_ranking, cursor=req.tree_cursor,
                        page_size=req.tree_page_size)
            queue_ms = (t_dispatch - req.t_submit) * 1e3
            self._stats.record_request(req.t_submit, t_done,
                                       approximate=approximate,
                                       queue_wait_ms=queue_ms,
                                       device_ms=device_ms)
            self._h_latency.observe((t_done - req.t_submit) * 1e3)
            self._h_queue.observe(queue_ms)
            self._h_device.observe(device_ms)
            trace_id = None
            if req.trace is not None:
                trace_id = req.trace.trace_id
                req.trace.set(outcome="served", approximate=approximate,
                              compiled=compiled)
                req.trace.finish()
            req.future.set_result(ServedResult(
                result=res, cache_hit=False, approximate=approximate,
                batch_size=n_real,
                latency_ms=(t_done - req.t_submit) * 1e3,
                opt_lower_bound=info["opt_lower_bound"],
                sound_opt_lower_bound=info["sound_opt_lower_bound"],
                trees=trees, trace_id=trace_id,
                queue_wait_ms=queue_ms, device_ms=device_ms))
