"""Dynamic micro-batching: an admission queue that coalesces concurrent
requests into shape buckets and dispatches each bucket as one call.

Only queries with the same shape key — keyword count ``m``, answer count
``k``, and policy overrides — can share a vmapped device program (the DKS
table is ``[V, 2^m, K]``), so the batcher buckets by exactly that.  A
bucket dispatches when it reaches ``max_batch`` or when its oldest member
has waited ``max_wait_ms`` (the classic latency/throughput knob pair).

Everything executes inline on the single dispatcher thread: client threads
only ever touch the queue and their futures, so jax sees one caller and the
service needs no further locking around device work.  Deadline-bounded
requests coalesce too — into buckets keyed by shape *and* budget
(``deadline_ms``), so same-budget requests ride one lane driver and share
supersteps; their admission window is capped at a fraction of the budget
so queue wait cannot eat the budget it counts against.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Hashable


@dataclasses.dataclass
class Request:
    """One admitted query, waiting in the batcher.

    ``overrides`` is the per-call policy override dict as a sorted item
    tuple (hashable, order-free).  ``deadline_t`` is an absolute
    ``time.perf_counter()`` deadline — queue wait counts against it.
    ``engine`` is the engine build that admitted (and will serve) the
    request: snapshotting it here keeps a ``set_engine`` swap from
    changing the build mid-flight — admission-time validation and the
    version-carrying cache key stay consistent with execution.
    """

    keywords: tuple
    k: int
    overrides: tuple[tuple[str, Any], ...]
    future: Future
    t_submit: float
    engine: Any = None
    deadline_t: float | None = None
    deadline_ms: float | None = None
    cache_key: Hashable = None
    # The request's trace (repro.obs.Trace) — admission begins it, the
    # resolve path finishes it.  Opaque to the batcher.
    trace: Any = None
    # Answer-tree serving (DKSService.submit(return_trees=True)).  These
    # shape only host-side rendering, never the device program, so they
    # are NOT part of shape_key — tree and non-tree requests co-batch.
    return_trees: bool = False
    tree_ranking: str = "diverse"      # "diverse" | "weight"
    tree_cursor: int = 0
    tree_page_size: int | None = None

    @property
    def shape_key(self) -> tuple:
        # The engine build is part of the shape: requests admitted under
        # different builds must never share a dispatch.  So is the build's
        # WEIGHT POLICY: two engines over the same artifact share a
        # version (the content hash) but may rank on different effective
        # weights — co-batching them would serve one policy's answers to
        # the other's requests.  The *budget* (deadline_ms, not the
        # absolute deadline) is part of it too: same-budget requests ride
        # one lane driver and stop together; deadline-less requests
        # (None) bucket separately.
        version = self.engine.version if self.engine is not None else None
        weights = (getattr(self.engine.policy, "weights", None)
                   if self.engine is not None else None)
        return (len(self.keywords), self.k, self.overrides, version,
                weights, self.deadline_ms)


_STOP = object()


class MicroBatcher:
    """Admission queue + dispatcher thread.

    ``dispatch`` is called on the dispatcher thread with a non-empty list
    of same-shape (and, for deadline requests, same-budget) requests and
    must resolve every request's future — including on error.
    :class:`DKSService` provides it; the batcher owns only admission,
    grouping, and timing.
    """

    def __init__(self, dispatch: Callable[[list[Request]], None], *,
                 max_batch: int = 8, max_wait_ms: float = 5.0,
                 max_batch_for: Callable[[], int] | None = None) -> None:
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._dispatch = dispatch
        self.max_batch = int(max_batch)
        # Optional dynamic fill target (adaptive lane policy): consulted
        # per drain cycle, clamped to [1, max_batch].  A bucket that
        # reaches the target dispatches immediately — the policy's
        # "bucket size worth waiting for" — while the window expiry
        # still bounds the wait for partial buckets.  None = fixed
        # max_batch, the classic behavior.
        self._max_batch_for = max_batch_for
        self.max_wait_s = float(max_wait_ms) / 1e3
        self._queue: queue.Queue = queue.Queue()
        self._thread: threading.Thread | None = None
        self._stopping = False
        # Why each bucket dispatched: "full" (hit max_batch), "window"
        # (oldest member's admission window expired), "flush" (service
        # stopping).  Counters are monotone; ``current_reason`` is valid
        # inside a dispatch call (same thread, set right before it) and
        # lets the service stamp the reason on the bucket's trace span.
        self.dispatch_counts = {"full": 0, "window": 0, "flush": 0}
        self.current_reason: str | None = None
        # Makes submit's running-check + enqueue atomic against stop():
        # any request admitted under the lock is enqueued before _STOP,
        # so the dispatcher always sees (and flushes) it before exiting.
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------

    def start(self) -> None:
        with self._lock:
            if self._thread is not None:
                raise RuntimeError("batcher already started")
            # Drain anything stale from a prior generation (a _STOP left
            # by a stop() whose dispatcher had already died would make
            # the new dispatcher exit on arrival, wedging every future).
            while True:
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    break
                if isinstance(item, Request) and not item.future.done():
                    item.future.set_exception(
                        RuntimeError("service restarted before dispatch"))
            self._stopping = False
            self._thread = threading.Thread(
                target=self._loop, name="dks-serve-dispatcher", daemon=True)
            self._thread.start()

    def stop(self) -> None:
        """Stop accepting requests, flush pending buckets, join.

        Safe under concurrent calls: the first caller claims the thread
        (and enqueues exactly one _STOP); later callers return at once.
        """
        with self._lock:
            thread = self._thread
            if thread is None:
                return
            self._thread = None
            self._stopping = True
            self._queue.put(_STOP)
        thread.join()

    @property
    def running(self) -> bool:
        return self._thread is not None

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------

    def submit(self, request: Request) -> None:
        with self._lock:
            if self._stopping or self._thread is None:
                raise RuntimeError("service is not running")
            self._queue.put(request)

    # ------------------------------------------------------------------
    # Dispatcher thread
    # ------------------------------------------------------------------

    def _loop(self) -> None:
        pending: dict[tuple, list[Request]] = {}
        try:
            self._loop_body(pending)
        except BaseException as exc:  # noqa: BLE001 — dispatcher last resort
            # A bookkeeping failure outside _safe_dispatch must not wedge
            # the service with unresolvable futures: fail everything
            # pending and queued, and refuse new submits.
            with self._lock:
                self._stopping = True
            for group in pending.values():
                for req in group:
                    if not req.future.done():
                        req.future.set_exception(exc)
            while True:
                try:
                    item = self._queue.get_nowait()
                except queue.Empty:
                    break
                if isinstance(item, Request) and not item.future.done():
                    item.future.set_exception(exc)

    def _loop_body(self, pending: dict[tuple, list[Request]]) -> None:
        stopping = False
        while True:
            timeout = self._next_timeout(pending)
            try:
                item = self._queue.get(
                    timeout=timeout) if timeout != 0 else None
            except queue.Empty:
                item = None
            drained = [] if item is None else [item]
            while True:
                try:
                    drained.append(self._queue.get_nowait())
                except queue.Empty:
                    break
            for req in drained:
                if req is _STOP:
                    stopping = True
                else:
                    pending.setdefault(req.shape_key, []).append(req)
            now = time.perf_counter()
            fill = self.max_batch
            if self._max_batch_for is not None:
                try:
                    fill = max(1, min(int(self._max_batch_for()),
                                      self.max_batch))
                except Exception:  # noqa: BLE001 — policy must not wedge
                    fill = self.max_batch
            for key in list(pending):
                group = pending[key]
                while len(group) >= fill:
                    self._safe_dispatch(group[:fill], "full")
                    del group[:fill]
                if group and (stopping or
                              now - group[0].t_submit
                              >= self._window_s(group[0])):
                    self._safe_dispatch(
                        group, "flush" if stopping else "window")
                    group = []
                if group:
                    pending[key] = group
                else:
                    del pending[key]
            if stopping and not pending:
                return

    def _window_s(self, req: Request) -> float:
        """Admission window for a request's bucket.  Deadline buckets cap
        it at a fraction of the budget — the wait counts against the very
        deadline it is coalescing for, so a bucket must dispatch with
        most of its budget intact even when ``max_wait_ms`` is larger.
        A 1 ms floor keeps near-zero budgets coalescing: such a request
        expires either way, and concurrent identical-budget requests
        submitted back-to-back must not race the dispatcher into
        singleton buckets."""
        if req.deadline_ms is None:
            return self.max_wait_s
        return min(self.max_wait_s,
                   max(1e-3, 0.2 * req.deadline_ms / 1e3))

    def _next_timeout(self, pending: dict[tuple, list[Request]]):
        """Block forever when idle; otherwise wake for the nearest bucket
        window expiry (0 = poll without blocking)."""
        if not pending:
            return None
        now = time.perf_counter()
        nearest = min(group[0].t_submit + self._window_s(group[0])
                      for group in pending.values())
        remaining = nearest - now
        return max(remaining, 0.0) if remaining > 1e-4 else 0

    def _safe_dispatch(self, group: list[Request],
                       reason: str = "window") -> None:
        self.dispatch_counts[reason] += 1
        self.current_reason = reason
        try:
            self._dispatch(group)
        except BaseException as exc:  # noqa: BLE001 — must resolve futures
            for req in group:
                if not req.future.done():
                    req.future.set_exception(exc)
        finally:
            self.current_reason = None
