"""Serving statistics: per-request latencies, dispatch batch-fill, and
cache counters, aggregated into the :class:`ServeStats` report (p50/p95
latency, throughput, batch-fill, cache-hit rate).

Latencies are end-to-end client latencies — submit to resolved future —
so they include queue wait and the micro-batching admission window, not
just device time.  That is the number a latency budget is written against.
The queue-wait and device-time splits (fed from the request traces, see
:mod:`repro.obs`) break that end-to-end number down: a p95 blowup with a
flat device split is an admission/queueing problem, not a kernel one.

Every ``ServeStats`` field carries its unit in the name or docstring:
``*_ms`` are milliseconds, ``window_s`` seconds, ``throughput_rps``
requests/second; everything else is a dimensionless count or ratio.
All fields are finite for any history, including the empty startup
window (no NaN percentiles before the first request resolves).
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque

import numpy as np

# Latency percentiles are computed over a bounded window of the most
# recent requests, so a long-lived service holds O(1) memory and stats()
# stays cheap; counters (requests, failures, ...) are exact totals.
LATENCY_WINDOW = 16384


def _pct(values, q: float) -> float:
    """Percentile that is 0.0 (not NaN) on an empty window."""
    arr = np.asarray(values, np.float64)
    return float(np.percentile(arr, q)) if arr.size else 0.0


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """Aggregate serving report (one snapshot of ``DKSService.stats()``).

    Attributes (units: ``*_ms`` milliseconds, ``window_s`` seconds,
    ``throughput_rps`` requests/second; all others counts or ratios):

      requests:        count of requests served so far (cache hits
                       included; admission-rejected submits are not
                       counted and do not skew the window).
      failures:        count of dispatched requests whose execution
                       raised (their futures carry the exception).
      batch_dispatches: count of device dispatches made by the
                       micro-batcher.
      deadline_dispatches: count of lane-driver dispatches for
                       deadline-bounded requests (same-shape same-budget
                       requests coalesce onto one stepwise driver and
                       share supersteps).
      batched_requests: count of requests served through batch dispatches.
      mean_batch_fill: ratio batched_requests / batch_dispatches — how
                       many client requests each lane-driver program
                       served (padding lanes are not counted; > 1 means
                       the batcher is amortizing dispatch across clients).
      deadline_batched_requests / mean_deadline_fill: the same pair for
                       deadline dispatches (> 1 mean fill means at least
                       one multi-lane deadline bucket rode one driver).
      deadline_driver_supersteps: count of supersteps the shared deadline
                       drivers actually stepped.
      deadline_lane_supersteps: sum of the per-lane superstep counts those
                       drivers served (what solo serving would pay at
                       minimum).  driver << lane = coalescing is working:
                       a bucket costs ~max(lane steps), not the sum.
      cache_hits / cache_misses / cache_evictions / cache_hit_rate:
                       result-cache counters (hit rate over hits+misses).
      single_flight_hits: count of requests that attached to an identical
                       request already in flight (cross-request
                       single-flight) — served from the leader's result,
                       no device work, not counted in the cache counters.
      approximate:     count of requests answered best-so-far under a
                       deadline.
      tree_requests:   count of requests that asked for answer trees
                       (``return_trees=True``).
      tree_cache_hits: tree requests served whole from the result cache
                       plus the tree-pool LRU — no device work, no
                       re-extraction (re-ranking/pagination only).
      p50_ms / p95_ms / mean_ms / max_ms: end-to-end latency (submit ->
                       resolved future, milliseconds) over the last
                       ``LATENCY_WINDOW`` requests (exact until the
                       window fills); 0.0 before the first request.
      queue_p50_ms / queue_p95_ms / queue_mean_ms: queue-wait split
                       (milliseconds): submit -> the dispatcher picking
                       the request up, fed from the ``queue_wait`` trace
                       span.  Cache hits and single-flight followers
                       never enter the queue and are not in this window.
      device_p50_ms / device_p95_ms / device_mean_ms: device-time split
                       (milliseconds): the compiled superstep program's
                       wall time attributed to each dispatched request
                       (one bucket's device time counted once per rider).
      window_s:        first submit -> last resolve, seconds.
      throughput_rps:  requests / window_s, requests per second.
      engine_swaps:    count of hot engine swaps (``set_engine``) this
                       service has performed — every swap invalidates the
                       result/tree caches and retires in-flight
                       single-flight leadership.
      hot_shapes:      dispatch shape histogram, hottest first:
                       ``(((m, k, lanes), count), ...)`` over every device
                       dispatch — what an engine swap pre-compiles so the
                       successor takes no cold-compile hit on the traffic
                       actually being served.
    """

    requests: int
    failures: int
    batch_dispatches: int
    deadline_dispatches: int
    batched_requests: int
    mean_batch_fill: float
    deadline_batched_requests: int
    mean_deadline_fill: float
    deadline_driver_supersteps: int
    deadline_lane_supersteps: int
    cache_hits: int
    cache_misses: int
    cache_evictions: int
    cache_hit_rate: float
    single_flight_hits: int
    approximate: int
    tree_requests: int
    tree_cache_hits: int
    p50_ms: float
    p95_ms: float
    mean_ms: float
    max_ms: float
    window_s: float
    throughput_rps: float
    queue_p50_ms: float = 0.0
    queue_p95_ms: float = 0.0
    queue_mean_ms: float = 0.0
    device_p50_ms: float = 0.0
    device_p95_ms: float = 0.0
    device_mean_ms: float = 0.0
    engine_swaps: int = 0
    hot_shapes: tuple = ()

    def summary(self) -> str:
        """Human-readable multi-line report (the CLI prints this)."""
        failed = f", {self.failures} failed" if self.failures else ""
        swaps = (f"\nengine swaps  {self.engine_swaps}"
                 if self.engine_swaps else "")
        return (
            f"requests      {self.requests}"
            f"  ({self.approximate} approximate under deadline{failed})\n"
            f"throughput    {self.throughput_rps:.1f} req/s"
            f" over {self.window_s:.2f}s\n"
            f"latency ms    p50={self.p50_ms:.1f} p95={self.p95_ms:.1f}"
            f" mean={self.mean_ms:.1f} max={self.max_ms:.1f}\n"
            f"  queue ms    p50={self.queue_p50_ms:.1f}"
            f" p95={self.queue_p95_ms:.1f} mean={self.queue_mean_ms:.1f}\n"
            f"  device ms   p50={self.device_p50_ms:.1f}"
            f" p95={self.device_p95_ms:.1f} mean={self.device_mean_ms:.1f}\n"
            f"batch-fill    {self.mean_batch_fill:.2f} mean over"
            f" {self.batch_dispatches} batch dispatches\n"
            f"deadline      {self.deadline_batched_requests} requests over"
            f" {self.deadline_dispatches} driver dispatches"
            f" (fill {self.mean_deadline_fill:.2f};"
            f" {self.deadline_driver_supersteps} driver vs"
            f" {self.deadline_lane_supersteps} lane supersteps)\n"
            f"cache         hits={self.cache_hits}"
            f" misses={self.cache_misses}"
            f" evictions={self.cache_evictions}"
            f" hit-rate={self.cache_hit_rate:.2f}"
            f" single-flight={self.single_flight_hits}\n"
            f"trees         {self.tree_requests} requests,"
            f" {self.tree_cache_hits} served from the tree cache"
            f"{swaps}"
        )


class StatsCollector:
    """Thread-safe recorder behind ``DKSService.stats()``.

    Requests resolve on two threads — cache hits on the client thread,
    everything else on the dispatcher thread — so every mutation takes the
    lock.  ``report()`` is a consistent snapshot.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._lat_ms: deque[float] = deque(maxlen=LATENCY_WINDOW)
        self._queue_ms: deque[float] = deque(maxlen=LATENCY_WINDOW)
        self._device_ms: deque[float] = deque(maxlen=LATENCY_WINDOW)
        self._n_requests = 0
        self._t_first: float | None = None
        self._t_last: float | None = None
        self._approximate = 0
        self._failures = 0
        self._batch_dispatches = 0
        self._deadline_dispatches = 0
        self._batched_requests = 0
        self._deadline_requests = 0
        self._deadline_driver_steps = 0
        self._deadline_lane_steps = 0
        self._single_flight = 0
        self._tree_requests = 0
        self._tree_cache_hits = 0
        self._engine_swaps = 0
        self._shape_counts: dict[tuple, int] = {}

    def record_request(self, t_submit: float, t_done: float,
                       approximate: bool = False,
                       queue_wait_ms: float | None = None,
                       device_ms: float | None = None) -> None:
        """One served request.  The stats window (t_first..t_last) is
        derived here, from served requests only — so a rejected submit
        never skews it and every snapshot is internally consistent.
        ``queue_wait_ms`` / ``device_ms`` feed the latency split windows
        (None for resolve paths where the phase does not exist — cache
        hits never queue, single-flight followers never dispatch)."""
        with self._lock:
            self._lat_ms.append((t_done - t_submit) * 1e3)
            if queue_wait_ms is not None:
                self._queue_ms.append(float(queue_wait_ms))
            if device_ms is not None:
                self._device_ms.append(float(device_ms))
            self._n_requests += 1
            if self._t_first is None or t_submit < self._t_first:
                self._t_first = t_submit
            if self._t_last is None or t_done > self._t_last:
                self._t_last = t_done
            if approximate:
                self._approximate += 1

    def record_failure(self, n_requests: int) -> None:
        with self._lock:
            self._failures += n_requests

    def record_single_flight(self) -> None:
        """One request served by attaching to an in-flight identical
        request (call alongside record_request for that request)."""
        with self._lock:
            self._single_flight += 1

    def record_tree_request(self, cache_hit: bool) -> None:
        """One ``return_trees`` request; ``cache_hit`` when it was served
        whole from the result + tree caches (no extraction)."""
        with self._lock:
            self._tree_requests += 1
            if cache_hit:
                self._tree_cache_hits += 1

    def record_dispatch(self, n_requests: int, deadline: bool,
                        driver_steps: int = 0, lane_steps: int = 0,
                        shape: tuple | None = None) -> None:
        """One device dispatch serving ``n_requests`` real lanes.  For
        deadline dispatches, ``driver_steps`` is what the shared driver
        stepped and ``lane_steps`` the sum of its lanes' own counters —
        the coalescing win is driver << lanes.  ``shape`` is the
        dispatched ``(m, k, lanes)`` bucket; the histogram is what an
        engine swap warms on the successor."""
        with self._lock:
            if deadline:
                self._deadline_dispatches += 1
                self._deadline_requests += n_requests
                self._deadline_driver_steps += driver_steps
                self._deadline_lane_steps += lane_steps
            else:
                self._batch_dispatches += 1
                self._batched_requests += n_requests
            if shape is not None:
                key = tuple(int(x) for x in shape)
                self._shape_counts[key] = self._shape_counts.get(key, 0) + 1

    def record_engine_swap(self) -> None:
        """One hot engine swap performed by ``set_engine``."""
        with self._lock:
            self._engine_swaps += 1

    def report(self, cache_stats: dict[str, int]) -> ServeStats:
        with self._lock:
            lat = np.asarray(self._lat_ms, np.float64)
            queue = np.asarray(self._queue_ms, np.float64)
            device = np.asarray(self._device_ms, np.float64)
            n = self._n_requests
            window = ((self._t_last - self._t_first)
                      if n and self._t_first is not None else 0.0)
            hits = cache_stats.get("hits", 0)
            misses = cache_stats.get("misses", 0)
            looked = hits + misses
            return ServeStats(
                requests=n,
                failures=self._failures,
                batch_dispatches=self._batch_dispatches,
                deadline_dispatches=self._deadline_dispatches,
                batched_requests=self._batched_requests,
                mean_batch_fill=(
                    self._batched_requests / self._batch_dispatches
                    if self._batch_dispatches else 0.0),
                deadline_batched_requests=self._deadline_requests,
                mean_deadline_fill=(
                    self._deadline_requests / self._deadline_dispatches
                    if self._deadline_dispatches else 0.0),
                deadline_driver_supersteps=self._deadline_driver_steps,
                deadline_lane_supersteps=self._deadline_lane_steps,
                cache_hits=hits,
                cache_misses=misses,
                cache_evictions=cache_stats.get("evictions", 0),
                cache_hit_rate=hits / looked if looked else 0.0,
                single_flight_hits=self._single_flight,
                approximate=self._approximate,
                tree_requests=self._tree_requests,
                tree_cache_hits=self._tree_cache_hits,
                p50_ms=_pct(lat, 50),
                p95_ms=_pct(lat, 95),
                mean_ms=float(lat.mean()) if lat.size else 0.0,
                max_ms=float(lat.max()) if lat.size else 0.0,
                window_s=window,
                throughput_rps=n / window if window > 0 else 0.0,
                queue_p50_ms=_pct(queue, 50),
                queue_p95_ms=_pct(queue, 95),
                queue_mean_ms=float(queue.mean()) if queue.size else 0.0,
                device_p50_ms=_pct(device, 50),
                device_p95_ms=_pct(device, 95),
                device_mean_ms=float(device.mean()) if device.size else 0.0,
                engine_swaps=self._engine_swaps,
                hot_shapes=tuple(sorted(self._shape_counts.items(),
                                        key=lambda kv: (-kv[1], kv[0]))),
            )
