"""Load generation: synthetic request traces + concurrent replay clients.

``make_trace`` builds a replay trace the way the paper builds query
workloads (Sec. 7.1: keywords sampled across the document-frequency
spectrum), then draws requests from that pool with a skewed (1/rank)
popularity — real query streams repeat, which is what gives a warm result
cache its hits.

``replay`` drives a :class:`~repro.serve.service.DKSService` with N
closed-loop clients (each submits, waits, submits the next), the standard
serving-benchmark shape: concurrency creates admission pressure, so the
micro-batcher has something to coalesce.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro.serve.service import DKSService, ServedResult


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One replayable request: keywords + answer count + optional budget."""

    keywords: tuple
    k: int = 1
    deadline_ms: float | None = None


def make_trace(index, n_requests: int = 48, *, unique: int = 8,
               m_choices: tuple = (2, 3), k: int = 1,
               deadline_frac: float = 0.0, deadline_ms: float = 75.0,
               deadline_burst: int = 4,
               seed: int = 0) -> list[TraceRequest]:
    """Synthetic request trace over an :class:`InvertedIndex`'s vocabulary.

    ``unique`` distinct queries are built first (keyword counts cycling
    through ``m_choices``, tokens picked from spread-out windows of the
    df-sorted vocabulary so keyword-node counts span the Fig. 9 range),
    then ``n_requests`` draws follow a 1/rank popularity — the head query
    repeats often enough that a warm cache sees hits.

    A ``deadline_frac`` fraction of requests carries a ``deadline_ms``
    budget, placed as **bursts** of up to ``deadline_burst`` consecutive
    requests sharing one keyword count ``m`` (real SLO traffic arrives
    in same-budget waves, not evenly interleaved): concurrent replay
    clients then land same-shape same-budget requests in one admission
    window, which is what exercises the service's coalesced deadline
    buckets — N lanes riding one stepwise driver.  Deterministic per
    ``seed``.
    """
    pairs = sorted(index.token_dfs(), key=lambda p: p[1])
    usable = [t for t, d in pairs if d >= 2]
    if len(usable) < max(m_choices) * 2:
        raise ValueError("vocabulary too small for a trace")
    rng = np.random.default_rng(seed)
    pool: list[tuple] = []
    for i in range(unique):
        m = m_choices[i % len(m_choices)]
        lo = int((len(usable) - m) * i / max(unique, 1))
        hi = min(len(usable) - 1, lo + max(2 * m, 10))
        picks = rng.choice(np.arange(lo, hi + 1), size=m, replace=False)
        pool.append(tuple(usable[int(p)] for p in picks))
    ranks = np.arange(len(pool))
    popularity = 1.0 / (ranks + 1.0)
    popularity /= popularity.sum()
    trace = []
    for j in range(n_requests):
        q = pool[int(rng.choice(len(pool), p=popularity))]
        trace.append(TraceRequest(keywords=q, k=k, deadline_ms=None))
    if deadline_frac > 0:
        pool_by_m: dict[int, list[tuple]] = {}
        for q in pool:
            pool_by_m.setdefault(len(q), []).append(q)
        n_dl = max(1, min(n_requests, int(round(deadline_frac
                                                * n_requests))))
        burst = max(1, min(deadline_burst, n_dl))
        n_bursts = max(1, -(-n_dl // burst))
        taken: set[int] = set()
        placed = 0
        for b in range(n_bursts):
            start = int(b * n_requests / n_bursts)
            same_m = pool_by_m[len(trace[start].keywords)]
            in_burst = 0
            p = start
            # Skip slots an earlier (overlapping) burst already claimed,
            # so the trace carries exactly n_dl deadline requests.
            while placed < n_dl and in_burst < burst and p < n_requests:
                if p not in taken:
                    q = same_m[int(rng.choice(len(same_m)))]
                    trace[p] = TraceRequest(keywords=q, k=k,
                                            deadline_ms=deadline_ms)
                    taken.add(p)
                    placed += 1
                    in_burst += 1
                p += 1
    return trace


def latency_split(results: list[ServedResult]) -> dict[str, float]:
    """Aggregate the end-to-end / queue-wait / device-time latency split
    over served results (milliseconds; p50/p95/mean per phase).

    Results missing a phase are excluded from that phase's window —
    cache hits and single-flight followers never queue or dispatch, so
    ``n_queue``/``n_device`` say how many results each split covers.
    Zeros (not NaN) when a window is empty, matching ``ServeStats``.
    """
    def summarize(values: list[float], tag: str) -> dict[str, float]:
        arr = np.asarray(values, np.float64)
        if not arr.size:
            return {f"{tag}_p50_ms": 0.0, f"{tag}_p95_ms": 0.0,
                    f"{tag}_mean_ms": 0.0}
        return {f"{tag}_p50_ms": float(np.percentile(arr, 50)),
                f"{tag}_p95_ms": float(np.percentile(arr, 95)),
                f"{tag}_mean_ms": float(arr.mean())}

    served = [r for r in results if r is not None]
    queue = [r.queue_wait_ms for r in served if r.queue_wait_ms is not None]
    device = [r.device_ms for r in served if r.device_ms is not None]
    out = {"n": len(served), "n_queue": len(queue),
           "n_device": len(device)}
    out.update(summarize([r.latency_ms for r in served], "latency"))
    out.update(summarize(queue, "queue"))
    out.update(summarize(device, "device"))
    return out


def replay(service: DKSService, trace: list[TraceRequest], *,
           n_clients: int = 8) -> list[ServedResult]:
    """Replay ``trace`` through ``service`` with ``n_clients`` concurrent
    closed-loop clients.  Returns results in trace order; the first client
    error (if any) is re-raised after all clients stop."""
    results: list[ServedResult | None] = [None] * len(trace)
    errors: list[BaseException] = []
    cursor = [0]
    lock = threading.Lock()
    n_clients = max(1, min(n_clients, len(trace)))
    barrier = threading.Barrier(n_clients)

    def client() -> None:
        barrier.wait()
        while True:
            with lock:
                i = cursor[0]
                cursor[0] += 1
            if i >= len(trace) or errors:
                return
            req = trace[i]
            try:
                results[i] = service.query(
                    list(req.keywords), k=req.k,
                    deadline_ms=req.deadline_ms)
            except BaseException as exc:  # noqa: BLE001 — reported below
                errors.append(exc)
                return

    threads = [threading.Thread(target=client, name=f"dks-client-{c}")
               for c in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        raise errors[0]
    return results  # type: ignore[return-value]
