"""EngineSwapper — zero-downtime engine replacement for DKSService.

The swap pipeline runs entirely OFF the dispatcher thread (the watcher
thread, or whatever thread calls :meth:`swap_to`), so serving never
stalls behind a rebuild:

    build   QueryEngine.build(artifact=chain)   — mmap-open the grown
            chain; version = the chained hash.
    warm    replay the hot ``(m, k, lanes)`` shape buckets ServeStats
            recorded for the *current* traffic, so the successor's
            executables are compiled before any request lands on them.
    swap    DKSService.set_engine(successor)     — atomic reference
            swap + cache/single-flight invalidation; in-flight requests
            finish on the build that admitted them.

Each swap is traced (``dks.swap`` with build/warm/swap child spans, the
target hash and outcome on the trace) and metered:
``dks_engine_swaps_total`` comes from :class:`ServeStats`;
:meth:`wire_metrics` adds ``dks_delta_applied_total`` and
``dks_graph_staleness_seconds`` (how long published-but-not-yet-served
data has been waiting — 0 when the serving engine is current).
"""

from __future__ import annotations

import threading
import time
from typing import Any, Optional

from repro.engine.engine import QueryEngine
from repro.graph.index import mid_df_tokens


class EngineSwapper:
    """Build, warm, and atomically install successor engines into a
    :class:`repro.serve.DKSService`.

    ``on_delta`` matches the :class:`repro.live.GraphWatcher` callback
    signature, so the whole live loop is::

        swapper = EngineSwapper(svc)
        swapper.wire_metrics()
        GraphWatcher(live, "incoming/", on_delta=swapper.on_delta).start()

    ``warm_top`` caps how many distinct hot shapes get pre-compiled per
    swap (each is one ``query_batch`` compile); ``policy=None`` carries
    the outgoing engine's execution policy forward.
    """

    def __init__(self, service: Any, *, policy: Any = None,
                 warm_top: int = 4) -> None:
        self.service = service
        self.policy = policy
        self.warm_top = int(warm_top)
        self._lock = threading.Lock()
        self._applied = 0          # deltas folded into a *serving* engine
        self._pending = 0          # published deltas not yet served
        self._pending_since: float | None = None
        self.swaps = 0
        self.last_warmed: list[tuple] = []

    # -- staleness bookkeeping -----------------------------------------

    def published(self, n: int = 1) -> None:
        """Record ``n`` published-but-not-yet-served deltas (starts the
        staleness clock if it isn't already running)."""
        with self._lock:
            self._pending += n
            if self._pending_since is None:
                self._pending_since = time.monotonic()

    @property
    def deltas_applied(self) -> int:
        with self._lock:
            return self._applied

    @property
    def staleness_seconds(self) -> float:
        """Seconds the oldest published-but-unserved delta has waited
        (0.0 when the serving engine is current)."""
        with self._lock:
            if self._pending_since is None:
                return 0.0
            return time.monotonic() - self._pending_since

    # -- the swap pipeline ---------------------------------------------

    def on_delta(self, live: Any, delta: Any) -> None:
        """:class:`GraphWatcher` callback: a delta was just published —
        rebuild on the grown chain and swap it in."""
        self.published()
        self.swap_to(live.chain())

    def swap_to(self, target: Any) -> QueryEngine:
        """Run build → warm → swap against ``target`` (a
        :class:`~repro.store.GraphChain`, artifact, or artifact path).
        Returns the installed engine.  Raises whatever the build raised
        — the service keeps serving the old graph, and the staleness
        gauge keeps climbing, which is the observable alarm."""
        svc = self.service
        trace = svc.tracer.begin(
            "dks.swap",
            target=getattr(target, "content_hash", str(target))[:12],
            from_version=svc.engine.version)
        try:
            t0 = time.perf_counter()
            engine = QueryEngine.build(
                artifact=target, policy=self.policy or svc.engine.policy)
            trace.add_span("build", t0, time.perf_counter(),
                           version=engine.version)

            t0 = time.perf_counter()
            warmed = self._warm(engine)
            trace.add_span("warm", t0, time.perf_counter(),
                           shapes=len(warmed))

            t0 = time.perf_counter()
            svc.set_engine(engine)
            trace.add_span("swap", t0, time.perf_counter())

            with self._lock:
                self._applied += self._pending
                self._pending = 0
                self._pending_since = None
                self.swaps += 1
            self.last_warmed = warmed
            trace.set(outcome="swapped", version=engine.version)
            return engine
        except BaseException as exc:
            trace.set(outcome="error", error=repr(exc))
            raise
        finally:
            trace.finish()

    def _warm(self, engine: QueryEngine) -> list[tuple]:
        """Pre-compile the successor's executables for the hot
        ``(m, k, lanes)`` buckets the service recorded.  Warming queries
        draw mid-df tokens from the *new* index, run with
        ``extract=False, strict=False, n_real=1`` — extract/strict don't
        key the executable cache, so a warmed shape is a compile-free
        shape for real traffic."""
        shapes = [s for s, _count in
                  getattr(self.service.stats(), "hot_shapes", ())
                  [:self.warm_top]]
        if not shapes:
            return []
        tokens = mid_df_tokens(engine.index)
        warmed: list[tuple] = []
        for shape in shapes:
            m, k, lanes = (int(x) for x in shape)
            if len(tokens) < m or m < 1 or lanes < 1:
                continue
            try:
                engine.query_batch([list(tokens[:m])] * lanes, k=k,
                                   extract=False, strict=False, n_real=1)
            except Exception:
                continue   # warming is best-effort; the swap still lands
            warmed.append((m, k, lanes))
        return warmed

    # -- metrics -------------------------------------------------------

    def wire_metrics(self, registry: Optional[Any] = None) -> None:
        """Register the live-graph collectors on ``registry`` (defaults
        to the service's own registry, i.e. its ``/metrics`` surface)."""
        reg = registry if registry is not None else self.service.registry

        def collect_live() -> dict[str, float]:
            return {
                "dks_delta_applied_total": float(self.deltas_applied),
                "dks_graph_staleness_seconds": self.staleness_seconds,
            }

        reg.register_collector(
            collect_live,
            kinds={"dks_delta_applied_total": "counter",
                   "dks_graph_staleness_seconds": "gauge"},
            helps={"dks_delta_applied_total":
                   "Delta artifacts folded into a serving engine.",
                   "dks_graph_staleness_seconds":
                   "Age of the oldest published-but-unserved delta "
                   "(0 when the serving engine is current)."})
