"""LiveDir — the on-disk state of one continuously-growing graph.

A live directory holds a base :class:`~repro.store.GraphArtifact`, the
stacked :class:`~repro.store.DeltaArtifact` directories published on top
of it, and a small ``CHAIN.json`` recording the stacking order plus
which source fragments have already been consumed.  ``CHAIN.json`` is
rewritten atomically (tmp sibling + ``os.replace``, the same discipline
as artifact publication) so a reader — another process, or this one
after a crash — always sees a complete, consistent chain description::

    live/
      CHAIN.json        {"base": "base-000000",
                         "deltas": ["delta-000001", …],
                         "chain_hash": "…",
                         "consumed": ["edits-0042.nt", …]}
      base-000000/      graph artifact (entity-name table persisted)
      delta-000001/     delta stacking on base-000000's content hash
      delta-000002/     delta stacking on the chain above it

The chain hash in the file is advisory — :meth:`LiveDir.chain` reopens
and re-verifies the stack hash-by-hash through
:func:`repro.store.open_chain` on every call, so a hand-edited
``CHAIN.json`` that mis-orders deltas fails loudly, naming both hashes.

:meth:`compact` folds the chain into a fresh ``base-NNNNNN`` artifact
(bit-identical to a union re-ingest, including ``content_hash``) and
resets the delta list; superseded directories are left in place for
in-flight readers and external cleanup.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Iterable

from repro.store.artifact import (
    ArtifactError, GraphArtifact, open_artifact, write_artifact,
)
from repro.store.delta import (
    DeltaArtifact, DeltaBuilder, GraphChain, compact_chain, open_chain,
)
from repro.store.ingest import IngestResult

_STATE = "CHAIN.json"
_STATE_FORMAT = "repro-live-dir"
_STATE_VERSION = 1


class LiveDir:
    """One live graph's on-disk state: base + delta chain + bookkeeping.

    Construct with :meth:`initialize` (first publication from an
    :class:`~repro.store.IngestResult`) or ``LiveDir(path)`` to reattach
    to an existing directory.
    """

    def __init__(self, path: str | Path) -> None:
        self.path = Path(path)
        spath = self.path / _STATE
        if not spath.is_file():
            raise ArtifactError(
                f"no live graph at {self.path} (missing {_STATE}) — "
                "create one with LiveDir.initialize(path, ingest_result)")
        try:
            state = json.loads(spath.read_text())
        except json.JSONDecodeError as exc:
            raise ArtifactError(
                f"unreadable {_STATE} in {self.path}: {exc}") from exc
        if state.get("format") != _STATE_FORMAT:
            raise ArtifactError(
                f"{spath} is not a {_STATE_FORMAT} state file "
                f"(format={state.get('format')!r})")
        if state.get("version") != _STATE_VERSION:
            raise ArtifactError(
                f"live-dir state v{state.get('version')} at {self.path}; "
                f"this reader supports v{_STATE_VERSION}")
        self._state = state
        # True while append/compact is between "directory being written"
        # and "state file updated" — the window where a new base/delta
        # directory exists on disk but CHAIN.json does not reference it
        # yet.  :meth:`gc` refuses to run during it (same process —
        # e.g. a GraphWatcher thread mid-publish on this instance).
        self._publishing = False

    # -- creation ------------------------------------------------------

    @classmethod
    def initialize(cls, path: str | Path, result: IngestResult, *,
                   overwrite: bool = False) -> "LiveDir":
        """Publish ``result`` as ``base-000000`` and write the initial
        state.  The ingest must carry the entity-name dictionary
        (reader-based ingests do; synthetic ``from_graph`` results
        don't and cannot grow by text fragments)."""
        if result.names is None:
            raise ArtifactError(
                "live graphs need the entity-name dictionary to stack "
                "deltas; this IngestResult has names=None (synthetic "
                "from_graph source?) — ingest a real N-Triples/TSV dump")
        path = Path(path)
        if (path / _STATE).exists() and not overwrite:
            raise ArtifactError(
                f"live graph already exists at {path} "
                "(pass overwrite=True)")
        path.mkdir(parents=True, exist_ok=True)
        base_name = "base-000000"
        art = write_artifact(
            path / base_name, result.graph, result.index, tau=result.tau,
            stats=result.stats.as_dict(), names=result.names,
            overwrite=overwrite)
        _write_state(path, {
            "format": _STATE_FORMAT, "version": _STATE_VERSION,
            "base": base_name, "base_seq": 0, "deltas": [],
            "chain_hash": art.content_hash, "consumed": [],
            "updated_unix": time.time(),
        })
        return cls(path)

    # -- chain access --------------------------------------------------

    @property
    def base_path(self) -> Path:
        return self.path / self._state["base"]

    @property
    def delta_paths(self) -> list[Path]:
        return [self.path / d for d in self._state["deltas"]]

    @property
    def depth(self) -> int:
        return len(self._state["deltas"])

    @property
    def chain_hash(self) -> str:
        """The recorded chain version (advisory; :meth:`chain`
        recomputes and re-verifies it)."""
        return self._state["chain_hash"]

    @property
    def consumed(self) -> set[str]:
        """Fragment file names already folded into a published delta."""
        return set(self._state["consumed"])

    def base(self) -> GraphArtifact:
        return open_artifact(self.base_path)

    def chain(self) -> GraphChain:
        """Open and hash-verify the current base + delta stack."""
        return open_chain(self.base_path, *self.delta_paths)

    # -- growth --------------------------------------------------------

    def append(self, fragments: Iterable[str | Path], *,
               fmt: str = "auto",
               on_error: str = "skip") -> DeltaArtifact | None:
        """Fold ``fragments`` into ONE new delta stacked on the current
        chain, publish it atomically, and mark the fragments consumed.

        Fragments that add nothing (all lines malformed/empty) still get
        marked consumed — returns ``None`` in that case instead of
        publishing an empty delta.
        """
        fragments = [Path(f) for f in fragments]
        builder = DeltaBuilder(self.chain())
        for frag in fragments:
            builder.add_file(frag, fmt=fmt, on_error=on_error)
        if builder.empty:
            self.mark_consumed(f.name for f in fragments)
            return None
        seq = self.depth + 1
        self._publishing = True
        try:
            delta = builder.write(self.path / f"delta-{seq:06d}")
            state = dict(self._state)
            state["deltas"] = state["deltas"] + [delta.path.name]
            state["chain_hash"] = delta.chain_hash
            state["consumed"] = sorted(
                self.consumed | {f.name for f in fragments})
            state["updated_unix"] = time.time()
            _write_state(self.path, state)
            self._state = state
        finally:
            self._publishing = False
        return delta

    def mark_consumed(self, names: Iterable[str]) -> None:
        state = dict(self._state)
        state["consumed"] = sorted(self.consumed | set(names))
        state["updated_unix"] = time.time()
        _write_state(self.path, state)
        self._state = state

    def compact(self) -> GraphArtifact:
        """Fold the current chain into a fresh base artifact and reset
        the delta list.  Old ``base-*``/``delta-*`` directories stay on
        disk (in-flight readers may hold them open); the state file
        stops referencing them."""
        chain = self.chain()
        seq = int(self._state.get("base_seq", 0)) + 1
        base_name = f"base-{seq:06d}"
        self._publishing = True
        try:
            art = compact_chain(chain, self.path / base_name)
            state = dict(self._state)
            state["base"] = base_name
            state["base_seq"] = seq
            state["deltas"] = []
            state["chain_hash"] = art.content_hash
            state["updated_unix"] = time.time()
            _write_state(self.path, state)
            self._state = state
        finally:
            self._publishing = False
        return art

    # -- cleanup -------------------------------------------------------

    def gc(self, keep_last: int = 1) -> list[str]:
        """Delete ``base-*``/``delta-*`` directories the state file no
        longer references (superseded by :meth:`compact`, or orphaned by
        a crashed publish).  Returns the deleted directory names,
        oldest-first.

        ``keep_last``: retain that many of the *newest* unreferenced
        directories as a grace window for in-flight readers that opened
        the previous chain just before a compact (0 = delete all).

        Refuses with :class:`RuntimeError` while a publish is mid-flight
        on this instance (e.g. a :class:`~repro.live.GraphWatcher`
        thread inside :meth:`append`/:meth:`compact`): in that window a
        new directory exists on disk that ``CHAIN.json`` does not
        reference yet, and gc would delete it.
        """
        if keep_last < 0:
            raise ValueError("keep_last must be >= 0")
        if self._publishing:
            raise RuntimeError(
                f"refusing to gc {self.path}: a publish is in progress "
                "on this LiveDir (its new directory is not referenced "
                "by CHAIN.json yet) — retry after it completes")
        referenced = {self._state["base"], *self._state["deltas"]}
        stale = [p for p in self.path.iterdir()
                 if p.is_dir() and p.name not in referenced
                 and (p.name.startswith("base-")
                      or p.name.startswith("delta-"))]
        stale.sort(key=lambda p: (p.stat().st_mtime, p.name))
        if keep_last:
            stale = stale[:-keep_last] or []
        deleted = []
        for p in stale:
            shutil.rmtree(p)
            deleted.append(p.name)
        return deleted

    def __repr__(self) -> str:
        return (f"LiveDir({str(self.path)!r}, base={self._state['base']}, "
                f"depth={self.depth}, chain={self.chain_hash[:12]}…, "
                f"consumed={len(self._state['consumed'])})")


def _write_state(path: Path, state: dict) -> None:
    tmp = path / f"{_STATE}.tmp-{os.getpid()}"
    tmp.write_text(json.dumps(state, indent=1))
    os.replace(tmp, path / _STATE)
