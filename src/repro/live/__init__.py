"""repro.live — live graphs: continuous ingest + zero-downtime serving.

A production relationship-query service cannot take the graph offline:
source dumps stream edits continuously, yet a classic deployment makes
any change a full re-ingest plus a service restart.  This subsystem
closes that gap on top of the delta substrate in :mod:`repro.store`:

    live directory (one graph's whole live state, on disk)
        live/
          CHAIN.json        base + stacked deltas + consumed fragments
                            (rewritten atomically on every change)
          base-000000/      GraphArtifact (entity-name table persisted)
          delta-000001/     DeltaArtifact stacking on the base hash
          delta-000002/     … stacking on the chain above it

    watch loop (tail a fragment directory into deltas)
        live = LiveDir.initialize("live", ingest_ntriples("dump.nt"))
        watcher = GraphWatcher(live, "incoming/", on_delta=swapper.on_delta)
        watcher.start()        # every new .nt/.tsv fragment becomes a
                               # delta, published atomically

    hot swap (zero-downtime engine replacement)
        svc = DKSService(QueryEngine.build(artifact=live.chain()))
        swapper = EngineSwapper(svc)
        swapper.wire_metrics()
        # on_delta: build + warm the successor engine off the dispatcher
        # thread (pre-compiling the hot (m, k, lanes) buckets ServeStats
        # recorded), then atomically set_engine it into the service.

In-flight requests finish on their admitting build (the engine snapshot
at admission plus version-keyed shape keys make cross-build dispatch
impossible); post-swap requests see the new chained-hash version.  Swap
progress is traced (``dks.swap`` spans: build / warm / swap) and
metered (``dks_engine_swaps_total``, ``dks_delta_applied_total``,
``dks_graph_staleness_seconds``).

Public API:
  LiveDir      — the on-disk live-graph state (base + deltas + bookkeeping).
  GraphWatcher — poll a fragment directory into published deltas.
  EngineSwapper — build/warm/swap successor engines into a DKSService.
"""

from repro.live.state import LiveDir  # noqa: F401
from repro.live.swap import EngineSwapper  # noqa: F401
from repro.live.watch import GraphWatcher  # noqa: F401
