"""GraphWatcher — tail a fragment directory into published deltas.

The continuous-ingest loop: a producer drops N-Triples/TSV fragment
files (``.nt``/``.ntriples``/``.tsv``/``.txt``/``.edges``, optionally
``.gz``) into a watch directory; the watcher polls, batches every
not-yet-consumed fragment into ONE delta via :meth:`LiveDir.append`
(atomic publication, consumed-set bookkeeping), and invokes
``on_delta(live, delta)`` — typically
:meth:`repro.live.EngineSwapper.on_delta`, which hot-swaps the serving
engine onto the grown chain.

Polling (not inotify) keeps the loop portable and dependency-free; the
consumed set in ``CHAIN.json`` makes it restart-safe — a watcher that
crashes after publishing but before deleting nothing (fragments are
never deleted) simply skips already-consumed names on the next scan.
"""

from __future__ import annotations

import threading
from pathlib import Path
from typing import Callable, Optional

from repro.live.state import LiveDir
from repro.store.delta import NT_SUFFIXES, TSV_SUFFIXES, DeltaArtifact

_FRAGMENT_SUFFIXES = NT_SUFFIXES + TSV_SUFFIXES


def _is_fragment(path: Path) -> bool:
    suffix = Path(path.stem).suffix if path.suffix == ".gz" else path.suffix
    return suffix in _FRAGMENT_SUFFIXES


class GraphWatcher:
    """Poll ``watch_dir`` for new fragments; publish each batch as one
    delta on ``live``.

    ``on_delta(live, delta)`` fires after every successful publication
    (not for no-op batches where every line was malformed).  Use
    :meth:`run_once` for deterministic/synchronous operation (tests, the
    ``--smoke`` legs) or :meth:`start`/:meth:`stop` for the background
    thread.  The first exception from the loop stops it and is kept in
    :attr:`error` — a serving process can surface it instead of silently
    serving a stale graph forever.
    """

    def __init__(self, live: LiveDir, watch_dir: str | Path, *,
                 poll_s: float = 0.25,
                 on_delta: Optional[
                     Callable[[LiveDir, DeltaArtifact], None]] = None,
                 fmt: str = "auto", on_error: str = "skip") -> None:
        self.live = live
        self.watch_dir = Path(watch_dir)
        self.poll_s = float(poll_s)
        self.on_delta = on_delta
        self.fmt = fmt
        self.on_error = on_error
        self.published = 0          # deltas published over this lifetime
        self.error: BaseException | None = None
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def pending(self) -> list[Path]:
        """Recognized fragments not yet consumed, oldest name first
        (producers name fragments monotonically; name order = arrival
        order)."""
        if not self.watch_dir.is_dir():
            return []
        consumed = self.live.consumed
        return sorted(
            (p for p in self.watch_dir.iterdir()
             if p.is_file() and _is_fragment(p) and p.name not in consumed),
            key=lambda p: p.name)

    def run_once(self) -> DeltaArtifact | None:
        """One poll cycle: batch every pending fragment into one delta,
        publish, notify.  Returns the delta (``None`` if nothing pended
        or the batch added nothing)."""
        frags = self.pending()
        if not frags:
            return None
        delta = self.live.append(frags, fmt=self.fmt,
                                 on_error=self.on_error)
        if delta is not None:
            self.published += 1
            if self.on_delta is not None:
                self.on_delta(self.live, delta)
        return delta

    # -- background thread ---------------------------------------------

    def start(self) -> "GraphWatcher":
        if self._thread is not None and self._thread.is_alive():
            raise RuntimeError("watcher already running")
        self._stop.clear()
        self.error = None
        self._thread = threading.Thread(
            target=self._loop, name="repro-graph-watcher", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        if self.error is not None:
            raise self.error

    def _loop(self) -> None:
        while not self._stop.is_set():
            try:
                self.run_once()
            except BaseException as exc:  # surface via stop(); don't spin
                self.error = exc
                return
            self._stop.wait(self.poll_s)
