from repro.analysis.hlo import HLOSummary, analyze_hlo  # noqa: F401
from repro.analysis.roofline import RooflineTerms  # noqa: F401
from repro.analysis.roofline import roofline as build_roofline  # noqa: F401
