"""Post-SPMD HLO text analyzer.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless
of trip count (verified empirically — a 12-iteration scan reports 1x flops),
so a scan-over-layers model would be under-counted by n_layers.  This module
re-derives roofline inputs from ``compiled.as_text()`` with loop-trip
multipliers:

- dot FLOPs        (2 * result_elems * contraction)  x enclosing trip counts
- HBM traffic      (operand+result bytes of non-fused ops) x trip counts
- collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
                    collective-permute), per type, x trip counts

Static trip counts are read from the loop-condition computation (max scalar
s32 constant).  Data-dependent loops (e.g. the DKS superstep while-loop)
report multiplier 1 and are flagged ``dynamic_loops`` — callers scale by
expected supersteps.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Ops whose operands/results are bookkeeping, not HBM traffic.
_SKIP_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
}


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dtype, dims = m.group(1), m.group(2)
        sz = _DTYPE_BYTES.get(dtype)
        if sz is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * sz
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = re.search(r"\w+\[([\d,]*)\]", type_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


def _balanced(s: str, start: int) -> int:
    """Index just past the matching ')' for the '(' at ``start``."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    operands: list[str]
    attrs: str
    raw_operands: str = ""
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    types: dict[str, str]            # value name -> type string
    ops: list[Op]
    params: list[str] = dataclasses.field(default_factory=list)  # in order


_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.endswith("{") and ("->" in stripped or "ENTRY" in stripped):
            m = _HDR_RE.match(stripped)
            if m:
                cur = Computation(name=m.group(2), is_entry=bool(m.group(1)),
                                  types={}, ops=[])
                comps[cur.name] = cur
                # Header params: "name: type, name: type".
                for pm in re.finditer(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\))|(?:[\w\[\]\{\},]+))",
                                      m.group(3)):
                    cur.types[pm.group(1)] = pm.group(2)
                    cur.params.append(pm.group(1))
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        is_root = line.lstrip().startswith("ROOT ")
        name, rhs = m.group(1), m.group(2)
        # rhs: "<type> <opcode>(<operands>), attrs..."
        # type may be a tuple "(f32[..], ...)".
        if rhs.startswith("("):
            tend = _balanced(rhs, 0)
        else:
            tend = rhs.find(" ")
            if tend < 0:
                continue
        rtype = rhs[:tend].strip()
        rest = rhs[tend:].lstrip()
        om = re.match(r"([\w\-]+)\(", rest)
        if not om:
            continue
        opcode = om.group(1)
        oend = _balanced(rest, om.end() - 1)
        operand_str = rest[om.end(): oend - 1]
        attrs = rest[oend:]
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        cur.types[name] = rtype
        cur.ops.append(Op(name=name, opcode=opcode, result_type=rtype,
                          operands=operands, attrs=attrs,
                          raw_operands=operand_str, is_root=is_root))
    return comps


def _trip_count(comps: dict[str, Computation], cond_name: str) -> int | None:
    """Max scalar s32 constant in the condition computation (+ its fusion
    callees); None if no static bound is found (dynamic loop)."""
    best = None
    stack = [cond_name]
    seen = set()
    while stack:
        cn = stack.pop()
        if cn in seen or cn not in comps:
            continue
        seen.add(cn)
        for op in comps[cn].ops:
            # Scalar constants look like: %c = s32[] constant(12) — the
            # value lands in the operand slot of our parse.
            if op.opcode == "constant" and re.fullmatch(r"[su]\d+\[\]",
                                                        op.result_type.split("{")[0]):
                m = re.fullmatch(r"(\d+)", op.raw_operands.strip())
                if m:
                    v = int(m.group(1))
                    best = v if best is None else max(best, v)
            m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
            if m:
                stack.append(m.group(1))
    return best


def _fusion_traffic(op: Op, c: Computation,
                    comps: dict[str, Computation]) -> float:
    """Fusion traffic: operands consumed only by dynamic-slice inside the
    body are charged at slice size (scan residual reads); a
    dynamic-update-slice root is charged at update size (scan residual
    writes).  Everything else: full operand/result bytes."""
    m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
    body = comps.get(m.group(1)) if m else None
    if body is None:
        return _op_traffic(op, c, None)
    total = 0.0
    for i, opnd in enumerate(op.operands):
        full = _shape_bytes(c.types.get(opnd, ""))
        if i < len(body.params):
            pname = body.params[i]
            consumers = [b for b in body.ops if pname in b.operands]
            if consumers and all(b.opcode == "dynamic-slice"
                                 for b in consumers):
                full = sum(_shape_bytes(b.result_type) for b in consumers)
            elif consumers and all(
                    b.opcode == "dynamic-update-slice"
                    and b.operands and b.operands[0] == pname
                    for b in consumers):
                # In-place scan-stack write: the root accounting charges the
                # read-modify-write of the update region; the aliased full
                # buffer is not streamed.
                full = 0.0
        total += full
    res = _shape_bytes(op.result_type)
    root = next((b for b in body.ops if b.is_root), None)
    if root is None:
        root = next((b for b in reversed(body.ops)), None)
    # Peel passthrough wrappers (copy/bitcast of the in-place update).
    by_name = {b.name: b for b in body.ops}
    seen_peel = 0
    while root is not None and root.opcode in ("copy", "bitcast") \
            and root.operands and seen_peel < 4:
        nxt = by_name.get(root.operands[0])
        if nxt is None:
            break
        root = nxt
        seen_peel += 1
    if root is not None and root.opcode == "dynamic-update-slice" \
            and len(root.operands) > 1:
        upd = _shape_bytes(body.types.get(root.operands[1], ""))
        res = 2.0 * upd
    elif root is not None and root.opcode == "tuple":
        elems = [body.ops[j] for j in range(len(body.ops))
                 if body.ops[j].name in root.operands]
        if elems and all(e.opcode == "dynamic-update-slice" for e in elems):
            res = sum(2.0 * _shape_bytes(body.types.get(e.operands[1], ""))
                      for e in elems if len(e.operands) > 1)
    return total + res


def _op_traffic(op: Op, c: Computation,
                comps: dict[str, Computation] | None = None) -> float:
    """HBM bytes touched by one op, matching HloCostAnalysis conventions:
    slicing ops touch the slice, not the sliced buffer; updates are
    in-place writes of the update region."""
    if op.opcode == "fusion" and comps is not None:
        return _fusion_traffic(op, c, comps)
    res = _shape_bytes(op.result_type)
    if op.opcode in ("dynamic-slice", "slice"):
        return 2.0 * res                      # read slice + write result
    if op.opcode == "dynamic-update-slice":
        upd = (_shape_bytes(c.types.get(op.operands[1], ""))
               if len(op.operands) > 1 else res)
        return 2.0 * upd                      # read update + write region
    if op.opcode == "gather":
        idx = (_shape_bytes(c.types.get(op.operands[1], ""))
               if len(op.operands) > 1 else 0)
        return 2.0 * res + idx                # read rows + indices, write out
    if op.opcode == "scatter":
        upd = (_shape_bytes(c.types.get(op.operands[2], ""))
               if len(op.operands) > 2 else res)
        idx = (_shape_bytes(c.types.get(op.operands[1], ""))
               if len(op.operands) > 1 else 0)
        return 3.0 * upd + idx                # read+write region + updates
    if op.opcode == "while":
        return 0.0                            # body/cond ops carry the cost
    nbytes = res
    nbytes += sum(_shape_bytes(c.types.get(o, "")) for o in op.operands)
    return float(nbytes)


@dataclasses.dataclass
class HLOSummary:
    dot_flops: float
    traffic_bytes: float
    collective_bytes: dict[str, float]   # per collective type (raw operand/result-max bytes)
    collective_counts: dict[str, int]
    dynamic_loops: int
    static_loops: int
    n_dots: int

    def total_collective_bytes(self) -> float:
        """Per-device wire-byte model: ring algorithms.

        all-gather: result bytes; reduce-scatter: operand bytes;
        all-reduce: 2x (reduce-scatter + all-gather); all-to-all &
        collective-permute: operand bytes.
        """
        f = {"all-gather": 1.0, "reduce-scatter": 1.0, "all-reduce": 2.0,
             "all-to-all": 1.0, "collective-permute": 1.0}
        return sum(f[k] * v for k, v in self.collective_bytes.items())


def analyze_hlo(text: str) -> HLOSummary:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # Which computations are inlined (fusion bodies, to_apply reducers)?
    inlined: set[str] = set()
    for c in comps.values():
        for op in c.ops:
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", op.attrs):
                inlined.add(m.group(1))

    # Propagate multipliers from entry.
    mult: dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    dynamic_loops = 0
    static_loops = 0
    stack = [entry.name]
    visited_edges = set()
    while stack:
        cn = stack.pop()
        c = comps.get(cn)
        if c is None:
            continue
        m_here = mult[cn]
        for op in c.ops:
            if op.opcode == "while":
                mc = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
                mb = re.search(r"body=%?([\w\.\-]+)", op.attrs)
                if not (mc and mb):
                    continue
                tc = _trip_count(comps, mc.group(1))
                if tc is None:
                    dynamic_loops += 1
                    tc = 1
                else:
                    static_loops += 1
                for child in (mb.group(1), mc.group(1)):
                    edge = (cn, child, op.name)
                    if edge in visited_edges:
                        continue
                    visited_edges.add(edge)
                    mult[child] += m_here * tc
                    stack.append(child)
            else:
                for m in re.finditer(
                        r"(?:calls|to_apply|true_computation|false_computation"
                        r")=%?([\w\.\-]+)", op.attrs):
                    child = m.group(1)
                    edge = (cn, child, op.name)
                    if edge in visited_edges:
                        continue
                    visited_edges.add(edge)
                    mult[child] += m_here
                    stack.append(child)
                bm = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
                if bm:
                    for child in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                        edge = (cn, child, op.name)
                        if edge not in visited_edges:
                            visited_edges.add(edge)
                            mult[child] += m_here
                            stack.append(child)

    dot_flops = 0.0
    n_dots = 0
    traffic = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, int] = defaultdict(int)

    for c in comps.values():
        m_here = mult.get(c.name, 0.0)
        if m_here == 0.0:
            continue
        for op in c.ops:
            # --- flops (dots everywhere, incl. fusion bodies) ---
            if op.opcode == "dot":
                res_elems = 1
                for d in _shape_dims(op.result_type):
                    res_elems *= d
                contract = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
                if cm and op.operands:
                    lhs_type = c.types.get(op.operands[0], "")
                    dims = _shape_dims(lhs_type)
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(dims):
                            contract *= dims[int(idx)]
                dot_flops += m_here * 2.0 * res_elems * contract
                n_dots += 1
            # --- collectives ---
            base = op.opcode.replace("-start", "")
            if base in COLLECTIVES:
                if base == "all-gather":
                    nbytes = _shape_bytes(op.result_type)
                else:
                    nbytes = sum(_shape_bytes(c.types.get(o, ""))
                                 for o in op.operands)
                coll_bytes[base] += m_here * nbytes
                coll_counts[base] += int(m_here)
            # --- HBM traffic (non-inlined computations only) ---
            if c.name not in inlined and op.opcode not in _SKIP_TRAFFIC \
                    and not op.opcode.endswith("-done"):
                traffic += m_here * _op_traffic(op, c, comps)

    return HLOSummary(
        dot_flops=dot_flops, traffic_bytes=traffic,
        collective_bytes=dict(coll_bytes), collective_counts=dict(coll_counts),
        dynamic_loops=dynamic_loops, static_loops=static_loops, n_dots=n_dots,
    )
