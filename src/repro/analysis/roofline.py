"""Roofline terms for TPU v5e (target hardware; container is CPU-only).

  t_compute    = FLOPs / (chips * 197 TFLOP/s bf16)
  t_memory     = HBM bytes / (chips * 819 GB/s)
  t_collective = wire bytes / (chips * links * 50 GB/s)

FLOPs / bytes / collective bytes come from the trip-count-corrected HLO
analysis (hlo.py) of the compiled dry-run; MODEL_FLOPS is the analytic
useful-work count (6·N·D dense, 6·N_active·D MoE, closed forms for
GNN/recsys), so MODEL_FLOPS / HLO_FLOPs exposes padding/remat waste.
"""

from __future__ import annotations

import dataclasses

from repro.analysis.hlo import HLOSummary

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
# v5e 16x16 pod: 2D torus, 4 links per chip; pod axis uses DCI but we apply
# the ICI number as the conservative bound.
LINKS_PER_CHIP = 4


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    useful_flops_frac: float
    dynamic_loops: int

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


def roofline(
    arch: str, shape: str, mesh_name: str, chips: int,
    summary: HLOSummary, model_flops: float,
    per_device: bool = True,
) -> RooflineTerms:
    """Build the three-term roofline.

    ``summary`` is per-device (post-SPMD HLO is the per-device program), so
    flops/bytes are divided by nothing further; model_flops is global and is
    divided by chips.
    """
    flops = summary.dot_flops
    # Dot-free programs (DKS min-plus, segment-op GNN aggregation) do their
    # compute on the VPU where it is invisible to dot counting: fall back to
    # the analytic model flops for the compute term.
    if flops < 0.01 * model_flops / chips:
        flops = model_flops / chips
    nbytes = summary.traffic_bytes
    coll = summary.total_collective_bytes()
    t_c = flops / PEAK_FLOPS
    t_m = nbytes / HBM_BW
    t_x = coll / (LINKS_PER_CHIP * ICI_BW)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    mf_per_chip = model_flops / chips
    frac = mf_per_chip / flops if flops > 0 else 0.0
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes, collective_bytes=coll,
        model_flops=model_flops,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck, useful_flops_frac=frac,
        dynamic_loops=summary.dynamic_loops,
    )


def model_flops_lm(cfg, shape, built=None) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) + attention flops.

    For decode shapes D = new tokens (=batch) but attention still reads the
    whole KV cache; we count matmul work: 6·N_active·B + attn 2·2·B·S·H·dh.
    """
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        attn = (12 * cfg.n_layers * cfg.n_heads * cfg.head_dim
                * shape.seq_len * shape.seq_len * shape.global_batch) // 2
        return 6.0 * n_act * tokens + attn
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        attn = (4 * cfg.n_layers * cfg.n_heads * cfg.head_dim
                * shape.seq_len * shape.seq_len * shape.global_batch) // 2
        return 2.0 * n_act * tokens + attn
    # decode: one token per sequence
    tokens = shape.global_batch
    attn = (4 * cfg.n_layers * cfg.n_heads * cfg.head_dim
            * shape.seq_len * shape.global_batch)
    return 2.0 * n_act * tokens + attn


def model_flops_gnn(cfg, shape, n_nodes: int, n_edges: int) -> float:
    """Closed-form useful flops per family (fwd+bwd = 3x fwd for training)."""
    d = cfg.d_hidden
    d_in = max(shape.d_feat, 1)
    if cfg.family == "gat":
        per_layer = (2 * n_nodes * d_in * d * cfg.n_heads
                     + 6 * n_edges * d * cfg.n_heads)
        fwd = cfg.n_layers * per_layer
    elif cfg.family == "gin":
        fwd = cfg.n_layers * (2 * n_edges * d + 4 * n_nodes * d * d)
    elif cfg.family == "pna":
        n_agg = len(cfg.aggregators) * len(cfg.scalers)
        fwd = cfg.n_layers * (2 * n_nodes * d * d
                              + 4 * n_edges * d
                              + 2 * n_nodes * (n_agg + 1) * d * d)
    else:  # schnet
        fwd = cfg.n_layers * (2 * n_edges * (cfg.rbf * d + d * d + d)
                              + 6 * n_nodes * d * d)
    return 3.0 * fwd


def model_flops_recsys(cfg, shape) -> float:
    d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    cross = cfg.n_cross_layers * 2 * d0 * d0
    dims = (d0,) + cfg.mlp_dims
    deep = sum(2 * dims[i] * dims[i + 1] for i in range(len(cfg.mlp_dims)))
    per_ex = cross + deep + 2 * (d0 + cfg.mlp_dims[-1])
    if shape.kind == "train":
        return 3.0 * shape.batch * per_ex
    if shape.kind == "retrieval":
        cand = shape.n_candidates
        return (shape.batch * (deep)
                + 2.0 * cand * cfg.embed_dim * cfg.mlp_dims[-1]
                + 2.0 * shape.batch * cand * cfg.mlp_dims[-1])
    return 1.0 * shape.batch * per_ex


def model_flops_dks(v: int, e: int, m: int, k: int) -> float:
    """Per-superstep useful work: relax (E·2^m·K adds + segment mins) +
    combine (V · pairs · K² min-plus)."""
    n_sets = 1 << m
    pairs = (3 ** m + 1) // 2 - 2 ** m
    relax = 2.0 * e * n_sets * k
    combine = 2.0 * v * pairs * k * k
    return relax + combine
