"""One front door for DKS relationship queries: plan -> execute -> ranked
answers with approximation bounds.

    from repro.engine import QueryEngine

    engine = QueryEngine.build(graph, tokens=tokens)
    result = engine.query(["paris", "piano"], k=3)
    for tree in result.answers:
        print(tree.weight, tree.root, tree.edges)

    # or from a persisted repro.store artifact (mmap, no re-tokenizing;
    # the artifact content hash keys version/cache_token):
    engine = QueryEngine.build(artifact="artifacts/sec-rdfabout")

Public API:
  QueryEngine      — owns graph device residency, the inverted index, and
                     the compiled-executable cache; query / query_batch /
                     query_stream / query_instrumented.
  ExecutionPolicy  — backend (jnp | pallas), partitioning (single |
                     sharded mesh), and WeightPolicy (how the typed edge
                     channel becomes effective weights) selection, made
                     once at build time.
  WeightPolicy     — degree | confidence-blended | predicate-filtered
                     ranking semantics (re-exported from repro.graph).
  QueryResult      — ranked AnswerTrees + superstep/message stats + SPA
                     approximation bounds (paper Sec. 5.4 / Fig. 12).
  StreamUpdate     — per-superstep approximate answers with monotonically
                     tightening bounds: the paper's reported SPA ratio plus
                     a provably sound lower bound (``proven_optimal``).
"""

from repro.engine.engine import QueryEngine  # noqa: F401
from repro.engine.policy import (  # noqa: F401
    AdaptiveLanePolicy,
    ExecutionPolicy,
    LaneDecision,
)
from repro.engine.result import QueryResult, StreamUpdate  # noqa: F401
from repro.graph.weights import WeightPolicy  # noqa: F401
