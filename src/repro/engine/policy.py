"""Execution policy: every backend/partitioning knob of a DKS run in one
place.

Before the engine existed, callers picked among ``run_dks`` /
``run_dks_batched`` / ``run_dks_instrumented`` / ``dks_sharded`` by hand and
threaded ``combine_impl`` / ``relax_impl`` / ``frontier_frac`` flags through
``DKSConfig`` at every call site.  :class:`ExecutionPolicy` is that choice
made once, at engine build time; per-query shape parameters (``m``, ``k``)
stay out of it so one policy serves every query.
"""

from __future__ import annotations

import dataclasses

from repro.core.dks import DKSConfig
from repro.graph.weights import WeightPolicy


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How a :class:`~repro.engine.QueryEngine` executes queries.

    Attributes:
      backend:    "jnp" (pure XLA ops) or "pallas" (hand-written TPU kernels
                  for the relax and combine phases).
      partition:  "single" — dense single-program graph residency (also the
                  right choice under pjit auto-sharding), or "sharded" —
                  frontier-compressed ``shard_map`` residency
                  (:mod:`repro.core.dks_sharded`) for multi-device meshes.
      n_shards:   shard count for ``partition="sharded"``; default = number
                  of local devices.
      exit_mode:  "sound" (stop once no better answer can appear, Sec. 6) or
                  "none" (run to frontier exhaustion).
      weights:    :class:`~repro.graph.weights.WeightPolicy` — how the typed
                  edge channel becomes the effective weight vector.  Applied
                  ONCE at engine build (the device graph is packed with the
                  effective weights), so it cannot be overridden per query;
                  it rides inside ``cache_token`` so caches never cross
                  ranking semantics.
      telemetry:  collect per-superstep counters (frontier size, message
                  totals, frozen-lane count) inside the *fused* driver's
                  while-loop, surfaced as ``QueryResult.telemetry``
                  (:class:`repro.obs.SuperstepTelemetry`).  The carry is a
                  bounded ``[T, 4]`` f32 device buffer written once per
                  superstep — answers are bit-identical with it on or off
                  (the buffer only reads the state), and the per-superstep
                  cost is noise next to the relax phase (asserted by
                  ``fig_telemetry``).  Excluded from ``cache_token``: a
                  cached answer is valid regardless of who watched it run.
      max_supersteps / message_budget / frontier_frac / combine_passes:
                  forwarded to :class:`DKSConfig` (paper Sec. 5.4 budget and
                  forced-stop semantics).
    """

    backend: str = "jnp"            # "jnp" | "pallas"
    partition: str = "single"       # "single" | "sharded"
    n_shards: int | None = None
    exit_mode: str = "sound"        # "sound" | "none"
    max_supersteps: int = 64
    message_budget: float = float("inf")
    frontier_frac: float = 0.25
    combine_passes: int | None = None
    weights: WeightPolicy = WeightPolicy()
    telemetry: bool = False

    def __post_init__(self) -> None:
        if self.backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.partition not in ("single", "sharded"):
            raise ValueError(f"unknown partition {self.partition!r}")
        if self.backend == "pallas" and self.partition == "sharded":
            # Refuse up front rather than silently running jnp: the fused
            # lane-superstep kernel is dense-only — the sharded path keeps
            # jnp inside its shard_map body (fusing it is the remaining
            # ROADMAP item).
            raise NotImplementedError(
                'backend="pallas" with partition="sharded" is not '
                "implemented: the frontier-compressed shard_map body "
                "still runs the jnp relax/combine ops.  Use "
                'backend="jnp" for sharded engines, or '
                'partition="single" for the fused pallas kernel.')
        if self.exit_mode not in ("sound", "none"):
            raise ValueError(f"unknown exit_mode {self.exit_mode!r}")
        if not isinstance(self.weights, WeightPolicy):
            raise ValueError(
                f"weights must be a WeightPolicy, got {self.weights!r}")

    def dks_config(self, m: int, k: int) -> DKSConfig:
        """Materialize the per-query static config for an (m, k) shape."""
        return DKSConfig(
            m=m,
            k=k,
            max_supersteps=self.max_supersteps,
            message_budget=self.message_budget,
            exit_mode=self.exit_mode,
            combine_impl=self.backend,
            relax_impl=self.backend,
            combine_passes=self.combine_passes,
            frontier_frac=self.frontier_frac,
        )


# --------------------------------------------------------------------------
# Adaptive lane occupancy
# --------------------------------------------------------------------------


def _pow2_ceil(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@dataclasses.dataclass(frozen=True)
class LaneDecision:
    """One padding decision: the lane count a bucket dispatches at, why,
    and (when measurements exist) the estimated device cost."""

    lanes: int
    reason: str                  # "exact" | "warm" | "pow2" | "cap"
    est_ms: float | None = None


class AdaptiveLanePolicy:
    """Pick bucket lane counts from MEASURED per-lane superstep cost and
    the serve layer's observed shape histogram, instead of blind pow2/max
    padding.

    The tradeoff it arbitrates: padding a bucket of ``n`` real requests
    up to a lane count ``c > n`` wastes ``(c - n)`` lanes of device time
    every dispatch, but dispatching at a *new* lane count pays a jit
    retrace + compile (the engine caches executables per lane count).
    Blind pow2 padding optimizes only the second term; with measurements
    this policy scores both::

        score(c) = measured_ms(c)            if c was dispatched before
                   per_lane_ms * c + retrace if c is cold

    and picks the cheapest count >= n (capped at ``max_lanes``).  Until
    the first measurement arrives it degrades to exactly the old pow2
    behavior, so an idle service is indistinguishable from the blind
    padder.  ``ServeStats.hot_shapes`` lane counts join the candidate
    set so a swapped-in engine (whose executable cache is cold but whose
    traffic histogram survives) keeps choosing the counts the workload
    actually uses.

    Thread-safe; the serve layer exports :meth:`snapshot` through the
    metrics registry (``dks_lane_policy_*``).
    """

    def __init__(self, max_lanes: int, retrace_cost_ms: float = 200.0,
                 ema: float = 0.3) -> None:
        import threading

        if max_lanes < 1:
            raise ValueError("max_lanes must be >= 1")
        self.max_lanes = int(max_lanes)
        self.retrace_cost_ms = float(retrace_cost_ms)
        self._ema = float(ema)
        self._lock = threading.Lock()
        self._cost_ms: dict[int, float] = {}     # lanes -> EMA device ms
        self._uses: dict[int, int] = {}          # lanes -> dispatch count
        self._decisions: dict[str, int] = {}     # reason -> count
        self._last: LaneDecision | None = None

    # -- measurement ---------------------------------------------------

    def observe(self, lanes: int, device_ms: float) -> None:
        """Record one dispatch's device time at a lane count."""
        if lanes < 1 or device_ms < 0:
            return
        with self._lock:
            prev = self._cost_ms.get(lanes)
            self._cost_ms[lanes] = (
                device_ms if prev is None
                else (1 - self._ema) * prev + self._ema * device_ms)
            self._uses[lanes] = self._uses.get(lanes, 0) + 1

    def per_lane_ms(self) -> float | None:
        """Use-weighted mean device cost per lane (None until measured)."""
        with self._lock:
            tot_ms = sum(self._cost_ms[c] / c * self._uses[c]
                         for c in self._cost_ms)
            tot_uses = sum(self._uses.values())
        return tot_ms / tot_uses if tot_uses else None

    # -- decisions -----------------------------------------------------

    def lanes_for(self, n_real: int, hot_shapes: tuple = ()) -> LaneDecision:
        """The lane count a bucket of ``n_real`` requests should dispatch
        at.  ``hot_shapes``: ``ServeStats.hot_shapes`` (``(((m, k,
        lanes), count), ...)``) — its lane counts are candidate targets
        even when this policy instance has no measurement for them yet."""
        n = max(1, min(int(n_real), self.max_lanes))
        pow2 = min(_pow2_ceil(n), self.max_lanes)
        with self._lock:
            warm = dict(self._cost_ms)
        per_lane = self.per_lane_ms()

        if per_lane is None:
            decision = LaneDecision(lanes=pow2, reason="pow2")
        else:
            hot = {lanes for (_m, _k, lanes), _cnt in hot_shapes
                   if isinstance(lanes, int)}
            cands = {n, pow2, self.max_lanes}
            cands |= {c for c in warm if c >= n}
            cands |= {c for c in hot if n <= c <= self.max_lanes}
            best, best_score = None, None
            for c in sorted(c for c in cands if n <= c <= self.max_lanes):
                if c in warm:
                    score = warm[c]
                else:
                    score = per_lane * c + self.retrace_cost_ms
                if best_score is None or score < best_score:
                    best, best_score = c, score
            reason = ("exact" if best == n
                      else "warm" if best in warm
                      else "pow2" if best == pow2
                      else "cap")
            decision = LaneDecision(lanes=best, reason=reason,
                                    est_ms=round(best_score, 3))
        with self._lock:
            self._decisions[decision.reason] = (
                self._decisions.get(decision.reason, 0) + 1)
            self._last = decision
        return decision

    def target_fill(self) -> int:
        """The bucket size worth waiting for: the most-dispatched warm
        lane count (a bucket that reaches it pads zero lanes and hits a
        compiled executable), or ``max_lanes`` before any traffic."""
        with self._lock:
            if not self._uses:
                return self.max_lanes
            return max(self._uses, key=lambda c: (self._uses[c], c))

    # -- export --------------------------------------------------------

    def snapshot(self) -> dict:
        """Point-in-time view for metrics/debugging."""
        with self._lock:
            return {
                "decisions": dict(self._decisions),
                "last_lanes": self._last.lanes if self._last else 0,
                "last_reason": self._last.reason if self._last else "",
                "observed_counts": dict(self._uses),
                "cost_ms": {c: round(v, 3)
                            for c, v in self._cost_ms.items()},
            }
