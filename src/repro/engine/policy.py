"""Execution policy: every backend/partitioning knob of a DKS run in one
place.

Before the engine existed, callers picked among ``run_dks`` /
``run_dks_batched`` / ``run_dks_instrumented`` / ``dks_sharded`` by hand and
threaded ``combine_impl`` / ``relax_impl`` / ``frontier_frac`` flags through
``DKSConfig`` at every call site.  :class:`ExecutionPolicy` is that choice
made once, at engine build time; per-query shape parameters (``m``, ``k``)
stay out of it so one policy serves every query.
"""

from __future__ import annotations

import dataclasses

from repro.core.dks import DKSConfig
from repro.graph.weights import WeightPolicy


@dataclasses.dataclass(frozen=True)
class ExecutionPolicy:
    """How a :class:`~repro.engine.QueryEngine` executes queries.

    Attributes:
      backend:    "jnp" (pure XLA ops) or "pallas" (hand-written TPU kernels
                  for the relax and combine phases).
      partition:  "single" — dense single-program graph residency (also the
                  right choice under pjit auto-sharding), or "sharded" —
                  frontier-compressed ``shard_map`` residency
                  (:mod:`repro.core.dks_sharded`) for multi-device meshes.
      n_shards:   shard count for ``partition="sharded"``; default = number
                  of local devices.
      exit_mode:  "sound" (stop once no better answer can appear, Sec. 6) or
                  "none" (run to frontier exhaustion).
      weights:    :class:`~repro.graph.weights.WeightPolicy` — how the typed
                  edge channel becomes the effective weight vector.  Applied
                  ONCE at engine build (the device graph is packed with the
                  effective weights), so it cannot be overridden per query;
                  it rides inside ``cache_token`` so caches never cross
                  ranking semantics.
      telemetry:  collect per-superstep counters (frontier size, message
                  totals, frozen-lane count) inside the *fused* driver's
                  while-loop, surfaced as ``QueryResult.telemetry``
                  (:class:`repro.obs.SuperstepTelemetry`).  The carry is a
                  bounded ``[T, 4]`` f32 device buffer written once per
                  superstep — answers are bit-identical with it on or off
                  (the buffer only reads the state), and the per-superstep
                  cost is noise next to the relax phase (asserted by
                  ``fig_telemetry``).  Excluded from ``cache_token``: a
                  cached answer is valid regardless of who watched it run.
      max_supersteps / message_budget / frontier_frac / combine_passes:
                  forwarded to :class:`DKSConfig` (paper Sec. 5.4 budget and
                  forced-stop semantics).
    """

    backend: str = "jnp"            # "jnp" | "pallas"
    partition: str = "single"       # "single" | "sharded"
    n_shards: int | None = None
    exit_mode: str = "sound"        # "sound" | "none"
    max_supersteps: int = 64
    message_budget: float = float("inf")
    frontier_frac: float = 0.25
    combine_passes: int | None = None
    weights: WeightPolicy = WeightPolicy()
    telemetry: bool = False

    def __post_init__(self) -> None:
        if self.backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.partition not in ("single", "sharded"):
            raise ValueError(f"unknown partition {self.partition!r}")
        if self.exit_mode not in ("sound", "none"):
            raise ValueError(f"unknown exit_mode {self.exit_mode!r}")
        if not isinstance(self.weights, WeightPolicy):
            raise ValueError(
                f"weights must be a WeightPolicy, got {self.weights!r}")

    def dks_config(self, m: int, k: int) -> DKSConfig:
        """Materialize the per-query static config for an (m, k) shape."""
        return DKSConfig(
            m=m,
            k=k,
            max_supersteps=self.max_supersteps,
            message_budget=self.message_budget,
            exit_mode=self.exit_mode,
            combine_impl=self.backend,
            relax_impl=self.backend,
            combine_passes=self.combine_passes,
            frontier_frac=self.frontier_frac,
        )
