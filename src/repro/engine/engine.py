"""QueryEngine — the one front door for DKS relationship queries.

The paper's end-to-end flow (Fig. 2c) is: inverted-index lookup ->
keyword-node masks -> DKS supersteps -> aggregator-side answer trees.
Before this module, every driver re-stitched that flow by hand and chose
among four overlapping entry points (``run_dks``, ``run_dks_batched``,
``run_dks_instrumented``, ``dks_sharded``).  The engine owns:

- **graph device residency** — dense :class:`DeviceGraph` for the single-
  program path, frontier-partitioned :class:`FrontierGraph` for the
  ``shard_map`` mesh path, built once and reused by every query;
- **the inverted index** — token -> keyword-node masks, padded to the
  device layout (no ``np.pad`` dance at call sites);
- **the lane-batched driver** — every surface is a thin loop over ONE
  step kernel (:mod:`repro.core.driver`): a :class:`DKSState` with a
  leading lane axis, advanced by ``lane_superstep`` on either
  partitioning (for "sharded" the lane axis lives *inside* the
  ``shard_map`` body, so a batch of queries costs one device program and
  one collective per superstep — no vmap-over-shard_map needed);
- **a compiled-executable cache** — per ``(DKSConfig, partition)`` there
  are exactly two compiled things: the **fused** driver (the whole
  while-loop as one device program, used by ``query`` — the degenerate
  1-lane case — and ``query_batch``) and the **stepwise** driver (an
  ``(init, superstep)`` pair the host loops over, used by the streaming,
  deadline, and instrumented surfaces).  Repeated queries with the same
  ``(m, k)`` shape reuse the compiled program with zero re-tracing
  (asserted by tests via :meth:`QueryEngine.trace_count`).

Query surfaces::

    engine = QueryEngine.build(graph, tokens=tokens)
    result = engine.query(["paris", "piano"], k=3)     # ranked AnswerTrees
    results = engine.query_batch(queries, k=1)          # m-bucketed lanes
    for upd in engine.query_stream(query, k=1):         # per-superstep
        ...  # upd.weights + upd.spa_ratio: answers with a sound bound
    engine.query_deadline_batch(queries, deadline_s=.05)  # shared driver

``query_stream`` makes the paper's early-termination guarantee (Sec. 5.4 /
Fig. 12) a first-class API: after every superstep the caller sees the
current best answers together with a monotonically tightening lower bound
on the optimum, so it can stop as soon as the approximation suffices.
``query_deadline_batch`` extends that to a *bucket* of same-shape queries
riding one driver: lanes freeze individually as they prove exits, and on
expiry every lane gets its own best-so-far answer with per-lane bounds.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from pathlib import Path
from typing import Any, Callable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import INF, shardmap
from repro.core.dks import DKSConfig, DKSState, run_dks_instrumented
from repro.core.driver import (lane_init, lane_superstep, lane_view,
                               run_lanes_telemetry)
from repro.obs.telemetry import SuperstepTelemetry
from repro.core.reconstruct import collect_answers
from repro.core.spa import nu_lower_bound, spa_cover_dp, spa_ratio
from repro.engine.policy import ExecutionPolicy
from repro.engine.result import QueryResult, StreamUpdate
from repro.graph.index import InvertedIndex
from repro.graph.structure import Graph


@dataclasses.dataclass(frozen=True)
class _StateBounds:
    """One DKS state's bound facts (see QueryEngine._state_bounds)."""

    best: float
    nu_full: float
    spa: float
    frontier: int
    opt_lb: float
    sound_lb: float


class QueryEngine:
    """Facade over index lookup, device residency, and the DKS executors.

    Build one per (graph, policy); serve many queries.  Thread-compatible
    for reads after build (the caches only grow).
    """

    # Monotone build ids: every built engine gets a fresh ``version``, so
    # result caches keyed on cache_token() can never serve answers computed
    # against a previous graph build.  Engines built from a persisted
    # artifact use the artifact's content hash instead — stable across
    # rebuilds of the SAME artifact (a serve restart keeps its cache
    # keys), necessarily different for any other graph content.
    _build_counter = itertools.count(1)

    def __init__(
        self,
        graph: Graph,
        index: InvertedIndex,
        policy: ExecutionPolicy,
        device_graph: Any,
        mesh: Any = None,
        graph_hash: str | None = None,
    ) -> None:
        self.graph = graph
        self.index = index
        self.policy = policy
        self.device_graph = device_graph
        self.mesh = mesh  # set for partition="sharded"; None otherwise
        self.graph_hash = graph_hash
        self.version: int | str = (
            f"artifact:{graph_hash}" if graph_hash is not None
            else next(QueryEngine._build_counter))
        self._e_min = float(device_graph.e_min())
        # Compiled-executable cache: (DKSConfig, partition, kind) -> callable.
        self._executables: dict[tuple, Any] = {}
        self._trace_counts: dict[tuple, int] = {}
        self._execute_count = 0
        # Answer subsystem hooks: the device-batched backtracer (lazy; its
        # kernels cache per bucket shape) and the artifact the engine was
        # built from (labels for answer rendering).  ``batched_extraction``
        # turns the device backtrace path of query_batch off (host-only
        # extraction) — a debugging escape hatch, not a serving knob.
        self._answer_backtracer: Any = None
        self.artifact: Any = None
        self.batched_extraction = True
        # backend="pallas": the fused lane-superstep kernel's padded-CSR
        # layout, built once per graph by ``build`` (None on jnp/sharded
        # engines).  Executables close over it and thread it into
        # ``lane_superstep`` — layout cost is paid at build, not per query.
        self.lane_csr: Any = None

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------

    @classmethod
    def build(
        cls,
        graph: Graph | None = None,
        tokens: np.ndarray | None = None,
        index: InvertedIndex | None = None,
        policy: ExecutionPolicy | None = None,
        artifact: Any = None,
    ) -> "QueryEngine":
        """Build an engine: inverted index + device-resident graph.

        Two entry modes:

        - ``graph=`` plus exactly one of ``tokens`` (int[V, L] token
          matrix) or ``index`` — or neither, when ``graph.labels`` is set
          (then the index is built from the labels);
        - ``artifact=`` — a :class:`repro.store.GraphArtifact` (or a path
          to one), or a :class:`repro.store.GraphChain` (a base plus
          stacked delta artifacts — the live-graph path): graph, device
          layout, and the persisted inverted index all come straight off
          the mmapped buffers — no re-tokenizing, no edge re-sort — and
          the artifact's ``content_hash`` (for a chain, the *chained*
          hash) becomes the engine ``version`` (so ``cache_token`` keys
          are stable across rebuilds of the same artifact, and distinct
          for any other graph or chain depth — a cache can never serve a
          stale build).
        """
        policy = policy or ExecutionPolicy()
        graph_hash = None
        if artifact is not None:
            if graph is not None or tokens is not None or index is not None:
                raise ValueError(
                    "pass artifact= alone — it already carries the graph "
                    "and the persisted index")
            if isinstance(artifact, (str, Path)):
                from repro.store import open_artifact
                artifact = open_artifact(artifact)
            graph = artifact.graph()
            index = artifact.index()
            graph_hash = artifact.content_hash
        if graph is None:
            raise ValueError("QueryEngine.build needs graph= or artifact=")
        if index is not None and tokens is not None:
            raise ValueError(
                "pass either tokens= or index=, not both (the tokens would "
                "be ignored in favor of the prebuilt index)")
        if index is None:
            if tokens is not None:
                index = InvertedIndex.from_token_matrix(np.asarray(tokens))
            elif graph.labels is not None:
                index = InvertedIndex.from_labels(graph.labels)
            else:
                raise ValueError(
                    "QueryEngine.build needs tokens=, index=, or graph.labels")
        # Fold the weight policy into the weight vectors ONCE, before any
        # device packing: the dense DeviceGraph, the sharded FrontierGraph,
        # host answer backtrace, and rendering all read the same effective
        # weights — the relaxation kernels never know a policy existed.
        # The default policy is the identity (same Graph object), which is
        # what keeps pre-typed artifacts bit-identical.
        from repro.graph.weights import apply_weight_policy
        graph = apply_weight_policy(graph, policy.weights)
        mesh = None
        if policy.partition == "sharded":
            from repro.core.dks_sharded import pack_frontier_graph
            n_shards = policy.n_shards or len(jax.devices())
            mesh = shardmap.make_mesh((n_shards,), ("data",))
            device_graph = pack_frontier_graph(graph, n_shards, mesh=mesh)
        else:
            device_graph = graph.to_device()
        engine = cls(graph, index, policy, device_graph, mesh=mesh,
                     graph_hash=graph_hash)
        engine.artifact = artifact
        if policy.backend == "pallas":
            # Dense-only by construction (the policy rejects
            # sharded+pallas).  The layout reads the DeviceGraph's
            # *effective* weights, so any WeightPolicy above already
            # flowed into the kernel's weight table.
            from repro.kernels.lane_superstep import (
                lane_csr_from_device_graph)
            engine.lane_csr = lane_csr_from_device_graph(device_graph)
        return engine

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------

    @property
    def n_nodes(self) -> int:
        return self.graph.n_nodes

    @property
    def n_edges(self) -> int:
        """Symmetrized device edge count (the |E| of Fig. 14)."""
        return self.device_graph.n_edges

    @property
    def v_pad(self) -> int:
        return self.device_graph.v_pad

    # Executor kinds, collapsed by the lane driver: "fused" (the whole
    # while-loop as one device program; query and query_batch) and
    # "stepwise" ((init, superstep) pair the host loops over; streaming
    # and deadline surfaces).  Legacy kind names from the four-executor
    # era keep resolving for callers of trace_count.  An engine built
    # with ExecutionPolicy(telemetry=True) resolves "fused" to the
    # telemetry-carrying variant, so callers asserting warm-cache
    # behavior via trace_count need not know which one serves them.
    _KIND_ALIASES = {"single": "fused", "batch": "fused",
                     "stream": "stepwise", "driver": "stepwise"}

    def _resolve_kind(self, kind: str) -> str:
        kind = self._KIND_ALIASES.get(kind, kind)
        if kind == "fused" and self.policy.telemetry:
            return "fused-telemetry"
        return kind

    def trace_count(self, m: int, k: int, kind: str = "fused",
                    **overrides) -> int:
        """How many times the executable for this query shape was traced.
        1 after any number of same-shape *and same-lane-count* queries =
        the cache works (a new lane count is a new input shape, so it
        re-traces once, like any jit)."""
        kind = self._resolve_kind(kind)
        key = (self._config(m, k, **overrides), self.policy.partition, kind)
        return self._trace_counts.get(key, 0)

    @property
    def cache_stats(self) -> dict[str, int]:
        """{executables, traces}: cache size vs. total trace events."""
        return {
            "executables": len(self._executables),
            "traces": sum(self._trace_counts.values()),
        }

    @property
    def extraction_stats(self) -> dict[str, int]:
        """Device-batched backtracer counters — ``device_resolved`` lanes
        whose answer trees the batched device program reconstructed, vs
        ``host_fallbacks`` ragged stragglers that re-ran the host search.
        Zeros before the backtracer is first used (it builds lazily)."""
        bt = self._answer_backtracer
        if bt is None:
            return {"device_resolved": 0, "host_fallbacks": 0}
        return {"device_resolved": int(bt.device_resolved),
                "host_fallbacks": int(bt.host_fallbacks)}

    @property
    def execute_count(self) -> int:
        """Device dispatches made through the compiled-executable cache —
        the ``query`` / ``query_batch`` / ``query_stream(ed)`` surfaces
        (streaming queries count one per superstep).  A serving layer's
        result-cache hit must leave this untouched — that is what its
        tests assert.  ``query_instrumented`` runs its own host-driven
        per-phase jits and is not counted here."""
        return self._execute_count

    def cache_token(self, keywords: Sequence, k: int = 1,
                    **overrides) -> tuple:
        """Hashable result-cache key for a query against THIS engine build.

        Normalizes the keywords to a sorted multiset — DKS answers are
        keyword-order invariant (permuting keywords permutes subset-lattice
        bits; every reduction is a min/top-k over the same value sets) —
        and folds in everything else that determines the answer: ``k``, the
        effective :class:`ExecutionPolicy` including per-call overrides,
        and the engine build ``version`` (a rebuilt graph gets a fresh
        version, so stale cached results can never be served).  For an
        artifact-built engine the version IS the artifact's content hash:
        rebuilding from the same artifact keys the same (caches survive a
        restart), any other graph content keys differently.
        """
        norm = tuple(sorted((type(t).__name__, t) for t in keywords))
        policy = self.policy
        if overrides:
            self._check_overrides(overrides)
            policy = dataclasses.replace(policy, **overrides)
        # Telemetry observes the run without changing the answer, so it
        # must not fragment result caches: engines built from the same
        # artifact share cache keys whether or not one of them watches
        # its supersteps.
        if policy.telemetry:
            policy = dataclasses.replace(policy, telemetry=False)
        return (norm, int(k), policy, self.version)

    @staticmethod
    def _check_overrides(overrides: dict) -> None:
        """Per-call overrides must not change the weight policy (the
        device graph was packed with the build policy's effective weights,
        so a per-query ``weights=`` would silently rank on the wrong
        vector) nor toggle telemetry (the flag picks the compiled fused
        variant at build; flipping it per call would double every entry
        in the executable cache).  Build a second engine instead."""
        if "weights" in overrides:
            raise ValueError(
                "the weight policy is fixed at engine build (the device "
                "graph is packed with its effective weights) — build an "
                "engine with ExecutionPolicy(weights=...) instead of "
                "overriding per call")
        if "telemetry" in overrides:
            raise ValueError(
                "telemetry is fixed at engine build (it selects the "
                "compiled fused-driver variant) — build an engine with "
                "ExecutionPolicy(telemetry=True) instead of overriding "
                "per call")

    def node_label(self, v: int) -> str:
        """Entity string for a node: in-memory graph labels when present,
        else the artifact's label blob (decoded per node, off the mmap),
        else ``node:<id>`` — the label function answer rendering plugs in.
        """
        v = int(v)
        if self.graph.labels is not None:
            return str(self.graph.labels[v])
        if self.artifact is not None and self.artifact.has_labels:
            return self.artifact.label(v)
        return f"node:{v}"

    def edge_info(self, u: int, v: int) -> tuple[str | None, float] | None:
        """``(predicate_name, confidence)`` of the effective edge between
        ``u`` and ``v`` (the cheapest parallel entry — the one backtrace
        resolved), or None on untyped graphs.  Rendering uses this to
        label answer-tree edges with their provenance."""
        return self.graph.edge_channel(int(u), int(v))

    def _backtracer(self):
        """The lazily-built device-batched backtracer (repro.answers);
        shared across queries so its per-shape kernels compile once."""
        if self._answer_backtracer is None:
            from repro.answers import BatchedBacktracer
            self._answer_backtracer = BatchedBacktracer(self.graph)
        return self._answer_backtracer

    # ------------------------------------------------------------------
    # Queries
    # ------------------------------------------------------------------

    def query(
        self,
        keywords: Sequence,
        k: int = 1,
        *,
        extract: bool = True,
        extract_pool: int | None = None,
        keep_state: bool = False,
        strict: bool = True,
        **overrides,
    ) -> QueryResult:
        """Answer one relationship query.

        ``keywords``: tokens understood by the index (int ids or strings).
        ``extract``: reconstruct ranked :class:`AnswerTree`\\ s on the host
        (skip for stats-only runs — the weights are always populated).
        ``extract_pool``: reconstruct up to this many distinct trees (>=
        ``k``) onto ``QueryResult.answer_pool`` — the material diversified
        re-ranking / pagination works from; ``answers`` stays the top-k.
        ``keep_state``: retain the raw final :class:`DKSState` on the
        result (a dense ``[V, 2^m, K]`` table — off by default so served
        results don't pin device memory).
        ``strict``: raise :class:`KeyError` when a keyword matches no node
        in the index (the query could only return INF after burning the
        full superstep budget).  ``strict=False`` runs best-effort; the
        offending tokens are reported on ``QueryResult.unmatched``.
        ``overrides``: per-call policy overrides (``max_supersteps``,
        ``message_budget``, ``exit_mode``) — they key the executable cache,
        so a steady workload should keep them constant.
        """
        keywords = list(keywords)
        cfg = self._config(len(keywords), k, **overrides)
        masks, unmatched = self._masks(keywords, strict)
        t0 = time.perf_counter()
        # The degenerate 1-lane case of the lane driver.
        states, telemetry = self._run_fused(cfg, masks[None])
        dt = time.perf_counter() - t0
        return self._make_result(keywords, masks, lane_view(states, 0), cfg,
                                 dt, extract, keep_state,
                                 unmatched=unmatched, own_time_s=dt,
                                 extract_pool=extract_pool,
                                 telemetry=telemetry)

    def query_batch(
        self,
        queries: Sequence[Sequence],
        k: int = 1,
        *,
        extract: bool = True,
        extract_pool: int | None = None,
        keep_state: bool = False,
        strict: bool = True,
        n_real: int | None = None,
        **overrides,
    ) -> list[QueryResult | None]:
        """Answer a batch of queries, amortizing graph residency and kernel
        launches (the paper's 100-query workloads).

        Queries are bucketed by keyword count ``m`` (the table shape is
        ``[V, 2^m, K]``, so only same-``m`` queries share an executable);
        each bucket rides the fused lane driver as ONE device program —
        on both partitionings.  On partition="sharded" the lanes live
        inside the ``shard_map`` body, so the whole bucket shares a
        single frontier exchange per superstep instead of degrading to
        sequential single-query runs.  Results come back in input order;
        ``wall_time_s`` is the shared bucket device time, and
        ``own_time_s`` is None inside a bucket (lanes advance in
        lockstep — there is no honest per-query time to report).

        ``n_real``: serving hook — queries at index >= ``n_real`` are
        padding lanes (added by a serving layer to stabilize the lane
        count the driver compiles for).  They still ride in their
        bucket's device program, but skip host-side result construction
        (answer-tree extraction is O(V·2^m) per lane) and come back as
        None.

        Answer-tree extraction for the whole bucket runs through the
        device-batched backtracer (:mod:`repro.answers`): one device
        program resolves the top-candidate decompositions of every real
        lane at once, and only ragged stragglers re-run the host search —
        bit-identical results, batched cost.
        """
        n_real = len(queries) if n_real is None else n_real
        results: list[QueryResult | None] = [None] * len(queries)
        buckets: dict[int, list[int]] = {}
        for i, q in enumerate(queries):
            buckets.setdefault(len(q), []).append(i)
        for m, idxs in sorted(buckets.items()):
            cfg = self._config(m, k, **overrides)
            pairs = [self._masks(list(queries[i]), strict) for i in idxs]
            masks = np.stack([p[0] for p in pairs])
            t0 = time.perf_counter()
            states, telemetry = self._run_fused(cfg, masks)
            dt = time.perf_counter() - t0
            pre: dict[int, tuple] = {}
            if extract and self.batched_extraction:
                topk = np.asarray(states.topk_w)
                lanes = [bi for bi in range(len(idxs))
                         if idxs[bi] < n_real and topk[bi, 0] < INF]
                if lanes:
                    S_lanes = states.S
                    if self.mesh is not None:
                        # Sharded runs leave S device-distributed; the
                        # backtrace kernel is a plain single-device jit.
                        S_lanes = np.asarray(S_lanes)
                    pre = dict(zip(lanes, self._backtracer().extract_lanes(
                        S_lanes, masks, k=max(cfg.k, extract_pool or 0),
                        lanes=lanes, n_nodes=self.n_nodes)))
            for bi, i in enumerate(idxs):
                if i >= n_real:
                    continue
                results[i] = self._make_result(
                    list(queries[i]), masks[bi], lane_view(states, bi), cfg,
                    dt, extract, keep_state, unmatched=pairs[bi][1],
                    extract_pool=extract_pool, answers_pre=pre.get(bi),
                    telemetry=telemetry)
        return results  # type: ignore[return-value]

    def query_stream(
        self,
        keywords: Sequence,
        k: int = 1,
        *,
        strict: bool = True,
        **overrides,
    ) -> Iterator[StreamUpdate]:
        """Yield per-superstep approximate answers with sound bounds.

        Every update carries the current top-k weights plus
        ``opt_lower_bound`` — the running max over supersteps of
        ``min(best_t, spa_t)`` and ``min(best_t, nu_full_t)``.  Any answer
        either appears by superstep ``t`` (weight >= ``best_t``) or later
        (weight >= the ``spa``/``nu`` bound at ``t``), so the optimum is
        >= every per-step ``min`` and hence >= their running max (``nu`` is
        provably sound; ``spa`` is the paper's Sec. 5.4 estimator).  The
        reported ``spa_ratio`` therefore never worsens as supersteps
        progress, and reaches 0 once the best answer cannot be improved per
        the bound (paper Fig. 12 convention).
        """
        keywords = list(keywords)
        cfg = self._config(len(keywords), k, **overrides)
        # Validate eagerly (this function is not a generator): strict-mode
        # KeyError fires at the call site, not at first iteration.
        masks, unmatched = self._masks(keywords, strict)

        def updates() -> Iterator[StreamUpdate]:
            for _state, update in self._stream(cfg, masks,
                                               unmatched=unmatched):
                yield update

        return updates()

    def query_streamed(
        self,
        keywords: Sequence,
        k: int = 1,
        *,
        on_update: Callable[[StreamUpdate], None] | None = None,
        until: Callable[[StreamUpdate], bool] | None = None,
        extract: bool = True,
        keep_state: bool = False,
        strict: bool = True,
        **overrides,
    ) -> QueryResult:
        """Run a streaming query to completion and return its result.

        Like :meth:`query_stream` but consumes the stream internally
        (invoking ``on_update`` per superstep) and builds the final
        :class:`QueryResult` from the last state — the run is not repeated.

        ``until``: optional host-side stop predicate evaluated on every
        update (after ``on_update``).  When it fires before the run's own
        exit criterion, the stream stops and the result is built from the
        best-so-far state *as a forced stop*: ``done=False`` and the SPA
        bound / ratio are computed exactly as for ``budget_hit`` — the
        paper's early-termination guarantee (Sec. 5.4) as a serving
        primitive (deadline-bounded answers route through this).
        """
        keywords = list(keywords)
        cfg = self._config(len(keywords), k, **overrides)
        masks, unmatched = self._masks(keywords, strict)
        t0 = time.perf_counter()
        state = None
        interrupted = False
        for state, update in self._stream(cfg, masks, unmatched=unmatched):
            if on_update is not None:
                on_update(update)
            if until is not None and not update.done and until(update):
                interrupted = True
                break
        dt = time.perf_counter() - t0
        assert state is not None
        return self._make_result(keywords, masks, state, cfg, dt, extract,
                                 keep_state, unmatched=unmatched,
                                 own_time_s=dt, interrupted=interrupted)

    def query_deadline(
        self,
        keywords: Sequence,
        k: int = 1,
        *,
        deadline_s: float,
        extract: bool = True,
        extract_pool: int | None = None,
        keep_state: bool = False,
        strict: bool = True,
        **overrides,
    ) -> tuple[QueryResult, dict[str, Any]]:
        """Serving hook: run under a wall-clock budget, bounds computed
        once at the end.

        Steps the streaming executor with a wall-clock check between
        supersteps but WITHOUT the per-superstep SPA/nu computation of
        :meth:`query_stream` — the cover DP is a host-driven O(3^m) loop
        of tiny dispatches that can cost many times a superstep, so under
        a tight budget it would eat the very time it is meant to bound.
        The lower bounds are computed once, from the final state (each
        per-step bound is individually valid, so the final one is too —
        it just isn't the running max a full stream would report).

        Returns ``(result, info)`` with ``info`` carrying
        ``opt_lower_bound`` (paper Sec. 5.4 reporting convention: max of
        min(best, spa) and min(best, nu), folded with the sound facts so
        it is never below the sound bound), ``sound_opt_lower_bound``
        (the provably sound part), and ``interrupted`` (True when the
        deadline expired before the run's own exit criterion).  On a
        proven exit both bounds equal the certified best answer and the
        cover DP is skipped entirely.

        The 1-lane case of :meth:`query_deadline_batch`.
        """
        out = self.query_deadline_batch(
            [list(keywords)], k, deadline_s=deadline_s, extract=extract,
            extract_pool=extract_pool, keep_state=keep_state, strict=strict,
            **overrides)
        assert out[0] is not None
        return out[0]

    def query_deadline_batch(
        self,
        queries: Sequence[Sequence],
        k: int = 1,
        *,
        deadline_s: float,
        extract: bool = True,
        extract_pool: int | None = None,
        keep_state: bool = False,
        strict: bool = True,
        n_real: int | None = None,
        **overrides,
    ) -> list[tuple[QueryResult, dict[str, Any]] | None]:
        """Serve a BUCKET of same-shape queries under one shared wall-clock
        budget, riding a single lane driver.

        All queries must share the keyword count ``m`` (they share one
        compiled driver — the serving layer's shape buckets guarantee
        this).  The driver steps every lane together; a lane whose exit
        criterion fires freezes individually (its counters and answer
        stop with it) while the driver keeps stepping the rest.  When the
        budget expires, every still-running lane is interrupted at the
        same superstep and gets its own best-so-far answer with
        *per-lane* bounds — the paper's early-termination guarantee
        (Sec. 5.4), amortized over concurrent requests: N same-budget
        queries cost ~max supersteps instead of the sum.

        Returns one ``(result, info)`` per query (input order), with
        ``info`` as in :meth:`query_deadline` plus ``driver_supersteps``
        (the shared driver's step count — compare against the sum of
        per-lane ``result.supersteps`` to see the sharing win).
        ``result.own_time_s`` is the lane's own serve time: the wall
        clock when its exit was observed, or the full bucket time if it
        ran to the deadline.  ``n_real``: as in :meth:`query_batch`,
        queries at index >= ``n_real`` are padding lanes and come back as
        None.

        Tree extraction *overlaps* the driver: a lane that freezes has a
        final table, so its host-side reconstruction starts on a worker
        thread immediately (:class:`repro.answers.ExtractionOverlap`)
        while the device steps the remaining lanes — by loop exit most
        trees already exist.  Interrupted lanes extract best-so-far trees
        from their frozen state at the deadline, alongside their bounds.
        """
        queries = [list(q) for q in queries]
        if not queries:
            return []
        ms = {len(q) for q in queries}
        if len(ms) != 1:
            raise ValueError(
                f"a deadline bucket shares one driver: all queries must "
                f"have the same keyword count (got m={sorted(ms)})")
        n_real = len(queries) if n_real is None else n_real
        cfg = self._config(ms.pop(), k, **overrides)
        pairs = [self._masks(q, strict) for q in queries]
        masks = np.stack([p[0] for p in pairs])
        init_fn, step_fn = self._executable(cfg, "stepwise")
        overlap = None
        if extract:
            from repro.answers import ExtractionOverlap
            overlap = ExtractionOverlap(
                self.graph, max(cfg.k, extract_pool or 0))
        t0 = time.perf_counter()
        deadline_t = t0 + max(deadline_s, 0.0)
        state = self._execute(init_fn, self.device_graph, jnp.asarray(masks))
        own_t: list[float | None] = [None] * len(queries)
        driver_steps = 0
        while True:
            done = np.asarray(state.done)
            now = time.perf_counter()
            for i in range(n_real):
                if done[i] and own_t[i] is None:
                    # The lane proved its exit here: that is ITS serve
                    # time, even while the driver keeps stepping others.
                    own_t[i] = now - t0
                    if overlap is not None and \
                            float(np.asarray(state.topk_w[i, 0])) < INF:
                        # Frozen lane => final table: reconstruct its
                        # trees NOW, under the remaining supersteps.
                        overlap.submit(i, state.S[i],
                                       masks[i][:, : self.n_nodes])
            if done[:n_real].all() or now >= deadline_t:
                break
            state = self._execute(step_fn, self.device_graph, state)
            driver_steps += 1
        dt = time.perf_counter() - t0
        out: list[tuple[QueryResult, dict[str, Any]] | None] = []
        for i, q in enumerate(queries):
            if i >= n_real:
                out.append(None)
                continue
            lane = lane_view(state, i)
            answers_pre = None
            if overlap is not None and float(lane.topk_w[0]) < INF:
                # Overlapped result for frozen lanes; inline best-so-far
                # extraction for lanes the deadline interrupted.
                answers_pre = overlap.result(
                    i, lane.S, masks[i][:, : self.n_nodes]) \
                    if not overlap.pending(i) else overlap.result(i)
            interrupted = not bool(lane.done)
            forced = bool(lane.budget_hit) or bool(lane.capped)
            if interrupted or forced:
                bounds = self._state_bounds(lane, cfg)
                spa = bounds.spa
                sound_lb = bounds.sound_lb
                # Reported bound folds in the sound facts, so it can
                # never sit below the guarantee it accompanies.
                opt_lb = max(bounds.opt_lb, sound_lb)
            else:
                # Proven exit: the run certified its best answer — that
                # IS the bound, and the O(3^m) cover DP is dead weight.
                spa = None
                opt_lb = sound_lb = min(float(lane.topk_w[0]), INF)
            res = self._make_result(
                q, masks[i], lane, cfg, dt, extract, keep_state,
                unmatched=pairs[i][1],
                own_time_s=own_t[i] if own_t[i] is not None else dt,
                interrupted=interrupted, spa_hint=spa,
                extract_pool=extract_pool, answers_pre=answers_pre)
            info = dict(
                opt_lower_bound=min(opt_lb, INF),
                sound_opt_lower_bound=min(sound_lb, INF),
                interrupted=interrupted,
                driver_supersteps=driver_steps,
            )
            out.append((res, info))
        if overlap is not None:
            overlap.close()
            # Bucket-wide extraction split (how many tree reconstructions
            # hid behind device supersteps) — shared by every lane's info,
            # like driver_supersteps.
            ext = overlap.stats()
            for pair in out:
                if pair is not None:
                    pair[1]["extraction"] = ext
        return out

    def _state_bounds(self, state: DKSState, cfg: DKSConfig):
        """One state's lower-bound facts, shared by the stream and
        deadline paths.

        ``opt_lb`` is the paper's reported bound — max of min(best, spa)
        and min(best, nu) — where ``nu`` is provably a lower bound on any
        future newly-appearing full-set value and ``spa`` is the Sec. 5.4
        estimator.  ``sound_lb`` keeps only the provable facts: the ``nu``
        component, plus ``best`` itself when an empty frontier (or an exit
        that is neither the budget nor the superstep cap) proves no future
        superstep changes anything.  Each is a valid bound on its own; a
        stream takes their running max across supersteps.
        """
        best = float(state.topk_w[0])
        nu = nu_lower_bound(state.g, jnp.float32(self._e_min), cfg.m)
        nu_full = float(nu[cfg.full])
        shat = jnp.minimum(state.s_front + self._e_min, INF)
        spa = float(spa_cover_dp(shat, cfg.m))
        frontier = int(jnp.sum(state.changed))
        opt_lb = max(min(best, spa), min(best, nu_full))
        sound_lb = min(best, nu_full)
        forced = bool(state.budget_hit) or bool(state.capped)
        if frontier == 0 or (bool(state.done) and not forced):
            sound_lb = max(sound_lb, best)
        return _StateBounds(best=best, nu_full=nu_full, spa=spa,
                            frontier=frontier, opt_lb=min(opt_lb, INF),
                            sound_lb=min(sound_lb, INF))

    def _stream(self, cfg: DKSConfig, masks: np.ndarray,
                unmatched: tuple = ()):
        """(state, StreamUpdate) pairs, one per superstep (incl. init) —
        a host loop over the 1-lane stepwise driver.  Yields un-batched
        lane views, so result construction stays lane-free."""
        init_fn, step_fn = self._executable(cfg, "stepwise")
        states = self._execute(init_fn, self.device_graph,
                               jnp.asarray(masks[None]))
        opt_lb = 0.0
        sound_lb = 0.0
        while True:
            state = lane_view(states, 0)
            bounds = self._state_bounds(state, cfg)
            best = bounds.best
            done = bool(state.done)
            opt_lb = max(opt_lb, bounds.opt_lb)
            sound_lb = max(sound_lb, bounds.sound_lb)
            if best >= INF:
                ratio = float("inf")
            elif best <= opt_lb or opt_lb >= INF:
                ratio = 0.0
            else:
                ratio = best / opt_lb if opt_lb > 0 else float("inf")
            yield state, StreamUpdate(
                step=int(state.step),
                weights=np.asarray(state.topk_w),
                roots=np.asarray(state.topk_root),
                frontier=bounds.frontier,
                msgs_bfs=float(state.msgs_bfs),
                msgs_deep=float(state.msgs_deep),
                nu_full=bounds.nu_full,
                spa=bounds.spa,
                opt_lower_bound=opt_lb,
                sound_opt_lower_bound=sound_lb,
                spa_ratio=ratio,
                done=done,
                unmatched=tuple(unmatched),
            )
            if done or int(state.step) >= cfg.max_supersteps:
                return
            states = self._execute(step_fn, self.device_graph, states)

    def query_instrumented(
        self,
        keywords: Sequence,
        k: int = 1,
        *,
        exit_hook: Callable[[DKSState], bool] | None = None,
        extract: bool = True,
        keep_state: bool = False,
        strict: bool = True,
        **overrides,
    ) -> tuple[QueryResult, dict[str, Any]]:
        """Host-driven run with per-phase wall times (paper Table 1) and an
        optional host-side exit criterion (e.g. ``fagin.paper_exit_hook``).

        Works on both partitionings.  On partition="sharded" the frontier
        exchange and edge relax are fused inside one shard_map, so the
        "send_bfs" bucket covers both (see
        :func:`repro.core.dks_sharded.run_dks_frontier_instrumented` for
        the exact attribution)."""
        if self.policy.partition == "sharded":
            from repro.core.dks_sharded import run_dks_frontier_instrumented
            run_fn = run_dks_frontier_instrumented
        else:
            run_fn = run_dks_instrumented
        keywords = list(keywords)
        cfg = self._config(len(keywords), k, **overrides)
        masks, unmatched = self._masks(keywords, strict)
        t0 = time.perf_counter()
        with self._mesh_context():
            state, info = run_fn(
                self.device_graph, jnp.asarray(masks), cfg,
                exit_hook=exit_hook)
        dt = time.perf_counter() - t0
        res = self._make_result(keywords, masks, state, cfg, dt, extract,
                                keep_state, unmatched=unmatched,
                                own_time_s=dt,
                                telemetry=info.get("telemetry"))
        return res, info

    # ------------------------------------------------------------------
    # Internals
    # ------------------------------------------------------------------

    def _mesh_context(self):
        """Ambient-mesh scope for sharded execution.

        The sharded executors take their mesh *explicitly* (it rides on
        :class:`FrontierGraph`), so this scope is not load-bearing for
        correctness — it is kept so any auto-sharded ops around the
        shard_map (and user callbacks) see the engine's mesh, on every jax
        generation (:func:`repro.shardmap.mesh_scope`).
        """
        return shardmap.mesh_scope(self.mesh)

    def _execute(self, fn, *args):
        """Run a compiled executor under the engine's mesh (if any) and
        block until the result is materialized."""
        self._execute_count += 1
        with self._mesh_context():
            return jax.block_until_ready(fn(*args))

    def _run_fused(self, cfg: DKSConfig, masks: np.ndarray):
        """One fused-driver dispatch over lane-batched masks.  Returns
        ``(final states, telemetry)`` where telemetry is the decoded
        :class:`~repro.obs.SuperstepTelemetry` under
        ``ExecutionPolicy(telemetry=True)`` and None otherwise — the
        state trajectory is identical either way (the telemetry carry
        only reads the state)."""
        fn = self._executable(cfg, "fused")
        if not self.policy.telemetry:
            states = self._execute(fn, self.device_graph,
                                   jnp.asarray(masks))
            return states, None
        states, buf, steps = self._execute(fn, self.device_graph,
                                           jnp.asarray(masks))
        telemetry = SuperstepTelemetry.from_buffer(np.asarray(buf),
                                                   int(steps))
        return states, telemetry

    def _config(self, m: int, k: int, **overrides) -> DKSConfig:
        if m < 1:
            raise ValueError("a query needs at least one keyword")
        policy = self.policy
        if overrides:
            self._check_overrides(overrides)
            policy = dataclasses.replace(policy, **overrides)
        return policy.dks_config(m, k)

    def _masks(self, keywords: list,
               strict: bool = True) -> tuple[np.ndarray, tuple]:
        """(masks, unmatched tokens).  ``strict`` raises on unmatched —
        and then guarantees ``unmatched == ()``, so the scan for them only
        runs in best-effort mode."""
        masks = self.index.keyword_masks(
            keywords, self.n_nodes, v_pad=self.v_pad,
            on_missing="raise" if strict else "ignore")
        unmatched = () if strict else tuple(
            self.index.missing_tokens(keywords))
        return masks, unmatched

    def _executable(self, cfg: DKSConfig, kind: str):
        """Fetch-or-compile the executor for a query shape.

        The four executor kinds of the pre-driver engine (single-query
        while-loop, vmapped batch, host-stepped stream, sequential
        sharded fallback) collapse to the lane driver plus a loop policy:

        - "fused": the whole driver as one jitted while-loop over the
          lane axis (``query`` runs it with 1 lane, ``query_batch`` with
          a bucket of lanes; on either partitioning it is ONE device
          execution per call);
        - "stepwise": the ``(init, superstep)`` pair of the same kernel,
          for surfaces that need host control between supersteps
          (streaming, deadline buckets).

        The trace counter increments at trace time only, so a cache hit
        leaves it untouched — that is the no-re-trace guarantee tests
        assert.  (jit itself re-traces per lane count, as for any new
        input shape; a serving layer pads buckets to keep the lane-count
        alphabet small.)
        """
        kind = self._resolve_kind(kind)
        key = (cfg, self.policy.partition, kind)
        fn = self._executables.get(key)
        if fn is not None:
            return fn

        # The fused pallas layout (None on jnp/sharded engines) rides the
        # executor closures as a trace-time constant — same graph, same
        # layout, for the engine's whole lifetime.
        csr = self.lane_csr

        if kind == "fused":
            def _run(graph, masks):
                self._trace_counts[key] = self._trace_counts.get(key, 0) + 1
                state = lane_init(graph, masks, cfg)
                return jax.lax.while_loop(
                    lambda st: ~jnp.all(st.done),
                    lambda st: lane_superstep(graph, st, cfg, csr=csr),
                    state)

            fn = jax.jit(_run)
        elif kind == "fused-telemetry":
            # Same loop, same kernel, plus the bounded counter-buffer
            # carry (repro.core.driver.run_lanes_telemetry).
            def _run_tel(graph, masks):
                self._trace_counts[key] = self._trace_counts.get(key, 0) + 1
                return run_lanes_telemetry(graph, masks, cfg, csr=csr)

            fn = jax.jit(_run_tel)
        elif kind == "stepwise":
            def _init(graph, masks):
                self._trace_counts[key] = self._trace_counts.get(key, 0) + 1
                return lane_init(graph, masks, cfg)

            def _step(graph, st):
                self._trace_counts[key] = self._trace_counts.get(key, 0) + 1
                return lane_superstep(graph, st, cfg, csr=csr)

            # A cached stepwise pair counts 2 traces (init + superstep).
            fn = (jax.jit(_init), jax.jit(_step))
        else:
            raise ValueError(f"unknown executable kind {kind!r}")
        self._executables[key] = fn
        return fn

    def _make_result(
        self,
        keywords: list,
        masks: np.ndarray,
        state: DKSState,
        cfg: DKSConfig,
        wall_time_s: float,
        extract: bool,
        keep_state: bool = False,
        unmatched: tuple = (),
        own_time_s: float | None = None,
        interrupted: bool = False,
        spa_hint: float | None = None,
        extract_pool: int | None = None,
        answers_pre: tuple | None = None,
        telemetry: SuperstepTelemetry | None = None,
    ) -> QueryResult:
        weights = np.asarray(state.topk_w)
        roots = np.asarray(state.topk_root)
        budget_hit = bool(state.budget_hit)
        capped = bool(state.capped)
        # The SPA cover DP (a host-driven O(3^m) loop of tiny device ops)
        # only informs the ratio on forced early exit (budget, superstep
        # cap, or a deadline-interrupted run) — skip it on proven exits,
        # and reuse ``spa_hint`` when the caller already computed it on
        # this very state (query_deadline does).
        spa = None
        ratio = 0.0
        if budget_hit or capped or interrupted:
            if spa_hint is not None:
                spa = spa_hint
            else:
                shat = jnp.minimum(state.s_front + self._e_min, INF)
                spa = float(spa_cover_dp(shat, cfg.m))
            ratio = float(spa_ratio(state.topk_w[0], spa))
        # Tree extraction: ``answers_pre`` is a ready-made
        # ``(ranked, exhausted)`` pair from the device-batched backtracer
        # (query_batch) or the extraction overlap (deadline buckets); the
        # inline host collector covers the rest.  ``extract_pool`` widens
        # the collection target so ``answer_pool`` carries material for
        # diversified re-ranking, with ``answers`` staying its top-k.
        answers: list = []
        answers_exhausted = pool_exhausted = False
        answer_pool = None
        if extract and weights[0] < INF:
            if answers_pre is not None:
                ranked, exhausted = answers_pre
            else:
                ranked, exhausted = collect_answers(
                    np.asarray(state.S), self.graph,
                    masks[:, : self.n_nodes],
                    k=max(cfg.k, extract_pool or 0))
            answers = ranked[: cfg.k]
            answers_exhausted = len(ranked) < cfg.k
            if extract_pool:
                answer_pool = ranked
                pool_exhausted = exhausted
        elif extract:
            # No finite answer => no trees exist; the empty pool is a
            # definitive (cacheable) fact, not a skipped extraction.
            answers_exhausted = True
            if extract_pool:
                answer_pool, pool_exhausted = [], True
        return QueryResult(
            query=tuple(keywords),
            m=cfg.m,
            k=cfg.k,
            answers=answers,
            weights=weights,
            roots=roots,
            kw_nodes=int(masks.sum()),
            supersteps=int(state.step),
            msgs_bfs=float(state.msgs_bfs),
            msgs_deep=float(state.msgs_deep),
            explored_frac=float(jnp.mean(state.visited[: self.n_nodes])),
            done=bool(state.done),
            budget_hit=budget_hit,
            capped=capped,
            spa=spa,
            spa_ratio=ratio,
            wall_time_s=wall_time_s,
            state=state if keep_state else None,
            unmatched=tuple(unmatched),
            own_time_s=own_time_s,
            answers_exhausted=answers_exhausted,
            answer_pool=answer_pool,
            pool_exhausted=pool_exhausted,
            telemetry=telemetry,
        )
