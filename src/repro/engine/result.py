"""Query results: ranked answer trees plus the run statistics and
approximation bounds the paper reports (supersteps, BFS/deep messages,
explored fraction, SPA ratio on forced early exit — Sec. 5.4 / Fig. 12).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro import INF
from repro.core.dks import DKSState
from repro.core.reconstruct import AnswerTree
from repro.obs.telemetry import SuperstepTelemetry


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """One answered relationship query.

    Attributes:
      query:         the tokens as given to the engine.
      m, k:          query shape (keywords, answers requested).
      answers:       ranked minimal answer trees (host-reconstructed; empty
                     when extraction was skipped or nothing was found).
      weights:       f32[k] global top-k distinct answer weights (INF pad).
      roots:         i32[k] their root nodes (-1 pad).
      kw_nodes:      total keyword-node count of the query (paper Fig. 9's
                     x-axis; the size of the superstep-0 frontier).
      supersteps:    Pregel supersteps executed.
      msgs_bfs / msgs_deep: cumulative message counts (paper Fig. 11/14).
      explored_frac: fraction of real nodes ever activated (paper Fig. 13).
      done:          the run stopped (for any reason, including forced
                     stops — check ``budget_hit``/``capped`` to tell).
      budget_hit:    stopped by the message budget / frontier overflow
                     (paper Sec. 5.4 forced stop).
      capped:        stopped only by the ``max_supersteps`` cap — the run
                     was truncated before any exit criterion fired (``spa``
                     / ``spa_ratio`` are reported, as for ``budget_hit``).
      spa:           smallest-possible-answer bound at exit (cover DP over
                     frontier minima), computed only on forced stops
                     (``budget_hit`` / ``capped``, or a streamed run
                     stopped early by its ``until=`` predicate — e.g. a
                     serving deadline); None otherwise.
      spa_ratio:     paper Fig. 12 degree of approximation: best/SPA, or 0
                     when the SPA estimate certifies the answer (paper
                     convention — on forced stops this relies on the SPA
                     estimator, not the sound ``nu`` bound; see
                     ``StreamUpdate.proven_optimal`` for the sound claim).
      wall_time_s:   device wall time for the superstep loop (for batched
                     queries: the shared bucket time).
      own_time_s:    THIS query's individual serve time, where one is
                     measurable: equal to ``wall_time_s`` for single-query
                     surfaces; on ``query_deadline_batch`` the wall clock
                     at which the lane's exit was observed (or the full
                     bucket time if it ran to the deadline — lanes freeze
                     individually, so this is the honest per-lane bill);
                     and None inside a ``query_batch`` bucket on either
                     partitioning (the lanes advance in lockstep through
                     one fused device program, so per-query time does not
                     exist).
      state:         the raw final :class:`DKSState` (device arrays) when
                     the query was made with ``keep_state=True``; None
                     otherwise, so served results don't pin the dense
                     ``[V, 2^m, K]`` table in device memory.
      unmatched:     tokens of the query that matched no node in the index
                     (always empty under ``strict=True``, which raises
                     instead; with ``strict=False`` a nonempty value
                     explains an INF answer without burning supersteps on
                     diagnosis).
      answers_exhausted: True when the final table holds fewer than ``k``
                     distinct answer trees — ``len(answers) < k`` is a
                     property of the graph/query, not an extraction
                     shortfall (the collector refills candidates until the
                     finite table is exhausted).  Always False when
                     extraction was skipped.
      answer_pool:   the larger ranked tree list when the query was made
                     with ``extract_pool > k`` (serving extracts a pool so
                     diversified re-ranking has material to choose from);
                     ``answers`` is its first ``k``.  None when no pool
                     was requested.
      pool_exhausted: as ``answers_exhausted`` but for the requested pool
                     size — True when the table holds fewer distinct trees
                     than the pool asked for (the pool is the complete
                     answer list; pagination past it cannot find more).
      telemetry:     per-superstep counters
                     (:class:`repro.obs.SuperstepTelemetry`) when the
                     engine was built with
                     ``ExecutionPolicy(telemetry=True)`` or the query ran
                     on the instrumented surface; None otherwise.  Inside
                     a ``query_batch`` bucket the object is shared by
                     every lane of the bucket with lane-summed columns.
    """

    query: tuple
    m: int
    k: int
    answers: list[AnswerTree]
    weights: np.ndarray
    roots: np.ndarray
    kw_nodes: int
    supersteps: int
    msgs_bfs: float
    msgs_deep: float
    explored_frac: float
    done: bool
    budget_hit: bool
    capped: bool
    spa: float | None
    spa_ratio: float
    wall_time_s: float
    state: DKSState | None
    unmatched: tuple = ()
    own_time_s: float | None = None
    answers_exhausted: bool = False
    answer_pool: list[AnswerTree] | None = None
    pool_exhausted: bool = False
    telemetry: SuperstepTelemetry | None = None

    @property
    def found(self) -> bool:
        return bool(self.weights[0] < INF)

    @property
    def best(self) -> AnswerTree | None:
        return self.answers[0] if self.answers else None

    @property
    def best_weight(self) -> float:
        return float(self.weights[0])

    @property
    def msgs_total(self) -> float:
        return self.msgs_bfs + self.msgs_deep


@dataclasses.dataclass(frozen=True)
class StreamUpdate:
    """One superstep of a streaming query (engine.query_stream).

    The paper's early-termination guarantee as a first-class value: after
    every superstep the caller sees the current best answers together with a
    lower bound on the optimum (the paper's SPA estimate, Sec. 5.4, combined
    with the provably sound ``nu`` bound), so it can stop as soon as the
    approximation is good enough.

    Attributes:
      step:          superstep index (1-based; the init superstep is 0).
      weights:       f32[k] current global top-k distinct answer weights.
      roots:         i32[k] their roots.
      frontier:      number of active vertices entering the next superstep.
      msgs_bfs / msgs_deep: cumulative message counts.
      nu_full:       sound lower bound on any *newly appearing* full-set
                     value in a future superstep (spa.nu_lower_bound).
      spa:           cover-DP smallest-possible-answer estimate from the
                     current frontier minima (paper Sec. 5.4).
      opt_lower_bound: running *reported* lower bound on the optimum: max
                     over supersteps so far of min(best, spa) and
                     min(best, nu_full).  The ``nu`` component is provably
                     sound; ``spa`` is the paper's estimator, so this is
                     the paper's reporting convention, not a proof.
      sound_opt_lower_bound: running lower bound built from sound facts
                     only — the ``nu`` bound, an exhausted frontier, or a
                     non-budget exit.  ``proven_optimal`` keys off this.
      spa_ratio:     inf while no answer is known; then
                     best / opt_lower_bound, monotonically non-increasing;
                     0 once the current best cannot be improved per the
                     reported bound (paper Fig. 12 convention).
      done:          the run's exit criterion has fired (final update).
      unmatched:     tokens that matched no node (nonempty only under
                     ``strict=False`` — the streamed diagnosis for an INF
                     answer, same as ``QueryResult.unmatched``).
    """

    step: int
    weights: np.ndarray
    roots: np.ndarray
    frontier: int
    msgs_bfs: float
    msgs_deep: float
    nu_full: float
    spa: float
    opt_lower_bound: float
    sound_opt_lower_bound: float
    spa_ratio: float
    done: bool
    unmatched: tuple = ()

    @property
    def best_weight(self) -> float:
        return float(self.weights[0])

    @property
    def proven_optimal(self) -> bool:
        """Sound claim: no future superstep can beat the current best."""
        return self.best_weight < INF and \
            self.best_weight <= self.sound_opt_lower_bound
