"""Per-superstep telemetry: one decoded form for both collection paths.

The paper's experimental section (§6) reads the run through
per-superstep curves — frontier sizes, message counts, convergence.
Two collectors feed the same decoded record:

- the **fused device path**: `ExecutionPolicy(telemetry=True)` makes
  the jitted while-loop carry a small ``[T, 4]`` f32 buffer and write
  one row per superstep (``core/driver.py`` owns the jnp side; this
  module decodes the buffer on the host), and
- the **host stepwise path**: `HostTelemetryCollector` accumulates rows
  inside ``host_instrumented_loop`` (the `query_instrumented` surface),
  which also tracks the per-step best weight the device buffer omits.

Both produce a :class:`SuperstepTelemetry`; ``rows()`` reproduces the
legacy instrumented ``history`` dicts, so the instrumented surface is a
compatibility wrapper over this collector rather than a second source
of per-superstep truth.

Buffer layout (column order is load-bearing — the device loop writes
it positionally): ``[frontier, msgs_bfs, msgs_deep, frozen]`` where
``frontier`` sums active vertices over all lanes, the message columns
are *cumulative* lane-summed totals (deltas are derived properties),
and ``frozen`` counts lanes already done after the superstep.  The
buffer is bounded at :data:`TELEMETRY_MAX_SUPERSTEPS` rows; runs past
that overwrite the last row and set ``truncated``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# Device-buffer row cap.  DKS supersteps are bounded by the graph
# diameter (tens, not hundreds); 512 rows is 8 KiB of f32 per query —
# big enough to never truncate a real run, small enough to be free.
TELEMETRY_MAX_SUPERSTEPS = 512

# Column indices in the device buffer / collector rows.
COL_FRONTIER, COL_MSGS_BFS, COL_MSGS_DEEP, COL_FROZEN = 0, 1, 2, 3
N_COLS = 4


@dataclass(frozen=True)
class SuperstepTelemetry:
    """Decoded per-superstep counters for one query (or one lane bucket,
    with lane-summed columns).  All arrays have length ``n_steps``.

    - ``frontier[i]``: active (changed) vertices entering superstep
      ``i+1``'s send phase, summed over lanes.
    - ``msgs_bfs[i]`` / ``msgs_deep[i]``: *cumulative* message totals
      after superstep ``i+1`` (lane-summed); per-step deltas via
      :attr:`msgs_bfs_delta` / :attr:`msgs_deep_delta`.
    - ``frozen[i]``: lanes whose exit condition held after superstep
      ``i+1`` (0 or 1 for single queries).
    - ``best``: best answer weight per step — host collector only;
      ``None`` from the device buffer.
    """

    n_steps: int
    frontier: np.ndarray
    msgs_bfs: np.ndarray
    msgs_deep: np.ndarray
    frozen: np.ndarray
    best: np.ndarray | None = None
    truncated: bool = False

    @classmethod
    def from_buffer(cls, buf, n_steps: int) -> "SuperstepTelemetry":
        """Decode the device carry buffer (``[T, 4]``, any array type
        np.asarray accepts).  Rows past ``n_steps`` are padding."""
        arr = np.asarray(buf, dtype=np.float64)
        if arr.ndim != 2 or arr.shape[1] != N_COLS:
            raise ValueError(f"telemetry buffer must be [T, {N_COLS}], "
                             f"got {arr.shape}")
        n = int(n_steps)
        truncated = n > arr.shape[0]
        rows = arr[:min(n, arr.shape[0])]
        return cls(
            n_steps=n,
            frontier=rows[:, COL_FRONTIER].astype(np.int64),
            msgs_bfs=rows[:, COL_MSGS_BFS].copy(),
            msgs_deep=rows[:, COL_MSGS_DEEP].copy(),
            frozen=rows[:, COL_FROZEN].astype(np.int64),
            truncated=truncated,
        )

    @property
    def msgs_bfs_delta(self) -> np.ndarray:
        return np.diff(self.msgs_bfs, prepend=0.0)

    @property
    def msgs_deep_delta(self) -> np.ndarray:
        return np.diff(self.msgs_deep, prepend=0.0)

    def rows(self) -> list[dict]:
        """Legacy instrumented ``history`` rows: one dict per superstep
        with keys ``step/frontier/msgs_bfs/msgs_deep`` (+ ``best`` when
        tracked), message columns cumulative — exactly what
        ``host_instrumented_loop`` used to build inline."""
        out = []
        for i in range(len(self.frontier)):
            row = {
                "step": i + 1,
                "frontier": int(self.frontier[i]),
                "msgs_bfs": float(self.msgs_bfs[i]),
                "msgs_deep": float(self.msgs_deep[i]),
            }
            if self.best is not None:
                row["best"] = float(self.best[i])
            out.append(row)
        return out

    def summary(self) -> dict:
        """Scalar digest for logs/benchmarks."""
        if len(self.frontier) == 0:
            return {"n_steps": self.n_steps, "peak_frontier": 0,
                    "msgs_total": 0.0, "truncated": self.truncated}
        return {
            "n_steps": self.n_steps,
            "peak_frontier": int(self.frontier.max()),
            "peak_frontier_step": int(self.frontier.argmax()) + 1,
            "msgs_total": float(self.msgs_bfs[-1] + self.msgs_deep[-1]),
            "truncated": self.truncated,
        }


@dataclass
class HostTelemetryCollector:
    """Row-at-a-time accumulator for host-looped drivers.

    ``host_instrumented_loop`` calls :meth:`record` once per superstep
    with lane-summed scalars; :meth:`build` freezes the result.  This is
    the single place the instrumented history format is defined.
    """

    _rows: list[tuple] = field(default_factory=list)
    _best: list[float] = field(default_factory=list)
    _has_best: bool = False

    def record(self, frontier: int, msgs_bfs: float, msgs_deep: float,
               frozen: int, best: float | None = None) -> None:
        self._rows.append((int(frontier), float(msgs_bfs),
                           float(msgs_deep), int(frozen)))
        if best is not None:
            self._has_best = True
            self._best.append(float(best))

    def __len__(self) -> int:
        return len(self._rows)

    def build(self) -> SuperstepTelemetry:
        arr = np.asarray(self._rows, dtype=np.float64).reshape(-1, N_COLS)
        best = None
        if self._has_best:
            if len(self._best) != len(self._rows):
                raise ValueError("best recorded for only some supersteps")
            best = np.asarray(self._best, dtype=np.float64)
        return SuperstepTelemetry(
            n_steps=len(self._rows),
            frontier=arr[:, COL_FRONTIER].astype(np.int64),
            msgs_bfs=arr[:, COL_MSGS_BFS].copy(),
            msgs_deep=arr[:, COL_MSGS_DEEP].copy(),
            frozen=arr[:, COL_FROZEN].astype(np.int64),
            best=best,
        )
