"""HTTP export surface: /metrics, /healthz, /traces on a stdlib server.

One `ThreadingHTTPServer` serving three read-only endpoints:

- ``/metrics``  — Prometheus text exposition from a MetricsRegistry.
- ``/healthz``  — ``ok`` + 200 while the server is up (liveness only;
  readiness is the caller's business).
- ``/traces``   — recent finished traces as JSONL, newest last;
  ``?n=K`` limits to the last K, ``?id=T`` returns one trace.

Runs on a daemon thread; ``port=0`` binds an ephemeral port (the bound
port is on ``server.port``), which is what tests and the serve smoke
use.  No auth, no TLS — bind to localhost unless you mean it.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

from .metrics import MetricsRegistry
from .trace import Tracer

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Serve a registry (+ optional tracer) over HTTP.  Context manager:
    ``with MetricsServer(reg, tracer, port=0) as srv: ... srv.port``."""

    def __init__(self, registry: MetricsRegistry,
                 tracer: Tracer | None = None, host: str = "127.0.0.1",
                 port: int = 0) -> None:
        self.registry = registry
        self.tracer = tracer
        outer = self

        class Handler(BaseHTTPRequestHandler):
            # quiet: per-request stderr logging would swamp the loadgen
            def log_message(self, fmt, *args):
                pass

            def _send(self, code: int, body: str, ctype: str) -> None:
                data = body.encode("utf-8")
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_GET(self):
                url = urlparse(self.path)
                try:
                    if url.path == "/metrics":
                        self._send(200, outer.registry.render(),
                                   PROM_CONTENT_TYPE)
                    elif url.path == "/healthz":
                        self._send(200, "ok\n", "text/plain")
                    elif url.path == "/traces":
                        self._traces(parse_qs(url.query))
                    else:
                        self._send(404, "not found\n", "text/plain")
                except BrokenPipeError:
                    pass
                except Exception as exc:  # surface, don't kill the thread
                    self._send(500, f"error: {exc!r}\n", "text/plain")

            def _traces(self, q):
                if outer.tracer is None:
                    self._send(404, "no tracer attached\n", "text/plain")
                    return
                if "id" in q:
                    tr = outer.tracer.get(int(q["id"][0]))
                    if tr is None:
                        self._send(404, "trace not in ring\n",
                                   "text/plain")
                        return
                    body = json.dumps(tr.to_dict(), separators=(",", ":"))
                    self._send(200, body + "\n", "application/json")
                    return
                n = int(q["n"][0]) if "n" in q else None
                body = outer.tracer.to_jsonl(n)
                self._send(200, body + ("\n" if body else ""),
                           "application/x-ndjson")

        self._server = ThreadingHTTPServer((host, port), Handler)
        self._server.daemon_threads = True
        self._thread: threading.Thread | None = None

    @property
    def port(self) -> int:
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        host = self._server.server_address[0]
        return f"http://{host}:{self.port}"

    def start(self) -> "MetricsServer":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(
            target=self._server.serve_forever, name="obs-metrics-http",
            daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._server.shutdown()
        self._thread.join(timeout=5.0)
        self._server.server_close()
        self._thread = None

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()
