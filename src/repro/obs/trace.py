"""Request tracing: per-request span trees on monotonic clocks.

The serving layer answers a request through half a dozen mechanisms —
admission checks, the micro-batcher's window, bucket coalescing, a
compile-or-warm device dispatch, extraction, rendering, caches — and an
aggregate percentile cannot say which one a slow request paid for.  A
:class:`Trace` is one request's answer to that question: a bounded tree
of :class:`Span`\\ s, each a named ``[t_start, t_end)`` interval on
``time.perf_counter()`` with a small attribute dict.

Design constraints (this sits on the serving hot path):

- **monotonic clocks only** — spans are perf_counter intervals; wall
  time appears once per trace (``t_unix``) for log correlation.
- **bounded memory** — finished traces land in a ring buffer of
  ``capacity`` entries; an unsampled trace records no spans at all (its
  id still exists, so every served result can carry one).
- **deterministic sampling** — the keep/drop decision hashes
  ``(seed, trace_id)``, so a given seed samples the same ids on every
  run (tests and incident replays see the same traces).
- **exactly one trace per admitted request** — ``begin()`` counts
  births, ``finish()`` is idempotent and counts completions; the ring
  plus the counters make "every admitted request resolves to exactly
  one trace" a checkable invariant.

Traces from requests that ride another request's work (micro-batch
followers, single-flight attachees) carry a ``coalesced_into`` link to
the leader's trace id instead of duplicating its spans.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
import zlib
from collections import deque


class Span:
    """One named interval inside a trace (see module docstring)."""

    __slots__ = ("span_id", "parent_id", "name", "t_start", "t_end",
                 "attrs")

    def __init__(self, span_id: int, parent_id: int | None, name: str,
                 t_start: float, t_end: float | None = None,
                 attrs: dict | None = None) -> None:
        self.span_id = span_id
        self.parent_id = parent_id
        self.name = name
        self.t_start = t_start
        self.t_end = t_end
        self.attrs = attrs or {}

    @property
    def duration_ms(self) -> float:
        if self.t_end is None:
            return 0.0
        return (self.t_end - self.t_start) * 1e3


class _SpanHandle:
    """Context manager returned by :meth:`Trace.span`; closes its span
    (and pops it off the current thread's nesting stack) on exit."""

    __slots__ = ("_trace", "_span")

    def __init__(self, trace: "Trace", span: Span | None) -> None:
        self._trace = trace
        self._span = span

    def set(self, **attrs) -> "_SpanHandle":
        if self._span is not None:
            self._span.attrs.update(attrs)
        return self

    def __enter__(self) -> "_SpanHandle":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._span is None:
            return
        if exc is not None:
            self._span.attrs.setdefault("error", repr(exc))
        self._trace._close(self._span)


class Trace:
    """One request's span tree.  Thread-compatible: spans may be opened
    from different threads (admission on a client thread, dispatch on
    the dispatcher thread); nesting is tracked per thread, so a span
    opened inside another span *on the same thread* becomes its child.
    """

    __slots__ = ("trace_id", "name", "sampled", "t_start", "t_unix",
                 "t_end", "attrs", "links", "spans", "_tracer", "_lock",
                 "_tls", "_ids", "_finished")

    def __init__(self, tracer: "Tracer", trace_id: int, name: str,
                 sampled: bool, attrs: dict) -> None:
        self.trace_id = trace_id
        self.name = name
        self.sampled = sampled
        self.t_start = time.perf_counter()
        self.t_unix = time.time()
        self.t_end: float | None = None
        self.attrs = attrs
        self.links: dict[str, int] = {}
        self.spans: list[Span] = []
        self._tracer = tracer
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._ids = itertools.count(1)
        self._finished = False

    # -- span recording --------------------------------------------------

    def span(self, name: str, **attrs) -> _SpanHandle:
        """Open a child span (context manager).  No-op when unsampled."""
        if not self.sampled:
            return _SpanHandle(self, None)
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        parent = stack[-1] if stack else None
        sp = Span(next(self._ids), parent, name, time.perf_counter(),
                  attrs=attrs)
        stack.append(sp.span_id)
        return _SpanHandle(self, sp)

    def _close(self, sp: Span) -> None:
        sp.t_end = time.perf_counter()
        stack = getattr(self._tls, "stack", None)
        if stack and stack[-1] == sp.span_id:
            stack.pop()
        with self._lock:
            self.spans.append(sp)

    def add_span(self, name: str, t_start: float, t_end: float,
                 **attrs) -> None:
        """Record an already-elapsed interval retroactively (e.g. queue
        wait, measured only when the dispatcher finally picks the
        request up).  Parents under the current thread's open span."""
        if not self.sampled:
            return
        stack = getattr(self._tls, "stack", None)
        parent = stack[-1] if stack else None
        sp = Span(next(self._ids), parent, name, t_start, t_end, attrs)
        with self._lock:
            self.spans.append(sp)

    def set(self, **attrs) -> None:
        """Merge trace-level attributes (recorded even when unsampled —
        they are O(1) and finish() reports them to the log)."""
        self.attrs.update(attrs)

    def link(self, **links) -> None:
        """Cross-trace links, e.g. ``coalesced_into=<leader trace id>``."""
        self.links.update({k: int(v) for k, v in links.items()})

    def finish(self) -> None:
        """Close the trace and hand it to the tracer's ring (idempotent:
        later calls are no-ops, so every resolve path may call it)."""
        with self._lock:
            if self._finished:
                return
            self._finished = True
            self.t_end = time.perf_counter()
        self._tracer._push(self)

    # -- export -----------------------------------------------------------

    @property
    def duration_ms(self) -> float:
        end = self.t_end if self.t_end is not None else time.perf_counter()
        return (end - self.t_start) * 1e3

    def to_dict(self) -> dict:
        """JSON-ready form; span times become offsets from trace start."""
        return {
            "trace_id": self.trace_id,
            "name": self.name,
            "t_unix": self.t_unix,
            "duration_ms": round(self.duration_ms, 3),
            "sampled": self.sampled,
            "attrs": self.attrs,
            "links": self.links,
            "spans": [
                {
                    "span_id": sp.span_id,
                    "parent_id": sp.parent_id,
                    "name": sp.name,
                    "offset_ms": round((sp.t_start - self.t_start) * 1e3, 3),
                    "duration_ms": round(sp.duration_ms, 3),
                    "attrs": sp.attrs,
                }
                for sp in sorted(self.spans, key=lambda s: s.t_start)
            ],
        }


class Tracer:
    """Trace factory + bounded ring of finished traces.

    ``sample``: fraction of traces that record spans (the decision is a
    deterministic hash of ``(seed, trace_id)`` — see module docstring).
    ``log_path``: append each finished *sampled* trace as one JSON line
    (the structured event log ``serve_dks --trace-sample`` exposes).
    """

    def __init__(self, capacity: int = 256, sample: float = 1.0,
                 seed: int = 0, log_path: str | None = None) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if not 0.0 <= sample <= 1.0:
            raise ValueError("sample must be in [0, 1]")
        self.capacity = int(capacity)
        self.sample = float(sample)
        self.seed = int(seed)
        self.log_path = log_path
        self._ring: deque[Trace] = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._begun = 0
        self._finished = 0
        self._sampled = 0

    def _sample_decision(self, trace_id: int) -> bool:
        if self.sample >= 1.0:
            return True
        if self.sample <= 0.0:
            return False
        h = zlib.crc32(f"{self.seed}:{trace_id}".encode()) & 0xFFFFFFFF
        return h / 0x100000000 < self.sample

    def begin(self, name: str, **attrs) -> Trace:
        trace_id = next(self._ids)
        with self._lock:
            self._begun += 1
        return Trace(self, trace_id, name,
                     self._sample_decision(trace_id), attrs)

    def _push(self, trace: Trace) -> None:
        line = None
        with self._lock:
            self._finished += 1
            if trace.sampled:
                self._sampled += 1
                self._ring.append(trace)
                if self.log_path is not None:
                    line = json.dumps(trace.to_dict(),
                                      separators=(",", ":"))
        if line is not None:
            # Outside the lock: one appending write per finished trace.
            with open(self.log_path, "a", encoding="utf-8") as f:
                f.write(line + "\n")

    # -- introspection ----------------------------------------------------

    def recent(self, n: int | None = None) -> list[Trace]:
        """Most recent finished sampled traces, newest last."""
        with self._lock:
            traces = list(self._ring)
        return traces if n is None else traces[-int(n):]

    def get(self, trace_id: int) -> Trace | None:
        with self._lock:
            for tr in reversed(self._ring):
                if tr.trace_id == trace_id:
                    return tr
        return None

    def to_jsonl(self, n: int | None = None) -> str:
        return "\n".join(json.dumps(tr.to_dict(), separators=(",", ":"))
                         for tr in self.recent(n))

    def stats(self) -> dict[str, int]:
        """{begun, finished, sampled, buffered} — ``begun == finished``
        once the service drains is the trace-completeness invariant."""
        with self._lock:
            return {"begun": self._begun, "finished": self._finished,
                    "sampled": self._sampled, "buffered": len(self._ring)}


def render_span_tree(trace: Trace) -> str:
    """Human-readable span tree with durations (``dks_query --explain``).

    ::

        trace 7 dks.request 58.1 ms  (m=2 k=1)
          admit 0.4 ms  (outcome=queued)
            cache_lookup 0.1 ms  (hit=False)
          queue_wait 5.2 ms
          ...
    """
    def fmt_attrs(attrs: dict) -> str:
        if not attrs:
            return ""
        inner = " ".join(f"{k}={v}" for k, v in attrs.items())
        return f"  ({inner})"

    lines = [f"trace {trace.trace_id} {trace.name} "
             f"{trace.duration_ms:.1f} ms{fmt_attrs(trace.attrs)}"]
    for k, v in trace.links.items():
        lines.append(f"  ~ {k} -> trace {v}")
    spans = sorted(trace.spans, key=lambda s: s.t_start)
    children: dict[int | None, list[Span]] = {}
    for sp in spans:
        children.setdefault(sp.parent_id, []).append(sp)

    def walk(parent: int | None, depth: int) -> None:
        for sp in children.get(parent, ()):  # already time-ordered
            lines.append(f"{'  ' * (depth + 1)}{sp.name} "
                         f"{sp.duration_ms:.1f} ms{fmt_attrs(sp.attrs)}")
            walk(sp.span_id, depth + 1)

    walk(None, 0)
    if not trace.sampled:
        lines.append("  (unsampled: no spans recorded)")
    return "\n".join(lines)
