"""Process-wide metrics registry with Prometheus text exposition.

Three instrument kinds, all stdlib, all thread-safe:

- :class:`Counter` — monotone float (``inc`` rejects negatives).
- :class:`Gauge` — settable float (last write wins).
- :class:`Histogram` — fixed buckets chosen at construction; observe is
  one bisect + two adds, cheap enough for per-request latencies.

A :class:`MetricsRegistry` renders everything as Prometheus text
exposition format 0.0.4 (the format every scraper parses).  Two ways to
get numbers in:

1. Direct instruments (``registry.counter(...)``/``.inc()``) for events
   that exist only in flight — dispatch reasons, latency samples.
2. ``register_collector(fn)`` for state that already lives somewhere
   authoritative: ``fn()`` returns ``{metric_name: value}`` and runs at
   scrape time.  The serve layer exports ``ServeStats`` counters this
   way, so ``/metrics`` equals ``svc.stats()`` *by construction* —
   there is no second bookkeeping path that could drift.

:func:`parse_prometheus` is the inverse (samples only, for tests and
the smoke scrape): no dependency on a prometheus client library.
"""

from __future__ import annotations

import threading
from bisect import bisect_left

_NAME_OK = frozenset(
    "abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_:")


def _check_name(name: str) -> str:
    if not name or name[0].isdigit() or not set(name) <= _NAME_OK:
        raise ValueError(f"invalid metric name: {name!r}")
    return name


def _fmt(v: float) -> str:
    """Prometheus value formatting: integers without a trailing .0."""
    f = float(v)
    if f == float("inf"):
        return "+Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class Counter:
    """Monotone counter; ``inc(v)`` with v < 0 raises."""

    kind = "counter"

    def __init__(self, name: str, help_text: str) -> None:
        self.name = _check_name(name)
        self.help = help_text
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def samples(self) -> list[tuple[str, float]]:
        return [(self.name, self.value)]


class Gauge:
    """Point-in-time value; ``set`` overwrites, ``inc``/``dec`` adjust."""

    kind = "gauge"

    def __init__(self, name: str, help_text: str) -> None:
        self.name = _check_name(name)
        self.help = help_text
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self._value += v

    def dec(self, v: float = 1.0) -> None:
        self.inc(-v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def samples(self) -> list[tuple[str, float]]:
        return [(self.name, self.value)]


# Default buckets suit serve-path latencies: sub-ms cache hits through
# multi-second cold compiles.
DEFAULT_BUCKETS_MS = (1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                      500.0, 1000.0, 2500.0, 5000.0)


class Histogram:
    """Fixed-bucket histogram with cumulative Prometheus exposition."""

    kind = "histogram"

    def __init__(self, name: str, help_text: str,
                 buckets: tuple[float, ...] = DEFAULT_BUCKETS_MS) -> None:
        self.name = _check_name(name)
        self.help = help_text
        if not buckets or list(buckets) != sorted(buckets):
            raise ValueError("buckets must be sorted and non-empty")
        self.buckets = tuple(float(b) for b in buckets)
        self._counts = [0] * (len(self.buckets) + 1)  # last = +Inf
        self._sum = 0.0
        self._n = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        i = bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._n += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._n

    def samples(self) -> list[tuple[str, float]]:
        with self._lock:
            counts, total, n = list(self._counts), self._sum, self._n
        out: list[tuple[str, float]] = []
        cum = 0
        for b, c in zip(self.buckets, counts):
            cum += c
            out.append((f'{self.name}_bucket{{le="{_fmt(b)}"}}',
                        float(cum)))
        out.append((f'{self.name}_bucket{{le="+Inf"}}', float(n)))
        out.append((f"{self.name}_sum", total))
        out.append((f"{self.name}_count", float(n)))
        return out


class MetricsRegistry:
    """Named instruments + scrape-time collectors (module docstring)."""

    def __init__(self) -> None:
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self._collectors: list = []
        self._lock = threading.Lock()

    def _register(self, metric):
        with self._lock:
            existing = self._metrics.get(metric.name)
            if existing is not None:
                if type(existing) is not type(metric):
                    raise ValueError(
                        f"metric {metric.name!r} re-registered as a "
                        f"different kind")
                return existing
            self._metrics[metric.name] = metric
            return metric

    def counter(self, name: str, help_text: str = "") -> Counter:
        return self._register(Counter(name, help_text))

    def gauge(self, name: str, help_text: str = "") -> Gauge:
        return self._register(Gauge(name, help_text))

    def histogram(self, name: str, help_text: str = "",
                  buckets: tuple[float, ...] = DEFAULT_BUCKETS_MS,
                  ) -> Histogram:
        return self._register(Histogram(name, help_text, buckets))

    def register_collector(self, fn, kinds: dict[str, str] | None = None,
                           helps: dict[str, str] | None = None) -> None:
        """``fn() -> {name: value}`` evaluated at every scrape.  ``kinds``
        maps names to "counter"/"gauge" for TYPE lines (default gauge)."""
        with self._lock:
            self._collectors.append((fn, kinds or {}, helps or {}))

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def sample(self) -> dict[str, float]:
        """Flat {sample_name: value} snapshot (instruments + collectors)."""
        out: dict[str, float] = {}
        with self._lock:
            metrics = list(self._metrics.values())
            collectors = list(self._collectors)
        for m in metrics:
            out.update(m.samples())
        for fn, _, _ in collectors:
            for name, v in fn().items():
                out[_check_name(name)] = float(v)
        return out

    def render(self) -> str:
        """Prometheus text exposition format 0.0.4."""
        lines: list[str] = []
        with self._lock:
            metrics = sorted(self._metrics.values(), key=lambda m: m.name)
            collectors = list(self._collectors)
        for m in metrics:
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            for name, v in m.samples():
                lines.append(f"{name} {_fmt(v)}")
        for fn, kinds, helps in collectors:
            for name, v in sorted(fn().items()):
                _check_name(name)
                if name in helps:
                    lines.append(f"# HELP {name} {helps[name]}")
                lines.append(f"# TYPE {name} {kinds.get(name, 'gauge')}")
                lines.append(f"{name} {_fmt(float(v))}")
        return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict[str, float]:
    """Parse exposition text back into {sample_name: value}.

    Strict about what it accepts (malformed lines raise), so the serve
    smoke's "the endpoint parses" assertion means something.
    """
    out: dict[str, float] = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        # Labels may contain spaces; split on the last space.
        name, _, value = line.rpartition(" ")
        if not name:
            raise ValueError(f"malformed sample line: {raw!r}")
        bare = name.split("{", 1)[0]
        _check_name(bare)
        out[name] = float(value.replace("+Inf", "inf"))
    return out


_default_registry: MetricsRegistry | None = None
_default_lock = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The process-wide registry (created on first use)."""
    global _default_registry
    with _default_lock:
        if _default_registry is None:
            _default_registry = MetricsRegistry()
        return _default_registry
