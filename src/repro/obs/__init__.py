"""repro.obs — tracing, metrics, and superstep telemetry.

Leaf package by design: nothing here imports from ``repro.core``,
``repro.engine``, or ``repro.serve``, so any of those layers can depend
on it (the driver attaches :class:`SuperstepTelemetry`, the service
wires a :class:`Tracer` and a :class:`MetricsRegistry`) without cycles.
Stdlib + numpy only — no jax at import time.
"""

from .export import MetricsServer, PROM_CONTENT_TYPE
from .metrics import (Counter, DEFAULT_BUCKETS_MS, Gauge, Histogram,
                      MetricsRegistry, default_registry, parse_prometheus)
from .telemetry import (HostTelemetryCollector, SuperstepTelemetry,
                        TELEMETRY_MAX_SUPERSTEPS)
from .trace import Span, Trace, Tracer, render_span_tree

__all__ = [
    "Counter",
    "DEFAULT_BUCKETS_MS",
    "Gauge",
    "Histogram",
    "HostTelemetryCollector",
    "MetricsRegistry",
    "MetricsServer",
    "PROM_CONTENT_TYPE",
    "Span",
    "SuperstepTelemetry",
    "TELEMETRY_MAX_SUPERSTEPS",
    "Trace",
    "Tracer",
    "default_registry",
    "parse_prometheus",
    "render_span_tree",
]
