"""jax-version compatibility layer for the shard_map / mesh APIs.

The sharded DKS path (and every other ``shard_map`` user in this repo) was
written against the jax >= 0.7 surface: ``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh`` and
``jax.sharding.get_abstract_mesh``.  Older jax (0.4.x, the pin in this
container) spells the same machinery ``jax.experimental.shard_map.shard_map``
with ``check_rep``/``auto`` keywords, a ``jax.sharding.Mesh`` that is itself
the ambient-mesh context manager, and no axis types at all.

This module is the single place that difference lives.  Resolution rules:

======================  ==============================  =======================
helper                  jax >= 0.7 (native)             jax 0.4.x (fallback)
======================  ==============================  =======================
``shard_map``           ``jax.shard_map`` with          ``jax.experimental
                        ``check_vma`` / ``axis_names``  .shard_map.shard_map``;
                                                        ``check_vma`` becomes
                                                        ``check_rep``,
                                                        ``axis_names`` becomes
                                                        the complementary
                                                        ``auto`` frozenset
``make_mesh``           ``jax.make_mesh`` with          ``jax.make_mesh``
                        ``axis_types=(Auto, ...)``      without axis types
``mesh_scope``          ``jax.set_mesh(mesh)``          the ``Mesh`` context
                        (or ``jax.sharding.use_mesh``)  manager (``with mesh:``)
``get_abstract_mesh``   ``jax.sharding                  the physical mesh the
                        .get_abstract_mesh()``          enclosing ``mesh_scope``
                                                        installed
======================  ==============================  =======================

``get_abstract_mesh`` normalizes "no mesh installed" to ``None`` on both
generations (native jax returns an *empty* ``AbstractMesh`` instead), so
callers write ``mesh = shardmap.get_abstract_mesh(); if mesh is None: ...``
and never touch ``axis_names`` of an empty mesh.  Whatever it returns can be
passed straight back to :func:`shard_map` as the ``mesh`` argument.

Prefer *explicit* meshes over the ambient lookup wherever a mesh can be
threaded through (e.g. ``FrontierGraph.mesh`` for the sharded DKS path);
``get_abstract_mesh`` exists for model code whose call signature cannot
carry one (sharding constraints deep inside a transformer block).
"""

from __future__ import annotations

import contextlib
import functools
import threading
from typing import Any, Callable, Iterable

import jax

__all__ = [
    "HAS_NATIVE_SHARD_MAP",
    "shard_map",
    "make_mesh",
    "mesh_scope",
    "get_abstract_mesh",
    "auto_axis_names",
    "mesh_axis_size",
    "manual_axes_scope",
    "constraints_supported_here",
]

# jax >= 0.7 exposes shard_map/set_mesh at the top level; 0.4.x does not.
HAS_NATIVE_SHARD_MAP: bool = hasattr(jax, "shard_map")

# 0.4.x meshes carry no axis types, so inside a shard_map body there is no
# way to ask jax which axes are Manual (constraining one is a lowering
# error).  shard_map() below records its manual set in this thread-local
# scope around the body instead; auto_axis_names() subtracts it.
_tls = threading.local()


@contextlib.contextmanager
def manual_axes_scope(names: Iterable[str]):
    """Mark ``names`` as Manual for :func:`auto_axis_names` in this thread.

    Installed automatically by :func:`shard_map` around the body; exposed
    for code that traces a body through some other manual-mode entry point.
    """
    prev = getattr(_tls, "manual_axes", frozenset())
    _tls.manual_axes = prev | frozenset(names)
    try:
        yield
    finally:
        _tls.manual_axes = prev


def shard_map(
    f: Callable,
    mesh: Any,
    in_specs: Any,
    out_specs: Any,
    *,
    check_vma: bool = True,
    axis_names: Iterable[str] | None = None,
) -> Callable:
    """``jax.shard_map`` on any jax generation.

    ``check_vma``: the jax >= 0.7 name for replication checking (0.4.x calls
    it ``check_rep``).  ``axis_names``: the mesh axes the body is *manual*
    over (all of them when None); on 0.4.x this is translated to the
    complementary ``auto`` frozenset.
    """
    if HAS_NATIVE_SHARD_MAP:
        kwargs: dict[str, Any] = dict(
            mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    # 0.4.x note: partial-manual (``auto`` nonempty) is broken in that
    # XLA generation — a ppermute inside the body aborts the SPMD
    # partitioner (``IsManualSubgroup`` check).  So the body always runs
    # fully manual here; axes a native-jax caller would leave Auto simply
    # replicate the body's computation (the in/out specs never mention
    # them), which is numerically equivalent.
    manual = frozenset(mesh.axis_names)

    @functools.wraps(f)
    def body(*args, **kw):
        # Whenever jax traces the body, constrain()/auto_axis_names() must
        # see these axes as Manual (native jax encodes that in axis_types).
        with manual_axes_scope(manual):
            return f(*args, **kw)

    return _shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=bool(check_vma), auto=frozenset())


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """A device mesh with Auto-typed axes on every jax generation.

    Unlike bare ``jax.make_mesh``, the product of ``axis_shapes`` may be
    smaller than the local device count — the first ``prod(axis_shapes)``
    devices are used.
    """
    import math

    if devices is None:
        n = math.prod(axis_shapes)
        local = jax.devices()
        if n < len(local):
            devices = local[:n]
    try:
        return jax.make_mesh(
            tuple(axis_shapes), tuple(axis_names), devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(axis_names)))
    except (AttributeError, TypeError):  # pre-AxisType jax (<= 0.4.x)
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                             devices=devices)


def mesh_scope(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    jax >= 0.7: ``jax.set_mesh`` (or ``jax.sharding.use_mesh``); 0.4.x: the
    ``Mesh`` object itself is the context manager.  ``None`` is accepted and
    yields a null context, so callers can write
    ``with mesh_scope(self.mesh):`` unconditionally.
    """
    if mesh is None:
        return contextlib.nullcontext()
    set_mesh = getattr(jax, "set_mesh", None) or getattr(
        jax.sharding, "use_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh  # jax 0.4.x: `with mesh:` installs the resource env


def get_abstract_mesh():
    """The ambient mesh installed by the enclosing :func:`mesh_scope`, or
    ``None`` when no mesh is active.

    The returned object exposes ``.axis_names`` / ``.shape`` and is a valid
    ``mesh=`` argument for :func:`shard_map` (an ``AbstractMesh`` on native
    jax, the physical ``Mesh`` on 0.4.x).
    """
    native = getattr(jax.sharding, "get_abstract_mesh", None)
    if native is not None:
        am = native()
        if am is None or not am.axis_names:
            return None
        return am
    from jax._src import mesh as _mesh_lib  # 0.4.x: Mesh ctx resource env
    pm = _mesh_lib.thread_resources.env.physical_mesh
    if pm is None or pm.empty:
        return None
    return pm


def auto_axis_names(mesh) -> tuple[str, ...]:
    """Mesh axes usable in sharding constraints (Auto-typed).

    Native jax encodes this in ``mesh.axis_types`` (axes made Manual by an
    enclosing shard_map are excluded).  0.4.x meshes carry no axis types
    (``axis_types is None``); there the enclosing :func:`shard_map`'s
    :func:`manual_axes_scope` supplies the Manual set.
    """
    manual = getattr(_tls, "manual_axes", frozenset())
    types = getattr(mesh, "axis_types", None)
    if types is None:
        return tuple(n for n in mesh.axis_names if n not in manual)
    return tuple(n for n, t in zip(mesh.axis_names, types)
                 if "Auto" in str(t) and n not in manual)


def constraints_supported_here() -> bool:
    """Whether ``with_sharding_constraint`` is safe at this trace point.

    Inside a 0.4.x shard_map body the partial-manual SPMD partitioner
    crashes on sharding constraints (``IsManualSubgroup`` check), so
    constraints — which are only performance hints — must be skipped
    there.  Native jax handles them via axis types, where this is always
    True.
    """
    return HAS_NATIVE_SHARD_MAP or not getattr(_tls, "manual_axes",
                                               frozenset())


def mesh_axis_size(mesh, *names: str) -> int:
    """Product of the sizes of ``names`` present in ``mesh`` (1 if none)."""
    if mesh is None:
        return 1
    size = 1
    for n in names:
        if n in mesh.axis_names:
            size *= mesh.shape[n]
    return size
