"""jit wrapper: [B, S, H, Dh] layout, padding, GQA flattening."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    block: int = 128, interpret: bool | None = None):
    """q [B, Sq, Hq, Dh]; k/v [B, Skv, Hkv, Dh] -> [B, Sq, Hq, Dh]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    block_q = min(block, max(8, sq))
    block_k = min(block, max(8, skv))
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k

    # [B, S, H, D] -> [B*H, S, D] with q heads grouped by kv head.
    g = hq // hkv
    q_t = q.transpose(0, 2, 1, 3)                      # [B, Hq, Sq, Dh]
    q_t = q_t.reshape(b * hq, sq, dh)
    k_t = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dh)
    v_t = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, dh)
    if pad_q:
        q_t = jnp.pad(q_t, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k_t = jnp.pad(k_t, ((0, 0), (0, pad_k), (0, 0)))
        v_t = jnp.pad(v_t, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_bhsd(
        q_t, k_t, v_t, causal=causal, block_q=block_q, block_k=block_k,
        q_offset=int(q_offset), kv_len=skv, interpret=interpret)
    out = out[:, :sq].reshape(b, hq, sq, dh).transpose(0, 2, 1, 3)
    return out
