"""Pallas TPU kernel: causal GQA flash attention (forward).

Grid (B*Hq, n_q_blocks, n_kv_blocks); the kv axis is innermost and
sequential on TPU, so the online-softmax state (m, l, acc) lives in VMEM
scratch across kv steps.  GQA is free: the k/v BlockSpec index maps divide
the head index by the group size instead of materializing repeated KV.
MXU alignment: block_q/block_k multiples of 128 (bf16-friendly), head_dim
64/128 rides the lane axis.

Forward-only (serving/prefill); training uses the chunked-scan JAX path
which differentiates natively.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_kv: int, sq: int, skv: int, q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # [block_q, dh]
    k = k_ref[0]                                   # [block_k, dh]
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # [block_q, block_k]

    qpos = (q_offset + iq * block_q
            + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < skv
    if causal:
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                            # [block_q, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)                 # [block_q, 1]
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv

    @pl.when(ik == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "q_offset", "kv_len",
                     "interpret"))
def flash_attention_bhsd(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, block_q: int = 128, block_k: int = 128,
    q_offset: int = 0, kv_len: int | None = None, interpret: bool = False,
) -> jax.Array:
    """q [BHq, Sq, Dh]; k/v [BHkv, Skv, Dh] with BHq = BHkv * G.

    Sq/Skv must be multiples of block_q/block_k (wrapper pads);
    ``kv_len`` is the true (pre-padding) KV length for masking.
    """
    bhq, sq, dh = q.shape
    bhkv, skv, _ = k.shape
    g = bhq // bhkv
    n_q = sq // block_q
    n_kv = skv // block_k
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(dh), causal=causal,
        block_q=block_q, block_k=block_k, n_kv=n_kv, sq=sq,
        skv=kv_len if kv_len is not None else skv,
        q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=(bhq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, iq, ik, g=g: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
