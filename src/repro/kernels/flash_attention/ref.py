"""Oracle: naive causal GQA attention (f32 softmax)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True, q_offset: int = 0):
    """q [B, Sq, Hq, Dh], k/v [B, Skv, Hkv, Dh] -> [B, Sq, Hq, Dh]."""
    b, sq, hq, dh = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qr = q.reshape(b, sq, hkv, g, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(dh)
    if causal:
        qpos = q_offset + jnp.arange(sq)
        kpos = jnp.arange(skv)
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return out.reshape(b, sq, hq, dh)
