"""Pallas TPU kernels for the compute hot-spots.

Each kernel ships three files: ``kernel.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd wrapper; interpret-mode switch for CPU validation),
``ref.py`` (pure-jnp oracle).  Tests sweep shapes/dtypes and assert_allclose
against the oracle with interpret=True.

- subset_combine:  DKS per-node min-plus subset convolution (paper Sec. 5.1,
                   the "most compute intensive task") — single-pass closure
                   in VMEM vs. ceil(log2 m) XLA passes.
- segment_minplus: DKS edge relaxation reduce on a padded-CSR layout with
                   hub splitting (degree decomposition).
- flash_attention: LM train/prefill causal GQA attention.
- embedding_bag:   recsys multi-hot gather-reduce.
"""
