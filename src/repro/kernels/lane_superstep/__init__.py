from repro.kernels.lane_superstep.ops import (  # noqa: F401
    LaneCSR,
    fused_lane_superstep,
    interpret_default,
    lane_csr_from_device_graph,
)
