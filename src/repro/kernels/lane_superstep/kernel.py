"""Pallas kernel: ONE launch for the whole per-superstep inner loop.

The lane driver's jnp superstep lowers to a long XLA op chain per
superstep: an edge gather, ``K`` rounds of segment-min scatter
(``semiring.segment_topk_min``), a sorted-unique merge, and a
``ceil(log2 m)``-pass subset-combine scan — each op re-streaming the
``S[L, V, 2^m, K]`` table through HBM.  This kernel fuses the chain into
a single ``pallas_call`` whose grid is ``(lanes, row blocks)``:

  1. **relax reduce** — per padded-CSR virtual row, the top-K distinct
     min-plus candidates (``kernels/segment_minplus``'s reduce, inlined);
  2. **hub merge** — a segmented Hillis–Steele merge along the row axis
     folds a hub's split rows (rows of one node are contiguous and the
     layout builder never lets them straddle a block);
  3. **receive** — merge with the node's previous table (``topk_merge``);
  4. **combine** — the unrolled popcount-ordered split-pair sweep from
     ``kernels/subset_combine``, reaching full closure in one pass while
     the table stays in VMEM;
  5. **freeze** — a finished lane writes its old table back (per-lane
     freeze masking; ragged frontiers cost nothing — an empty-frontier
     lane just produces all-INF candidates).

Layout (hardware adaptation, same choice as ``subset_combine``): virtual
rows ride the minor 128-wide lane axis — ``cand[L, 2^m, dmax*K, Vv]``,
``S0/out [L, 2^m, K, Vv]`` — so every min/add/select is a full-width
vector op.  VMEM per block: ``2^m * dmax * K * BV * 4B`` for the
candidate tile (m=4, dmax=16, K=2, BV=128 -> 256 KiB).

Bit-identity to the jnp path holds because every stage reduces the same
candidate multiset with the same distinct-top-K semantics: the combine
dependency graph is acyclic in popcount, so the one-sweep closure equals
the jnp scan's ``ceil(log2 m)``-pass fixpoint, float rounding included
(each candidate is a single f32 add of fixpoint values on both paths).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import INF
from repro.core.spa import split_pairs


def _topk_distinct(cand: jnp.ndarray, k: int, axis: int) -> jnp.ndarray:
    """K rounds of (min, mask-equal) along ``axis``: the k smallest
    *distinct* values, sorted ascending, INF-padded — exactly
    ``semiring.segment_topk_min``'s per-cell semantics, vectorized."""
    outs = []
    for _ in range(k):
        cur = jnp.minimum(jnp.min(cand, axis=axis), INF)
        outs.append(cur)
        cand = jnp.where(cand <= jnp.expand_dims(cur, axis), INF, cand)
    return jnp.stack(outs, axis=axis)


def _merge2(a: jnp.ndarray, b: jnp.ndarray, k: int) -> jnp.ndarray:
    """topk_merge of two [..., K, BV] tables along the K axis."""
    return _topk_distinct(jnp.concatenate([a, b], axis=-2), k, axis=-2)


def _lane_step_kernel(seg_ref, done_ref, cand_ref, s0_ref, out_ref,
                      *, m: int, k: int, bv: int):
    """One (lane, row-block) grid step.

    seg_ref:  i32[1, BV]   node id per virtual row (-1 on pad rows)
    done_ref: i32[1, 1]    this lane's freeze flag
    cand_ref: f32[1, 2^m, dmax*K, BV]  min-plus candidates
    s0_ref:   f32[1, 2^m, K, BV]       pre-relax table, gathered per row
    out_ref:  f32[1, 2^m, K, BV]       post-combine table (valid at each
                                       node's tail row)
    """
    cand = cand_ref[0]                              # [F, C, BV]
    s0 = s0_ref[0]                                  # [F, K, BV]
    seg = seg_ref[0]                                # [BV]

    # 1) per-row relax reduce: top-K distinct over the candidate axis.
    r = _topk_distinct(cand, k, axis=1)             # [F, K, BV]

    # 2) segmented hub merge along rows.  The merge is associative and
    #    idempotent, so an inclusive Hillis–Steele scan leaves the full
    #    per-node merge at each segment's LAST row (the tail row the
    #    host gathers).  Pad rows (seg == -1) never join a segment.
    shift = 1
    while shift < bv:
        prev = jnp.concatenate(
            [jnp.full(r.shape[:-1] + (shift,), INF, r.dtype),
             r[..., :-shift]], axis=-1)
        pseg = jnp.concatenate(
            [jnp.full((shift,), -2, seg.dtype), seg[:-shift]], axis=0)
        same = (seg == pseg) & (seg >= 0)           # [BV]
        r = jnp.where(same[None, None, :], _merge2(r, prev, k), r)
        shift *= 2

    # 3) receive: merge what arrived with the node's previous table.
    s = _merge2(r, s0, k)                           # [F, K, BV]

    # 4) subset-combine sweep (popcount order -> closure in one pass).
    for t, a, b in split_pairs(m):
        pair = s[a][:, None, :] + s[b][None, :, :]  # [K, K, BV]
        pair = jnp.minimum(pair, INF)
        cand_t = jnp.concatenate(
            [s[t], pair.reshape(k * k, -1)], axis=0)  # [K+K^2, BV]
        s = s.at[t].set(_topk_distinct(cand_t, k, axis=0))

    # 5) per-lane freeze: a finished lane keeps its pre-step table.
    frozen = done_ref[0, 0] != 0
    out_ref[0] = jnp.where(frozen, s0, s)


@functools.partial(jax.jit,
                   static_argnames=("m", "block_v", "interpret"))
def fused_lane_step(
    cand_t: jax.Array,   # f32[L, 2^m, dmax*K, Vv]
    s0_t: jax.Array,     # f32[L, 2^m, K, Vv]
    seg: jax.Array,      # i32[1, Vv]
    done: jax.Array,     # i32[L, 1]
    m: int,
    block_v: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """The fused superstep body as ONE pallas launch over
    ``grid = (lanes, Vv / block_v)``.  Returns f32[L, 2^m, K, Vv]."""
    lanes, n_sets, c, vv = cand_t.shape
    k = s0_t.shape[2]
    assert n_sets == 1 << m and vv % block_v == 0
    grid = (lanes, vv // block_v)
    return pl.pallas_call(
        functools.partial(_lane_step_kernel, m=m, k=k, bv=block_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_v), lambda l, i: (0, i)),
            pl.BlockSpec((1, 1), lambda l, i: (l, 0)),
            pl.BlockSpec((1, n_sets, c, block_v), lambda l, i: (l, 0, 0, i)),
            pl.BlockSpec((1, n_sets, k, block_v), lambda l, i: (l, 0, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, n_sets, k, block_v),
                               lambda l, i: (l, 0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((lanes, n_sets, k, vv), cand_t.dtype),
        interpret=interpret,
    )(seg, done, cand_t, s0_t)
