"""Host-facing wrapper for the fused lane-superstep kernel.

Two pieces:

- :class:`LaneCSR` / :func:`lane_csr_from_device_graph` — the padded-CSR
  layout the kernel consumes, built ONCE per graph on the host (numpy)
  and cached by ``QueryEngine.build``.  It is ``segment_minplus``'s
  ``PaddedCSR`` idea (per-destination padded rows, hubs split into
  ``ceil(d / dmax)`` virtual rows) with one extra invariant: a node's
  rows are **block-aligned** — they never straddle a ``block_v``
  boundary — so the kernel's in-block segmented scan always produces the
  complete hub merge at the node's tail row, and no second-level jnp
  merge is needed.

- :func:`fused_lane_superstep` — the drop-in replacement for the lane
  driver's vmapped :func:`~repro.core.dks.superstep` on dense graphs:
  XLA gathers build the candidate tensor (weights straight from the
  ``DeviceGraph``, so :class:`~repro.graph.weights.WeightPolicy`
  effective weights flow in untouched), ONE ``pallas_call`` runs
  relax + hub merge + receive + combine + per-lane freeze
  (:mod:`.kernel`), and the shared jnp tail
  (:func:`~repro.core.dks.finish_superstep`) recomputes the frontier,
  aggregators, and exit check — bit-identical to the jnp superstep.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import INF
from repro.core import semiring
from repro.core.dks import DKSConfig, DKSState, finish_superstep
from repro.kernels.lane_superstep.kernel import fused_lane_step


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LaneCSR:
    """Block-aligned padded CSR over the *symmetrized padded* node space.

    Attributes:
      src_pad: i32[Vv, dmax] source node per candidate slot (0 on pads).
      w_pad:   f32[Vv, dmax] effective edge weight (INF on pads).
      gather_of: i32[Vv] owning real node per virtual row (0 on pad
        rows — their candidates are all INF, so the gathered table is
        never consumed).
      seg:     i32[Vv] owning real node per row, -1 on pad rows (the
        kernel's segment ids; distinct from ``gather_of`` so pad rows
        never join a real segment).
      tail_row: i32[v_pad] LAST virtual row of each node — where the
        kernel's segmented scan leaves the complete merge.
      dmax / block_v / n_rows: static layout parameters.
    """

    src_pad: jax.Array
    w_pad: jax.Array
    gather_of: jax.Array
    seg: jax.Array
    tail_row: jax.Array
    dmax: int = dataclasses.field(metadata=dict(static=True))
    block_v: int = dataclasses.field(metadata=dict(static=True))
    n_rows: int = dataclasses.field(metadata=dict(static=True))


def lane_csr_from_device_graph(graph, dmax: int = 16,
                               block_v: int = 128) -> LaneCSR:
    """Build the kernel layout from a dense :class:`DeviceGraph`.

    Host-side numpy, paid once per ``QueryEngine.build``.  ``dmax``
    auto-bumps so every node fits in at most ``block_v`` virtual rows
    (the block-alignment invariant is unconditional).
    """
    valid = np.asarray(graph.valid)
    src = np.asarray(graph.src)[valid].astype(np.int64)
    dst = np.asarray(graph.dst)[valid].astype(np.int64)
    w = np.asarray(graph.w)[valid].astype(np.float32)
    n = int(graph.v_pad)

    deg = np.bincount(dst, minlength=n).astype(np.int64)
    max_deg = int(deg.max()) if deg.size else 0
    if max_deg > dmax * block_v:
        dmax = int(np.ceil(max_deg / block_v))
    rows = np.maximum(1, -(-deg // dmax))           # ceil, >= 1 row/node

    # Block-aligned row starts: advance to the next block boundary when a
    # node's rows would straddle it.
    row0 = np.zeros(n, np.int64)
    cur = 0
    for v in range(n):
        if (cur % block_v) + rows[v] > block_v:
            cur = (cur // block_v + 1) * block_v
        row0[v] = cur
        cur += rows[v]
    n_rows = max(block_v, int(np.ceil(cur / block_v)) * block_v)

    seg = np.full(n_rows, -1, np.int32)
    starts = np.cumsum(rows) - rows
    row_idx = np.repeat(row0, rows) + (np.arange(rows.sum()) -
                                       np.repeat(starts, rows))
    seg[row_idx] = np.repeat(np.arange(n, dtype=np.int32), rows)
    tail_row = (row0 + rows - 1).astype(np.int32)

    src_pad = np.zeros((n_rows, dmax), np.int32)
    w_pad = np.full((n_rows, dmax), INF, np.float32)
    order = np.argsort(dst, kind="stable")
    ds, ss, ws = dst[order], src[order], w[order]
    estart = np.cumsum(deg) - deg
    within = np.arange(ds.size) - estart[ds]
    r, c = row0[ds] + within // dmax, within % dmax
    src_pad[r, c] = ss.astype(np.int32)
    w_pad[r, c] = ws

    return LaneCSR(
        src_pad=jnp.asarray(src_pad), w_pad=jnp.asarray(w_pad),
        gather_of=jnp.asarray(np.maximum(seg, 0).astype(np.int32)),
        seg=jnp.asarray(seg), tail_row=jnp.asarray(tail_row),
        dmax=int(dmax), block_v=int(block_v), n_rows=int(n_rows),
    )


def interpret_default() -> bool:
    """Pallas interpret mode unless a real TPU backs the default device
    (same auto-detection as the other kernel packages).  Benchmarks
    record this flag so CPU rows are never mistaken for device rows."""
    return jax.default_backend() != "tpu"


def fused_lane_superstep(graph, csr: LaneCSR, state: DKSState,
                         cfg: DKSConfig,
                         interpret: bool | None = None) -> DKSState:
    """One superstep for every lane, inner loop as ONE kernel launch.

    ``state``: lane-batched (``S[L, V, 2^m, K]``, ``done[L]``, ...).
    Returns the stepped state *without* the driver's cross-lane freeze
    select — :func:`~repro.core.driver.lane_superstep` applies
    ``freeze_lanes`` exactly as on the jnp path (the kernel's own
    per-lane freeze keeps a finished lane's table; the driver select
    keeps its counters).
    """
    if interpret is None:
        interpret = interpret_default()
    S0 = state.S                                    # [L, V, F, K]
    lanes = S0.shape[0]
    f, k = cfg.n_sets, cfg.k

    deg = graph.out_degree.astype(jnp.float32)
    n_bfs = jnp.sum(jnp.where(state.first_fire, deg, 0.0), axis=1)
    n_deep = jnp.sum(
        jnp.where(state.changed & ~state.first_fire, deg, 0.0), axis=1)

    # Candidate gather (XLA): cand[l, row, slot] = S0[l, src] + w, masked
    # by the sender's active flag — identical candidate multiset to the
    # jnp relax (invalid edges carry w=INF and bump to INF either way).
    src_flat = csr.src_pad.reshape(-1)              # [Vv*dmax]
    fire = jnp.take(state.changed, src_flat, axis=1)
    cand = (jnp.take(S0, src_flat, axis=1)
            + csr.w_pad.reshape(-1)[None, :, None, None])
    cand = jnp.where(fire[:, :, None, None], cand, INF)
    cand = semiring.bump_to_inf(cand)
    cand = cand.reshape(lanes, csr.n_rows, csr.dmax, f, k)
    cand_t = cand.transpose(0, 3, 2, 4, 1).reshape(
        lanes, f, csr.dmax * k, csr.n_rows)

    s0_t = jnp.take(S0, csr.gather_of, axis=1).transpose(0, 2, 3, 1)
    done_i = state.done.astype(jnp.int32).reshape(lanes, 1)

    out_t = fused_lane_step(cand_t, s0_t, csr.seg[None, :], done_i,
                            m=cfg.m, block_v=csr.block_v,
                            interpret=interpret)   # [L, F, K, Vv]
    S1 = jnp.take(out_t, csr.tail_row, axis=3).transpose(0, 3, 1, 2)

    nxt = dataclasses.replace(
        state,
        S=S1,
        msgs_bfs=state.msgs_bfs + n_bfs,
        msgs_deep=state.msgs_deep + n_deep,
        step=state.step + 1,
    )
    return jax.vmap(
        lambda s0, st: finish_superstep(graph, s0, st, cfg))(S0, nxt)
