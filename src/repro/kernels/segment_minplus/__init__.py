from repro.kernels.segment_minplus.ops import (  # noqa: F401
    padded_csr_from_graph, segment_minplus,
)
