"""Pallas TPU kernel: padded-CSR top-K min-plus reduce (DKS relax receive).

Hardware adaptation: JAX segment_min over power-law edge lists is a scatter
— bad on TPU.  The graph substrate re-lays edges as a *padded CSR* with hub
splitting ("degree decomposition"): every (virtual) destination owns at most
DMAX candidate rows, so the reduce is a dense [BV, C, F] -> [BV, F, K]
block op: K rounds of (min over the candidate axis, mask equals), every op
a full-width VPU vector.  Hub nodes split into ceil(d/DMAX) virtual rows and
a cheap second-level merge (jnp) combines them.

VMEM per block: BV * C * F * 4B  (BV=8, C=128, F=16 -> 64 KiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import INF


def _reduce_kernel(cand_ref, out_ref, *, k: int):
    """cand_ref: [BV, C, F] -> out_ref: [BV, F, K]."""
    cand = cand_ref[...]
    outs = []
    for _ in range(k):
        cur = jnp.min(cand, axis=1)                    # [BV, F]
        outs.append(cur)
        cand = jnp.where(cand <= cur[:, None, :], INF, cand)
    out_ref[...] = jnp.stack(outs, axis=-1)            # [BV, F, K]


@functools.partial(jax.jit, static_argnames=("k", "block_v", "interpret"))
def padded_topk(
    cand: jax.Array, k: int, block_v: int = 8, interpret: bool = False,
) -> jax.Array:
    """cand: [Vv, C, F] (Vv multiple of block_v) -> [Vv, F, K]."""
    vv, c, f = cand.shape
    assert vv % block_v == 0, (vv, block_v)
    grid = (vv // block_v,)
    return pl.pallas_call(
        functools.partial(_reduce_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((block_v, c, f), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_v, f, k), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((vv, f, k), cand.dtype),
        interpret=interpret,
    )(cand)
