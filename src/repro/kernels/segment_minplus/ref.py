"""Oracle for the padded-CSR top-K min reduce.

Input: per-virtual-node candidate matrix ``cand[Vv, DMAX*K, F]`` (INF on
padding).  Output: per virtual node and feature, the K smallest *distinct*
candidates, sorted ascending, INF padded — i.e. the DKS "receive messages"
reduce on the degree-decomposed layout.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.semiring import sorted_unique_k


def padded_topk_ref(cand: jnp.ndarray, k: int) -> jnp.ndarray:
    """cand: [Vv, C, F] -> [Vv, F, K]."""
    x = jnp.swapaxes(cand, 1, 2)           # [Vv, F, C]
    return sorted_unique_k(x, k)
