"""Wrapper: DKS relax via padded CSR + hub splitting + the Pallas reduce.

``padded_csr_from_graph`` (host, numpy) builds the degree-decomposed layout
once per graph; ``segment_minplus`` runs each superstep: XLA gather of
source tables (+w), Pallas padded top-K reduce, jnp second-level merge of
split hubs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import INF
from repro.core import semiring
from repro.kernels.segment_minplus.kernel import padded_topk


@dataclasses.dataclass(frozen=True)
class PaddedCSR:
    """Degree-decomposed incoming-edge layout.

    src_pad:  i32[Vv, DMAX]  source node per candidate slot (0 on padding)
    w_pad:    f32[Vv, DMAX]  edge length (INF on padding)
    real_of:  i32[Vv]        owning real node of each virtual row
    dmax:     int
    n_virtual:int
    """

    src_pad: jax.Array
    w_pad: jax.Array
    real_of: jax.Array
    dmax: int
    n_virtual: int


def padded_csr_from_graph(src: np.ndarray, dst: np.ndarray, w: np.ndarray,
                          n_nodes: int, dmax: int = 64,
                          pad_rows_to: int = 8) -> PaddedCSR:
    """Build per-destination padded rows, splitting hubs over >1 row."""
    order = np.argsort(dst, kind="stable")
    src, dst, w = src[order], dst[order], w[order]
    deg = np.bincount(dst, minlength=n_nodes)
    rows_per = np.maximum(1, -(-deg // dmax))
    n_virt = int(rows_per.sum())
    n_virt_pad = int(-(-n_virt // pad_rows_to) * pad_rows_to)
    src_pad = np.zeros((n_virt_pad, dmax), np.int32)
    w_pad = np.full((n_virt_pad, dmax), INF, np.float32)
    real_of = np.zeros(n_virt_pad, np.int32)
    row_start = np.concatenate([[0], np.cumsum(rows_per)])
    edge_start = np.concatenate([[0], np.cumsum(deg)])
    for v in range(n_nodes):
        e0, e1 = edge_start[v], edge_start[v + 1]
        r0 = row_start[v]
        for j, e in enumerate(range(e0, e1)):
            r, c = divmod(j, dmax)
            src_pad[r0 + r, c] = src[e]
            w_pad[r0 + r, c] = w[e]
        for r in range(row_start[v], row_start[v + 1]):
            real_of[r] = v
    real_of[n_virt:] = 0
    w_pad[n_virt:] = INF
    return PaddedCSR(
        src_pad=jnp.asarray(src_pad), w_pad=jnp.asarray(w_pad),
        real_of=jnp.asarray(real_of), dmax=dmax, n_virtual=n_virt_pad)


def segment_minplus_padded(
    S: jax.Array, csr: PaddedCSR, changed: jax.Array, k: int,
    n_nodes: int, block_v: int = 8, interpret: bool | None = None,
) -> jax.Array:
    """One relax step: S[V, F, K] tables -> R[V, F, K] received tables."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    v, f, _ = S.shape
    vv, dmax = csr.src_pad.shape
    # Gather source tables (+ edge length) — XLA gather, streams well.
    src_flat = csr.src_pad.reshape(-1)
    fire = changed[src_flat]
    cand = S[src_flat] + csr.w_pad.reshape(-1)[:, None, None]
    cand = jnp.where(fire[:, None, None], cand, INF)
    cand = semiring.bump_to_inf(cand)
    cand = cand.reshape(vv, dmax, f, k)
    cand = cand.transpose(0, 1, 3, 2).reshape(vv, dmax * k, f)
    red = padded_topk(cand, k, block_v=block_v, interpret=interpret)  # [Vv,F,K]
    # Second-level merge of split hubs (few rows per real node).
    out = jnp.full((n_nodes, f, k), INF, S.dtype)
    flat = red.transpose(0, 2, 1).reshape(vv * k, f)   # rows (virt, slot)
    seg = jnp.repeat(csr.real_of, k)
    return semiring.segment_topk_min(flat, seg, n_nodes, k)


def segment_minplus(S, src, dst, w, changed, v_pad, k):
    """Engine-compatible signature (graph edge-list); builds candidates via
    gather and reduces with the K-round jnp path.  The padded-CSR Pallas
    path is selected by the engine when a PaddedCSR is attached."""
    send = changed[src]
    cand = S[src] + w[:, None, None]
    cand = jnp.where(send[:, None, None], cand, INF)
    cand = semiring.bump_to_inf(cand)
    e_pad, n, kk = cand.shape
    vals = cand.transpose(0, 2, 1).reshape(e_pad * kk, n)
    seg = jnp.repeat(dst, kk)
    return semiring.segment_topk_min(vals, seg, v_pad, kk)
