"""jit wrapper: engine-layout in/out, TPU kernel or interpret fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import INF
from repro.kernels.subset_combine.kernel import subset_combine_t


def _pad_nodes(s_t: jax.Array, block_v: int) -> tuple[jax.Array, int]:
    v = s_t.shape[-1]
    pad = (-v) % block_v
    if pad:
        s_t = jnp.pad(s_t, ((0, 0), (0, 0), (0, pad)),
                      constant_values=INF)
    return s_t, v


def subset_combine(S: jax.Array, m: int, n_passes_unused: int = 0,
                   block_v: int = 512, interpret: bool | None = None) -> jax.Array:
    """Engine layout S [V, 2^m, K] -> closed table, via the Pallas kernel.

    One kernel pass reaches closure (in-kernel sequential popcount sweep),
    so ``n_passes_unused`` from the jnp path is ignored.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    s_t = jnp.transpose(S, (1, 2, 0))          # [2^m, K, V]
    s_t, v = _pad_nodes(s_t, block_v)
    out = subset_combine_t(s_t, m, block_v=block_v, interpret=interpret)
    out = out[:, :, :v]
    return jnp.transpose(out, (2, 0, 1))
