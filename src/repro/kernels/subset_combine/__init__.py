from repro.kernels.subset_combine.ops import subset_combine  # noqa: F401
