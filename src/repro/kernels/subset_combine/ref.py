"""Pure-jnp oracle for the min-plus subset convolution (top-K distinct).

Semantics: for every node v and every split a ⊎ b = t,
``S[v, t] <- topk_unique(S[v, t] ∪ (S[v, a] ⊕ S[v, b]))`` iterated to
closure (popcount order ⇒ one sequential sweep suffices).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro import INF
from repro.core.semiring import outer_combine, topk_merge
from repro.core.spa import split_pairs


def subset_combine_ref(S: jnp.ndarray, m: int) -> jnp.ndarray:
    """S: [V, 2^m, K] -> closed [V, 2^m, K] (sequential, exact)."""
    for t, a, b in split_pairs(m):
        cand = outer_combine(S[:, a, :], S[:, b, :])
        S = S.at[:, t, :].set(topk_merge(S[:, t, :], cand))
    return S
