"""Pallas TPU kernel: per-node min-plus subset convolution with top-K.

Layout choice (hardware adaptation): the engine's ``S[V, 2^m, K]`` puts K
(2..4) in the minor dim — hostile to the 8x128 VPU registers.  The kernel
operates on the transposed ``S_t[2^m, K, V]`` so nodes ride the 128-wide
lane axis and every min/add/select is a full-width vector op.  The (t,a,b)
split-pair loop is unrolled in popcount order *inside* the kernel, so one
grid step reaches full closure for its node block while the table stays in
VMEM — the jnp fallback needs ceil(log2 m) passes, each re-streaming S
through HBM.

VMEM per block: 2^m * K * BV * 4B  (m=6, K=4, BV=1024 -> 1 MiB) plus the
[K, K, BV] outer-sum scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import INF
from repro.core.spa import split_pairs


def _topk_unique_rows(cand: jnp.ndarray, k: int) -> jnp.ndarray:
    """cand: [n, BV] -> [k, BV]: per-column k smallest distinct values.

    K rounds of (column-min, mask-equal) — every op is lane-vectorized.
    """
    outs = []
    for _ in range(k):
        cur = jnp.min(cand, axis=0)                    # [BV]
        outs.append(cur)
        cand = jnp.where(cand <= cur[None, :], INF, cand)
    return jnp.stack(outs, axis=0)                     # [k, BV]


def _combine_kernel(s_ref, o_ref, *, m: int, k: int):
    """s_ref/o_ref: [2^m, K, BV] block in VMEM."""
    s = s_ref[...]
    for t, a, b in split_pairs(m):
        av = s[a]                                      # [K, BV]
        bv = s[b]
        pair = av[:, None, :] + bv[None, :, :]         # [K, K, BV]
        pair = jnp.minimum(pair, INF)
        cand = jnp.concatenate(
            [s[t], pair.reshape(k * k, -1)], axis=0)   # [K+K^2, BV]
        s = s.at[t].set(_topk_unique_rows(cand, k))
    o_ref[...] = s


@functools.partial(jax.jit, static_argnames=("m", "block_v", "interpret"))
def subset_combine_t(
    s_t: jax.Array, m: int, block_v: int = 512, interpret: bool = False,
) -> jax.Array:
    """s_t: [2^m, K, V] (V multiple of block_v) -> closed table."""
    n_sets, k, v = s_t.shape
    assert n_sets == 1 << m and v % block_v == 0
    grid = (v // block_v,)
    return pl.pallas_call(
        functools.partial(_combine_kernel, m=m, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((n_sets, k, block_v),
                               lambda i: (0, 0, i))],
        out_specs=pl.BlockSpec((n_sets, k, block_v), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct(s_t.shape, s_t.dtype),
        interpret=interpret,
    )(s_t)
