"""Pallas TPU kernel: EmbeddingBag (multi-hot gather-reduce).

The table stays in HBM (memory_space=ANY); each grid step owns a block of
bags, walks its nnz ids and accumulates rows in VMEM.  Ids ride in SMEM so
the row index is a scalar read.  A production kernel would double-buffer
the row DMAs (make_async_copy); this single-stream version keeps the same
interface and validates in interpret mode — the roofline for this op is
pure HBM bandwidth either way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(ids_ref, w_ref, table_ref, out_ref, *, nnz: int, block_b: int,
                mean: bool):
    for i in range(block_b):
        acc = jnp.zeros((1, out_ref.shape[1]), jnp.float32)
        cnt = jnp.zeros((), jnp.float32)
        for j in range(nnz):
            idx = ids_ref[i, j]
            valid = idx >= 0
            safe = jnp.maximum(idx, 0)
            row = table_ref[pl.dslice(safe, 1), :]
            wj = w_ref[i, j]
            acc = acc + jnp.where(valid, row.astype(jnp.float32) * wj, 0.0)
            cnt = cnt + jnp.where(valid, 1.0, 0.0)
        if mean:
            acc = acc / jnp.maximum(cnt, 1.0)
        out_ref[i, :] = acc[0].astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("mode", "block_b", "interpret"))
def embedding_bag_kernel(
    table: jax.Array, ids: jax.Array, weights: jax.Array,
    mode: str = "sum", block_b: int = 8, interpret: bool = False,
) -> jax.Array:
    b, nnz = ids.shape
    v, d = table.shape
    assert b % block_b == 0
    kernel = functools.partial(_bag_kernel, nnz=nnz, block_b=block_b,
                               mean=(mode == "mean"))
    return pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, nnz), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, nnz), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(ids, weights, table)
