"""jit wrapper for the EmbeddingBag kernel (padding + default weights)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_kernel


def embedding_bag(table, ids, weights=None, mode: str = "sum",
                  block_b: int = 8, interpret: bool | None = None):
    """table [V, D]; ids [B, nnz] (-1 pad) -> [B, D]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, nnz = ids.shape
    pad = (-b) % block_b
    if weights is None:
        weights = jnp.ones_like(ids, jnp.float32)
    if pad:
        ids = jnp.pad(ids, ((0, pad), (0, 0)), constant_values=-1)
        weights = jnp.pad(weights, ((0, pad), (0, 0)))
    out = embedding_bag_kernel(table, ids, weights, mode=mode,
                               block_b=block_b, interpret=interpret)
    return out[:b]
