"""Oracle: EmbeddingBag (gather + masked weighted segment reduce)."""

from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, ids, weights=None, mode: str = "sum"):
    """table [V, D]; ids [B, nnz] (-1 pad); weights [B, nnz] | None."""
    b, nnz = ids.shape
    valid = ids >= 0
    safe = jnp.where(valid, ids, 0)
    rows = jnp.take(table, safe.reshape(-1), axis=0).reshape(b, nnz, -1)
    if weights is not None:
        rows = rows * weights[..., None]
    rows = jnp.where(valid[..., None], rows, 0.0)
    out = jnp.sum(rows, axis=1)
    if mode == "mean":
        out = out / jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
    return out
