"""Gradient compression: int8 ring all-reduce with error feedback.

On a 2-pod mesh the cross-pod (DCI) hop is the scarce resource; int8
quantization cuts gradient wire bytes 4x vs f32.  Implementation is a
shard_map ring over the chosen axis using ``jax.lax.ppermute`` on int8
chunks (reduce-scatter phase) followed by an int8 all-gather phase —
the same two-phase schedule NCCL/ICI rings use, so the dry-run's
collective-permute bytes reflect the real wire traffic.

Error feedback (Seide et al. '14 / EF21): the quantization residual is
carried to the next step, making the compressed SGD convergent where plain
quantized gradients stall.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import shardmap


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompressionState:
    error: Any          # residual carry, same tree as grads


def init_compression(grads: Any) -> CompressionState:
    return CompressionState(
        error=jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _ring_allreduce_int8(x: jax.Array, axis: str, n: int) -> jax.Array:
    """Two-phase ring all-reduce; payload quantized per hop.

    x: f32[n*chunk] (flat, padded). Returns the mean over the axis.
    """
    chunk = x.shape[0] // n
    xs = x.reshape(n, chunk)
    idx = jax.lax.axis_index(axis)

    # Phase 1: reduce-scatter. After n-1 hops, device i owns the full sum of
    # chunk (i+1) mod n.
    def rs_step(j, xs):
        send_idx = (idx - j) % n
        q, s = _quantize(xs[send_idx])
        perm = [(k, (k + 1) % n) for k in range(n)]
        q = jax.lax.ppermute(q, axis, perm)
        s = jax.lax.ppermute(s, axis, perm)
        recv_idx = (idx - j - 1) % n
        return xs.at[recv_idx].add(q.astype(jnp.float32) * s)

    xs = jax.lax.fori_loop(0, n - 1, rs_step, xs)

    # Phase 2: all-gather the reduced chunks around the ring.
    def ag_step(j, xs):
        send_idx = (idx + 1 - j) % n
        q, s = _quantize(xs[send_idx])
        perm = [(k, (k + 1) % n) for k in range(n)]
        q = jax.lax.ppermute(q, axis, perm)
        s = jax.lax.ppermute(s, axis, perm)
        recv_idx = (idx - j) % n
        return xs.at[recv_idx].set(q.astype(jnp.float32) * s)

    xs = jax.lax.fori_loop(0, n - 1, ag_step, xs)
    return xs.reshape(-1) / n


def compressed_allreduce(
    grads: Any, state: CompressionState, mesh, axis: str = "data",
) -> tuple[Any, CompressionState]:
    """Mean-all-reduce ``grads`` over ``axis`` with int8 ring + error
    feedback.  grads enter sharded/replicated per their usual specs; each
    leaf is flattened, padded to the ring size and reduced."""
    n = mesh.shape[axis]
    if n == 1:
        return grads, state

    def leaf_reduce(g_and_e):
        g, e = g_and_e

        def block(gl, el):
            x = gl.reshape(-1).astype(jnp.float32) + el.reshape(-1)
            pad = (-x.shape[0]) % n
            xp = jnp.pad(x, (0, pad))
            red = _ring_allreduce_int8(xp, axis, n)
            red = red[: x.shape[0]]
            new_e = x - red  # local error feedback (what the wire lost)
            return (red.reshape(gl.shape).astype(gl.dtype),
                    new_e.reshape(gl.shape))

        other = tuple(a for a in mesh.axis_names if a != axis)
        return shardmap.shard_map(
            block, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False,
        )(g, e)

    pairs = jax.tree_util.tree_map(
        lambda g, e: leaf_reduce((g, e)), grads, state.error,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, tuple))
    new_grads = jax.tree_util.tree_map(
        lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree_util.tree_map(
        lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, CompressionState(error=new_err)
