"""Fault tolerance & straggler mitigation for the step loop.

TPU pods fail as whole slices; the recovery model is checkpoint/restart
(handled by repro.checkpoint).  What the *step loop* owns:

- :class:`StepGuard` — per-step deadline + retry.  A step that throws a
  transient runtime error (preemption, ICI timeout surfaced as
  XlaRuntimeError) is retried from the last good state up to
  ``max_retries``; a step exceeding the deadline is logged as a straggler
  event and, past ``straggler_patience`` consecutive events, escalates to
  a checkpoint-now signal so the controller can replace the slow host.
- :class:`StragglerPolicy` — EMA of step times; flags steps slower than
  ``threshold`` x the EMA (the standard fleet-level detection signal).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable


@dataclasses.dataclass
class StragglerPolicy:
    threshold: float = 2.0
    ema_decay: float = 0.9
    patience: int = 3
    _ema: float | None = None
    _consecutive: int = 0

    def observe(self, step_time: float) -> bool:
        """Returns True if this step is a straggler event."""
        if self._ema is None:
            self._ema = step_time
            return False
        is_straggler = step_time > self.threshold * self._ema
        # Slow steps should not poison the baseline.
        if not is_straggler:
            self._ema = (self.ema_decay * self._ema
                         + (1 - self.ema_decay) * step_time)
            self._consecutive = 0
        else:
            self._consecutive += 1
        return is_straggler

    @property
    def should_escalate(self) -> bool:
        return self._consecutive >= self.patience


@dataclasses.dataclass
class StepGuard:
    """Wraps a jitted step with retry + straggler accounting."""

    max_retries: int = 2
    straggler: StragglerPolicy = dataclasses.field(
        default_factory=StragglerPolicy)
    on_retry: Callable[[int, BaseException], None] | None = None
    events: list = dataclasses.field(default_factory=list)

    def run(self, step_fn: Callable, state: Any, *args) -> tuple[Any, Any, dict]:
        """Returns (new_state, aux, info).  On failure, retries from the
        SAME input state (the functional step makes replay trivial —
        this is the Pregel superstep-recovery model the paper inherits
        from Giraph, applied to training)."""
        last_exc: BaseException | None = None
        for attempt in range(self.max_retries + 1):
            t0 = time.perf_counter()
            try:
                import jax
                out = step_fn(state, *args)
                out = jax.block_until_ready(out)
                dt = time.perf_counter() - t0
                info = {
                    "step_time_s": dt,
                    "straggler": self.straggler.observe(dt),
                    "escalate_checkpoint": self.straggler.should_escalate,
                    "retries": attempt,
                }
                if info["straggler"]:
                    self.events.append(("straggler", dt))
                new_state, aux = out
                return new_state, aux, info
            except Exception as e:  # noqa: BLE001 — runtime faults retried
                last_exc = e
                self.events.append(("retry", repr(e)))
                if self.on_retry is not None:
                    self.on_retry(attempt, e)
        raise RuntimeError(
            f"step failed after {self.max_retries + 1} attempts"
        ) from last_exc
