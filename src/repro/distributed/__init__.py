from repro.distributed.compression import (  # noqa: F401
    CompressionState, compressed_allreduce, init_compression,
)
from repro.distributed.fault import StepGuard, StragglerPolicy  # noqa: F401
