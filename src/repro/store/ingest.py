"""Streaming ingestion: LOD dumps -> (Graph, InvertedIndex) in bounded
memory.

The paper's experiments run on real RDF dumps (sec-rdfabout: 460k nodes;
bluk-bnb: 16.1M nodes / 46.6M edges) — graphs that arrive as text, not as
numpy arrays.  This module turns such dumps into the host objects
:mod:`repro.store.artifact` persists:

- **readers** for N-Triples (``<s> <p> <o> .``, with an optional numeric
  4th term read as a per-statement confidence) and TSV edge lists
  (``src dst [pred] [conf]``), both line-streamed (``.gz`` transparently
  supported) — nothing holds the raw text;
- **dictionary encoding**: entity and predicate strings become dense int32
  ids the moment they are seen; node label text (a URI's local name, a
  literal's text) feeds the inverted index at finalization; the predicate
  dictionary survives into the graph (``pred_names``) and the artifact
  manifest, so artifacts are self-describing;
- **typed channel**: every accumulated edge carries ``(pred_id, conf)``
  next to its endpoints; untyped sources leave the channel dormant
  (``pred=-1, conf=1.0``) and finalize to a plain single-weight graph —
  byte-identical to the pre-typed pipeline;
- **chunked edge accumulation**: edges land in fixed-size int32 chunks
  (optionally spilled to ``.npy`` files under ``spill_dir`` once
  ``spill_after`` chunks are resident), so raw text never accumulates and
  the working set *during accumulation* is the dictionary + labels + one
  chunk.  Finalization still materializes the full int32 edge array
  (O(E) — spilled chunks are streamed into a single preallocated buffer,
  so there is no transient second copy; fully out-of-core finalize is
  future work);
- **finalization** emits the paper's degree-derived edge weights
  (``w = max(1, int(log10 d_in))``, INF above the hub cutoff ``tau`` —
  :func:`repro.graph.structure.degree_weights`) and the symmetrized CSR
  via :func:`repro.graph.structure.build_graph`.

``from_graph`` wraps an already-materialized synthetic graph in the same
:class:`IngestResult` envelope, with honest counts (``edges_requested`` vs
produced — the generator-side contract the fixed ``rmat_edges`` upholds).
"""

from __future__ import annotations

import dataclasses
import gzip
import re
import time
from pathlib import Path
from typing import Any, Iterable, Iterator

import numpy as np

from repro.graph.index import InvertedIndex
from repro.graph.structure import Graph, build_graph

_CHUNK_EDGES = 1 << 20


@dataclasses.dataclass
class IngestStats:
    """True counts out of an ingestion run (recorded in the artifact
    manifest, so an artifact documents what its source actually held)."""

    source: str
    lines_read: int = 0
    statements: int = 0           # parsed edge rows / triples
    malformed_lines: int = 0
    self_loops_dropped: int = 0
    edges_requested: int | None = None   # synthetic sources only
    edges_directed: int = 0
    n_nodes: int = 0
    n_predicates: int = 0
    chunks: int = 0
    spilled_chunks: int = 0
    ingest_s: float = 0.0

    @property
    def edges_per_s(self) -> float:
        return self.edges_directed / self.ingest_s if self.ingest_s else 0.0

    def as_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["edges_per_s"] = round(self.edges_per_s, 1)
        return d


@dataclasses.dataclass
class IngestResult:
    """What an ingestion run hands to :func:`repro.store.write_artifact`.

    ``names`` is the entity dictionary in id order (full URIs / raw TSV
    endpoint strings) — present for reader-based ingests, ``None`` for
    synthetic ``from_graph`` sources.  Persisting it (``write_artifact``'s
    ``names=``) is what makes the artifact a valid delta base."""

    graph: Graph
    index: InvertedIndex
    stats: IngestStats
    tau: int
    names: list[str] | None = None


class StreamIngestor:
    """Dictionary-encoding edge accumulator with bounded-memory chunks.

    Feed ``add_edge(src_name, dst_name)`` (strings — encoded to dense
    int32 ids on first sight) or ``add_edge_ids`` for pre-encoded ids,
    then :meth:`finalize`.  Node labels default to the entity's display
    text (see the readers); ``finalize`` builds the inverted index from
    them unless the caller supplies token labels itself.
    """

    def __init__(self, *, chunk_edges: int = _CHUNK_EDGES,
                 spill_dir: str | Path | None = None,
                 spill_after: int = 4) -> None:
        if chunk_edges < 1:
            raise ValueError("chunk_edges must be >= 1")
        self.chunk_edges = int(chunk_edges)
        self.spill_dir = Path(spill_dir) if spill_dir is not None else None
        self.spill_after = int(spill_after)
        self._ids: dict[str, int] = {}
        self._pred_ids: dict[str, int] = {}
        self._labels: list[str] = []
        # [4, n] int32 chunks: src, dst, pred_id, conf (float32 bits).
        self._chunks: list[np.ndarray | Path] = []
        self._cur = np.empty((4, self.chunk_edges), np.int32)
        self._fill = 0
        self._n_spilled = 0
        self._self_loops = 0
        self._n_edges = 0
        self._typed = False

    # -- encoding ------------------------------------------------------

    def entity_id(self, name: str, label: str | None = None) -> int:
        """Dense id for an entity string (assigned on first sight).
        ``label``: display/keyword text for the node (defaults to
        ``name``)."""
        nid = self._ids.get(name)
        if nid is None:
            nid = len(self._ids)
            self._ids[name] = nid
            self._labels.append(name if label is None else label)
        return nid

    def predicate_id(self, name: str) -> int:
        """Dense id for a predicate string (assigned on first sight).
        Registering any predicate makes the ingest *typed*: finalize will
        attach the ``(pred, conf)`` channel to the graph."""
        pid = self._pred_ids.get(name)
        if pid is None:
            pid = len(self._pred_ids)
            self._pred_ids[name] = pid
            self._typed = True
        return pid

    @property
    def n_nodes(self) -> int:
        return len(self._ids)

    @property
    def n_edges(self) -> int:
        return self._n_edges

    @property
    def n_predicates(self) -> int:
        return len(self._pred_ids)

    @property
    def pred_names(self) -> list[str]:
        return list(self._pred_ids)

    @property
    def entity_names(self) -> list[str]:
        """The entity dictionary keys in id order (materializes O(V))."""
        return list(self._ids)

    @property
    def node_labels(self) -> list[str]:
        """Display/keyword text per node, id order (materializes O(V))."""
        return list(self._labels)

    # -- accumulation --------------------------------------------------

    def add_edge(self, src: str, dst: str,
                 src_label: str | None = None,
                 dst_label: str | None = None,
                 pred: str | None = None,
                 conf: float = 1.0) -> None:
        self.add_edge_ids(self.entity_id(src, src_label),
                          self.entity_id(dst, dst_label),
                          pred=-1 if pred is None else self.predicate_id(pred),
                          conf=conf)

    def add_edge_ids(self, src: int, dst: int,
                     pred: int = -1, conf: float = 1.0) -> None:
        if src == dst:
            # Self-loops contribute nothing to answer trees (build_graph
            # drops them anyway); reject at the door and count honestly.
            self._self_loops += 1
            return
        if pred >= 0 or conf != 1.0:
            self._typed = True
        self._cur[0, self._fill] = src
        self._cur[1, self._fill] = dst
        self._cur[2, self._fill] = pred
        self._cur[3, self._fill] = np.float32(conf).view(np.int32)
        self._fill += 1
        self._n_edges += 1
        if self._fill == self.chunk_edges:
            self._flush()

    def _flush(self) -> None:
        if self._fill == 0:
            return
        chunk = self._cur[:, : self._fill].copy()
        self._fill = 0
        resident = sum(1 for c in self._chunks if isinstance(c, np.ndarray))
        if self.spill_dir is not None and resident >= self.spill_after:
            self.spill_dir.mkdir(parents=True, exist_ok=True)
            path = self.spill_dir / f"chunk-{len(self._chunks):06d}.npy"
            np.save(path, chunk)
            self._chunks.append(path)
            self._n_spilled += 1
        else:
            self._chunks.append(chunk)

    def _edges(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Stream every chunk (resident or spilled) into preallocated
        arrays — peak = the final O(E) buffers + one chunk, with no
        transient concatenate copy.  Returns ``(src, dst, pred, conf)``;
        the typed rows are dormant (-1 / 1.0) for untyped ingests."""
        self._flush()
        src = np.empty(self._n_edges, np.int32)
        dst = np.empty(self._n_edges, np.int32)
        pred = np.empty(self._n_edges, np.int32)
        conf_bits = np.empty(self._n_edges, np.int32)
        pos = 0
        for c in self._chunks:
            arr = c if isinstance(c, np.ndarray) else \
                np.load(c, mmap_mode="r")
            n = arr.shape[1]
            src[pos:pos + n] = arr[0]
            dst[pos:pos + n] = arr[1]
            pred[pos:pos + n] = arr[2]
            conf_bits[pos:pos + n] = arr[3]
            pos += n
        assert pos == self._n_edges
        return src, dst, pred, conf_bits.view(np.float32)

    def edges(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Materialize the accumulated directed edges as
        ``(src, dst, pred, conf)`` without finalizing — the delta writer's
        access path (O(E); predicate ids stay raw: -1 = untyped)."""
        return self._edges()

    # -- finalization --------------------------------------------------

    def finalize(self, stats: IngestStats, *, tau: int = 1001,
                 index: InvertedIndex | None = None,
                 tokens: np.ndarray | None = None) -> IngestResult:
        """Symmetrize + CSR + degree weights + inverted index.

        The paper's edge-weight model is applied here, over the *final*
        in-degrees (weights depend on global degree counts, so they can
        only be emitted at finalization).  ``index``/``tokens`` override
        the default labels-derived index (synthetic token matrices).

        Typed ingests (any registered predicate or non-unit confidence)
        attach the ``(pred, conf)`` channel and the predicate dictionary
        to the graph; edges that arrived without a predicate are filed
        under a synthetic ``"(untyped)"`` entry so the channel is total.
        """
        src, dst, pred, conf = self._edges()
        labels = list(self._labels) if self._labels else None
        t0 = time.perf_counter()
        if self._typed:
            if len(pred) and (pred < 0).any():
                pred = np.where(pred < 0,
                                np.int32(self.predicate_id("(untyped)")),
                                pred)
            graph = build_graph(src, dst, max(self.n_nodes, 1),
                                labels=labels, tau=tau,
                                pred=pred, conf=conf,
                                pred_names=self.pred_names)
            stats.n_predicates = self.n_predicates
        else:
            graph = build_graph(src, dst, max(self.n_nodes, 1),
                                labels=labels, tau=tau)
        if index is None:
            if tokens is not None:
                index = InvertedIndex.from_token_matrix(np.asarray(tokens))
            elif labels is not None:
                index = InvertedIndex.from_labels(labels)
            elif self.n_nodes == 0:
                index = InvertedIndex()   # empty source, empty index
            else:
                raise ValueError(
                    "finalize needs labels, tokens=, or index= to build "
                    "the inverted index")
        stats.edges_directed = int(len(src))
        stats.self_loops_dropped += self._self_loops
        stats.n_nodes = graph.n_nodes
        stats.chunks = len(self._chunks)
        stats.spilled_chunks = self._n_spilled
        stats.ingest_s += time.perf_counter() - t0
        return IngestResult(graph=graph, index=index, stats=stats, tau=tau,
                            names=self.entity_names if self._ids else None)


# ----------------------------------------------------------------------
# Text readers
# ----------------------------------------------------------------------


def _open_text(path: str | Path):
    path = Path(path)
    if path.suffix == ".gz":
        return gzip.open(path, "rt", encoding="utf-8", errors="replace")
    return open(path, "rt", encoding="utf-8", errors="replace")


_LOCAL = re.compile(r"[/#]")
_WORDISH = re.compile(r"[_\-.:]+")


def display_text(term: str) -> str:
    """Keyword text for an RDF term: a URI's local name (after the last
    ``/`` or ``#``, separators spaced), a literal's lexical form, a blank
    node's id.  This is what the inverted index tokenizes."""
    if term.startswith("<") and term.endswith(">"):
        local = _LOCAL.split(term[1:-1])[-1] or term[1:-1]
        return _WORDISH.sub(" ", local).strip() or local
    if term.startswith('"'):
        end = term.rfind('"')
        text = term[1:end] if end > 0 else term.strip('"')
        return text.replace('\\"', '"').replace("\\\\", "\\")
    return term


def _nt_terms(line: str) -> list[str] | None:
    """Parse one N-Triples statement into raw terms: ``[s, p, o]`` or
    ``[s, p, o, x]`` when a 4th term precedes the final ``.`` (an
    N-Quads-style annotation — our readers interpret a *numeric* 4th term
    as the statement's confidence).  Handles ``<uri>``, ``_:bnode``, and
    quoted literals with escapes / ``@lang`` / ``^^<datatype>`` suffixes.
    Returns None for a line that isn't a statement."""
    terms = []
    i, n = 0, len(line)
    while i < n and len(terms) < 4:
        while i < n and line[i] in " \t":
            i += 1
        if i >= n:
            break
        ch = line[i]
        if ch == "<":
            j = line.find(">", i + 1)
            if j < 0:
                return None
            terms.append(line[i:j + 1])
            i = j + 1
        elif ch == '"':
            j = i + 1
            while j < n:
                if line[j] == "\\":
                    j += 2
                    continue
                if line[j] == '"':
                    break
                j += 1
            if j >= n:
                return None
            # Swallow @lang / ^^<datatype> up to the next whitespace.
            k = j + 1
            while k < n and line[k] not in " \t":
                k += 1
            terms.append(line[i:k])
            i = k
        elif ch == ".":
            break
        else:  # blank node or bare token
            j = i
            while j < n and line[j] not in " \t":
                j += 1
            terms.append(line[i:j])
            i = j
    if len(terms) not in (3, 4):
        return None
    s, p, o = terms[:3]
    # N-Triples grammar: subject is a URI or blank node, predicate a URI,
    # object any term — reject bare-word lines instead of inventing nodes.
    if not (s.startswith("<") or s.startswith("_:")):
        return None
    if not p.startswith("<"):
        return None
    if not (o.startswith("<") or o.startswith("_:") or o.startswith('"')):
        return None
    return terms


def _term_confidence(term: str) -> float | None:
    """A 4th statement term read as a confidence: a bare number or a
    numeric literal (``"0.9"``, ``"0.9"^^<xsd:double>``); anything else
    (e.g. an N-Quads graph label) is None — ignored, not an error."""
    try:
        c = float(display_text(term))
    except (TypeError, ValueError):
        return None
    return c if c > 0 else None


def feed_nt_line(ing: StreamIngestor, line: str) -> bool:
    """Parse + accumulate one stripped N-Triples statement line.

    Returns False for a malformed line (nothing accumulated).  This is
    the ONE statement→edge mapping shared by the bulk reader and the
    delta builder, so a fragment appended as a delta and the same lines
    in a full re-ingest produce identical dictionary growth, labels, and
    edge rows."""
    terms = _nt_terms(line)
    if terms is None:
        return False
    s, p, o = terms[:3]
    conf = _term_confidence(terms[3]) if len(terms) == 4 else None
    ing.add_edge(s, o, display_text(s), display_text(o),
                 pred=display_text(p),
                 conf=1.0 if conf is None else conf)
    return True


def feed_tsv_line(ing: StreamIngestor, line: str) -> bool:
    """Parse + accumulate one stripped TSV edge row (see
    :func:`ingest_tsv` for the column convention).  Returns False for a
    malformed line.  Shared by the bulk reader and the delta builder."""
    cols = line.split("\t") if "\t" in line else line.split()
    if len(cols) < 2 or not cols[0] or not cols[1]:
        return False
    pred, conf = None, None
    if len(cols) >= 3 and cols[2].strip():
        conf = _term_confidence(cols[2].strip())
        if conf is None:
            pred = cols[2].strip()
            if len(cols) >= 4 and cols[3].strip():
                conf = _term_confidence(cols[3].strip())
    ing.add_edge(cols[0].strip(), cols[1].strip(),
                 pred=pred, conf=1.0 if conf is None else conf)
    return True


def ingest_ntriples(
    path: str | Path,
    *,
    tau: int = 1001,
    chunk_edges: int = _CHUNK_EDGES,
    spill_dir: str | Path | None = None,
    on_error: str = "skip",
) -> IngestResult:
    """Stream an N-Triples dump into ``(graph, index, stats)``.

    Every distinct subject/object term becomes a node (dictionary-encoded
    int32); every statement's predicate becomes the edge's type — the
    predicate dictionary keys on :func:`display_text` of the predicate URI
    (the name the CLI filter flags accept; URIs sharing a local name share
    an id).  A numeric 4th term (N-Quads-style annotation) is read as the
    statement's confidence; a non-numeric one is ignored.  Node keyword
    text is the term's :func:`display_text`.  ``on_error``: ``"skip"``
    counts malformed lines in the stats, ``"raise"`` fails fast.
    """
    if on_error not in ("skip", "raise"):
        raise ValueError(f"unknown on_error={on_error!r}")
    stats = IngestStats(source=f"ntriples:{path}")
    ing = StreamIngestor(chunk_edges=chunk_edges, spill_dir=spill_dir)
    t0 = time.perf_counter()
    with _open_text(path) as f:
        for line in f:
            stats.lines_read += 1
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            if not feed_nt_line(ing, line):
                if on_error == "raise":
                    raise ValueError(
                        f"malformed N-Triples line {stats.lines_read} "
                        f"in {path}: {line[:120]!r}")
                stats.malformed_lines += 1
                continue
            stats.statements += 1
    stats.n_predicates = ing.n_predicates
    stats.ingest_s = time.perf_counter() - t0
    return ing.finalize(stats, tau=tau)


def ingest_tsv(
    path: str | Path,
    *,
    tau: int = 1001,
    chunk_edges: int = _CHUNK_EDGES,
    spill_dir: str | Path | None = None,
    on_error: str = "skip",
) -> IngestResult:
    """Stream a TSV/whitespace edge list (``src<TAB>dst[<TAB>pred][<TAB>conf]``
    per line; ``#`` comments skipped).  Endpoint strings are
    dictionary-encoded and double as the node keyword text.  A numeric
    3rd column is read as the edge's confidence; a non-numeric one as its
    predicate name (then a numeric 4th column is the confidence); columns
    past those are ignored."""
    if on_error not in ("skip", "raise"):
        raise ValueError(f"unknown on_error={on_error!r}")
    stats = IngestStats(source=f"tsv:{path}")
    ing = StreamIngestor(chunk_edges=chunk_edges, spill_dir=spill_dir)
    t0 = time.perf_counter()
    with _open_text(path) as f:
        for line in f:
            stats.lines_read += 1
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            if not feed_tsv_line(ing, line):
                if on_error == "raise":
                    raise ValueError(
                        f"malformed TSV line {stats.lines_read} in {path}: "
                        f"{line[:120]!r}")
                stats.malformed_lines += 1
                continue
            stats.statements += 1
    stats.n_predicates = ing.n_predicates
    stats.ingest_s = time.perf_counter() - t0
    return ing.finalize(stats, tau=tau)


def from_graph(
    graph: Graph,
    *,
    tokens: np.ndarray | None = None,
    index: InvertedIndex | None = None,
    tau: int = 1001,
    edges_requested: int | None = None,
    source: str = "graph",
) -> IngestResult:
    """Wrap an in-memory (synthetic) graph in the ingestion envelope.

    ``edges_requested`` lets generator callers record the asked-for edge
    count next to the true one (``stats.edges_directed``) — the honesty
    knob for generators that may drop slots."""
    if index is None:
        if tokens is not None:
            index = InvertedIndex.from_token_matrix(np.asarray(tokens))
        elif graph.labels is not None:
            index = InvertedIndex.from_labels(graph.labels)
        else:
            raise ValueError("from_graph needs tokens=, index=, or "
                             "graph.labels")
    stats = IngestStats(
        source=source,
        statements=graph.n_edges_directed,
        edges_requested=edges_requested,
        edges_directed=graph.n_edges_directed,
        n_nodes=graph.n_nodes,
    )
    return IngestResult(graph=graph, index=index, stats=stats, tau=tau)


def write_tsv(path: str | Path, src: Iterable[int], dst: Iterable[int],
              name: str = "n",
              pred: Iterable[str] | None = None,
              conf: Iterable[float] | None = None) -> int:
    """Dump an edge list as a TSV file (benchmark/test helper for the
    streaming reader; entity names are ``{name}{id}``).  Optional
    ``pred``/``conf`` columns produce a typed edge list the reader's
    3rd/4th-column convention picks up.  Returns the number of lines
    written."""
    n = 0
    preds = list(pred) if pred is not None else None
    confs = list(conf) if conf is not None else None
    with open(path, "w", encoding="utf-8") as f:
        for i, (s, d) in enumerate(zip(src, dst)):
            row = f"{name}{int(s)}\t{name}{int(d)}"
            if preds is not None:
                row += f"\t{preds[i]}"
            if confs is not None:
                row += f"\t{float(confs[i]):g}"
            f.write(row + "\n")
            n += 1
    return n


def iter_lines(path: str | Path) -> Iterator[str]:
    """Line iterator with transparent .gz handling (exposed for tools)."""
    with _open_text(path) as f:
        yield from f
