"""repro.store — graph store & ingestion: stream LOD dumps into versioned
on-disk artifacts, mmap-load them into the engine.

The paper's workloads are real RDF dumps; an engine that re-generates and
re-packs its graph on every process start cannot serve them.  This
subsystem splits the lifecycle:

    ingest (once, streaming, bounded memory)
        result = ingest_ntriples("dump.nt.gz")          # or ingest_tsv,
        # or from_graph(g, tokens=...) for synthetic graphs
        art = write_artifact("artifacts/dump", result.graph, result.index,
                             tau=result.tau, stats=result.stats.as_dict())

    open (every serve start, milliseconds)
        art = open_artifact("artifacts/dump")           # mmap, zero-copy
        engine = QueryEngine.build(artifact=art)        # no re-tokenizing

Artifacts are versioned (format_version + magic), checksummed (sha256 per
buffer, ``verify="full"`` re-checks), written atomically, and carry a
``content_hash`` that :class:`~repro.engine.QueryEngine` folds into its
``version``/``cache_token`` — a serving result cache can never cross two
different graph builds.

Live graphs stack **delta artifacts** on a base instead of re-ingesting:

    append (seconds, proportional to the fragment)
        b = DeltaBuilder(open_artifact("artifacts/dump"))
        b.add_file("edits-0042.nt")
        delta = b.write("artifacts/dump-delta-0001")

    open the chain (merged, engine-ready, chained-hash versioned)
        chain = open_chain("artifacts/dump", "artifacts/dump-delta-0001")
        engine = QueryEngine.build(artifact=chain)   # version = chained hash
        compact_chain(chain, "artifacts/dump-v2")    # == union re-ingest,
                                                     # bit-identical

Public API:
  ingest_ntriples / ingest_tsv — streaming readers (dictionary-encoded
                  entities, chunked edge accumulation, degree weights at
                  finalization).
  from_graph    — the synthetic-graph path into the same envelope.
  StreamIngestor / IngestResult / IngestStats — the pieces behind them.
  write_artifact / open_artifact / GraphArtifact — the on-disk format.
  DeltaBuilder / open_delta / DeltaArtifact — edge/node adds stacked on a
                  base ``content_hash`` (repro.store.delta).
  open_chain / GraphChain / compact_chain — merged live view + folding.
  ArtifactError / FormatVersionError / ChecksumError — validation errors.

CLI: ``python -m repro.launch.ingest`` (generate-or-read -> ingest ->
write -> reopen -> verify query parity; ``--smoke`` for CI;
``--live DIR --append frag…`` for delta publication).
"""

from repro.store.artifact import (  # noqa: F401
    FORMAT_VERSION,
    SUPPORTED_VERSIONS,
    ArtifactError,
    ChecksumError,
    FormatVersionError,
    GraphArtifact,
    LazyArtifactIndex,
    open_artifact,
    write_artifact,
)
from repro.store.delta import (  # noqa: F401
    DELTA_FORMAT_VERSION,
    ChainIndex,
    DeltaArtifact,
    DeltaBuilder,
    GraphChain,
    chained_hash,
    compact_chain,
    open_chain,
    open_delta,
)
from repro.store.ingest import (  # noqa: F401
    IngestResult,
    IngestStats,
    StreamIngestor,
    from_graph,
    ingest_ntriples,
    ingest_tsv,
    write_tsv,
)
