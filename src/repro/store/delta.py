"""Delta artifacts: live-graph growth without full re-ingest.

A :class:`DeltaArtifact` is a directory of ``.npy`` buffers holding only
what changed — new directed edges (with the typed ``(pred, conf)``
channel), new entities (dictionary growth: names + labels), and new
predicate names — stacked on an exact base identified by its
``content_hash``.  :func:`open_chain` merges ``base + delta_1 + … +
delta_d`` into an engine-ready :class:`GraphChain` whose
``content_hash`` is the *chained* hash, so ``QueryEngine.version`` /
``cache_token`` can never serve a stale build; :func:`compact_chain`
folds a chain back into a fresh base artifact.

The invariant everything here is built around: **a chain is
bit-identical to re-ingesting the union.**  The base ingest is a prefix
of the union ingest's statement stream, so its dictionary (entity ids,
predicate ids, labels) is exactly the union dictionary's prefix; a
:class:`DeltaBuilder` reproduces the suffix by seeding a fresh
:class:`StreamIngestor` with the base's persisted name table and real
predicate dictionary, then feeding fragments through the *same*
statement→edge mapping the bulk readers use
(:func:`repro.store.ingest.feed_nt_line` / ``feed_tsv_line``).  Merging
re-derives degree weights over the union in-degrees and re-runs
:func:`build_graph` on the concatenated directed edges — the identical
inputs the union re-ingest would hand it — so weights, CSR, answer
trees, and even the compacted artifact's ``content_hash`` come out
equal (the manifest ``stats`` block is excluded from the hash by
design, which is what makes that equality testable).

Predicate-dictionary mechanics mirror ``StreamIngestor.finalize``
exactly: deltas store ``pred=-1`` for untyped statements and never
resolve the synthetic ``"(untyped)"`` entry; the merge renumbers base
predicates compactly over the *real* names (base order preserved),
appends each delta's new names in chain order, and files remaining
``-1`` rows under a final ``"(untyped)"`` id — the same
"registered-at-finalize, therefore last" position the union ingest
produces.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
from collections.abc import Sequence
from pathlib import Path
from typing import Any, Union

import numpy as np

from repro.graph.index import InvertedIndex
from repro.graph.structure import Graph, build_graph
from repro.store.artifact import (
    DELTA_MAGIC, _MANIFEST, ArtifactError, BufferDir, FormatVersionError,
    GraphArtifact, MAGIC, _content_hash, _decode_strings, _encode_strings,
    _sha256_file, open_artifact, write_artifact,
)
from repro.store.ingest import (
    _CHUNK_EDGES, IngestStats, StreamIngestor, feed_nt_line, feed_tsv_line,
)

DELTA_FORMAT_VERSION = 1
_UNTYPED = "(untyped)"

#: Suffixes the format sniffer maps to a reader (``.gz`` is stripped
#: first) — shared with the watcher's directory scan.
NT_SUFFIXES = (".nt", ".ntriples")
TSV_SUFFIXES = (".tsv", ".txt", ".edges")


def chained_hash(below: str, delta_hash: str) -> str:
    """Version of a chain after stacking one delta: a digest of the
    (chain-below, delta) hash pair.  Order-sensitive and
    collision-separated from plain content hashes by the prefix."""
    return hashlib.sha256(
        f"chain:{below}+{delta_hash}".encode()).hexdigest()


def sniff_format(path: str | Path) -> str:
    """``"nt"`` | ``"tsv"`` from a fragment's suffix (``.gz`` stripped).
    Raises :class:`ArtifactError` for an unrecognized suffix."""
    p = Path(path)
    suffix = Path(p.stem).suffix if p.suffix == ".gz" else p.suffix
    if suffix in NT_SUFFIXES:
        return "nt"
    if suffix in TSV_SUFFIXES:
        return "tsv"
    raise ArtifactError(
        f"cannot sniff fragment format of {p} (suffix {suffix!r}; "
        f"known: {NT_SUFFIXES + TSV_SUFFIXES}, optionally .gz) — pass "
        "fmt='nt' or fmt='tsv'")


class _StringTable(Sequence):
    """Concatenated (offsets, blob) string segments that duck-type as a
    ``list[str]`` — node labels / entity names across a chain without
    decoding V strings up front.  ``labels[v]`` decodes one string off
    the mmapped segment; iteration (e.g. artifact compaction) streams
    them all."""

    def __init__(self, segments: list[tuple[np.ndarray, np.ndarray]]):
        self._segments = segments
        counts = [len(off) - 1 for off, _ in segments]
        self._bounds = np.cumsum([0] + counts)

    def __len__(self) -> int:
        return int(self._bounds[-1])

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        i = int(i)
        if i < 0:
            i += len(self)
        if not 0 <= i < len(self):
            raise IndexError(f"string index {i} out of range "
                             f"[0, {len(self)})")
        seg = int(np.searchsorted(self._bounds, i, side="right")) - 1
        off, blob = self._segments[seg]
        j = i - int(self._bounds[seg])
        return bytes(blob[int(off[j]):int(off[j + 1])]).decode("utf-8")

    def __iter__(self):
        for off, blob in self._segments:
            data = np.asarray(blob).tobytes()
            for j in range(len(off) - 1):
                yield data[int(off[j]):int(off[j + 1])].decode("utf-8")


class DeltaArtifact(BufferDir):
    """An opened delta: additions stacked on one exact base build.

    Buffers: ``src``/``dst``/``pred``/``conf`` (new directed edges in
    union-global entity ids and chain-global *real* predicate ids,
    ``pred=-1`` for untyped statements) and the new entities' name/label
    tables.  Use :func:`open_delta` rather than constructing directly.
    """

    @property
    def base_content_hash(self) -> str:
        return self.manifest["base_content_hash"]

    @property
    def base_depth(self) -> int:
        return int(self.manifest.get("base_depth", 0))

    @property
    def depth(self) -> int:
        """Chain depth after stacking this delta (base artifact = 0)."""
        return self.base_depth + 1

    @property
    def chain_hash(self) -> str:
        """``chained_hash(base_content_hash, content_hash)`` — the chain
        version after this delta (recorded for convenience; readers
        recompute it rather than trust it)."""
        return self.manifest["chain_hash"]

    @property
    def base_n_nodes(self) -> int:
        return int(self.manifest["base_n_nodes"])

    @property
    def base_n_predicates(self) -> int:
        """REAL predicates in the base (the synthetic ``"(untyped)"``
        entry excluded) — the id offset this delta's new names start at."""
        return int(self.manifest["base_n_predicates"])

    @property
    def n_new_nodes(self) -> int:
        return int(self.manifest["n_new_nodes"])

    @property
    def n_new_edges(self) -> int:
        return int(self.manifest["n_new_edges"])

    @property
    def new_predicates(self) -> list[str]:
        return list(self.manifest.get("new_predicates", []))

    @property
    def typed(self) -> bool:
        return bool(self.manifest.get("typed", False))

    @property
    def tau(self) -> int:
        return int(self.manifest["tau"])

    @property
    def token_kind(self) -> str:
        return self.manifest["token_kind"]

    def edges(self) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                             np.ndarray]:
        """Mmapped ``(src, dst, pred, conf)`` of the new directed edges."""
        return (self.buffer("src"), self.buffer("dst"),
                self.buffer("pred"), self.buffer("conf"))

    def new_labels(self) -> list[str]:
        return _decode_strings(np.asarray(self.buffer("label_offsets")),
                               self.buffer("label_bytes"))

    def new_names(self) -> list[str]:
        return _decode_strings(np.asarray(self.buffer("ent_offsets")),
                               self.buffer("ent_bytes"))

    def __repr__(self) -> str:
        return (f"DeltaArtifact({str(self.path)!r}, "
                f"+V={self.n_new_nodes:,}, +E={self.n_new_edges:,}, "
                f"base={self.base_content_hash[:12]}…, "
                f"depth={self.depth}, hash={self.content_hash[:12]}…)")


def open_delta(path: str | Path, verify: str = "meta") -> DeltaArtifact:
    """Open a delta artifact (mmap; same layered validation contract as
    :func:`repro.store.open_artifact`)."""
    if verify not in ("meta", "full"):
        raise ValueError(f"unknown verify={verify!r} "
                         "(expected 'meta' or 'full')")
    path = Path(path)
    mpath = path / _MANIFEST
    if not mpath.is_file():
        raise ArtifactError(f"no delta artifact at {path} "
                            f"(missing {_MANIFEST})")
    try:
        manifest = json.loads(mpath.read_text())
    except json.JSONDecodeError as exc:
        raise ArtifactError(f"unreadable manifest in {path}: {exc}") from exc
    if manifest.get("magic") != DELTA_MAGIC:
        if manifest.get("magic") == MAGIC:
            raise FormatVersionError(
                f"{path} is a base graph artifact "
                f"(hash={str(manifest.get('content_hash'))[:12]}…), not a "
                "delta — open it with open_artifact(), or pass it as the "
                "base of open_chain(base, *deltas)")
        raise FormatVersionError(
            f"{path} is not a {DELTA_MAGIC} "
            f"(magic={manifest.get('magic')!r})")
    version = manifest.get("format_version")
    if version != DELTA_FORMAT_VERSION:
        raise FormatVersionError(
            f"delta format v{version} at {path}; this reader supports "
            f"v{DELTA_FORMAT_VERSION}")
    for key in ("content_hash", "buffers", "base_content_hash",
                "base_n_nodes", "n_new_nodes", "n_new_edges"):
        if key not in manifest:
            raise ArtifactError(f"manifest missing {key!r} in {path}")
    delta = DeltaArtifact(path, manifest)
    delta.validate()
    if verify == "full":
        delta.verify_checksums()
    return delta


def _real_predicates(predicates: list[str]) -> list[str]:
    return [p for p in predicates if p != _UNTYPED]


class DeltaBuilder:
    """Accumulate fragments into one delta against an exact base build.

    ``base`` is a :class:`GraphArtifact` or :class:`GraphChain` — it must
    carry the entity-name table (``write_artifact(..., names=...)``; only
    reader-produced artifacts do) and a string-token index.  The builder
    seeds a fresh :class:`StreamIngestor` with the base dictionary so
    fragment statements resolve existing entities/predicates to their
    base ids and new ones grow the dictionary exactly as a full union
    re-ingest would.
    """

    def __init__(self, base: Union[GraphArtifact, "GraphChain"], *,
                 chunk_edges: int = _CHUNK_EDGES,
                 spill_dir: str | Path | None = None) -> None:
        if base.token_kind != "str":
            raise ArtifactError(
                f"delta bases need a string-token index; base "
                f"{base.content_hash[:12]}… has token_kind="
                f"{base.token_kind!r} (synthetic int-token graphs don't "
                "grow by text fragments)")
        names = base.entity_names()   # raises ArtifactError without table
        self.base = base
        self.base_content_hash = base.content_hash
        self.base_depth = int(getattr(base, "depth", 0))
        self.base_n_nodes = int(base.n_nodes)
        self.tau = int(base.tau)
        real = _real_predicates(base.predicates)
        self.base_n_predicates = len(real)
        self.stats = IngestStats(
            source=f"delta:base={self.base_content_hash[:12]}")
        self._ing = StreamIngestor(chunk_edges=chunk_edges,
                                   spill_dir=spill_dir)
        # Seed the dictionary: ids are assigned in call order, so walking
        # the persisted tables reproduces the base assignment exactly.
        for name in names:
            self._ing.entity_id(name)
        for p in real:
            self._ing.predicate_id(p)

    # -- accumulation --------------------------------------------------

    def add_statement(self, src: str, dst: str,
                      src_label: str | None = None,
                      dst_label: str | None = None,
                      pred: str | None = None,
                      conf: float = 1.0) -> None:
        """One pre-parsed statement (same contract as
        ``StreamIngestor.add_edge``)."""
        self.stats.statements += 1
        self._ing.add_edge(src, dst, src_label, dst_label,
                           pred=pred, conf=conf)

    def add_file(self, path: str | Path, fmt: str = "auto",
                 on_error: str = "skip") -> None:
        """Stream one N-Triples/TSV fragment (``.gz`` transparent) into
        the delta, through the same line parsers as the bulk readers."""
        if on_error not in ("skip", "raise"):
            raise ValueError(f"unknown on_error={on_error!r}")
        fmt = sniff_format(path) if fmt == "auto" else fmt
        if fmt not in ("nt", "tsv"):
            raise ValueError(f"unknown fmt={fmt!r} (expected 'nt'/'tsv')")
        feed = feed_nt_line if fmt == "nt" else feed_tsv_line
        from repro.store.ingest import iter_lines
        for line in iter_lines(path):
            self.stats.lines_read += 1
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            if not feed(self._ing, line):
                if on_error == "raise":
                    raise ValueError(
                        f"malformed {fmt} line {self.stats.lines_read} "
                        f"in {path}: {line[:120]!r}")
                self.stats.malformed_lines += 1
                continue
            self.stats.statements += 1

    # -- introspection -------------------------------------------------

    @property
    def n_new_nodes(self) -> int:
        return self._ing.n_nodes - self.base_n_nodes

    @property
    def n_new_edges(self) -> int:
        return self._ing.n_edges

    @property
    def new_predicates(self) -> list[str]:
        return self._ing.pred_names[self.base_n_predicates:]

    @property
    def empty(self) -> bool:
        return self.n_new_nodes == 0 and self.n_new_edges == 0

    # -- publication ---------------------------------------------------

    def write(self, path: str | Path,
              overwrite: bool = False) -> DeltaArtifact:
        """Publish the delta atomically (tmp sibling + rename — the
        ``write_artifact`` discipline) and reopen it from disk."""
        if self.empty:
            raise ArtifactError(
                "empty delta (no new edges or entities) — nothing to "
                "publish")
        path = Path(path)
        if path.exists() and not overwrite:
            raise ArtifactError(
                f"delta path exists: {path} (pass overwrite=True)")
        src, dst, pred, conf = self._ing.edges()
        # Typedness of the delta *content* (the seeded predicate
        # dictionary alone doesn't make the additions typed).
        typed = bool(self.new_predicates) \
            or bool(len(pred) and (pred >= 0).any()) \
            or bool(len(conf) and (conf != 1.0).any())
        new_labels = self._ing.node_labels[self.base_n_nodes:]
        new_names = self._ing.entity_names[self.base_n_nodes:]
        lab_off, lab_blob = _encode_strings(new_labels)
        ent_off, ent_blob = _encode_strings(new_names)
        arrays: dict[str, np.ndarray] = {
            "src": np.ascontiguousarray(src, np.int32),
            "dst": np.ascontiguousarray(dst, np.int32),
            "pred": np.ascontiguousarray(pred, np.int32),
            "conf": np.ascontiguousarray(conf, np.float32),
            "label_offsets": lab_off, "label_bytes": lab_blob,
            "ent_offsets": ent_off, "ent_bytes": ent_blob,
        }

        tmp = path.parent / f"{path.name}.tmp-{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        try:
            buffers: dict[str, dict[str, Any]] = {}
            for name, arr in arrays.items():
                fname = f"{name}.npy"
                np.save(tmp / fname, arr)
                buffers[name] = {
                    "file": fname, "dtype": str(arr.dtype),
                    "shape": list(arr.shape),
                    "sha256": _sha256_file(tmp / fname),
                }
            meta = {
                "magic": DELTA_MAGIC,
                "format_version": DELTA_FORMAT_VERSION,
                "base_content_hash": self.base_content_hash,
                "base_depth": self.base_depth,
                "base_n_nodes": self.base_n_nodes,
                "base_n_predicates": self.base_n_predicates,
                "n_new_nodes": self.n_new_nodes,
                "n_new_edges": int(len(src)),
                "new_predicates": self.new_predicates,
                "typed": typed,
                "tau": self.tau,
                "token_kind": "str",
            }
            manifest = dict(meta)
            self.stats.edges_directed = int(len(src))
            self.stats.self_loops_dropped = self._ing._self_loops
            self.stats.n_nodes = self.n_new_nodes
            self.stats.n_predicates = len(self.new_predicates)
            manifest["stats"] = self.stats.as_dict()
            manifest["buffers"] = buffers
            content = _content_hash(meta, buffers)
            manifest["content_hash"] = content
            manifest["chain_hash"] = chained_hash(
                self.base_content_hash, content)
            (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1))
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

        if path.exists():  # overwrite=True: checked above
            shutil.rmtree(path)
        os.replace(tmp, path)
        return open_delta(path)


class ChainIndex(InvertedIndex):
    """The base artifact's lazy index plus an in-memory posting overlay
    for the chain's new nodes.  New-node ids are all >= the base node
    count, so ``concat(base_posting, overlay_posting)`` IS the sorted
    unique posting a from-scratch tokenization of the merged labels
    would produce — no re-sort, and the base postings stay mmapped."""

    def __init__(self, base: InvertedIndex,
                 overlay: dict[str, np.ndarray]) -> None:
        super().__init__()
        self._base = base
        self._overlay = overlay

    @property
    def base_index(self) -> InvertedIndex:
        """The wrapped base index (a ``LazyArtifactIndex`` for
        artifact-backed chains)."""
        return self._base

    def lookup(self, token) -> np.ndarray:
        b = self._base.lookup(token)
        o = self._overlay.get(token)
        if o is None or len(o) == 0:
            return b
        if len(b) == 0:
            return o
        return np.concatenate([np.asarray(b, np.int32), o])

    def df(self, token) -> int:
        o = self._overlay.get(token)
        return int(self._base.df(token)) + (0 if o is None else len(o))

    def vocabulary(self) -> list:
        vocab = self._base.vocabulary()
        seen = set(vocab)
        return vocab + [t for t in self._overlay if t not in seen]

    def token_dfs(self) -> list[tuple]:
        seen = set()
        out = []
        for tok, d in self._base.token_dfs():
            seen.add(tok)
            o = self._overlay.get(tok)
            out.append((tok, d + (0 if o is None else len(o))))
        out.extend((tok, len(post)) for tok, post in self._overlay.items()
                   if tok not in seen)
        return out

    def to_postings(self) -> tuple[list, np.ndarray, np.ndarray]:
        tokens = sorted(set(self._base.vocabulary()) | set(self._overlay))
        offsets = np.zeros(len(tokens) + 1, np.int64)
        posts = []
        for i, tok in enumerate(tokens):
            p = np.asarray(self.lookup(tok), np.int32)
            offsets[i + 1] = offsets[i] + len(p)
            posts.append(p)
        nodes = (np.concatenate(posts) if posts
                 else np.zeros(0, np.int32))
        return tokens, offsets, nodes


class GraphChain:
    """``base + delta_1 + … + delta_d`` merged into an engine-ready view.

    Duck-types the :class:`GraphArtifact` surface ``QueryEngine.build``
    consumes — ``graph()``, ``index()``, ``content_hash`` — plus the
    label/name accessors, so ``QueryEngine.build(artifact=chain)``
    serves the live graph with ``version = f"artifact:{chained hash}"``.
    Stacking order is verified hash-by-hash at construction; a
    mis-stacked delta fails immediately, naming both hashes and the
    depth, instead of surfacing later as a checksum/shape error.
    """

    def __init__(self, base: GraphArtifact,
                 deltas: tuple[DeltaArtifact, ...]) -> None:
        if not base.has_labels:
            raise ArtifactError(
                f"chain base {base.path} has no label text — delta chains "
                "need the base labels to extend the keyword index")
        self.base = base
        self.deltas = tuple(deltas)
        running = base.content_hash
        n_nodes = int(base.n_nodes)
        real = _real_predicates(base.predicates)
        for i, d in enumerate(self.deltas):
            if d.base_content_hash != running:
                raise ArtifactError(
                    f"mis-stacked delta at depth {i + 1}: {d.path} was "
                    f"built against {d.base_content_hash[:12]}… but the "
                    f"chain below it is {running[:12]}… — apply deltas in "
                    "publication order (or re-build the delta against the "
                    "current chain)")
            if int(d.tau) != int(base.tau):
                raise ArtifactError(
                    f"delta {d.path} was built with tau={d.tau}, base has "
                    f"tau={base.tau} — weights would diverge from a union "
                    "re-ingest")
            if d.base_n_nodes != n_nodes:
                raise ArtifactError(
                    f"delta {d.path} expects a base of {d.base_n_nodes:,} "
                    f"nodes; the chain below it has {n_nodes:,} "
                    f"(base={running[:12]}…, depth {i + 1})")
            if d.base_n_predicates != len(real):
                raise ArtifactError(
                    f"delta {d.path} expects {d.base_n_predicates} base "
                    f"predicates; the chain below it has {len(real)} "
                    f"(depth {i + 1})")
            running = chained_hash(running, d.content_hash)
            n_nodes += d.n_new_nodes
            real.extend(d.new_predicates)
        self._version = running
        self._n_nodes = n_nodes
        self._real_preds = real
        self._graph: Graph | None = None
        self._index: InvertedIndex | None = None

    # -- identity / metadata -------------------------------------------

    @property
    def content_hash(self) -> str:
        """The chained hash — every delta's content folded into the base
        hash in stacking order.  This is the engine/cache version."""
        return self._version

    @property
    def depth(self) -> int:
        return len(self.deltas)

    @property
    def n_nodes(self) -> int:
        return self._n_nodes

    @property
    def n_edges_directed(self) -> int:
        return int(self.base.n_edges_directed) + sum(
            d.n_new_edges for d in self.deltas)

    @property
    def tau(self) -> int:
        return int(self.base.tau)

    @property
    def token_kind(self) -> str:
        return self.base.token_kind

    @property
    def typed(self) -> bool:
        return self.base.typed or any(d.typed for d in self.deltas)

    @property
    def has_labels(self) -> bool:
        return self.base.has_labels

    @property
    def has_names(self) -> bool:
        return self.base.has_names

    @property
    def predicates(self) -> list[str]:
        """Merged predicate dictionary (``"(untyped)"`` last when any
        merged edge is untyped — matching ``StreamIngestor.finalize``)."""
        if not self.typed:
            return []
        names = list(self._real_preds)
        if self._any_untyped():
            names.append(_UNTYPED)
        return names

    def _any_untyped(self) -> bool:
        if self.base.typed:
            if _UNTYPED in self.base.predicates:
                return True
        elif self.base.n_edges_directed:
            return True
        for d in self.deltas:
            pred = d.buffer("pred")
            if len(pred) and bool((np.asarray(pred) < 0).any()):
                return True
        return False

    # -- merged engine-facing objects ----------------------------------

    def _merged_edges(self) -> tuple[np.ndarray, np.ndarray,
                                     np.ndarray, np.ndarray]:
        base = self.base
        e_base = int(base.n_edges_directed)
        srcs = [np.asarray(base.buffer("src"), np.int32)]
        dsts = [np.asarray(base.buffer("dst"), np.int32)]
        if base.typed:
            if "pred" not in base._buffers:
                raise ArtifactError(
                    f"chain base {base.path} persists no directed typed "
                    "buffers (pred/conf) — re-write the base with this "
                    "version")
            bp = np.asarray(base.buffer("pred"), np.int32)
            # Renumber base predicate ids over the real (non-"(untyped)")
            # names, base order preserved; "(untyped)" rows go back to -1
            # so the merge can re-file them under the final union id.
            idmap = np.empty(max(len(base.predicates), 1), np.int32)
            j = 0
            for i, name in enumerate(base.predicates):
                if name == _UNTYPED:
                    idmap[i] = -1
                else:
                    idmap[i] = j
                    j += 1
            preds = [np.where(bp >= 0, idmap[np.clip(bp, 0, None)],
                              np.int32(-1)) if len(bp) else bp]
            confs = [np.asarray(base.buffer("conf"), np.float32)]
        else:
            preds = [np.full(e_base, -1, np.int32)]
            confs = [np.ones(e_base, np.float32)]
        for d in self.deltas:
            src, dst, pred, conf = d.edges()
            srcs.append(np.asarray(src, np.int32))
            dsts.append(np.asarray(dst, np.int32))
            preds.append(np.asarray(pred, np.int32))
            confs.append(np.asarray(conf, np.float32))
        return (np.concatenate(srcs), np.concatenate(dsts),
                np.concatenate(preds), np.concatenate(confs))

    def graph(self) -> Graph:
        """The merged host graph: one :func:`build_graph` over the
        concatenated directed edges, degree weights re-derived over the
        union in-degrees — the identical inputs a union re-ingest hands
        it, hence bit-identical outputs."""
        if self._graph is None:
            src, dst, pred, conf = self._merged_edges()
            labels = self._label_table()
            if self.typed:
                names = list(self._real_preds)
                if len(pred) and bool((pred < 0).any()):
                    untyped_id = len(names)
                    names.append(_UNTYPED)
                    pred = np.where(pred < 0, np.int32(untyped_id), pred)
                self._graph = build_graph(
                    src, dst, max(self._n_nodes, 1), labels=labels,
                    tau=self.tau, pred=pred, conf=conf, pred_names=names)
            else:
                self._graph = build_graph(
                    src, dst, max(self._n_nodes, 1), labels=labels,
                    tau=self.tau)
        return self._graph

    def index(self) -> InvertedIndex:
        """Base lazy index + in-memory overlay of the new nodes' tokens
        (tokenized exactly like ``InvertedIndex.from_labels``)."""
        if self._index is None:
            overlay: dict[str, list[int]] = {}
            off = int(self.base.n_nodes)
            for d in self.deltas:
                for j, text in enumerate(d.new_labels()):
                    for tok in text.lower().split():
                        overlay.setdefault(tok, []).append(off + j)
                off += d.n_new_nodes
            frozen = {tok: np.unique(np.asarray(nodes, np.int32))
                      for tok, nodes in overlay.items()}
            self._index = ChainIndex(self.base.index(), frozen)
        return self._index

    def _label_table(self) -> _StringTable:
        segments = [(np.asarray(self.base.buffer("label_offsets")),
                     self.base.buffer("label_bytes"))]
        segments += [(np.asarray(d.buffer("label_offsets")),
                      d.buffer("label_bytes")) for d in self.deltas]
        return _StringTable(segments)

    def labels(self) -> list[str]:
        return list(self._label_table())

    def label(self, i: int) -> str:
        return self._label_table()[i]

    def entity_names(self) -> list[str]:
        names = self.base.entity_names()
        for d in self.deltas:
            names.extend(d.new_names())
        return names

    def __repr__(self) -> str:
        return (f"GraphChain(base={self.base.content_hash[:12]}…, "
                f"depth={self.depth}, V={self.n_nodes:,}, "
                f"E_directed={self.n_edges_directed:,}, "
                f"hash={self.content_hash[:12]}…)")


def open_chain(base: str | Path | GraphArtifact,
               *deltas: "str | Path | DeltaArtifact",
               verify: str = "meta") -> GraphChain:
    """Open ``base + deltas`` as one :class:`GraphChain` (paths or
    already-opened objects, in stacking order).  With no deltas the
    chain is the base view itself — same ``content_hash``, so an engine
    built from it shares caches with one built from the base artifact."""
    if isinstance(base, (str, Path)):
        base = open_artifact(base, verify=verify)
    opened = tuple(
        open_delta(d, verify=verify) if isinstance(d, (str, Path)) else d
        for d in deltas)
    return GraphChain(base, opened)


def compact_chain(chain: GraphChain, path: str | Path,
                  overwrite: bool = False) -> GraphArtifact:
    """Fold a chain into a fresh base artifact.

    The merged graph/index/labels/names are written through the ordinary
    :func:`write_artifact` path, so the result is **bit-identical to
    re-ingesting the union** — including ``content_hash``, because the
    manifest ``stats`` block (where the chain provenance is recorded) is
    excluded from the hash by design.
    """
    graph = chain.graph()
    stats = {
        "source": f"compact:{chain.base.path}",
        "compacted_from_chain": chain.content_hash,
        "chain_depth": chain.depth,
        "n_deltas": len(chain.deltas),
        "edges_directed": int(chain.n_edges_directed),
        "n_nodes": int(chain.n_nodes),
    }
    names = chain.entity_names() if chain.has_names else None
    return write_artifact(path, graph, chain.index(), tau=chain.tau,
                          stats=stats, labels=graph.labels, names=names,
                          overwrite=overwrite)
