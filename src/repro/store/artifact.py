"""Versioned on-disk graph artifacts: write once, mmap-open in milliseconds.

A :class:`GraphArtifact` is a directory of raw ``.npy`` buffers plus a
``manifest.json``:

    artifact/
      manifest.json            magic, format version, counts, tau,
                               per-buffer {dtype, shape, sha256},
                               ingest stats, content_hash
      src.npy dst.npy w.npy    directed raw edges (int32/int32/float32)
      indptr.npy indices.npy   symmetrized CSR (int64 / int32 / float32)
      ew.npy
      sym_src.npy sym_dst.npy  dst-sorted symmetric edge list — the exact
      sym_w.npy                DeviceGraph layout, so loading skips the sort
      pred.npy conf.npy        typed channel (format v2, typed graphs only):
      csr_pred.npy             per-edge predicate id + confidence for the
      csr_conf.npy             directed, CSR, and dst-sorted symmetric
      sym_pred.npy             layouts; the predicate dictionary itself
      sym_conf.npy             lives in the manifest (``predicates``)
      post_offsets.npy         InvertedIndex frozen postings (int64[T+1] /
      post_nodes.npy           int32[sum df]) + the vocabulary keys
      token_keys.npy           (int tokens)  — or token_offsets.npy +
                               token_bytes.npy (utf-8 str tokens)
      label_offsets.npy        optional node label text (utf-8 blob +
      label_bytes.npy          int64[V+1] offsets)
      ent_offsets.npy          optional entity-name table (same layout):
      ent_bytes.npy            the ingest dictionary keys in id order —
                               the substrate delta artifacts stack on

Buffers are opened with ``np.load(mmap_mode="r")`` — nothing is read until
touched, so opening a multi-GB artifact costs a manifest parse, not a
graph rebuild.  The vocabulary is persisted as a *sorted* token table
(:meth:`InvertedIndex.to_postings` emits it sorted), so the loaded index
(:class:`LazyArtifactIndex`) resolves tokens by binary search over the
mmapped table — O(log T) touched pages per lookup, and **O(1) in
vocabulary size at open time**: no token dict is ever materialized unless
a caller enumerates ``vocabulary()``.  Writes are atomic: everything
lands in a ``<path>.tmp-<pid>`` sibling first and is renamed into place,
so a crashed ingest can never leave a half-written artifact at the
target path.

Validation is layered: :func:`open_artifact` always checks the magic and
format version (``FormatVersionError`` on mismatch) and that every buffer's
on-disk dtype/shape matches its manifest entry (``ArtifactError``);
``verify="full"`` additionally re-hashes every buffer file against the
recorded sha256 (``ChecksumError`` — use for freshly copied artifacts).
``content_hash`` — a sha256 over the manifest's scalar metadata and buffer
hashes — identifies the graph *content*: engines built from an artifact
fold it into ``QueryEngine.version`` / ``cache_token``, so a result cache
can never serve answers computed against a different graph build.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
from pathlib import Path
from typing import Any

import numpy as np

from repro.graph.index import InvertedIndex
from repro.graph.structure import Graph

MAGIC = "repro-graph-artifact"
# Magic of a *delta* artifact (repro.store.delta) — named here so the base
# reader can say "that's a delta, open the chain" instead of a generic
# magic mismatch when the two get confused for each other.
DELTA_MAGIC = "repro-graph-delta"
# v1: untyped single-weight artifacts.  v2 adds the optional typed channel
# (pred/conf buffers + manifest "predicates") — pure superset: a v2
# artifact of an untyped graph differs from v1 only in the version field,
# and this reader opens both (v1 artifacts keep serving bit-identical
# results under the default WeightPolicy).  The optional entity-name table
# (``ent_offsets``/``ent_bytes``, the live-graph delta substrate) is a
# further pure superset within v2: readers load only the buffers the
# manifest lists, so artifacts without it open unchanged.
FORMAT_VERSION = 2
SUPPORTED_VERSIONS = (1, 2)
_MANIFEST = "manifest.json"


class ArtifactError(RuntimeError):
    """Malformed, incomplete, or mismatched artifact."""


class FormatVersionError(ArtifactError):
    """The artifact's magic/format version doesn't match this reader."""


class ChecksumError(ArtifactError):
    """A buffer's bytes don't hash to the manifest's recorded sha256."""


def _sha256_file(path: Path, chunk: int = 1 << 22) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                return h.hexdigest()
            h.update(block)


def _encode_strings(strings: list[str]) -> tuple[np.ndarray, np.ndarray]:
    """utf-8 blob + int64[n+1] offsets (the persisted string-list layout)."""
    encoded = [s.encode("utf-8") for s in strings]
    offsets = np.zeros(len(encoded) + 1, np.int64)
    np.cumsum([len(b) for b in encoded], out=offsets[1:])
    blob = np.frombuffer(b"".join(encoded), dtype=np.uint8).copy()
    return offsets, blob


def _decode_strings(offsets: np.ndarray, blob: np.ndarray) -> list[str]:
    data = blob.tobytes()
    return [data[offsets[i]:offsets[i + 1]].decode("utf-8")
            for i in range(len(offsets) - 1)]


@dataclasses.dataclass(frozen=True)
class _BufferSpec:
    file: str
    dtype: str
    shape: tuple[int, ...]
    sha256: str


class LazyArtifactIndex(InvertedIndex):
    """An :class:`InvertedIndex` resolved straight off the mmapped
    artifact buffers: token -> posting is a binary search over the
    persisted *sorted* token table, and posting lists are mmap views.

    Nothing vocabulary-sized is materialized at construction — opening an
    artifact stays O(1) in vocabulary — and a lookup touches O(log T)
    pages of the token table plus the one posting it returns.
    ``vocabulary()`` / ``to_postings()`` do materialize the token list
    (callers that enumerate the vocabulary, e.g. the CLI keyword
    auto-pick, pay for what they use).
    """

    def __init__(self, artifact: "GraphArtifact") -> None:
        super().__init__()
        self._n_tokens = int(artifact.manifest["n_tokens"])
        self._token_kind = artifact.token_kind
        self._offsets = artifact.buffer("post_offsets")
        self._nodes = artifact.buffer("post_nodes")
        if self._token_kind == "int":
            self._keys = artifact.buffer("token_keys")
        else:
            self._tok_off = artifact.buffer("token_offsets")
            self._tok_blob = artifact.buffer("token_bytes")

    def _token_at(self, i: int):
        if self._token_kind == "int":
            return int(self._keys[i])
        return bytes(
            self._tok_blob[self._tok_off[i]:self._tok_off[i + 1]]
        ).decode("utf-8")

    def _find(self, token) -> int:
        """Sorted-table position of ``token``, or -1.  The table order is
        the writer's ``sorted()`` — ascending ints, or code-point order
        for strings, which utf-8 byte comparison reproduces exactly."""
        n = self._n_tokens
        if self._token_kind == "int":
            if not isinstance(token, (int, np.integer)):
                return -1
            i = int(np.searchsorted(self._keys, int(token)))
            return i if i < n and int(self._keys[i]) == int(token) else -1
        if not isinstance(token, str):
            return -1
        key = token.encode("utf-8")
        lo, hi = 0, n
        while lo < hi:
            mid = (lo + hi) // 2
            b = bytes(self._tok_blob[
                self._tok_off[mid]:self._tok_off[mid + 1]])
            if b < key:
                lo = mid + 1
            else:
                hi = mid
        if lo < n and bytes(self._tok_blob[
                self._tok_off[lo]:self._tok_off[lo + 1]]) == key:
            return lo
        return -1

    def lookup(self, token) -> np.ndarray:
        i = self._find(token)
        if i < 0:
            return np.zeros(0, np.int32)
        return self._nodes[self._offsets[i]:self._offsets[i + 1]]

    def df(self, token) -> int:
        i = self._find(token)
        return 0 if i < 0 else int(self._offsets[i + 1] - self._offsets[i])

    def vocabulary(self) -> list:
        return [self._token_at(i) for i in range(self._n_tokens)]

    def token_dfs(self) -> list[tuple]:
        """Bulk ``(token, df)`` enumeration: one diff over the offsets
        table — not a binary search per token like ``df()`` would be."""
        dfs = np.diff(np.asarray(self._offsets))
        return [(self._token_at(i), int(dfs[i]))
                for i in range(self._n_tokens)]

    def to_postings(self) -> tuple[list, np.ndarray, np.ndarray]:
        return (self.vocabulary(), np.asarray(self._offsets),
                np.asarray(self._nodes, np.int32))


class BufferDir:
    """Shared plumbing for a directory of manifest-described ``.npy``
    buffers: lazy mmap access plus layered validation.  Base class of
    :class:`GraphArtifact` and :class:`repro.store.delta.DeltaArtifact`.
    """

    def __init__(self, path: Path, manifest: dict[str, Any]) -> None:
        self.path = Path(path)
        self.manifest = manifest
        self._buffers: dict[str, _BufferSpec] = {
            name: _BufferSpec(file=spec["file"], dtype=spec["dtype"],
                              shape=tuple(spec["shape"]),
                              sha256=spec["sha256"])
            for name, spec in manifest["buffers"].items()}
        self._arrays: dict[str, np.ndarray] = {}

    @property
    def format_version(self) -> int:
        return int(self.manifest["format_version"])

    @property
    def content_hash(self) -> str:
        return self.manifest["content_hash"]

    @property
    def stats(self) -> dict[str, Any]:
        """Ingestion stats recorded at write time (true counts etc.)."""
        return self.manifest.get("stats", {})

    def nbytes(self) -> int:
        """Total on-disk buffer bytes (payload, excluding npy headers)."""
        return sum(int(np.prod(spec.shape)) * np.dtype(spec.dtype).itemsize
                   for spec in self._buffers.values())

    def buffer(self, name: str) -> np.ndarray:
        """Memory-mapped view of one buffer (cached, read-only)."""
        arr = self._arrays.get(name)
        if arr is None:
            spec = self._buffers.get(name)
            if spec is None:
                raise ArtifactError(f"artifact has no buffer {name!r} "
                                    f"({self.path})")
            arr = np.load(self.path / spec.file, mmap_mode="r")
            if str(arr.dtype) != spec.dtype or arr.shape != spec.shape:
                raise ArtifactError(
                    f"buffer {name!r} on disk is {arr.dtype}{arr.shape}, "
                    f"manifest says {spec.dtype}{spec.shape} ({self.path})")
            self._arrays[name] = arr
        return arr

    def validate(self) -> None:
        """Cheap structural check: every buffer opens and matches its
        manifest dtype/shape (reads npy headers only, not the data)."""
        for name in self._buffers:
            self.buffer(name)

    def verify_checksums(self) -> None:
        """Re-hash every buffer file against the manifest (full read)."""
        for name, spec in self._buffers.items():
            digest = _sha256_file(self.path / spec.file)
            if digest != spec.sha256:
                raise ChecksumError(
                    f"buffer {name!r} hash mismatch in {self.path}: "
                    f"{digest[:16]}… != recorded {spec.sha256[:16]}… "
                    "(artifact corrupted or truncated)")


class GraphArtifact(BufferDir):
    """An opened artifact: manifest metadata + lazily mmapped buffers.

    Use :func:`open_artifact` (or :func:`write_artifact`, which returns the
    reopened artifact) rather than constructing directly.  ``graph()`` and
    ``index()`` build the engine-facing objects on top of the mmapped
    buffers without re-tokenizing or re-sorting anything.
    """

    def __init__(self, path: Path, manifest: dict[str, Any]) -> None:
        super().__init__(path, manifest)
        self._graph: Graph | None = None
        self._index: InvertedIndex | None = None

    # -- manifest metadata ---------------------------------------------

    @property
    def n_nodes(self) -> int:
        return int(self.manifest["n_nodes"])

    @property
    def n_edges_directed(self) -> int:
        return int(self.manifest["n_edges_directed"])

    @property
    def n_edges_sym(self) -> int:
        return int(self.manifest["n_edges_sym"])

    @property
    def tau(self) -> int:
        return int(self.manifest["tau"])

    @property
    def token_kind(self) -> str:
        return self.manifest["token_kind"]  # "int" | "str"

    @property
    def has_labels(self) -> bool:
        return "label_offsets" in self._buffers

    @property
    def has_names(self) -> bool:
        """True when the entity-name table is persisted.  Names are the
        ingest-time dictionary keys (e.g. full URIs), distinct from the
        display labels — deltas need them to resolve existing entities."""
        return "ent_offsets" in self._buffers

    @property
    def typed(self) -> bool:
        """True when the artifact persists the per-edge (pred, conf)
        channel (format v2 typed graphs)."""
        return "csr_pred" in self._buffers

    @property
    def predicates(self) -> list[str]:
        """Predicate dictionary recorded at write time (empty when
        untyped — v1 artifacts never have one)."""
        return list(self.manifest.get("predicates", []))

    # -- engine-facing objects -----------------------------------------

    def graph(self) -> Graph:
        """Host :class:`Graph` over the mmapped buffers (zero-copy: CSR,
        raw edges, and the dst-sorted symmetric list are all views).

        ``labels`` stays ``None`` here — the engine takes the persisted
        index instead of re-tokenizing; call :meth:`labels` when the text
        itself is needed."""
        if self._graph is None:
            typed: dict[str, Any] = {}
            if self.typed:
                typed = dict(
                    csr_pred=self.buffer("csr_pred"),
                    csr_conf=self.buffer("csr_conf"),
                    sym_typed=(self.buffer("sym_pred"),
                               self.buffer("sym_conf")),
                    pred_names=self.predicates,
                )
                if "pred" in self._buffers:
                    typed["pred"] = self.buffer("pred")
                    typed["conf"] = self.buffer("conf")
            self._graph = Graph(
                n_nodes=self.n_nodes,
                src=self.buffer("src"), dst=self.buffer("dst"),
                w=self.buffer("w"),
                indptr=self.buffer("indptr"),
                indices=self.buffer("indices"), ew=self.buffer("ew"),
                labels=None,
                sym_sorted=(self.buffer("sym_src"),
                            self.buffer("sym_dst"),
                            self.buffer("sym_w")),
                **typed,
            )
        return self._graph

    def index(self) -> InvertedIndex:
        """The persisted :class:`InvertedIndex`, fully lazy
        (:class:`LazyArtifactIndex`): tokens resolve by binary search over
        the mmapped sorted token table and postings stay on disk until
        looked up — no token dict is materialized, so this is O(1) in
        vocabulary size (the former dict build made artifact open scale
        with the vocabulary)."""
        if self._index is None:
            self._index = LazyArtifactIndex(self)
        return self._index

    def labels(self) -> list[str] | None:
        """Decode the node label text (materializes V strings)."""
        if not self.has_labels:
            return None
        return _decode_strings(np.asarray(self.buffer("label_offsets")),
                               self.buffer("label_bytes"))

    def label(self, i: int) -> str:
        """Decode ONE node's label straight off the mmapped blob — answer
        rendering pays per served node, not per graph."""
        if not self.has_labels:
            raise ArtifactError(f"artifact has no labels ({self.path})")
        offsets = self.buffer("label_offsets")
        if not 0 <= i < len(offsets) - 1:
            raise IndexError(f"label index {i} out of range "
                             f"[0, {len(offsets) - 1})")
        blob = self.buffer("label_bytes")
        return blob[int(offsets[i]):int(offsets[i + 1])].tobytes() \
            .decode("utf-8")

    def entity_names(self) -> list[str]:
        """Decode the entity-name table (ingest dictionary keys, id order).

        Raises :class:`ArtifactError` when the table wasn't persisted —
        only reader-produced artifacts written by this version carry it,
        and without it a delta cannot resolve existing entities."""
        if not self.has_names:
            raise ArtifactError(
                f"artifact has no entity-name table ({self.path}) — "
                "re-ingest the source with this version to enable delta "
                "stacking")
        return _decode_strings(np.asarray(self.buffer("ent_offsets")),
                               self.buffer("ent_bytes"))

    def entity_name(self, i: int) -> str:
        """Decode ONE entity name straight off the mmapped blob."""
        if not self.has_names:
            raise ArtifactError(f"artifact has no entity-name table "
                                f"({self.path})")
        offsets = self.buffer("ent_offsets")
        if not 0 <= i < len(offsets) - 1:
            raise IndexError(f"entity index {i} out of range "
                             f"[0, {len(offsets) - 1})")
        blob = self.buffer("ent_bytes")
        return blob[int(offsets[i]):int(offsets[i + 1])].tobytes() \
            .decode("utf-8")

    def __repr__(self) -> str:
        chain = ""
        st = self.manifest.get("stats") or {}
        if "compacted_from_chain" in st:
            chain = (f", compacted[chain={str(st['compacted_from_chain'])[:12]}…"
                     f", depth={st.get('chain_depth')}]")
        return (f"GraphArtifact({str(self.path)!r}, V={self.n_nodes:,}, "
                f"E_sym={self.n_edges_sym:,}, "
                f"hash={self.content_hash[:12]}…{chain})")


def _content_hash(meta: dict[str, Any],
                  buffers: dict[str, dict[str, Any]]) -> str:
    """Deterministic digest of the graph *content*: scalar metadata plus
    every buffer's recorded hash (canonical JSON, sorted keys)."""
    payload = {"meta": meta,
               "buffers": {k: v["sha256"] for k, v in sorted(
                   buffers.items())}}
    return hashlib.sha256(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()


def write_artifact(
    path: str | Path,
    graph: Graph,
    index: InvertedIndex,
    *,
    tau: int = 1001,
    stats: dict[str, Any] | None = None,
    labels: list[str] | None = None,
    names: list[str] | None = None,
    overwrite: bool = False,
) -> GraphArtifact:
    """Write ``(graph, index)`` as a versioned artifact and reopen it.

    Atomic: buffers and manifest land in a temp sibling directory which is
    renamed onto ``path`` last — readers never observe a partial write.
    ``stats`` (e.g. ``IngestStats.as_dict()``) is recorded verbatim in the
    manifest.  ``labels`` defaults to ``graph.labels``.  ``names`` is the
    optional entity-name table (ingest dictionary keys in id order, e.g.
    full URIs) — persisting it makes the artifact a valid base for delta
    stacking (:mod:`repro.store.delta`).  Returns the artifact *reopened
    from disk*, so the caller's engine build exercises the same mmap path
    a later process will.
    """
    path = Path(path)
    if path.exists() and not overwrite:
        raise ArtifactError(
            f"artifact path exists: {path} (pass overwrite=True)")
    tmp = path.parent / f"{path.name}.tmp-{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    try:
        _write_buffers(tmp, graph, index, tau=tau, stats=stats,
                       labels=labels, names=names)
    except BaseException:
        # Never leave half-written debris behind: only the atomic rename
        # below publishes state.
        shutil.rmtree(tmp, ignore_errors=True)
        raise

    if path.exists():  # overwrite=True: checked above
        shutil.rmtree(path)
    os.replace(tmp, path)
    return open_artifact(path)


def _write_buffers(
    tmp: Path,
    graph: Graph,
    index: InvertedIndex,
    *,
    tau: int,
    stats: dict[str, Any] | None,
    labels: list[str] | None,
    names: list[str] | None = None,
) -> None:
    labels = graph.labels if labels is None else labels
    tokens, post_offsets, post_nodes = index.to_postings()
    token_kind = ("int" if not tokens or isinstance(tokens[0], (int,
                  np.integer)) else "str")

    arrays: dict[str, np.ndarray] = {
        "src": np.ascontiguousarray(graph.src, np.int32),
        "dst": np.ascontiguousarray(graph.dst, np.int32),
        "w": np.ascontiguousarray(graph.w, np.float32),
        "indptr": np.ascontiguousarray(graph.indptr, np.int64),
        "indices": np.ascontiguousarray(graph.indices, np.int32),
        "ew": np.ascontiguousarray(graph.ew, np.float32),
        "post_offsets": post_offsets,
        "post_nodes": np.ascontiguousarray(post_nodes, np.int32),
    }
    sym_src, sym_dst, sym_w = graph.sym_sorted_edges(cache=True)
    arrays["sym_src"] = np.ascontiguousarray(sym_src, np.int32)
    arrays["sym_dst"] = np.ascontiguousarray(sym_dst, np.int32)
    arrays["sym_w"] = np.ascontiguousarray(sym_w, np.float32)
    if graph.typed:
        arrays["csr_pred"] = np.ascontiguousarray(graph.csr_pred, np.int32)
        arrays["csr_conf"] = np.ascontiguousarray(graph.csr_conf, np.float32)
        sym_pred, sym_conf = graph.sym_typed_edges(cache=True)
        arrays["sym_pred"] = np.ascontiguousarray(sym_pred, np.int32)
        arrays["sym_conf"] = np.ascontiguousarray(sym_conf, np.float32)
        if graph.pred is not None:
            arrays["pred"] = np.ascontiguousarray(graph.pred, np.int32)
            arrays["conf"] = np.ascontiguousarray(graph.conf, np.float32)
    if token_kind == "int":
        arrays["token_keys"] = np.asarray([int(t) for t in tokens],
                                          np.int64)
    else:
        tok_off, tok_blob = _encode_strings([str(t) for t in tokens])
        arrays["token_offsets"] = tok_off
        arrays["token_bytes"] = tok_blob
    if labels is not None:
        lab_off, lab_blob = _encode_strings(list(labels))
        arrays["label_offsets"] = lab_off
        arrays["label_bytes"] = lab_blob
    if names is not None:
        ent_off, ent_blob = _encode_strings(list(names))
        arrays["ent_offsets"] = ent_off
        arrays["ent_bytes"] = ent_blob

    buffers: dict[str, dict[str, Any]] = {}
    for name, arr in arrays.items():
        fname = f"{name}.npy"
        np.save(tmp / fname, arr)
        buffers[name] = {
            "file": fname,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "sha256": _sha256_file(tmp / fname),
        }

    meta = {
        "magic": MAGIC,
        "format_version": FORMAT_VERSION,
        "n_nodes": int(graph.n_nodes),
        "n_edges_directed": int(graph.n_edges_directed),
        "n_edges_sym": int(graph.n_edges_sym),
        "tau": int(tau),
        "token_kind": token_kind,
        "n_tokens": len(tokens),
    }
    if graph.typed:
        # Predicate dictionary in the (content-hashed) meta: the artifact
        # is self-describing — names, not just a count — and renaming a
        # predicate changes the content identity.
        meta["predicates"] = list(graph.pred_names or [])
    manifest = dict(meta)
    manifest["stats"] = stats or {}
    manifest["buffers"] = buffers
    manifest["content_hash"] = _content_hash(meta, buffers)
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1))


def open_artifact(path: str | Path,
                  verify: str = "meta") -> GraphArtifact:
    """Open an artifact for reading (mmap; nothing large is touched).

    ``verify``: ``"meta"`` (default) checks magic/format version and that
    every buffer's on-disk dtype/shape matches the manifest; ``"full"``
    additionally re-hashes every buffer against its recorded sha256.
    Raises :class:`FormatVersionError` on a version mismatch,
    :class:`ChecksumError` on corruption, :class:`ArtifactError` on
    anything structurally wrong.
    """
    if verify not in ("meta", "full"):
        raise ValueError(f"unknown verify={verify!r} "
                         "(expected 'meta' or 'full')")
    path = Path(path)
    mpath = path / _MANIFEST
    if not mpath.is_file():
        raise ArtifactError(f"no graph artifact at {path} "
                            f"(missing {_MANIFEST})")
    try:
        manifest = json.loads(mpath.read_text())
    except json.JSONDecodeError as exc:
        raise ArtifactError(f"unreadable manifest in {path}: {exc}") from exc
    if manifest.get("magic") != MAGIC:
        if manifest.get("magic") == DELTA_MAGIC:
            raise FormatVersionError(
                f"{path} is a delta artifact stacking on base "
                f"{str(manifest.get('base_content_hash'))[:12]}… at depth "
                f"{manifest.get('base_depth', 0) + 1} — open it with "
                "repro.store.open_chain(base, …), not open_artifact()")
        raise FormatVersionError(
            f"{path} is not a {MAGIC} (magic={manifest.get('magic')!r})")
    version = manifest.get("format_version")
    if version not in SUPPORTED_VERSIONS:
        raise FormatVersionError(
            f"artifact format v{version} at {path}; this reader supports "
            f"v{SUPPORTED_VERSIONS} — re-ingest the source with this "
            "version")
    for key in ("content_hash", "buffers", "n_nodes"):
        if key not in manifest:
            raise ArtifactError(f"manifest missing {key!r} in {path}")
    art = GraphArtifact(path, manifest)
    art.validate()
    if verify == "full":
        art.verify_checksums()
    return art
