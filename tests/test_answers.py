"""The repro.answers subsystem: device-batched backtrace parity,
diversified ranking, rendering/pagination, and streaming extraction
overlap."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.answers import (
    BatchedBacktracer,
    ExtractionOverlap,
    cluster_trees,
    diversified_order,
    paginate,
    render_tree,
    split_pair_table,
    top_k_diverse,
    tree_distance,
)
from repro.core.reconstruct import AnswerTree, collect_answers
from repro.engine import ExecutionPolicy, QueryEngine
from repro.graph.generators import random_weighted_graph


def tree(root, edges, weight):
    nodes = tuple(sorted({n for e in edges for n in e} | {root}))
    return AnswerTree(root=root, edges=tuple(sorted(edges)), weight=weight,
                      raw_value=weight, nodes=nodes)


def lane_tables(g, masks_host, k, L=4, max_supersteps=24):
    """Final lane-batched tables straight off the fused driver."""
    engine = QueryEngine.build(
        g, tokens=np.zeros((g.n_nodes, 1), np.int64),
        policy=ExecutionPolicy(max_supersteps=max_supersteps))
    m = masks_host.shape[0]
    kw = np.zeros((L, m, engine.device_graph.v_pad), bool)
    kw[:, :, : g.n_nodes] = masks_host
    fn = engine._executable(engine._config(m, k), "fused")
    states = engine._execute(fn, engine.device_graph, jnp.asarray(kw))
    return np.asarray(states.S), kw


# -- device-batched backtrace ------------------------------------------


def test_split_pair_table_matches_host_scan():
    pa, pb = split_pair_table(3)
    # ks=0b111: host scans a = 6,5,4,3,2,1 keeping a <= b, so the kept
    # pairs arrive as (3,4),(2,5),(1,6).
    row = [(int(a), int(b)) for a, b in zip(pa[7], pb[7]) if a > 0]
    assert row == [(3, 4), (2, 5), (1, 6)]
    # Singletons split nowhere.
    assert int(pa[1].max()) == 0 and int(pa[2].max()) == 0


@pytest.mark.parametrize("seed", range(6))
def test_batched_backtrace_bit_identical_to_host(seed):
    rng = np.random.default_rng(300 + seed)
    n = int(rng.integers(10, 24))
    g = random_weighted_graph(n, n + int(rng.integers(6, 30)), seed=seed)
    m = int(rng.integers(2, 4))
    k = int(rng.integers(1, 4))
    masks_host = np.zeros((m, n), bool)
    for t in range(m):
        masks_host[t, rng.choice(n, size=max(1, n // 4), replace=False)] = True
    S_all, kw = lane_tables(g, masks_host, k)
    bt = BatchedBacktracer(g)
    got = bt.extract_lanes(S_all, kw, k=k, n_nodes=n)
    assert bt.device_resolved > 0, "device pass resolved nothing"
    for lane in range(S_all.shape[0]):
        ref, ex_ref = collect_answers(S_all[lane], g, masks_host, k=k)
        ans, ex = got[lane]
        key = lambda a: (a.root, a.weight, tuple(sorted(a.edges)))
        assert [key(a) for a in ans] == [key(a) for a in ref]
        assert ex == ex_ref


def test_ragged_stragglers_fall_back_to_host():
    """A degree window smaller than the hub degree must produce the same
    answers anyway — via the host fallback."""
    seed = 5
    rng = np.random.default_rng(400)
    n = 16
    g = random_weighted_graph(n, 48, seed=seed)
    masks_host = np.zeros((2, n), bool)
    masks_host[0, rng.choice(n, 4, replace=False)] = True
    masks_host[1, rng.choice(n, 4, replace=False)] = True
    S_all, kw = lane_tables(g, masks_host, k=2)
    tight = BatchedBacktracer(g, degree_cap=1, buffer=3)
    got = tight.extract_lanes(S_all, kw, k=2, n_nodes=n)
    assert tight.host_fallbacks > 0, "tight caps should produce stragglers"
    for lane in range(S_all.shape[0]):
        ref, _ = collect_answers(S_all[lane], g, masks_host, k=2)
        ans, _ = got[lane]
        key = lambda a: (a.root, a.weight, tuple(sorted(a.edges)))
        assert [key(a) for a in ans] == [key(a) for a in ref]


# -- diversified ranking ------------------------------------------------


def test_tree_distance_extremes():
    a = tree(0, [(0, 1), (1, 2)], 2.0)
    b = tree(0, [(0, 1), (1, 2)], 2.0)
    c = tree(7, [(7, 8)], 1.0)
    assert tree_distance(a, b) == 0.0
    assert tree_distance(a, c) == 1.0
    assert 0.0 < tree_distance(a, tree(0, [(0, 1), (1, 3)], 2.0)) < 1.0


def test_diversified_order_is_permutation_and_leads_with_best():
    trees = [tree(0, [(0, 1), (1, 2)], 2.0),
             tree(0, [(0, 1), (1, 3)], 2.1),   # near-copy of #0
             tree(7, [(7, 8), (8, 9)], 2.2),   # disjoint
             tree(0, [(0, 1), (1, 4)], 2.3)]   # near-copy of #0
    order = diversified_order(trees, lambda_=0.5)
    assert sorted(order) == [0, 1, 2, 3]
    assert order[0] == 0
    # The disjoint tree outranks the near-copies under diversification.
    assert order[1] == 2
    # lambda_=1 reproduces weight order exactly.
    assert diversified_order(trees, lambda_=1.0) == [0, 1, 2, 3]
    with pytest.raises(ValueError):
        diversified_order(trees, lambda_=1.5)


def test_top_k_diverse_no_duplicates():
    trees = [tree(0, [(0, 1), (1, 2)], 2.0),
             tree(0, [(0, 1), (1, 2)], 2.0),   # exact duplicate
             tree(7, [(7, 8)], 3.0)]
    top = top_k_diverse(trees, 2, lambda_=0.5)
    assert len(top) == 2
    assert tree_distance(top[0], top[1]) > 0.0


def test_cluster_trees_groups_near_copies():
    trees = [tree(0, [(0, 1), (1, 2)], 2.0),
             tree(0, [(0, 1), (1, 3)], 2.1),
             tree(7, [(7, 8), (8, 9)], 2.2)]
    clusters = cluster_trees(trees, threshold=0.6)
    assert [0, 1] in clusters and [2] in clusters


# -- rendering / pagination ---------------------------------------------


def test_render_and_paginate():
    g = random_weighted_graph(6, 10, seed=1)
    trees = [tree(0, [(0, 1)], 1.0), tree(2, [(2, 3)], 1.5),
             tree(4, [(4, 5)], 2.0)]
    labels = {i: f"entity-{i}" for i in range(6)}
    page = paginate(trees, [0, 1, 2], cursor=0, page_size=2,
                    ranking="weight", exhausted=False,
                    label_fn=labels.get, graph=g)
    assert [t.root_label for t in page.items] == ["entity-0", "entity-2"]
    assert page.next_cursor == 2 and page.total == 3
    # Edge weights come from the graph, labels from label_fn.
    e = page.items[0].edges[0]
    assert e.u_label == "entity-0" and e.weight > 0.0
    assert "entity-0" in page.items[0].describe()
    # Last page: clamped cursor, next_cursor None.
    last = paginate(trees, [0, 1, 2], cursor=2, page_size=2,
                    ranking="weight", exhausted=True)
    assert len(last.items) == 1 and last.next_cursor is None
    assert last.exhausted
    # Default labels without a label_fn.
    assert last.items[0].root_label == "node:4"
    beyond = paginate(trees, [0, 1, 2], cursor=99, page_size=2,
                      ranking="weight", exhausted=False)
    assert beyond.items == () and beyond.next_cursor is None


def test_render_single_node_tree():
    t = AnswerTree(root=3, edges=(), weight=0.0, raw_value=0.0, nodes=(3,))
    rt = render_tree(t)
    assert "single node" in rt.describe()


# -- streaming extraction -----------------------------------------------


def test_extraction_overlap_matches_inline():
    rng = np.random.default_rng(7)
    n = 12
    g = random_weighted_graph(n, 30, seed=3)
    masks_host = np.zeros((2, n), bool)
    masks_host[0, rng.choice(n, 3, replace=False)] = True
    masks_host[1, rng.choice(n, 3, replace=False)] = True
    S_all, _ = lane_tables(g, masks_host, k=2, L=3)
    with ExtractionOverlap(g, k=2) as ov:
        ov.submit(0, S_all[0], masks_host)
        ov.submit(0, S_all[0], masks_host)  # idempotent per lane
        ov.submit(1, S_all[1], masks_host)
        assert ov.pending(0) and ov.pending(1) and not ov.pending(2)
        got0 = ov.result(0)
        got2 = ov.result(2, S_all[2], masks_host)  # inline path
        assert ov.overlapped == 2 and ov.inline == 1
        with pytest.raises(ValueError):
            ov.result(9)
    for lane, got in ((0, got0), (2, got2)):
        ref = collect_answers(S_all[lane], g, masks_host, k=2)
        key = lambda a: (a.root, a.weight, tuple(sorted(a.edges)))
        assert [key(a) for a in got[0]] == [key(a) for a in ref[0]]
        assert got[1] == ref[1]
