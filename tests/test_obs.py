"""Observability tests: tracer ring + deterministic sampling, registry
render/parse round-trip, fused-loop telemetry bit-identity (dense and
sharded), instrumented-surface parity with the shared collector, the
serve-layer /metrics surface (counters equal ServeStats, monotone across
scrapes), and trace completeness under coalescing + single-flight."""

import json
import urllib.request

import numpy as np
import pytest

from repro.engine import ExecutionPolicy, QueryEngine
from repro.graph.generators import lod_like_graph
from repro.graph.index import InvertedIndex
from repro.obs import (
    MetricsRegistry,
    MetricsServer,
    Tracer,
    parse_prometheus,
    render_span_tree,
)
from repro.serve import DKSService, ServeConfig
from repro.serve.loadgen import latency_split
from repro.serve.stats import StatsCollector


@pytest.fixture(scope="module")
def graph_data():
    g, tokens = lod_like_graph(600, 1800, seed=11, vocab=120)
    return g, InvertedIndex.from_token_matrix(tokens)


@pytest.fixture(scope="module")
def engine(graph_data):
    g, index = graph_data
    return QueryEngine.build(
        g, index=index, policy=ExecutionPolicy(max_supersteps=32))


@pytest.fixture(scope="module")
def tel_engine(graph_data):
    g, index = graph_data
    return QueryEngine.build(
        g, index=index,
        policy=ExecutionPolicy(max_supersteps=32, telemetry=True))


def mid_df_tokens(index, n, lo=2, hi=60):
    toks = [t for t in sorted(index.vocabulary(), key=index.df)
            if lo <= index.df(t) <= hi]
    assert len(toks) >= n
    return toks[:n]


# ---------------------------------------------------------------------------
# repro.obs.trace
# ---------------------------------------------------------------------------


def test_tracer_ring_bounded_and_counters():
    tracer = Tracer(capacity=4)
    ids = []
    for i in range(10):
        tr = tracer.begin("req", i=i)
        with tr.span("outer") as outer:
            outer.set(note="x")
            with tr.span("inner"):
                pass
        tr.add_span("retro", tr.t_start, tr.t_start + 0.001, kind="queue")
        tr.finish()
        tr.finish()  # idempotent: must not double-count
        ids.append(tr.trace_id)
    st = tracer.stats()
    assert st == {"begun": 10, "finished": 10, "sampled": 10, "buffered": 4}
    # The ring keeps the newest `capacity` traces, newest last.
    assert [t.trace_id for t in tracer.recent()] == ids[-4:]
    assert tracer.get(ids[0]) is None and tracer.get(ids[-1]) is not None
    # Span tree: inner nested under outer (same thread), retro a sibling.
    tr = tracer.get(ids[-1])
    by_name = {sp.name: sp for sp in tr.spans}
    assert by_name["inner"].parent_id == by_name["outer"].span_id
    assert by_name["retro"].parent_id is None
    rendered = render_span_tree(tr)
    for name in ("outer", "inner", "retro", "note=x"):
        assert name in rendered
    # to_dict round-trips through JSON, spans ordered by start time (the
    # retro span was backdated to trace start, so it sorts first).
    d = json.loads(json.dumps(tr.to_dict()))
    assert [s["name"] for s in d["spans"]] == ["retro", "outer", "inner"]


def test_sampling_deterministic_per_seed():
    def sampled_ids(seed):
        tracer = Tracer(capacity=256, sample=0.3, seed=seed)
        out = set()
        for _ in range(200):
            tr = tracer.begin("req")
            if tr.sampled:
                out.add(tr.trace_id)
            with tr.span("s"):
                pass
            tr.finish()
        return out, tracer.stats()

    a, st_a = sampled_ids(7)
    b, _ = sampled_ids(7)
    c, _ = sampled_ids(8)
    assert a == b, "same seed must sample the same trace ids"
    assert a != c, "a different seed must pick a different subset"
    assert 0 < len(a) < 200
    # Unsampled traces still finish (completeness counts every request)
    # but record no spans and stay out of the ring.
    assert st_a["begun"] == st_a["finished"] == 200
    assert st_a["sampled"] == st_a["buffered"] == len(a)
    tracer = Tracer(sample=0.0)
    tr = tracer.begin("req")
    with tr.span("ignored") as h:
        h.set(x=1)
    tr.finish()
    assert tr.spans == [] and tracer.stats()["sampled"] == 0


def test_trace_log_jsonl(tmp_path):
    log = tmp_path / "traces.jsonl"
    tracer = Tracer(capacity=8, log_path=str(log))
    for i in range(3):
        tr = tracer.begin("req", i=i)
        with tr.span("work"):
            pass
        tr.finish()
    lines = [json.loads(ln) for ln in log.read_text().splitlines()]
    assert [d["attrs"]["i"] for d in lines] == [0, 1, 2]
    assert all(d["spans"][0]["name"] == "work" for d in lines)


# ---------------------------------------------------------------------------
# repro.obs.metrics
# ---------------------------------------------------------------------------


def test_registry_render_parse_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("rt_requests_total", "requests")
    g = reg.gauge("rt_depth", "queue depth")
    h = reg.histogram("rt_latency_ms", "latency", buckets=(1.0, 10.0, 100.0))
    c.inc(); c.inc(2.5)
    g.set(7); g.dec(2)
    for v in (0.5, 5.0, 50.0, 500.0):
        h.observe(v)
    reg.register_collector(
        lambda: {"rt_external_total": 42},
        kinds={"rt_external_total": "counter"})

    parsed = parse_prometheus(reg.render())
    assert parsed == reg.sample()
    assert parsed["rt_requests_total"] == 3.5
    assert parsed["rt_depth"] == 5.0
    assert parsed["rt_external_total"] == 42.0
    # Histogram exposition: cumulative buckets ending at +Inf == count.
    assert parsed['rt_latency_ms_bucket{le="1"}'] == 1.0
    assert parsed['rt_latency_ms_bucket{le="10"}'] == 2.0
    assert parsed['rt_latency_ms_bucket{le="100"}'] == 3.0
    assert parsed['rt_latency_ms_bucket{le="+Inf"}'] == 4.0
    assert parsed["rt_latency_ms_count"] == 4.0
    assert parsed["rt_latency_ms_sum"] == pytest.approx(555.5)
    # Same-name same-kind returns the SAME instrument; kind change raises.
    assert reg.counter("rt_requests_total") is c
    with pytest.raises(ValueError):
        reg.gauge("rt_requests_total")
    with pytest.raises(ValueError):
        c.inc(-1)
    with pytest.raises(ValueError):
        reg.counter("0bad name")


def test_stats_empty_window_no_nan():
    empty = StatsCollector().report({})
    for f, v in vars(empty).items():
        if isinstance(v, (int, float)):
            assert np.isfinite(v), \
                f"ServeStats.{f} not finite on empty window"
    assert empty.hot_shapes == ()
    assert empty.p50_ms == 0.0 and empty.throughput_rps == 0.0
    assert empty.queue_p95_ms == 0.0 and empty.device_mean_ms == 0.0
    assert "nan" not in empty.summary().lower()
    split = latency_split([])
    assert split["n"] == 0 and split["latency_p95_ms"] == 0.0


# ---------------------------------------------------------------------------
# Superstep telemetry (the fused-loop carry)
# ---------------------------------------------------------------------------


def test_telemetry_bit_identical_dense(engine, tel_engine):
    toks = mid_df_tokens(engine.index, 4)
    for q in (toks[0:2], toks[1:4]):
        r_base = engine.query(q, k=2, extract=False)
        r_tel = tel_engine.query(q, k=2, extract=False)
        np.testing.assert_array_equal(r_base.weights, r_tel.weights)
        np.testing.assert_array_equal(r_base.roots, r_tel.roots)
        assert r_base.supersteps == r_tel.supersteps
        assert r_base.telemetry is None
        tel = r_tel.telemetry
        assert tel is not None and tel.n_steps == r_tel.supersteps
        assert not tel.truncated
        # Column semantics: message columns are cumulative (nondecreasing,
        # per-step deltas nonnegative); the run converged, so the final
        # frozen count covers the lane and the totals match the result.
        assert np.all(np.diff(tel.msgs_bfs) >= 0)
        assert np.all(np.diff(tel.msgs_deep) >= 0)
        assert np.all(tel.msgs_bfs_delta >= 0)
        assert int(tel.frozen[-1]) == 1
        assert tel.msgs_bfs[-1] == pytest.approx(r_tel.msgs_bfs)
        assert tel.msgs_deep[-1] == pytest.approx(r_tel.msgs_deep)
        rows = tel.rows()
        assert [r["step"] for r in rows] == list(range(1, tel.n_steps + 1))
        assert tel.summary()["msgs_total"] == pytest.approx(
            r_tel.msgs_bfs + r_tel.msgs_deep)


def test_telemetry_batch_and_lane_sums(engine, tel_engine):
    toks = mid_df_tokens(engine.index, 4)
    queries = [toks[0:2], toks[2:4]]
    base = engine.query_batch(queries, k=1, extract=False)
    tel = tel_engine.query_batch(queries, k=1, extract=False)
    for rb, rt in zip(base, tel):
        np.testing.assert_array_equal(rb.weights, rt.weights)
        assert rt.telemetry is not None
    # One bucket = one fused dispatch = ONE lane-summed telemetry record
    # shared by the bucket's results; its final frozen count is the lanes.
    assert tel[0].telemetry is tel[1].telemetry
    assert int(tel[0].telemetry.frozen[-1]) == len(queries)


def test_telemetry_bit_identical_sharded(graph_data):
    g, index = graph_data
    base = QueryEngine.build(g, index=index, policy=ExecutionPolicy(
        max_supersteps=32, partition="sharded", n_shards=1))
    tel = QueryEngine.build(g, index=index, policy=ExecutionPolicy(
        max_supersteps=32, partition="sharded", n_shards=1, telemetry=True))
    q = mid_df_tokens(index, 2)
    r_base = base.query(q, k=1, extract=False)
    r_tel = tel.query(q, k=1, extract=False)
    np.testing.assert_array_equal(r_base.weights, r_tel.weights)
    np.testing.assert_array_equal(r_base.roots, r_tel.roots)
    assert r_tel.telemetry is not None
    assert r_tel.telemetry.n_steps == r_tel.supersteps


def test_instrumented_parity_with_collector(engine, tel_engine):
    """query_instrumented is a compat wrapper over the shared collector:
    its legacy history rows ARE telemetry.rows(), and the counters agree
    with the device-carried buffer for the same query."""
    q = mid_df_tokens(engine.index, 2)
    res, info = engine.query_instrumented(q, k=1)
    tel = info["telemetry"]
    assert info["history"] == tel.rows()
    assert tel.n_steps == res.supersteps
    assert tel.best is not None  # host collector tracks best weight
    r_dev = tel_engine.query(q, k=1, extract=False)
    dev = r_dev.telemetry
    assert dev.n_steps == tel.n_steps
    np.testing.assert_array_equal(dev.frontier, tel.frontier)
    np.testing.assert_allclose(dev.msgs_bfs, tel.msgs_bfs)
    np.testing.assert_allclose(dev.msgs_deep, tel.msgs_deep)


# ---------------------------------------------------------------------------
# Serve-layer observability (traces + /metrics)
# ---------------------------------------------------------------------------


def test_trace_completeness_coalescing_and_single_flight(engine):
    toks = mid_df_tokens(engine.index, 6)
    distinct = [toks[0:2], toks[2:4], toks[4:6]]
    with DKSService(engine, ServeConfig(max_batch=4, max_wait_ms=250.0,
                                        cache_size=8)) as svc:
        # Three DISTINCT same-shape queries coalesce into one bucket.
        served = [f.result(timeout=300)
                  for f in [svc.submit(q, k=1) for q in distinct]]
        assert [s.batch_size for s in served] == [3, 3, 3]
        traces = [svc.trace(s.trace_id) for s in served]
        leader, riders = traces[0], traces[1:]
        names = {sp.name for sp in leader.spans}
        assert {"admit", "cache_lookup", "queue_wait", "coalesce",
                "device_dispatch", "extract"} <= names
        coalesce = next(sp for sp in leader.spans if sp.name == "coalesce")
        assert coalesce.attrs["fill"] == 3 and coalesce.attrs["shape"] == "m2k1"
        dispatch = next(
            sp for sp in leader.spans if sp.name == "device_dispatch")
        assert dispatch.attrs["compiled"] in (True, False)
        for tr in riders:
            assert tr.links["coalesced_into"] == leader.trace_id
            assert tr.attrs["outcome"] == "served"
        # A repeat is a cache hit: its trace resolves without queue spans.
        hit = svc.query(distinct[0], k=1)
        assert hit.cache_hit
        hit_tr = svc.trace(hit.trace_id)
        assert hit_tr.attrs["outcome"] == "cache_hit"
        assert {sp.name for sp in hit_tr.spans} == {"admit", "cache_lookup"}
        # Five identical concurrent misses: leader + 4 single-flight
        # attachees, each with its own finished trace linking the leader.
        q = toks[1:3]
        sf = [f.result(timeout=300)
              for f in [svc.submit(q, k=1) for _ in range(5)]]
        sf_traces = [svc.trace(s.trace_id) for s in sf]
        followers = [t for t in sf_traces if "coalesced_into" in t.links]
        assert len(followers) == 4
        lead_id = {t.links["coalesced_into"] for t in followers}
        assert lead_id == {t.trace_id for t in sf_traces
                           if "coalesced_into" not in t.links}
        # Completeness: every admitted request resolved to one finished
        # trace (no leaks from any resolve path).
        st = svc.tracer.stats()
        assert st["begun"] == st["finished"] == 9
        assert len(svc.recent_traces(100)) == 9


def test_metrics_surface_matches_stats_and_is_monotone(engine):
    toks = mid_df_tokens(engine.index, 4)
    with DKSService(engine, ServeConfig(max_batch=2, max_wait_ms=5.0,
                                        cache_size=8)) as svc:
        svc.query(toks[0:2], k=1)
        svc.query(toks[0:2], k=1)  # cache hit
        first = parse_prometheus(svc.registry.render())
        stats = svc.stats()
        assert first["dks_requests_total"] == stats.requests == 2
        assert first["dks_cache_hits_total"] == stats.cache_hits == 1
        assert first["dks_batch_dispatches_total"] == stats.batch_dispatches
        assert first["dks_request_latency_ms_count"] == stats.requests
        assert first["dks_engine_execute_count_total"] == \
            engine.execute_count
        assert first["dks_traces_begun_total"] == \
            first["dks_traces_finished_total"] == 2
        # Dispatch-reason counters partition total dispatches.
        reasons = (first["dks_dispatch_reason_full_total"]
                   + first["dks_dispatch_reason_window_total"]
                   + first["dks_dispatch_reason_flush_total"])
        assert reasons == stats.batch_dispatches + stats.deadline_dispatches
        svc.query(toks[2:4], k=1)
        second = parse_prometheus(svc.registry.render())
        for name in ("dks_requests_total", "dks_cache_misses_total",
                     "dks_batch_dispatches_total",
                     "dks_request_latency_ms_count"):
            assert second[name] > first[name], f"{name} must be monotone"
        assert second["dks_cache_hits_total"] == first["dks_cache_hits_total"]


def test_metrics_server_endpoints(engine):
    toks = mid_df_tokens(engine.index, 2)
    with DKSService(engine, ServeConfig(max_batch=2, max_wait_ms=5.0,
                                        cache_size=8)) as svc:
        svc.query(toks, k=1)
        server = MetricsServer(svc.registry, tracer=svc.tracer).start()
        try:
            def get(path):
                with urllib.request.urlopen(server.url + path,
                                            timeout=30) as resp:
                    return resp.read().decode()

            assert get("/healthz").strip() == "ok"
            scraped = parse_prometheus(get("/metrics"))
            assert scraped["dks_requests_total"] == svc.stats().requests
            lines = [json.loads(ln)
                     for ln in get("/traces?n=8").splitlines() if ln]
            assert len(lines) == 1
            span_names = {sp["name"] for sp in lines[0]["spans"]}
            assert {"admit", "device_dispatch"} <= span_names
            one = json.loads(get(f"/traces?id={lines[0]['trace_id']}"))
            assert one["trace_id"] == lines[0]["trace_id"]
        finally:
            server.stop()
