"""repro.shardmap compat layer: the same calls must resolve and run on
every jax generation (native >= 0.7 API or the 0.4.x experimental one).
Single-device meshes here; multi-device behavior is covered by
tests/test_distributed.py."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import shardmap


def test_make_mesh_and_scope_roundtrip():
    mesh = shardmap.make_mesh((1,), ("data",))
    assert mesh.axis_names == ("data",)
    assert shardmap.get_abstract_mesh() is None
    with shardmap.mesh_scope(mesh):
        am = shardmap.get_abstract_mesh()
        assert am is not None
        assert tuple(am.axis_names) == ("data",)
        assert shardmap.mesh_axis_size(am, "data") == 1
        assert shardmap.mesh_axis_size(am, "model") == 1
    assert shardmap.get_abstract_mesh() is None
    # None mesh -> null scope, usable unconditionally.
    with shardmap.mesh_scope(None):
        pass


def test_shard_map_executes_with_collective():
    mesh = shardmap.make_mesh((1,), ("data",))

    def block(x):
        return jax.lax.psum(x, "data")

    f = jax.jit(shardmap.shard_map(
        block, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        check_vma=False))
    y = f(jnp.arange(4.0))
    np.testing.assert_array_equal(np.asarray(y), np.arange(4.0))


def test_shard_map_axis_names_subset():
    """axis_names={...} (partial-manual on native jax; fully-manual
    fallback on 0.4.x) must trace and run."""
    mesh = shardmap.make_mesh((1,), ("data",))

    def block(x):
        assert not shardmap.constraints_supported_here() or \
            shardmap.HAS_NATIVE_SHARD_MAP
        return x * 2.0

    f = jax.jit(shardmap.shard_map(
        block, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
        axis_names={"data"}, check_vma=False))
    np.testing.assert_array_equal(np.asarray(f(jnp.ones(4))), 2 * np.ones(4))


def test_auto_axis_names_respects_manual_scope():
    mesh = shardmap.make_mesh((1,), ("data",))
    assert shardmap.auto_axis_names(mesh) in (("data",), ())
    with shardmap.manual_axes_scope({"data"}):
        assert "data" not in shardmap.auto_axis_names(mesh)


def test_mesh_scope_enables_sharding_constraint():
    """constrain()-style bare-PartitionSpec constraints must work under
    mesh_scope on any jax generation (the models rely on this)."""
    from repro.models.common import constrain

    mesh = shardmap.make_mesh((1,), ("data",))
    # No mesh: identity.
    x = jnp.ones((4, 2))
    np.testing.assert_array_equal(np.asarray(constrain(x, "data", None)),
                                  np.asarray(x))
    with shardmap.mesh_scope(mesh):
        y = jax.jit(lambda v: constrain(v, "data", None))(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
