"""Live graphs: delta artifacts bit-identical to union re-ingest (dense
and 1-shard sharded, including answer-tree keys), compaction hash
identity, dictionary growth across stacked deltas through the lazy chain
index, mis-stack/open-guard error surfaces, the fragment watcher, and
zero-downtime engine swaps into DKSService (hardened set_engine, hot
shapes, swap-under-inflight-load completeness)."""

import threading

import numpy as np
import pytest

from repro.engine import ExecutionPolicy, QueryEngine
from repro.live import EngineSwapper, GraphWatcher, LiveDir
from repro.obs import parse_prometheus
from repro.serve import DKSService, ServeConfig
from repro.store import (
    ArtifactError,
    ChainIndex,
    DeltaBuilder,
    FormatVersionError,
    LazyArtifactIndex,
    chained_hash,
    compact_chain,
    from_graph,
    ingest_ntriples,
    ingest_tsv,
    open_artifact,
    open_chain,
    open_delta,
    write_artifact,
)

BASE_LINES = []
for i in range(23):
    conf = " 0.9" if i % 2 else ""
    BASE_LINES.append(f"<http://x.example/e{i}> <http://p.example/knows> "
                      f"<http://x.example/e{i + 1}>{conf} .")
for i in range(0, 18, 3):
    BASE_LINES.append(f"<http://x.example/e{i}> <http://p.example/cites> "
                      f"<http://x.example/e{i + 6}> 0.5 .")
FRAG1_LINES = [
    f"<http://x.example/e{i}> <http://p.example/mentions> "
    f"<http://x.example/fresh{j}> 0.8 ."
    for j, i in enumerate((0, 5, 11))]
FRAG2_LINES = [   # fresh0 resolves to its delta-1 id; fresh3 is new
    "<http://x.example/fresh0> <http://p.example/knows> "
    "<http://x.example/fresh3> .",
    "<http://x.example/fresh3> <http://p.example/cites> "
    "<http://x.example/e2> 0.6 .",
]


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    """A LiveDir with two stacked deltas, plus the union re-ingest."""
    tmp = tmp_path_factory.mktemp("live")
    for name, lines in [("base.nt", BASE_LINES), ("frag1.nt", FRAG1_LINES),
                        ("frag2.nt", FRAG2_LINES),
                        ("union.nt", BASE_LINES + FRAG1_LINES
                         + FRAG2_LINES)]:
        (tmp / name).write_text("\n".join(lines) + "\n", encoding="utf-8")
    live = LiveDir.initialize(tmp / "live", ingest_ntriples(tmp / "base.nt"))
    d1 = live.append([tmp / "frag1.nt"])
    d2 = live.append([tmp / "frag2.nt"])
    union = ingest_ntriples(tmp / "union.nt")
    return tmp, live, (d1, d2), union


def _policy(partition="single", max_supersteps=24):
    return ExecutionPolicy(
        max_supersteps=max_supersteps, partition=partition,
        n_shards=1 if partition == "sharded" else None,
        frontier_frac=1.0 if partition == "sharded" else 0.25)


QUERIES = [["e3", "e7"], ["fresh0", "e3"], ["fresh3", "e10"],
           ["e1", "e5", "fresh1"]]


@pytest.mark.parametrize("partition", ["single", "sharded"])
def test_chain_parity_with_union_reingest(setup, partition):
    tmp, live, _deltas, union = setup
    policy = _policy(partition)
    e_chain = QueryEngine.build(artifact=live.chain(), policy=policy)
    e_union = QueryEngine.build(union.graph, index=union.index,
                                policy=policy)
    for q in QUERIES:
        r_c = e_chain.query(q, k=2)
        r_u = e_union.query(q, k=2)
        np.testing.assert_array_equal(r_c.weights, r_u.weights,
                                      err_msg=f"weights diverged for {q}")
        np.testing.assert_array_equal(r_c.roots, r_u.roots)
        assert r_c.supersteps == r_u.supersteps
        # Answer-tree identity, not just scores.
        assert [(a.root, a.weight, tuple(sorted(a.edges)))
                for a in r_c.answers] == \
               [(a.root, a.weight, tuple(sorted(a.edges)))
                for a in r_u.answers], q


def test_chain_version_is_chained_hash(setup):
    tmp, live, (d1, d2), _union = setup
    base = live.base()
    chain = live.chain()
    expect = chained_hash(chained_hash(base.content_hash, d1.content_hash),
                          d2.content_hash)
    assert chain.content_hash == expect
    assert chain.depth == 2
    # Delta 2 stacks on the chain *above* delta 1, not on the raw base.
    assert d2.base_content_hash == chained_hash(base.content_hash,
                                                d1.content_hash)
    engine = QueryEngine.build(artifact=chain)
    assert engine.version == f"artifact:{expect}"
    # No deltas: the chain degrades to the base version (shared caches).
    assert open_chain(base).content_hash == base.content_hash


def test_compaction_bit_identical_to_union(setup, tmp_path):
    tmp, live, _deltas, union = setup
    compacted = compact_chain(live.chain(), tmp_path / "compacted")
    union_art = write_artifact(tmp_path / "union-art", union.graph,
                               union.index, tau=union.tau,
                               stats=union.stats.as_dict(),
                               names=union.names)
    assert compacted.content_hash == union_art.content_hash
    assert "compacted[chain=" in repr(compacted)
    assert compacted.stats["chain_depth"] == 2


def test_live_dir_compact_resets_chain(setup, tmp_path):
    tmp = tmp_path
    (tmp / "b.nt").write_text("\n".join(BASE_LINES) + "\n")
    (tmp / "f.nt").write_text("\n".join(FRAG1_LINES) + "\n")
    live = LiveDir.initialize(tmp / "live", ingest_ntriples(tmp / "b.nt"))
    live.append([tmp / "f.nt"])
    before = live.chain().content_hash
    art = live.compact()
    assert live.depth == 0
    assert live.chain_hash == art.content_hash
    assert art.stats["compacted_from_chain"] == before
    # Reattach from disk: the rewritten CHAIN.json round-trips.
    again = LiveDir(tmp / "live")
    assert again.chain().content_hash == art.content_hash


def test_dictionary_growth_through_lazy_chain_index(setup):
    tmp, live, (d1, d2), _union = setup
    chain = live.chain()
    engine = QueryEngine.build(artifact=chain)
    idx = engine.index
    assert isinstance(idx, ChainIndex)
    assert isinstance(idx.base_index, LazyArtifactIndex)
    # fresh3 exists only in delta 2; fresh0 was minted by delta 1 and
    # re-referenced by delta 2 without a second id.
    assert idx.df("fresh3") == 1
    assert idx.df("fresh0") == 1
    assert "fresh3" in idx.vocabulary()
    assert engine.node_label(int(idx.lookup("fresh3")[0])) == "fresh3"
    names = chain.entity_names()
    assert names.count("<http://x.example/fresh0>") == 1
    assert d2.new_names() == ["<http://x.example/fresh3>"]


def test_mis_stacked_delta_names_both_hashes(setup):
    tmp, live, (d1, d2), _union = setup
    with pytest.raises(ArtifactError, match="mis-stacked"):
        open_chain(live.base_path, d2.path)   # skips delta 1
    try:
        open_chain(live.base_path, d2.path)
    except ArtifactError as exc:
        msg = str(exc)
        assert d2.base_content_hash[:12] in msg
        assert live.base().content_hash[:12] in msg
        assert "depth 1" in msg


def test_open_guards_route_to_the_right_opener(setup):
    tmp, live, (d1, _d2), _union = setup
    with pytest.raises(FormatVersionError, match="open_chain"):
        open_artifact(d1.path)
    with pytest.raises(FormatVersionError, match="open_artifact"):
        open_delta(live.base_path)
    assert f"base={d1.base_content_hash[:12]}" in repr(d1)
    assert "depth=1" in repr(d1)


def test_tau_mismatch_and_empty_delta_refused(setup, tmp_path):
    tmp, live, _deltas, _union = setup
    other = ingest_ntriples(tmp / "base.nt", tau=7)
    write_artifact(tmp_path / "tau7", other.graph, other.index,
                   tau=other.tau, names=other.names)
    b = DeltaBuilder(open_artifact(tmp_path / "tau7"))
    with pytest.raises(ArtifactError, match="empty delta"):
        b.write(tmp_path / "never")
    b.add_statement("<http://x.example/e0>", "<http://x.example/zz>")
    d = b.write(tmp_path / "tau7-delta")
    with pytest.raises(ArtifactError, match="tau"):
        open_chain(live.base_path, d.path)


def test_initialize_requires_entity_names(tmp_path):
    from repro.graph.generators import lod_like_graph
    g, tokens = lod_like_graph(64, 128, seed=3, vocab=32)
    result = from_graph(g, tokens=tokens)
    with pytest.raises(ArtifactError, match="names"):
        LiveDir.initialize(tmp_path / "live", result)


def test_watcher_run_once_marks_consumed(tmp_path):
    (tmp_path / "b.nt").write_text("\n".join(BASE_LINES) + "\n")
    live = LiveDir.initialize(tmp_path / "live",
                              ingest_ntriples(tmp_path / "b.nt"))
    incoming = tmp_path / "incoming"
    incoming.mkdir()
    (incoming / "frag-01.nt").write_text("\n".join(FRAG1_LINES) + "\n")
    (incoming / "notes.json").write_text("{}")   # unrecognized: ignored
    seen = []
    watcher = GraphWatcher(live, incoming,
                           on_delta=lambda lv, d: seen.append(d))
    assert [p.name for p in watcher.pending()] == ["frag-01.nt"]
    delta = watcher.run_once()
    assert delta is not None and seen == [delta]
    assert watcher.published == 1
    assert watcher.run_once() is None            # consumed; no re-publish
    # A fresh LiveDir attached to the same directory sees the consumed
    # set (CHAIN.json round-trip), so a restarted watcher skips it too.
    assert "frag-01.nt" in LiveDir(tmp_path / "live").consumed
    # A fragment with no well-formed statements is consumed, not
    # published.
    (incoming / "frag-02.nt").write_text("not a triple\n")
    assert watcher.run_once() is None
    assert "frag-02.nt" in live.consumed
    assert live.depth == 1


def test_watcher_thread_publishes(tmp_path):
    (tmp_path / "b.nt").write_text("\n".join(BASE_LINES) + "\n")
    live = LiveDir.initialize(tmp_path / "live",
                              ingest_ntriples(tmp_path / "b.nt"))
    incoming = tmp_path / "incoming"
    incoming.mkdir()
    published = threading.Event()
    watcher = GraphWatcher(live, incoming, poll_s=0.02,
                           on_delta=lambda lv, d: published.set()).start()
    try:
        (incoming / "frag-01.nt").write_text("\n".join(FRAG1_LINES) + "\n")
        assert published.wait(60), "watcher never published the delta"
    finally:
        watcher.stop()
    assert watcher.published == 1 and live.depth == 1


def _small_engines(tmp_path):
    """Two engines over the same live dir: chain depth 0 and depth 1."""
    (tmp_path / "b.nt").write_text("\n".join(BASE_LINES) + "\n")
    (tmp_path / "f.nt").write_text("\n".join(FRAG1_LINES) + "\n")
    live = LiveDir.initialize(tmp_path / "live",
                              ingest_ntriples(tmp_path / "b.nt"))
    policy = _policy(max_supersteps=12)
    e0 = QueryEngine.build(artifact=live.chain(), policy=policy)
    return live, e0


def test_set_engine_hardening(tmp_path):
    live, e0 = _small_engines(tmp_path)
    cfg = ServeConfig(max_batch=2, max_wait_ms=1.0, cache_size=16)
    with DKSService(e0, cfg) as svc:
        q = ["e3", "e7"]
        svc.query(q, k=1, return_trees=True)     # seeds result+tree pools
        assert svc.query(q, k=1, return_trees=True).cache_hit
        live.append([tmp_path / "f.nt"])
        e1 = QueryEngine.build(artifact=live.chain(), policy=e0.policy)
        svc.set_engine(e1)
        assert svc.engine is e1
        # Both caches were evicted with the outgoing build.
        cold = svc.query(q, k=1, return_trees=True)
        assert not cold.cache_hit
        stats = svc.stats()
        assert stats.engine_swaps == 1
        assert "engine swaps" in stats.summary()
        samples = parse_prometheus(svc.registry.render())
        assert samples["dks_engine_swaps_total"] == 1


def test_hot_shapes_recorded(tmp_path):
    _live, e0 = _small_engines(tmp_path)
    with DKSService(e0, ServeConfig(max_batch=2, max_wait_ms=1.0,
                                    cache_size=0)) as svc:
        for _ in range(3):
            svc.query(["e3", "e7"], k=1)
        hot = svc.stats().hot_shapes
    assert hot, "no hot shapes recorded"
    (shape, count), = [(s, c) for s, c in hot if c == max(c for _, c in hot)]
    m, k, lanes = shape
    assert (m, k) == (2, 1) and lanes >= 1 and count >= 1


def test_swap_under_inflight_load(tmp_path):
    live, e0 = _small_engines(tmp_path)
    incoming = tmp_path / "incoming"
    incoming.mkdir()
    cfg = ServeConfig(max_batch=4, max_wait_ms=20.0, cache_size=0)
    with DKSService(e0, cfg) as svc:
        swapper = EngineSwapper(svc)
        swapper.wire_metrics()
        watcher = GraphWatcher(live, incoming, on_delta=swapper.on_delta)
        old_version = svc.engine.version
        # Requests in flight while the swap happens on this thread.
        futures = [svc.submit(q, k=1)
                   for q in (["e3", "e7"], ["e2", "e10"], ["e1", "e5"])]
        (incoming / "frag-01.nt").write_text("\n".join(FRAG1_LINES) + "\n")
        assert watcher.run_once() is not None    # publish + swap, inline
        served = [f.result(timeout=300) for f in futures]
        assert all(s.result.weights[0] > 0 for s in served)
        assert swapper.swaps == 1 and swapper.deltas_applied == 1
        assert svc.engine.version == \
            f"artifact:{live.chain().content_hash}" != old_version
        post = svc.query(["fresh0", "e3"], k=1)  # delta-only keyword
        assert post.result.weights[0] > 0
        samples = parse_prometheus(svc.registry.render())
        assert samples["dks_delta_applied_total"] == 1
        assert samples["dks_graph_staleness_seconds"] == 0.0
        swaps = [t for t in svc.recent_traces() if t.name == "dks.swap"]
        assert [sp.name for sp in swaps[-1].spans] == \
            ["build", "warm", "swap"]
    ts = svc.tracer.stats()
    assert ts["begun"] == ts["finished"], ts


def test_tsv_and_gz_fragments(tmp_path):
    lines = [f"a{i} left\ta{i + 1} right\tknows\t1.0" for i in range(6)]
    (tmp_path / "b.tsv").write_text("\n".join(lines) + "\n")
    live = LiveDir.initialize(tmp_path / "live",
                              ingest_tsv(tmp_path / "b.tsv"))
    import gzip
    with gzip.open(tmp_path / "f.tsv.gz", "wt") as f:
        f.write("a6 right\ta7 tail\tcites\t0.5\n")
    delta = live.append([tmp_path / "f.tsv.gz"])
    assert delta.n_new_nodes == 1 and delta.new_predicates == ["cites"]
    engine = QueryEngine.build(artifact=live.chain())
    assert engine.query(["tail", "a0"], k=1, extract=False).weights[0] > 0


# ----------------------------------------------------------------------
# LiveDir.gc — superseded-directory cleanup
# ----------------------------------------------------------------------


def _fresh_live(tmp_path):
    (tmp_path / "base.nt").write_text("\n".join(BASE_LINES) + "\n",
                                      encoding="utf-8")
    (tmp_path / "frag1.nt").write_text("\n".join(FRAG1_LINES) + "\n",
                                       encoding="utf-8")
    live = LiveDir.initialize(tmp_path / "live",
                              ingest_ntriples(tmp_path / "base.nt"))
    return live


def test_gc_deletes_only_unreferenced_dirs(tmp_path):
    live = _fresh_live(tmp_path)
    live.append([tmp_path / "frag1.nt"])
    assert live.gc(keep_last=0) == []   # everything still referenced
    live.compact()                      # supersedes base-000000 + delta
    before = {p.name for p in live.path.iterdir() if p.is_dir()}
    assert {"base-000000", "delta-000001", "base-000001"} <= before
    deleted = live.gc(keep_last=0)
    assert sorted(deleted) == ["base-000000", "delta-000001"]
    after = {p.name for p in live.path.iterdir() if p.is_dir()}
    assert "base-000001" in after and "base-000000" not in after
    # The surviving chain still opens and hash-verifies.
    assert live.chain().content_hash == live.chain_hash


def test_gc_keep_last_retains_newest_superseded(tmp_path):
    live = _fresh_live(tmp_path)
    live.append([tmp_path / "frag1.nt"])
    live.compact()
    deleted = live.gc(keep_last=1)
    # Two unreferenced dirs; the newest one survives as reader grace.
    assert len(deleted) == 1
    survivors = {p.name for p in live.path.iterdir() if p.is_dir()}
    assert len(survivors & {"base-000000", "delta-000001"}) == 1


def test_gc_refuses_mid_publish(tmp_path):
    live = _fresh_live(tmp_path)
    live.append([tmp_path / "frag1.nt"])
    live.compact()
    live._publishing = True   # simulate a watcher thread inside append()
    try:
        with pytest.raises(RuntimeError, match="publish is in progress"):
            live.gc(keep_last=0)
    finally:
        live._publishing = False
    assert live.gc(keep_last=0)  # clears once the publish window closes


def test_ingest_cli_gc(tmp_path):
    """--compact --gc end to end through the ingest CLI."""
    import subprocess
    import sys
    from pathlib import Path as _P

    src = str(_P(__file__).resolve().parent.parent / "src")
    live = _fresh_live(tmp_path)
    live.append([tmp_path / "frag1.nt"])
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.ingest",
         "--live", str(live.path), "--compact", "--gc", "--gc-keep", "0"],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": src, "PATH": "/usr/bin:/bin", "HOME": "/root",
             "JAX_PLATFORMS": "cpu"})
    assert res.returncode == 0, res.stderr[-3000:]
    assert "compacted chain" in res.stdout
    assert "gc: deleted" in res.stdout
    survivors = {p.name for p in live.path.iterdir() if p.is_dir()}
    assert survivors == {"base-000001"}
