"""Property tests for the aggregator-side tree reconstruction
(:mod:`repro.core.reconstruct`): every returned answer must be a
connected, acyclic, keyword-covering, minimal tree; the collector must
refill past dedup collapses and report exhaustion honestly; and the
cycle-repair path (:func:`_spanning_tree`) must turn walk-union cycles
back into valid trees."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro import INF
from repro.core import DKSConfig, run_dks
from repro.core.reconstruct import (
    _spanning_tree,
    collect_answers,
    finish_tree,
    prune_non_minimal,
)
from repro.graph.generators import random_weighted_graph
from repro.graph.structure import build_graph


def make_masks(groups, n_nodes):
    m = np.zeros((len(groups), n_nodes), bool)
    for i, grp in enumerate(groups):
        m[i, list(grp)] = True
    return m


def run_engine(g, groups, k=1, **kw):
    masks = make_masks(groups, g.n_nodes)
    cfg = DKSConfig(m=len(groups), k=k, **kw)
    state = run_dks(g.to_device(), jnp.asarray(masks), cfg)
    return np.asarray(state.S), masks


def check_tree(tree, masks):
    """The paper's answer-tree contract (Def. 2.1)."""
    nodes = set(tree.nodes)
    edges = list(tree.edges)
    # Tree shape: |E| = |V| - 1 (acyclic + connected given connectivity).
    assert len(edges) == len(nodes) - 1, (
        f"not a tree: {len(nodes)} nodes, {len(edges)} edges")
    # Connected: BFS from the root reaches every node.
    adj: dict[int, set] = {n: set() for n in nodes}
    for u, v in edges:
        assert u != v, "self-loop edge"
        adj[u].add(v)
        adj[v].add(u)
    seen = {tree.root}
    frontier = [tree.root]
    while frontier:
        nxt = [u for f in frontier for u in adj[f] if u not in seen]
        seen.update(nxt)
        frontier = nxt
    assert seen == nodes, f"disconnected: reached {seen} of {nodes}"
    # Coverage: every keyword group has a node in the tree.
    for i in range(masks.shape[0]):
        assert any(masks[i, n] for n in nodes), f"keyword {i} uncovered"
    # Minimality: no leaf is redundant (pruning is a fixed point).
    assert prune_non_minimal(edges, masks, tree.root) == edges, (
        "returned tree still has a prunable leaf")


@pytest.mark.parametrize("seed", range(6))
def test_collected_answers_are_minimal_covering_trees(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 16))
    g = random_weighted_graph(n, n + int(rng.integers(4, 16)), seed=seed)
    m = int(rng.integers(2, 4))
    groups = [rng.choice(n, size=max(1, n // 4), replace=False)
              for _ in range(m)]
    k = int(rng.integers(1, 5))
    S, masks = run_engine(g, groups, k=k, max_supersteps=64)
    answers, exhausted = collect_answers(S, g, masks, k=k)
    assert len(answers) <= k
    assert exhausted == (len(answers) < k)
    keys = set()
    for a in answers:
        check_tree(a, masks)
        # True weight is the sum over the deduped edge set, never above
        # the DP value (walk artifacts only ever overcount).
        assert a.weight <= a.raw_value + 1e-3
        keys.add(a.key())
    assert len(keys) == len(answers), "duplicate trees in ranked answers"
    # Ranked ascending by recomputed weight.
    ws = [a.weight for a in answers]
    assert ws == sorted(ws)


def test_refill_past_dedup_collapse():
    """candidate_factor=1 gives a k-cell initial window; on a graph where
    many cells collapse to the same pruned tree, the scan must refill
    from the table instead of returning fewer than k answers."""
    # Path 0-1-2-3-4, keywords at {0} and {4}: the k=3 best root cells
    # (roots 1,2,3 all seeing weight 4) all reconstruct the same chain.
    g = build_graph([0, 1, 2, 3], [1, 2, 3, 4], 5, w=np.ones(4, np.float32))
    groups = [[0], [4]]
    S, masks = run_engine(g, groups, k=3, max_supersteps=32)
    win1, exhausted = collect_answers(S, g, masks, k=3, candidate_factor=1)
    win4, exhausted4 = collect_answers(S, g, masks, k=3, candidate_factor=4)
    # Both windows end at the same answer set: refill closed the gap.
    assert [a.key() for a in win1] == [a.key() for a in win4]
    assert exhausted == exhausted4
    # The path graph holds exactly one minimal tree for this query.
    assert len(win1) == 1 and exhausted
    assert win1[0].weight == pytest.approx(4.0, abs=1e-3)


def test_exhausted_flag_on_thin_table():
    # Single edge, one tree total; k=5 cannot be met.
    g = build_graph([0], [1], 2, w=np.asarray([1.0], np.float32))
    S, masks = run_engine(g, [[0], [1]], k=5, max_supersteps=8)
    answers, exhausted = collect_answers(S, g, masks, k=5)
    assert len(answers) == 1 and exhausted


def test_spanning_tree_repairs_cycles():
    """A walk-union containing a cycle must come back as a spanning tree
    of the union, and finish_tree must then deliver a valid answer."""
    # Triangle 0-1-2 plus a pendant 2-3; weights make 0-1 the heavy edge.
    g = build_graph([0, 1, 0, 2], [1, 2, 2, 3], 4,
                    w=np.asarray([5.0, 1.0, 1.0, 1.0], np.float32))
    cyclic = [(0, 1), (1, 2), (0, 2), (2, 3)]
    st = _spanning_tree(cyclic, g)
    assert len(st) == 3, "spanning tree of 4 nodes must have 3 edges"
    assert {n for e in st for n in e} == {0, 1, 2, 3}
    # Kruskal drops the heaviest cycle edge.
    assert (0, 1) not in [tuple(sorted(e)) for e in st]
    # End-to-end: finish_tree on the cyclic union yields a checkable tree.
    masks = make_masks([[0], [3]], 4)
    tree = finish_tree(cyclic, g, masks, root=0, raw_value=8.0)
    check_tree(tree, masks)
    # MST keeps (1,2),(0,2),(2,3); re-pruning drops the now-redundant
    # leaf 1, leaving the 0-2-3 path.
    assert tree.weight == pytest.approx(2.0, abs=1e-3)
    assert set(tree.nodes) == {0, 2, 3}


def test_root_pruned_rerooting():
    """A root that is itself a redundant leaf gets pruned; the answer
    re-roots inside what remains and stays a valid tree."""
    # Star: center 1 with leaves 0, 2; keywords live at 1 and 2 only, so
    # branch 1-0 is redundant whichever root found it.
    g = build_graph([0, 1], [1, 2], 3, w=np.ones(2, np.float32))
    masks = make_masks([[1], [2]], 3)
    tree = finish_tree([(0, 1), (1, 2)], g, masks, root=0, raw_value=2.0)
    check_tree(tree, masks)
    assert tree.root != 0 and 0 not in tree.nodes
    assert tree.weight == pytest.approx(1.0, abs=1e-3)
