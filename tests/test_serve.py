"""Serving subsystem tests: micro-batcher coalescing + shape separation,
result-cache hits that skip device execution (asserted via the engine's
trace/executor counters), deadline-bounded approximate answers with valid
SPA bounds, and multi-threaded client parity with direct engine.query."""

import numpy as np
import pytest

from repro.engine import ExecutionPolicy, QueryEngine
from repro.graph.generators import lod_like_graph
from repro.graph.index import InvertedIndex
from repro.serve import DKSService, ResultCache, ServeConfig
from repro.serve.loadgen import TraceRequest, make_trace, replay


@pytest.fixture(scope="module")
def engine():
    g, tokens = lod_like_graph(600, 1800, seed=11, vocab=120)
    index = InvertedIndex.from_token_matrix(tokens)
    return QueryEngine.build(
        g, index=index, policy=ExecutionPolicy(max_supersteps=32))


def mid_df_tokens(index, n, lo=2, hi=60):
    toks = [t for t in sorted(index.vocabulary(), key=index.df)
            if lo <= index.df(t) <= hi]
    assert len(toks) >= n
    return toks[:n]


def test_concurrent_clients_match_direct_engine(engine):
    """8 closed-loop clients; every served answer equals engine.query."""
    toks = mid_df_tokens(engine.index, 9)
    pool = [tuple(toks[0:2]), tuple(toks[2:4]), tuple(toks[4:6]),
            tuple(toks[6:9]), tuple(toks[3:6])]
    trace = [TraceRequest(pool[i % len(pool)]) for i in range(15)]
    with DKSService(engine, ServeConfig(max_batch=4, max_wait_ms=40.0,
                                        cache_size=64)) as svc:
        served = replay(svc, trace, n_clients=8)
        stats = svc.stats()
    assert stats.requests == len(trace)
    assert stats.batch_dispatches > 0
    # The trace repeats each query 3x; a repeat is reused either from the
    # warm cache (the earlier run resolved) or by single-flight attach
    # (it was still in flight) — never re-executed.
    assert stats.cache_hits + stats.single_flight_hits > 0
    refs = {q: engine.query(list(q), k=1) for q in pool}
    for req, srv in zip(trace, served):
        assert not srv.approximate
        ref = refs[req.keywords]
        np.testing.assert_allclose(srv.result.weights, ref.weights)
        assert [a.weight for a in srv.result.answers] == \
               [a.weight for a in ref.answers]


def test_batcher_coalesces_same_shape_and_separates(engine):
    """Same-shape requests share one vmapped dispatch; a different m (or
    k) cannot ride along — the DKS table shape [V, 2^m, K] differs."""
    toks = mid_df_tokens(engine.index, 9)
    m2 = [toks[0:2], toks[2:4], toks[4:6], toks[6:8]]
    m3 = [toks[0:3], toks[6:9]]
    with DKSService(engine, ServeConfig(max_batch=4, max_wait_ms=250.0,
                                        cache_size=0)) as svc:
        futures = [svc.submit(q, k=1) for q in m2 + m3]
        served = [f.result(timeout=300) for f in futures]
        stats = svc.stats()
    # The four m=2 queries filled one batch exactly...
    assert [s.batch_size for s in served[:4]] == [4, 4, 4, 4]
    # ...and the m=3 queries dispatched separately, together.
    assert [s.batch_size for s in served[4:]] == [2, 2]
    assert stats.batch_dispatches == 2
    assert stats.mean_batch_fill == 3.0
    assert stats.cache_hits == 0 and stats.cache_misses == 0  # cache off
    for q, srv in zip(m2 + m3, served):
        np.testing.assert_allclose(
            srv.result.weights, engine.query(q, k=1).weights)


def test_cache_hit_skips_execution_and_normalizes(engine):
    q = mid_df_tokens(engine.index, 2)
    with DKSService(engine, ServeConfig(max_batch=2, max_wait_ms=1.0,
                                        cache_size=8)) as svc:
        first = svc.query(q, k=1)
        assert not first.cache_hit and first.batch_size == 1
        executes = engine.execute_count
        traces = engine.cache_stats["traces"]
        second = svc.query(q, k=1)
        permuted = svc.query(list(reversed(q)), k=1)
        # Hits skip the device entirely: no dispatch, no re-trace.
        assert second.cache_hit and permuted.cache_hit
        assert second.batch_size == 0
        assert engine.execute_count == executes
        assert engine.cache_stats["traces"] == traces
        np.testing.assert_allclose(second.result.weights,
                                   first.result.weights)
        np.testing.assert_allclose(permuted.result.weights,
                                   first.result.weights)
        stats = svc.stats()
        assert stats.cache_hits == 2 and stats.cache_misses == 1
        # A different k or policy override is a different answer: miss.
        assert not svc.query(q, k=2).cache_hit
        assert not svc.query(q, k=1, max_supersteps=8).cache_hit
        # Explicit invalidation (graph rebuild): the entry is gone.
        assert svc.invalidate_cache() > 0
        assert not svc.query(q, k=1).cache_hit


def test_single_flight_coalesces_identical_misses(engine):
    """Two (here: five) concurrent identical cache misses execute once —
    the first leads, the rest attach to its in-flight future and resolve
    from the leader's result with ``coalesced=True``."""
    q = mid_df_tokens(engine.index, 2)
    ref = engine.query(q, k=1)
    executes = engine.execute_count
    with DKSService(engine, ServeConfig(max_batch=8, max_wait_ms=300.0,
                                        cache_size=8)) as svc:
        futures = [svc.submit(q, k=1) for _ in range(5)]
        served = [f.result(timeout=300) for f in futures]
        stats = svc.stats()
    # One device dispatch total for the five identical requests.
    assert engine.execute_count == executes + 1
    leaders = [s for s in served if not s.coalesced and not s.cache_hit]
    followers = [s for s in served if s.coalesced]
    assert len(leaders) == 1 and len(followers) == 4
    assert stats.requests == 5
    assert stats.single_flight_hits == 4
    assert stats.cache_misses == 1   # one durable miss, not five
    for srv in served:
        np.testing.assert_array_equal(srv.result.weights, ref.weights)
    # A later identical request is a plain cache hit, not single-flight.
    with DKSService(engine, ServeConfig(cache_size=8)) as svc:
        first = svc.query(q, k=1)
        again = svc.query(q, k=1)
    assert not first.cache_hit and again.cache_hit and not again.coalesced


def test_cache_lru_eviction_and_disable():
    cache = ResultCache(capacity=2)
    cache.put("a", 1)
    cache.put("b", 2)
    assert cache.get("a") == 1       # refreshes a
    cache.put("c", 3)                # evicts b (LRU)
    assert cache.get("b") is None
    assert cache.get("a") == 1 and cache.get("c") == 3
    st = cache.stats()
    assert st["evictions"] == 1 and st["size"] == 2
    disabled = ResultCache(capacity=0)
    disabled.put("a", 1)
    assert disabled.get("a") is None
    assert disabled.stats()["hits"] == 0 and disabled.stats()["misses"] == 0


def test_deadline_expiry_returns_approximate_with_bound():
    """The paper's early-termination guarantee as a serving feature: a
    heavy direct edge is found early, the cheap 10-hop path later; an
    expired deadline returns best-so-far + a valid lower bound."""
    from repro.graph.structure import build_graph
    src = [0, 0] + list(range(2, 10)) + [10]
    dst = [1, 2] + list(range(3, 11)) + [1]
    w = np.asarray([100.0] + [1.0] * 10, np.float32)
    g = build_graph(src, dst, 11, w=w)
    tokens = np.arange(11, dtype=np.int32).reshape(11, 1)
    engine = QueryEngine.build(g, tokens=tokens)
    with DKSService(engine, ServeConfig(cache_size=8)) as svc:
        exact = svc.query([0, 1], k=1)
        assert not exact.approximate and exact.best_weight == 10.0
        svc.invalidate_cache()
        served = svc.query([0, 1], k=1, deadline_ms=0.0)
        assert served.approximate
        assert not served.result.done
        # Valid bracket: lower bound <= optimum <= best-so-far.  The
        # sound bound is the guaranteed one; the reported bound (paper
        # convention, SPA estimator) also holds on this graph.
        assert served.opt_lower_bound is not None
        assert served.sound_opt_lower_bound is not None
        assert served.sound_opt_lower_bound <= served.opt_lower_bound
        assert served.sound_opt_lower_bound <= 10.0 + 1e-6
        assert served.opt_lower_bound <= 10.0 + 1e-6
        assert served.result.weights[0] >= 10.0 - 1e-6
        # The interrupted run reports its forced-stop SPA bound, and is
        # never presented as certified (ratio 0 only means certified).
        assert served.result.spa is not None
        # Approximate results are budget-specific: never cached.
        assert svc.stats().cache_hits == 0
        again = svc.query([0, 1], k=1)
        assert not again.cache_hit and not again.approximate
        assert again.best_weight == 10.0
        # A budget generous enough to finish yields the exact answer.
        done = svc.query([0, 1], k=1, deadline_ms=60_000.0)
        assert done.cache_hit and not done.approximate


def test_deadline_bucket_coalesces_and_shares_supersteps(engine):
    """Same-budget same-shape deadline requests ride ONE lane driver:
    one deadline dispatch for the bucket, and the shared driver's
    superstep count is max(lane steps), far below the N x solo sum a
    per-request streaming executor would pay."""
    toks = mid_df_tokens(engine.index, 8)
    queries = [toks[0:2], toks[2:4], toks[4:6], toks[6:8]]
    solo = [engine.query(q, k=1, extract=False) for q in queries]
    with DKSService(engine, ServeConfig(max_batch=4, max_wait_ms=400.0,
                                        cache_size=0)) as svc:
        futures = [svc.submit(q, k=1, deadline_ms=60_000.0)
                   for q in queries]
        served = [f.result(timeout=300) for f in futures]
        stats = svc.stats()
    assert stats.deadline_dispatches == 1
    assert stats.deadline_batched_requests == 4
    assert stats.mean_deadline_fill == 4.0
    # All lanes finished inside the generous budget: exact answers...
    for q, srv, ref in zip(queries, served, solo):
        assert not srv.approximate and srv.batch_size == 4
        np.testing.assert_allclose(srv.result.weights, ref.weights)
    # ...each lane billed its own supersteps (frozen individually)...
    assert stats.deadline_lane_supersteps == \
        sum(r.supersteps for r in solo)
    # ...while the shared driver stepped only as far as the slowest lane.
    assert stats.deadline_driver_supersteps == \
        max(r.supersteps for r in solo)
    assert stats.deadline_driver_supersteps < stats.deadline_lane_supersteps


def test_deadline_bucket_expiry_per_lane_bounds():
    """An expired coalesced bucket resolves every lane with its own
    best-so-far answer and a valid per-lane bound bracket."""
    from repro.graph.structure import build_graph
    src = [0, 0] + list(range(2, 10)) + [10]
    dst = [1, 2] + list(range(3, 11)) + [1]
    w = np.asarray([100.0] + [1.0] * 10, np.float32)
    g = build_graph(src, dst, 11, w=w)
    tokens = np.arange(11, dtype=np.int32).reshape(11, 1)
    engine = QueryEngine.build(g, tokens=tokens)
    with DKSService(engine, ServeConfig(max_batch=4, max_wait_ms=10.0,
                                        cache_size=0)) as svc:
        # Occupy the dispatcher with a deadline-less query (cold compile
        # takes far longer than the admission window), so the two
        # zero-budget submits below are guaranteed to sit in the queue
        # together and drain into ONE deadline bucket — the coalescing
        # must not depend on racing the tiny budget-capped window.
        warm = svc.submit([3, 4], k=1)
        import time as _time
        _time.sleep(0.05)
        futures = [svc.submit([0, 1], k=1, deadline_ms=0.0),
                   svc.submit([2, 10], k=1, deadline_ms=0.0)]
        served = [f.result(timeout=300) for f in futures]
        warm.result(timeout=300)
        stats = svc.stats()
    assert stats.deadline_dispatches == 1 and stats.mean_deadline_fill == 2.0
    ref = {(0, 1): engine.query([0, 1], k=1).best_weight,
           (2, 10): engine.query([2, 10], k=1).best_weight}
    for srv, q in zip(served, [(0, 1), (2, 10)]):
        assert srv.approximate and not srv.result.done
        assert srv.result.spa is not None
        assert srv.sound_opt_lower_bound <= srv.opt_lower_bound + 1e-6
        assert srv.sound_opt_lower_bound <= ref[q] + 1e-6
        assert srv.result.weights[0] >= ref[q] - 1e-6


def test_streamed_until_bound_monotone_and_forced(engine):
    """The engine primitive under the deadline path: until= interrupts the
    stream, bounds never worsen, and the result reports a forced stop."""
    q = mid_df_tokens(engine.index, 3)
    updates = []
    res = engine.query_streamed(
        q, k=1, extract=False, on_update=updates.append,
        until=lambda u: u.step >= 1)
    assert len(updates) == 2 and not res.done
    assert res.spa is not None
    ratios = [u.spa_ratio for u in updates]
    assert all(cur <= prev for prev, cur in zip(ratios, ratios[1:]))
    bounds = [u.opt_lower_bound for u in updates]
    assert all(cur >= prev for prev, cur in zip(bounds, bounds[1:]))
    # Without until= the same call runs to its proven exit.
    full = engine.query_streamed(q, k=1, extract=False)
    assert full.done and full.spa is None


def test_strict_admission_rejects_unmatched_alone(engine):
    """An unmatched keyword fails its own future at admission — it must
    not poison a co-batched dispatch."""
    good = mid_df_tokens(engine.index, 2)
    missing = max(engine.index.vocabulary()) + 1000
    with DKSService(engine, ServeConfig(max_batch=4, max_wait_ms=60.0,
                                        cache_size=0)) as svc:
        bad_future = svc.submit([missing, missing + 1], k=1)
        good_future = svc.submit(good, k=1)
        with pytest.raises(KeyError, match=str(missing)):
            bad_future.result(timeout=300)
        served = good_future.result(timeout=300)
    np.testing.assert_allclose(served.result.weights,
                               engine.query(good, k=1).weights)


def test_set_engine_inflight_served_by_admitting_build(engine):
    """A set_engine swap must not change the build mid-flight: queued
    requests are served by the engine that admitted them, and their
    results are unreachable to post-swap clients (version-keyed cache)."""
    g2, tokens2 = lod_like_graph(300, 900, seed=5, vocab=80)
    engine2 = QueryEngine.build(g2, tokens=tokens2)
    both = set(engine2.index.vocabulary())
    q = [t for t in sorted(engine.index.vocabulary(), key=engine.index.df)
         if engine.index.df(t) >= 2 and t in both][:2]
    assert len(q) == 2
    with DKSService(engine, ServeConfig(max_batch=8, max_wait_ms=400.0,
                                        cache_size=8)) as svc:
        queued = svc.submit(q, k=1)          # sits in the admission window
        svc.set_engine(engine2)              # graph rebuild mid-flight
        served = queued.result(timeout=300)
        np.testing.assert_allclose(served.result.weights,
                                   engine.query(q, k=1).weights)
        # The old build's answer was cached under its version: a
        # post-swap client cannot hit it.
        post = svc.query(q, k=1)
        assert not post.cache_hit
        np.testing.assert_allclose(post.result.weights,
                                   engine2.query(q, k=1).weights)


def test_default_equal_override_coalesces(engine):
    """An override equal to the engine policy's value is normalized away
    at admission, so the request coalesces with no-override requests."""
    toks = mid_df_tokens(engine.index, 4)
    with DKSService(engine, ServeConfig(max_batch=2, max_wait_ms=250.0,
                                        cache_size=0)) as svc:
        f1 = svc.submit(toks[0:2], k=1)
        f2 = svc.submit(toks[2:4], k=1, max_supersteps=32)  # policy value
        r1 = f1.result(timeout=300)
        r2 = f2.result(timeout=300)
    assert r1.batch_size == 2 and r2.batch_size == 2


def test_unhashable_override_fails_alone(engine):
    """An unhashable override value fails its own future at admission —
    it must not reach (and kill) the dispatcher thread."""
    good = mid_df_tokens(engine.index, 2)
    with DKSService(engine, ServeConfig(max_wait_ms=1.0,
                                        cache_size=0)) as svc:
        bad = svc.submit(good, k=1, max_supersteps=[8])
        with pytest.raises(TypeError, match="unhashable"):
            bad.result(timeout=60)
        # The service is still alive and serving.
        ok = svc.query(good, k=1)
    np.testing.assert_allclose(ok.result.weights,
                               engine.query(good, k=1).weights)


def test_loadgen_trace_shapes(engine):
    trace = make_trace(engine.index, 12, unique=4, deadline_frac=0.25,
                       deadline_ms=50.0, seed=1)
    assert len(trace) == 12
    assert {len(t.keywords) for t in trace} <= {2, 3}
    assert sum(t.deadline_ms is not None for t in trace) == 3
    assert len({t.keywords for t in trace}) <= 4
    # deterministic
    assert trace == make_trace(engine.index, 12, unique=4,
                               deadline_frac=0.25, deadline_ms=50.0, seed=1)


def test_stopped_service_rejects_submits(engine):
    svc = DKSService(engine, ServeConfig())
    with pytest.raises(RuntimeError):
        svc.submit(mid_df_tokens(engine.index, 2), k=1)
    svc.start()
    svc.stop()
    with pytest.raises(RuntimeError):
        svc.submit(mid_df_tokens(engine.index, 2), k=1)


def tree_key(t):
    return (t.root, tuple(sorted((e.u, e.v) for e in t.edges)))


def test_return_trees_end_to_end_from_artifact(tmp_path):
    """The full answer pipeline off an ingested artifact: served trees are
    label-rendered from the artifact's label blob (the graph itself
    carries no labels in memory), diversity-ranked, paginated, and a
    warm identical request is served whole from the tree-pool cache."""
    from repro.graph.structure import build_graph
    from repro.store import open_artifact, write_artifact

    #   paris hotel (0) --- cafe (2) --- piano bar (1)
    #        \------------ bistro (3) ------/
    # plus pendants so the graph has non-answer material.
    labels = ["paris hotel", "piano bar", "cafe central", "bistro nord",
              "museum", "shop"]
    src = [0, 2, 0, 3, 4, 5]
    dst = [2, 1, 3, 1, 0, 1]
    g = build_graph(src, dst, 6, w=np.ones(6, np.float32), labels=labels)
    index = InvertedIndex.from_labels(labels)
    art = write_artifact(tmp_path / "art", g, index)
    engine = QueryEngine.build(artifact=open_artifact(art.path))
    assert engine.graph.labels is None  # labels live only in the blob
    with DKSService(engine, ServeConfig(cache_size=8,
                                        tree_page_size=2)) as svc:
        srv = svc.query(["paris", "piano"], k=2, return_trees=True)
        page = srv.trees
        assert page is not None and page.ranking == "diverse"
        assert page.total >= 2 and len(page.items) == 2
        assert len({tree_key(t) for t in page.items}) == 2
        for t in page.items:
            # Labels are the artifact's entity strings, not node:<id>.
            assert t.root_label == labels[t.root]
            assert all(lbl == labels[n]
                       for n, lbl in zip(t.nodes, t.node_labels))
            joined = " ".join(t.node_labels)
            assert "paris" in joined and "piano" in joined
        # Both two-hop connections appear among the served explanations.
        mids = {n for t in page.items for n in t.nodes} - {0, 1}
        assert {2, 3} <= mids
        before = svc.stats()
        assert before.tree_requests == 1 and before.tree_cache_hits == 0
        executes = engine.execute_count
        warm = svc.query(["paris", "piano"], k=2, return_trees=True)
        assert warm.cache_hit and engine.execute_count == executes
        assert [tree_key(t) for t in warm.trees.items] == \
               [tree_key(t) for t in page.items]
        assert svc.stats().tree_cache_hits == 1
        # Tree caches drain on invalidation too.
        assert svc.invalidate_cache() >= 2
        assert not svc.query(["paris", "piano"], k=2,
                             return_trees=True).cache_hit


def test_tree_ranking_and_pagination(engine):
    toks = mid_df_tokens(engine.index, 2)
    with DKSService(engine, ServeConfig(cache_size=8, tree_page_size=2,
                                        tree_pool_factor=4)) as svc:
        srv = svc.query(toks, k=3, return_trees=True, tree_ranking="weight")
        page = srv.trees
        assert page.ranking == "weight"
        ws = [t.weight for t in page.items]
        assert ws == sorted(ws), "weight ranking must be ascending"
        # Walk the cursor to the end: pages partition the pool, each
        # follow-up is served from the caches (no device work).
        seen = list(page.items)
        cursor = page.next_cursor
        while cursor is not None:
            nxt = svc.query(toks, k=3, return_trees=True,
                            tree_ranking="weight", tree_cursor=cursor)
            assert nxt.cache_hit
            assert nxt.trees.cursor == cursor
            seen.extend(nxt.trees.items)
            cursor = nxt.trees.next_cursor
        assert len(seen) == page.total
        assert len({tree_key(t) for t in seen}) == page.total, (
            "pool contains duplicate trees")
        # Diverse ranking is a permutation of the same pool.
        div = svc.query(toks, k=3, return_trees=True,
                        tree_ranking="diverse", tree_page_size=page.total)
        assert {tree_key(t) for t in div.trees.items} == \
               {tree_key(t) for t in seen}
        # Bad ranking fails that request alone; the service lives on.
        with pytest.raises(ValueError, match="tree_ranking"):
            svc.submit(toks, k=1, return_trees=True,
                       tree_ranking="bogus").result(timeout=60)
        assert svc.query(toks, k=3, return_trees=True).trees is not None


# ----------------------------------------------------------------------
# Adaptive lane occupancy (AdaptiveLanePolicy + pad_batches="adaptive")
# ----------------------------------------------------------------------


def test_adaptive_lane_policy_degrades_to_pow2_until_measured():
    from repro.engine import AdaptiveLanePolicy

    pol = AdaptiveLanePolicy(max_lanes=16)
    d = pol.lanes_for(5)
    assert d.lanes == 8 and d.reason == "pow2" and d.est_ms is None
    assert pol.lanes_for(16).lanes == 16
    assert pol.lanes_for(100).lanes == 16  # clamped at max_lanes


def test_adaptive_lane_policy_prefers_cheap_warm_counts():
    from repro.engine import AdaptiveLanePolicy

    pol = AdaptiveLanePolicy(max_lanes=16, retrace_cost_ms=200.0)
    # Warm measurements: 6 lanes is cheap, 8 lanes is pathological.
    for _ in range(3):
        pol.observe(6, 10.0)
        pol.observe(8, 500.0)
    d = pol.lanes_for(5)
    assert d.lanes == 6 and d.reason == "warm"
    # Exact fit wins when padding to a warm count costs more than a
    # cold dispatch at n itself would.
    d2 = pol.lanes_for(7)   # candidates: 7 (cold), 8 (warm but 500ms), 16
    assert d2.lanes == 7 and d2.reason == "exact"
    assert pol.target_fill() in (6, 8)
    snap = pol.snapshot()
    assert snap["last_lanes"] == d2.lanes
    assert snap["decisions"]["warm"] >= 1


def test_adaptive_lane_policy_uses_hot_shape_candidates():
    from repro.engine import AdaptiveLanePolicy

    pol = AdaptiveLanePolicy(max_lanes=32, retrace_cost_ms=0.0)
    pol.observe(4, 100.0)   # per-lane estimate: 25 ms
    # A swapped-in engine's histogram says the workload runs 6-lane
    # buckets: 6 joins the candidate set though never measured here.
    d = pol.lanes_for(5, hot_shapes=(((3, 2, 6), 40),))
    # With zero retrace cost the cheapest candidate >= 5 is 5 itself;
    # raise the retrace cost and the hot 6 would compete.  Just assert
    # the decision is sane and 6 was considered (<= max, >= n).
    assert d.lanes in (5, 6)


def test_adaptive_padding_serves_parity_and_exports_metrics(engine):
    """pad_batches='adaptive' end to end: answers match the direct
    engine, the policy observes real dispatches, and the decision
    metrics ride /metrics."""
    from repro.obs import parse_prometheus

    toks = mid_df_tokens(engine.index, 6)
    queries = [toks[i:i + 3] for i in range(3)]
    with DKSService(engine, ServeConfig(
            max_batch=8, max_wait_ms=4.0,
            pad_batches="adaptive", cache_size=0)) as svc:
        futs = [svc.submit(q, k=1) for q in queries]
        results = [f.result(120) for f in futs]
        # Second wave: the policy now has measurements to score with.
        futs2 = [svc.submit(q, k=1) for q in reversed(queries)]
        results2 = [f.result(120) for f in futs2]
        snap = svc.lane_policy.snapshot()
        metrics = parse_prometheus(svc.registry.render())
    for q, served in zip(queries, results):
        direct = engine.query(q, k=1)
        np.testing.assert_array_equal(served.result.weights,
                                      direct.weights)
    for q, served in zip(list(reversed(queries)), results2):
        direct = engine.query(q, k=1)
        np.testing.assert_array_equal(served.result.weights,
                                      direct.weights)
    assert snap["observed_counts"]          # dispatches were observed
    assert sum(snap["decisions"].values()) >= 1
    assert "dks_lane_policy_last_lanes" in metrics
    assert "dks_lane_policy_decision_pow2_total" in metrics


def test_serve_config_rejects_unknown_pad_mode():
    with pytest.raises(ValueError, match="pad_batches"):
        ServeConfig(pad_batches="nope")
