"""QueryEngine facade tests: parity with the raw core entry points,
m-bucketed batching, streaming bound monotonicity, and compiled-executable
cache reuse (no re-tracing for repeated query shapes)."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro import INF
from repro.core import DKSConfig, extract_answers, run_dks
from repro.engine import ExecutionPolicy, QueryEngine, WeightPolicy
from repro.graph.generators import lod_like_graph
from repro.graph.index import InvertedIndex


@pytest.fixture(scope="module")
def setup():
    g, tokens = lod_like_graph(600, 1800, seed=11, vocab=120)
    index = InvertedIndex.from_token_matrix(tokens)
    engine = QueryEngine.build(
        g, index=index, policy=ExecutionPolicy(max_supersteps=32))
    return g, index, engine


def mid_df_tokens(index, n, lo=2, hi=60):
    """n tokens with moderate document frequency (answerable queries)."""
    toks = [t for t in sorted(index.vocabulary(), key=index.df)
            if lo <= index.df(t) <= hi]
    assert len(toks) >= n
    return toks[:n]


def test_query_matches_raw_run_dks(setup):
    g, index, engine = setup
    query = mid_df_tokens(index, 3)
    k = 2
    res = engine.query(query, k=k)

    masks = index.keyword_masks(query, g.n_nodes,
                                v_pad=engine.device_graph.v_pad)
    cfg = DKSConfig(m=len(query), k=k, max_supersteps=32)
    state = run_dks(engine.device_graph, jnp.asarray(masks), cfg)
    np.testing.assert_allclose(res.weights, np.asarray(state.topk_w))
    assert res.supersteps == int(state.step)
    assert res.msgs_bfs == float(state.msgs_bfs)
    assert res.msgs_deep == float(state.msgs_deep)

    raw_answers = extract_answers(np.asarray(state.S), g,
                                  masks[:, : g.n_nodes], k=k)
    assert [(a.weight, a.edges) for a in res.answers] == \
           [(a.weight, a.edges) for a in raw_answers]
    assert res.found and res.best.weight == res.answers[0].weight


def test_query_batch_matches_per_query_runs(setup):
    g, index, engine = setup
    toks = mid_df_tokens(index, 10)
    # Mixed keyword counts force m-bucketing (2- and 3-keyword buckets).
    queries = [toks[0:2], toks[2:5], toks[5:7], toks[7:10]]
    batched = engine.query_batch(queries, k=2)
    assert len(batched) == len(queries)
    for q, br in zip(queries, batched):
        sr = engine.query(q, k=2)
        assert br.query == tuple(q) and br.m == len(q)
        np.testing.assert_allclose(br.weights, sr.weights)
        assert br.supersteps == sr.supersteps
        # Finished lanes are frozen, so batched counters match exactly even
        # though the vmapped while-loop runs until the slowest query exits.
        assert br.msgs_bfs == sr.msgs_bfs
        assert br.msgs_deep == sr.msgs_deep
        assert [a.weight for a in br.answers] == [a.weight for a in sr.answers]


def test_query_stream_bound_never_worsens(setup):
    g, index, engine = setup
    query = mid_df_tokens(index, 3)
    updates = list(engine.query_stream(query, k=1))
    assert updates, "stream yielded nothing"
    ratios = [u.spa_ratio for u in updates]
    # inf while no answer is known, then monotone non-increasing.
    for prev, cur in zip(ratios, ratios[1:]):
        assert cur <= prev, f"SPA ratio worsened: {ratios}"
    # Steps advance one superstep at a time.
    assert [u.step for u in updates] == list(range(len(updates)))
    last = updates[-1]
    assert last.done
    # Sound exit without a budget: the final answer is proven optimal.
    assert last.spa_ratio == 0.0 and last.proven_optimal
    # And the streamed final weights match the one-shot query.
    res = engine.query(query, k=1)
    np.testing.assert_allclose(last.weights, res.weights)


def test_compiled_executable_cache_reuse(setup):
    g, index, engine = setup
    toks = mid_df_tokens(index, 8)
    before = engine.cache_stats["traces"]
    engine.query(toks[0:3], k=3, extract=False)
    engine.query(toks[3:6], k=3, extract=False)
    engine.query(toks[5:8], k=3, extract=False)
    # Three same-(m, k) queries -> exactly one trace.
    assert engine.trace_count(3, 3) == 1
    assert engine.cache_stats["traces"] == before + 1
    # A different shape compiles its own executable once.
    engine.query(toks[0:2], k=3, extract=False)
    engine.query(toks[2:4], k=3, extract=False)
    assert engine.trace_count(2, 3) == 1


def test_policy_overrides_key_the_cache(setup):
    g, index, engine = setup
    toks = mid_df_tokens(index, 2)
    r1 = engine.query(toks, k=1, extract=False)
    r2 = engine.query(toks, k=1, extract=False, message_budget=10.0)
    assert r2.budget_hit and not r1.budget_hit
    assert engine.trace_count(2, 1) == 1
    assert engine.trace_count(2, 1, message_budget=10.0) == 1


def test_keyword_masks_v_pad():
    idx = InvertedIndex.from_token_matrix(
        np.asarray([[0, 1], [1, 2], [2, 0]], np.int32))
    masks = idx.keyword_masks([1, 2], 3, v_pad=8)
    assert masks.shape == (2, 8)
    assert masks[:, 3:].sum() == 0
    np.testing.assert_array_equal(
        masks[:, :3], idx.keyword_masks([1, 2], 3))
    with pytest.raises(ValueError):
        idx.keyword_masks([1], 3, v_pad=2)


def test_build_from_labels():
    from repro.graph.structure import build_graph
    g = build_graph([0, 1], [1, 2], 3, w=np.ones(2, np.float32),
                    labels=["red piano", "blue piano", "red door"])
    engine = QueryEngine.build(g)
    res = engine.query(["blue", "door"], k=1)
    assert res.found
    assert res.best_weight == 1.0  # blue@1 -- door@2 over the unit edge


def test_capped_run_is_not_certified_optimal():
    """A run truncated by max_supersteps must report capped (with an SPA
    ratio), never a proven-optimal answer — the heavy direct edge is found
    early, the cheap long path only after more supersteps."""
    from repro.graph.structure import build_graph
    # Direct edge 0-1 of weight 100 vs a cheap 10-hop unit path 0-2-...-10-1.
    src = [0, 0] + list(range(2, 10)) + [10]
    dst = [1, 2] + list(range(3, 11)) + [1]
    w = np.asarray([100.0] + [1.0] * 10, np.float32)
    g = build_graph(src, dst, 11, w=w)
    tokens = np.arange(11, dtype=np.int32).reshape(11, 1)  # node i holds tok i
    engine = QueryEngine.build(g, tokens=tokens)

    trunc = engine.query([0, 1], k=1, max_supersteps=2)
    assert trunc.best_weight == 100.0
    assert trunc.capped and trunc.done and not trunc.budget_hit
    assert trunc.spa is not None and trunc.spa_ratio > 0.0

    updates = list(engine.query_stream([0, 1], k=1, max_supersteps=2))
    assert not updates[-1].proven_optimal

    full = engine.query([0, 1], k=1)
    assert full.best_weight == 10.0  # the cheap path, proven
    assert not full.capped and full.spa_ratio == 0.0 and full.spa is None


def test_infeasible_query(setup):
    g, index, engine = setup
    missing = max(index.vocabulary()) + 1000
    # strict (default): unmatched keywords are a hard error naming the token.
    with pytest.raises(KeyError, match=str(missing)):
        engine.query([missing, missing + 1], k=1)
    # best-effort: INF answer, and the result says *why*.
    res = engine.query([missing, missing + 1], k=1, strict=False)
    assert not res.found and res.answers == []
    assert res.done and not res.budget_hit
    assert res.weights[0] >= INF
    assert res.unmatched == (missing, missing + 1)
    # The streaming surface carries the same diagnosis on every update,
    # and strict validation fires at the call site (not first iteration).
    with pytest.raises(KeyError):
        engine.query_stream([missing], k=1)
    ups = list(engine.query_stream([missing, missing + 1], k=1,
                                   strict=False))
    assert ups and ups[0].unmatched == (missing, missing + 1)
    seen = []
    engine.query_streamed([missing, missing + 1], k=1, strict=False,
                          extract=False, on_update=seen.append)
    assert seen and seen[0].unmatched == (missing, missing + 1)


def test_partially_matched_query_reports_unmatched(setup):
    g, index, engine = setup
    tok = index.vocabulary()[0]
    missing = max(index.vocabulary()) + 1000
    with pytest.raises(KeyError):
        engine.query([tok, missing], k=1)
    res = engine.query([tok, missing], k=1, strict=False)
    assert res.unmatched == (missing,)
    matched = engine.query([tok, index.vocabulary()[1]], k=1)
    assert matched.unmatched == ()


def test_own_time_reporting(setup):
    """own_time_s: per-query serve time where measurable — equal to the
    wall time on single-query surfaces, None inside a vmapped bucket."""
    g, index, engine = setup
    toks = mid_df_tokens(index, 4)
    res = engine.query(toks[:2], k=1, extract=False)
    assert res.own_time_s == res.wall_time_s and res.own_time_s > 0
    batched = engine.query_batch([toks[0:2], toks[2:4]], k=1, extract=False)
    assert all(b.own_time_s is None for b in batched)


def test_query_deadline_hook(setup):
    """The serving hook: wall-clock-bounded stepping, bounds computed once
    at the end (valid, though not the stream's running max)."""
    g, index, engine = setup
    q = mid_df_tokens(index, 3)
    full = engine.query(q, k=1, extract=False)
    res, info = engine.query_deadline(q, k=1, extract=False,
                                      deadline_s=120.0)
    assert not info["interrupted"] and res.done
    np.testing.assert_allclose(res.weights, full.weights)
    # A proven exit certifies the best answer soundly; both bounds say so.
    assert info["sound_opt_lower_bound"] == res.best_weight
    assert info["opt_lower_bound"] == res.best_weight
    trunc, info2 = engine.query_deadline(q, k=1, extract=False,
                                         deadline_s=0.0)
    assert info2["interrupted"] and not trunc.done
    assert trunc.spa is not None  # forced-stop SPA on the result
    # Valid bracket around the optimum.
    assert info2["sound_opt_lower_bound"] <= info2["opt_lower_bound"] + 1e-6
    assert info2["sound_opt_lower_bound"] <= full.best_weight + 1e-5
    assert trunc.weights[0] >= full.weights[0] - 1e-5


def test_query_deadline_batch_per_lane_bounds(setup):
    """A deadline bucket of heterogeneous same-m queries rides ONE lane
    driver; every lane gets its own best-so-far answer with a valid
    per-lane bound bracket, and with a generous budget the bucket costs
    max(lane supersteps), not the sum."""
    g, index, engine = setup
    toks = mid_df_tokens(index, 6)
    queries = [toks[0:3], toks[3:6]]
    fulls = [engine.query(q, k=1, extract=False) for q in queries]
    out = engine.query_deadline_batch(queries, k=1, extract=False,
                                      deadline_s=0.0)
    assert len(out) == 2
    for (res, info), full in zip(out, fulls):
        assert info["interrupted"] and not res.done
        assert res.spa is not None  # per-lane forced-stop SPA
        # Valid per-lane bracket: sound <= reported <= optimum <= best.
        assert info["sound_opt_lower_bound"] <= \
            info["opt_lower_bound"] + 1e-6
        assert info["sound_opt_lower_bound"] <= full.best_weight + 1e-5
        assert res.weights[0] >= full.weights[0] - 1e-5
        assert res.own_time_s is not None and res.own_time_s > 0

    out2 = engine.query_deadline_batch(queries, k=1, extract=False,
                                       deadline_s=120.0)
    for (res, info), full in zip(out2, fulls):
        assert not info["interrupted"] and res.done
        np.testing.assert_allclose(res.weights, full.weights)
        # Lanes freeze individually: per-lane counters match solo runs...
        assert res.supersteps == full.supersteps
        # ...while the shared driver stepped only as far as the slowest.
        assert info["driver_supersteps"] == \
            max(f.supersteps for f in fulls)
        assert info["opt_lower_bound"] == res.best_weight

    # Padding lanes (serving hook) skip result construction.
    padded = engine.query_deadline_batch(queries + [queries[-1]], k=1,
                                         extract=False, deadline_s=120.0,
                                         n_real=2)
    assert padded[2] is None and padded[0] is not None

    # A bucket cannot mix keyword counts (one driver = one table shape).
    with pytest.raises(ValueError, match="same keyword count"):
        engine.query_deadline_batch([toks[0:2], toks[0:3]], k=1,
                                    deadline_s=1.0)


def test_query_batch_n_real_skips_padding(setup):
    """The serving hook: padding lanes (index >= n_real) ride the vmapped
    program but skip host-side result construction, returning None."""
    g, index, engine = setup
    toks = mid_df_tokens(index, 4)
    queries = [toks[0:2], toks[2:4], toks[2:4]]
    out = engine.query_batch(queries, k=1, extract=False, n_real=2)
    assert out[2] is None
    refs = engine.query_batch(queries[:2], k=1, extract=False)
    for served, ref in zip(out[:2], refs):
        np.testing.assert_allclose(served.weights, ref.weights)


def test_engine_reexports_from_core():
    import repro.core as core
    assert core.QueryEngine is QueryEngine
    assert core.ExecutionPolicy is ExecutionPolicy
    with pytest.raises(AttributeError):
        core.not_a_symbol


# ---------------------------------------------------------------------------
# Sharded partition in-process (1 local device -> 1-shard mesh).  The full
# multi-device story lives in tests/test_distributed.py; these tier-1 tests
# keep the shard_map code path and its engine plumbing exercised on every
# pytest run, on any jax generation (via repro.shardmap).
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sharded_setup(setup):
    g, index, _ = setup
    engine = QueryEngine.build(
        g, index=index,
        policy=ExecutionPolicy(partition="sharded", max_supersteps=32,
                               frontier_frac=1.0))
    return g, index, engine


def test_sharded_engine_matches_single_inprocess(setup, sharded_setup):
    _, index, single = setup
    _, _, sharded = sharded_setup
    assert sharded.mesh is not None
    query = mid_df_tokens(index, 3)
    rs = single.query(query, k=2, extract=False)
    rh = sharded.query(query, k=2, extract=False)
    np.testing.assert_array_equal(rs.weights, rh.weights)
    assert rs.supersteps == rh.supersteps
    assert not rh.budget_hit


def test_sharded_engine_stream_inprocess(sharded_setup):
    _, index, sharded = sharded_setup
    query = mid_df_tokens(index, 2)
    updates = list(sharded.query_stream(query, k=1))
    assert updates and updates[-1].done
    ratios = [u.spa_ratio for u in updates]
    assert all(cur <= prev for prev, cur in zip(ratios, ratios[1:]))
    res = sharded.query(query, k=1, extract=False)
    np.testing.assert_array_equal(updates[-1].weights, res.weights)


def test_sharded_query_batch_one_execution_per_bucket(setup, sharded_setup):
    """The restored sharded batch win: a bucket of same-m queries rides
    the lane driver as ONE device execution (the lane axis lives inside
    the shard_map body — no sequential fallback, no vmap-over-shard_map),
    and the answers are bit-identical to the dense batch."""
    _, index, single = setup
    _, _, sharded = sharded_setup
    toks = mid_df_tokens(index, 7)
    queries = [toks[0:2], toks[2:4], toks[4:7]]  # two m=2, one m=3
    before = sharded.execute_count
    results = sharded.query_batch(queries, k=1, extract=False)
    # Two m-buckets -> exactly two device executions, regardless of
    # bucket size (the acceptance criterion: count dispatches, not time).
    assert sharded.execute_count == before + 2
    t2a, t2b, t3 = (results[0].wall_time_s, results[1].wall_time_s,
                    results[2].wall_time_s)
    # Same-m queries share one bucket and must report one shared time;
    # lanes advance in lockstep, so there is no honest per-query time.
    assert t2a == t2b
    assert t2a > 0 and t3 > 0
    assert all(br.own_time_s is None for br in results)
    dense = single.query_batch(queries, k=1, extract=False)
    for q, br, dr in zip(queries, results, dense):
        np.testing.assert_array_equal(br.weights, dr.weights)
        assert br.supersteps == dr.supersteps
        assert br.msgs_bfs == dr.msgs_bfs and br.msgs_deep == dr.msgs_deep
        sr = sharded.query(q, k=1, extract=False)
        np.testing.assert_array_equal(br.weights, sr.weights)


def test_sharded_query_instrumented(setup, sharded_setup):
    """The partition='single' restriction is lifted: the sharded engine
    serves query_instrumented with the same timings/history contract and
    parity with the dense path."""
    _, index, single = setup
    _, _, sharded = sharded_setup
    query = mid_df_tokens(index, 2)
    res, info = sharded.query_instrumented(query, k=1, extract=False,
                                           max_supersteps=24)
    ref = single.query(query, k=1, extract=False, max_supersteps=24)
    np.testing.assert_allclose(res.weights, ref.weights)
    assert set(info["timings"]) == \
        {"send_bfs", "receive", "evaluate", "send_agg"}
    assert all(v >= 0 for v in info["timings"].values())
    assert res.supersteps == len(info["history"])
    assert info["history"][-1]["best"] == ref.best_weight


# ----------------------------------------------------------------------
# Fused pallas lane-superstep kernel (interpret mode on CPU)
# ----------------------------------------------------------------------


@pytest.fixture(scope="module")
def pallas_setup():
    """A jnp engine and its pallas twin over one graph, small enough for
    the interpret-mode kernel to stay CI-speed."""
    g, tokens = lod_like_graph(300, 1200, seed=7, vocab=80)
    index = InvertedIndex.from_token_matrix(tokens)
    ej = QueryEngine.build(g, index=index, policy=ExecutionPolicy(
        backend="jnp", max_supersteps=16))
    ep = QueryEngine.build(g, index=index, policy=ExecutionPolicy(
        backend="pallas", max_supersteps=16))
    assert ep.lane_csr is not None  # built once per graph at build()
    return index, ej, ep


def test_pallas_query_bit_identical(pallas_setup):
    index, ej, ep = pallas_setup
    query = mid_df_tokens(index, 3)
    rj = ej.query(query, k=2, extract=False)
    rp = ep.query(query, k=2, extract=False)
    np.testing.assert_array_equal(rp.weights, rj.weights)
    assert rp.supersteps == rj.supersteps
    assert rp.msgs_bfs == rj.msgs_bfs and rp.msgs_deep == rj.msgs_deep


def test_pallas_query_batch_bit_identical(pallas_setup):
    index, ej, ep = pallas_setup
    toks = mid_df_tokens(index, 8)
    queries = [toks[0:2], toks[2:5], toks[5:8], toks[1:3]]
    bj = ej.query_batch(queries, k=2, extract=False)
    bp = ep.query_batch(queries, k=2, extract=False)
    for rj, rp in zip(bj, bp):
        np.testing.assert_array_equal(rp.weights, rj.weights)
        assert rp.supersteps == rj.supersteps


def test_pallas_stream_bit_identical(pallas_setup):
    index, ej, ep = pallas_setup
    query = mid_df_tokens(index, 3)
    upd_j, upd_p = [], []
    rj = ej.query_streamed(query, k=2, on_update=upd_j.append,
                           extract=False)
    rp = ep.query_streamed(query, k=2, on_update=upd_p.append,
                           extract=False)
    np.testing.assert_array_equal(rp.weights, rj.weights)
    # The whole per-superstep trajectory matches, not just the answer.
    assert len(upd_p) == len(upd_j)
    for uj, up in zip(upd_j, upd_p):
        assert up.step == uj.step and up.frontier == uj.frontier
        assert up.best_weight == uj.best_weight


def test_pallas_deadline_bit_identical(pallas_setup):
    index, ej, ep = pallas_setup
    query = mid_df_tokens(index, 3)
    rj, _ = ej.query_deadline(query, k=2, deadline_s=60.0, extract=False)
    rp, _ = ep.query_deadline(query, k=2, deadline_s=60.0, extract=False)
    np.testing.assert_array_equal(rp.weights, rj.weights)
    assert rp.supersteps == rj.supersteps
    # A deadline bucket shares one driver, so both lanes need the same m.
    toks = mid_df_tokens(index, 6)
    bucket = [toks[:3], toks[3:6]]
    out_j = ej.query_deadline_batch(
        bucket, k=2, deadline_s=60.0, extract=False)
    out_p = ep.query_deadline_batch(
        bucket, k=2, deadline_s=60.0, extract=False)
    for (qj, _), (qp, _) in zip(out_j, out_p):
        np.testing.assert_array_equal(qp.weights, qj.weights)


def test_pallas_telemetry_buffer_bit_identical(pallas_setup):
    """telemetry=True rides the same fused loop: the per-superstep
    counter rows AND the answers must match the jnp telemetry path
    exactly."""
    index, ej, ep = pallas_setup
    g = ej.graph
    tj = QueryEngine.build(g, index=index, policy=ExecutionPolicy(
        backend="jnp", max_supersteps=16, telemetry=True))
    tp = QueryEngine.build(g, index=index, policy=ExecutionPolicy(
        backend="pallas", max_supersteps=16, telemetry=True))
    query = mid_df_tokens(index, 3)
    rj = tj.query(query, k=2, extract=False)
    rp = tp.query(query, k=2, extract=False)
    np.testing.assert_array_equal(rp.weights, rj.weights)
    assert rj.telemetry is not None and rp.telemetry is not None
    assert rp.telemetry.rows() == rj.telemetry.rows()
    # And telemetry-on matches telemetry-off on the pallas path.
    base = pallas_setup[2].query(query, k=2, extract=False)
    np.testing.assert_array_equal(rp.weights, base.weights)


def test_pallas_typed_weight_policy_bit_identical():
    """Effective WeightPolicy weights (typed channel) flow through the
    LaneCSR layout: confidence-blended and predicate-filtered engines
    answer bit-identically on both backends."""
    from tests.test_weights import typed_diamond

    g, index = typed_diamond()
    for wp in (WeightPolicy(kind="confidence", blend=1.0),
               WeightPolicy(predicates=("knows", "funds"))):
        ej = QueryEngine.build(g, index=index, policy=ExecutionPolicy(
            backend="jnp", max_supersteps=8, weights=wp))
        ep = QueryEngine.build(g, index=index, policy=ExecutionPolicy(
            backend="pallas", max_supersteps=8, weights=wp))
        rj = ej.query(["alpha", "beta"], k=2, extract=False)
        rp = ep.query(["alpha", "beta"], k=2, extract=False)
        np.testing.assert_array_equal(rp.weights, rj.weights)
        assert rp.supersteps == rj.supersteps


def test_pallas_ragged_frontier_lane_frozen_mid_bucket(pallas_setup):
    """A bucket whose lanes finish at different supersteps: once a lane's
    exit fires its frontier is empty and the kernel's per-lane freeze
    mask must hold its table at s0 while other lanes keep relaxing."""
    index, ej, ep = pallas_setup
    toks = mid_df_tokens(index, 6)
    # Same-m bucket, different finishing times (different keyword sets).
    queries = [toks[0:3], toks[3:6]]
    bj = ej.query_batch(queries, k=1, extract=False)
    bp = ep.query_batch(queries, k=1, extract=False)
    steps = {r.supersteps for r in bj}
    assert len(steps) >= 1  # trajectory lengths may or may not differ...
    for rj, rp in zip(bj, bp):
        np.testing.assert_array_equal(rp.weights, rj.weights)
        assert rp.supersteps == rj.supersteps
        # Frozen lanes stop accumulating: message counters must match the
        # per-query runs exactly (the freeze-mask acceptance check).
        assert rp.msgs_bfs == rj.msgs_bfs
        assert rp.msgs_deep == rj.msgs_deep


def test_pallas_executable_cache_no_retrace(pallas_setup):
    index, _, ep = pallas_setup
    query = mid_df_tokens(index, 3)
    ep.query(query, k=2, extract=False)
    traces = ep.trace_count(3, 2)
    ep.query(list(reversed(query)), k=2, extract=False)
    assert ep.trace_count(3, 2) == traces  # same shape -> no re-trace


def test_pallas_single_launch_per_superstep(pallas_setup):
    """The perf claim's structural proxy on CPU: the fused path lowers to
    exactly ONE pallas_call per superstep and strictly fewer jaxpr
    equations than the jnp op chain."""
    import jax

    from repro.core.driver import lane_init, lane_superstep

    index, ej, ep = pallas_setup
    query = mid_df_tokens(index, 3)
    cfg_j = ej.policy.dks_config(3, 2)
    cfg_p = ep.policy.dks_config(3, 2)
    masks = jnp.asarray(ej._masks(query)[0])[None]
    st = lane_init(ej.device_graph, masks, cfg_j)
    jx_j = jax.make_jaxpr(
        lambda s: lane_superstep(ej.device_graph, s, cfg_j))(st)
    jx_p = jax.make_jaxpr(
        lambda s: lane_superstep(ep.device_graph, s, cfg_p,
                                 csr=ep.lane_csr))(st)

    def all_eqns(jaxpr):
        out = list(jaxpr.eqns)
        for eq in jaxpr.eqns:
            for p in eq.params.values():
                inner = getattr(p, "jaxpr", None)
                if inner is not None:
                    out += all_eqns(getattr(inner, "jaxpr", inner))
        return out

    eq_j, eq_p = all_eqns(jx_j.jaxpr), all_eqns(jx_p.jaxpr)
    assert sum(1 for e in eq_p if e.primitive.name == "pallas_call") == 1
    assert sum(1 for e in eq_j if e.primitive.name == "pallas_call") == 0
    assert len(eq_p) < len(eq_j)


def test_pallas_sharded_raises_not_implemented():
    with pytest.raises(NotImplementedError, match="shard_map body"):
        ExecutionPolicy(backend="pallas", partition="sharded")
