"""Paper-fidelity tests: instrumented phases (Table 1), literal Eq. 2 exit
("paper" mode), SPA on forced stop (Sec. 5.4), vanilla-BFS baseline, and
the benchmark query generator."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro import INF
from repro.core import DKSConfig, dreyfus_wagner, run_dks, run_dks_instrumented
from repro.core.baselines import vanilla_parallel_bfs
from repro.core.fagin import paper_exit_hook
from repro.core.spa import spa_cover_dp, spa_ratio
from repro.graph.generators import grid_graph, random_weighted_graph


def masks_of(groups, n):
    m = np.zeros((len(groups), n), bool)
    for i, grp in enumerate(groups):
        m[i, list(grp)] = True
    return m


def test_instrumented_matches_jitted_loop():
    g = random_weighted_graph(30, 80, seed=2)
    groups = [[1], [7], [19]]
    masks = masks_of(groups, g.n_nodes)
    dg = g.to_device()
    cfg = DKSConfig(m=3, k=2, max_supersteps=48)
    jit_state = run_dks(dg, jnp.asarray(masks), cfg)
    inst_state, info = run_dks_instrumented(dg, jnp.asarray(masks), cfg)
    np.testing.assert_allclose(np.asarray(jit_state.topk_w),
                               np.asarray(inst_state.topk_w))
    assert set(info["timings"]) == {"send_bfs", "receive", "evaluate",
                                    "send_agg"}
    assert all(t >= 0 for t in info["timings"].values())
    assert len(info["history"]) == int(inst_state.step)


def test_paper_eq2_exit_mode_finds_optimum():
    """Literal paper exit (Eq. 2 via host hook) never misses the optimum."""
    for seed in range(3):
        g = random_weighted_graph(14, 26, seed=seed)
        rng = np.random.default_rng(seed)
        groups = [[int(rng.integers(0, 14))] for _ in range(2)]
        masks = masks_of(groups, g.n_nodes)
        dg = g.to_device()
        cfg = DKSConfig(m=2, k=1, max_supersteps=64, exit_mode="none")
        hook = paper_exit_hook(g, masks, cfg, float(dg.e_min()))
        state, _ = run_dks_instrumented(dg, jnp.asarray(masks), cfg,
                                        exit_hook=hook)
        opt = dreyfus_wagner(g, groups)
        got = float(state.topk_w[0])
        assert got == pytest.approx(opt, abs=1e-3), (seed, got, opt)


def test_budget_stop_with_spa_bound():
    """Forced stop (Sec. 5.4): SPA is a true lower bound on the optimum."""
    g = grid_graph(10, 10)
    groups = [[0], [99]]
    masks = masks_of(groups, g.n_nodes)
    dg = g.to_device()
    cfg = DKSConfig(m=2, k=1, message_budget=50.0, max_supersteps=64)
    state = run_dks(dg, jnp.asarray(masks), cfg)
    assert bool(state.budget_hit)
    shat = state.s_front + dg.e_min()
    spa = float(spa_cover_dp(shat, 2))
    opt = dreyfus_wagner(g, groups)
    assert spa <= opt + 1e-4, f"SPA {spa} must lower-bound optimum {opt}"


def test_vanilla_bfs_baseline():
    g = grid_graph(6, 6)
    dg = g.to_device()
    src = jnp.zeros(dg.v_pad, bool).at[0].set(True)
    dist, steps = vanilla_parallel_bfs(dg, src)
    # Corner-to-corner hop distance on a 6x6 grid is 10.
    assert int(dist[35]) == 10
    assert int(steps) <= 12


def test_benchmark_queries_span_df_spectrum():
    from benchmarks.common import load
    bench = load("sec-rdfabout-cpu", m_max=3, per_count=4)
    assert len(bench.queries) == 8
    dfs = [sum(bench.index.df(t) for t in q) for q in bench.queries]
    assert max(dfs) > 3 * min(dfs)  # spectrum, not one regime
    ms = sorted({len(q) for q in bench.queries})
    assert ms == [2, 3]
