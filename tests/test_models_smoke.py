"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, assert output shapes + finite values (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import gnn as gnn_lib
from repro.models import lm as lm_lib
from repro.models import recsys as rec_lib
from repro.models import transformer as tfm
from repro.optim import AdamWConfig

LM_ARCHS = [a for a, e in ARCHS.items() if e.family == "lm"]
GNN_ARCHS = [a for a, e in ARCHS.items() if e.family == "gnn"]

KEY = jax.random.PRNGKey(0)


def tiny_lm_batch(cfg, bsz=2, seq=16):
    toks = jax.random.randint(KEY, (bsz, seq), 0, cfg.vocab)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step(arch):
    cfg = get_arch(arch).config.smoke()
    b = tfm.build(cfg, tp=1)
    state = lm_lib.init_train_state(KEY, b)
    step = lm_lib.make_train_step(b, AdamWConfig(), attn_impl="naive")
    batch = tiny_lm_batch(cfg)
    state2, metrics = jax.jit(step)(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # Params actually changed.
    d0 = jax.tree_util.tree_leaves(state.params)[0]
    d1 = jax.tree_util.tree_leaves(state2.params)[0]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_decode_consistency(arch):
    """Greedy decode after prefill matches teacher-forced forward.

    MoE capacity dropping is token-population dependent (prefill routes 16
    tokens, decode routes 2), so the consistency check requires a no-drop
    capacity factor — drops are a training-time load-shedding mechanism.
    """
    import dataclasses as dc
    cfg = get_arch(arch).config.smoke()
    if cfg.moe is not None:
        cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=64.0))
    b = tfm.build(cfg, tp=1)
    params = tfm.init_params(KEY, b)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)

    hidden, _, _ = tfm.forward(params, toks, b, attn_impl="naive")
    logits_full = tfm.unembed(params, hidden, b)[:, :, : cfg.vocab]

    prefill = lm_lib.make_prefill_step(b, attn_impl="naive")
    logits_last, cache = jax.jit(prefill)(params, toks[:, :-1])
    # Cache from prefill covers positions < 7; decode token 7.
    cache = {"k": jnp.pad(cache["k"], ((0, 0), (0, 0), (0, 9), (0, 0), (0, 0))),
             "v": jnp.pad(cache["v"], ((0, 0), (0, 0), (0, 9), (0, 0), (0, 0))),
             "pos": cache["pos"]}
    logits_step, cache = tfm.decode_step(params, cache, toks[:, -1:], b,
                                         attn_impl="naive")
    np.testing.assert_allclose(
        np.asarray(logits_step[:, 0, : cfg.vocab]),
        np.asarray(logits_full[:, -1]), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_train_step_grad_accum(arch):
    cfg = get_arch(arch).config.smoke()
    b = tfm.build(cfg, tp=1)
    state = lm_lib.init_train_state(KEY, b)
    step = lm_lib.make_train_step(b, AdamWConfig(), attn_impl="naive",
                                  grad_accum=2)
    state2, metrics = jax.jit(step)(state, tiny_lm_batch(cfg))
    assert np.isfinite(float(metrics["loss"]))


def small_graph_batch(d_feat=8, n=20, e=40, n_graphs=1, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, d_feat)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    gid = (jnp.arange(n) % n_graphs).astype(jnp.int32)
    labels = jnp.asarray(rng.integers(0, 2, n_graphs if n_graphs > 1 else n)
                         .astype(np.int32))
    pos = jnp.asarray(rng.normal(size=(n, 3)).astype(np.float32) * 3)
    return gnn_lib.GraphBatch(
        x=x, edge_src=src, edge_dst=dst,
        node_mask=jnp.ones(n, bool), edge_mask=jnp.ones(e, bool),
        labels=labels, graph_ids=gid, positions=pos, n_graphs=n_graphs)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_forward_and_grad(arch):
    cfg = get_arch(arch).config.smoke()
    n_graphs = 4 if cfg.family in ("gin", "schnet") else 1
    batch = small_graph_batch(d_feat=8, n_graphs=n_graphs)
    params = gnn_lib.init_gnn(KEY, cfg, d_in=8)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: gnn_lib.gnn_loss(p, batch, cfg)))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_recsys_forward_loss_retrieval():
    cfg = get_arch("dcn-v2").config.smoke()
    rng = np.random.default_rng(0)
    bsz = 8
    batch = {
        "dense": jnp.asarray(rng.normal(size=(bsz, cfg.n_dense)).astype(np.float32)),
        "sparse": jnp.asarray(rng.integers(0, 50, (bsz, cfg.n_sparse)).astype(np.int32)),
        "label": jnp.asarray(rng.integers(0, 2, bsz).astype(np.int32)),
    }
    params = rec_lib.init_dcn(KEY, cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: rec_lib.dcn_loss(p, batch, cfg)))(params)
    assert np.isfinite(float(loss))
    scores, idx = jax.jit(lambda p: rec_lib.retrieval_scores(
        p, batch["dense"][:1], batch["sparse"][:1],
        jnp.arange(64, dtype=jnp.int32), cfg, top_k=8))(params)
    assert scores.shape == (1, 8)
    assert np.all(np.isfinite(np.asarray(scores)))


def test_embedding_bag_modes():
    table = jnp.asarray(np.random.default_rng(0).normal(size=(20, 4)).astype(np.float32))
    ids = jnp.asarray([[0, 1, -1], [2, -1, -1]], jnp.int32)
    out_sum = rec_lib.embedding_bag(table, ids, None, mode="sum")
    np.testing.assert_allclose(np.asarray(out_sum[0]),
                               np.asarray(table[0] + table[1]), rtol=1e-6)
    out_mean = rec_lib.embedding_bag(table, ids, None, mode="mean")
    np.testing.assert_allclose(np.asarray(out_mean[1]), np.asarray(table[2]),
                               rtol=1e-6)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_padding_builds(arch):
    """tp=16 build pads heads/vocab/experts to the production TP degree."""
    cfg = get_arch(arch).config
    b = tfm.build(cfg, tp=16)
    assert b.n_heads_p % 16 == 0
    assert b.vocab_p % 16 == 0
    assert b.n_heads_p % b.n_kv_heads_p == 0
    if cfg.moe:
        assert b.e_pad % 16 == 0
