"""Kernel <-> engine integration: the DKS engine with Pallas combine
(interpret mode) produces identical results to the jnp path end-to-end."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import DKSConfig, run_dks
from repro.graph.generators import random_weighted_graph


def masks_of(groups, n):
    m = np.zeros((len(groups), n), bool)
    for i, grp in enumerate(groups):
        m[i, list(grp)] = True
    return m


@pytest.mark.parametrize("seed", [0, 1])
def test_engine_with_pallas_combine(seed):
    g = random_weighted_graph(24, 60, seed=seed)
    groups = [[2], [9], [17]]
    masks = jnp.asarray(masks_of(groups, g.n_nodes))
    dg = g.to_device()

    jnp_state = run_dks(dg, masks, DKSConfig(m=3, k=2, max_supersteps=48))
    pl_state = run_dks(dg, masks, DKSConfig(m=3, k=2, max_supersteps=48,
                                            combine_impl="pallas"))
    np.testing.assert_allclose(np.asarray(jnp_state.topk_w),
                               np.asarray(pl_state.topk_w), atol=1e-4)
    np.testing.assert_allclose(np.asarray(jnp_state.S),
                               np.asarray(pl_state.S), atol=1e-4)
    assert int(jnp_state.step) == int(pl_state.step)


def test_attention_impls_agree_in_model():
    """Full transformer forward with flash_jax == naive attention."""
    import jax
    from repro.configs import get_arch
    from repro.models import transformer as tfm

    cfg = get_arch("chatglm3-6b").config.smoke()
    b = tfm.build(cfg, tp=1)
    params = tfm.init_params(jax.random.PRNGKey(0), b)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    h_naive, _, _ = tfm.forward(params, toks, b, attn_impl="naive")
    h_flash, _, _ = tfm.forward(params, toks, b, attn_impl="flash_jax")
    np.testing.assert_allclose(
        np.asarray(h_naive, np.float32), np.asarray(h_flash, np.float32),
        atol=5e-2, rtol=5e-2)
