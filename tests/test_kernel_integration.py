"""Kernel <-> engine integration: the DKS engine with Pallas combine
(interpret mode) produces identical results to the jnp path end-to-end."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import INF

from repro.core import DKSConfig, run_dks
from repro.graph.generators import random_weighted_graph


def masks_of(groups, n):
    m = np.zeros((len(groups), n), bool)
    for i, grp in enumerate(groups):
        m[i, list(grp)] = True
    return m


@pytest.mark.parametrize("seed", [0, 1])
def test_engine_with_pallas_combine(seed):
    g = random_weighted_graph(24, 60, seed=seed)
    groups = [[2], [9], [17]]
    masks = jnp.asarray(masks_of(groups, g.n_nodes))
    dg = g.to_device()

    jnp_state = run_dks(dg, masks, DKSConfig(m=3, k=2, max_supersteps=48))
    pl_state = run_dks(dg, masks, DKSConfig(m=3, k=2, max_supersteps=48,
                                            combine_impl="pallas"))
    np.testing.assert_allclose(np.asarray(jnp_state.topk_w),
                               np.asarray(pl_state.topk_w), atol=1e-4)
    np.testing.assert_allclose(np.asarray(jnp_state.S),
                               np.asarray(pl_state.S), atol=1e-4)
    assert int(jnp_state.step) == int(pl_state.step)


def test_attention_impls_agree_in_model():
    """Full transformer forward with flash_jax == naive attention."""
    import jax
    from repro.configs import get_arch
    from repro.models import transformer as tfm

    cfg = get_arch("chatglm3-6b").config.smoke()
    b = tfm.build(cfg, tp=1)
    params = tfm.init_params(jax.random.PRNGKey(0), b)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    h_naive, _, _ = tfm.forward(params, toks, b, attn_impl="naive")
    h_flash, _, _ = tfm.forward(params, toks, b, attn_impl="flash_jax")
    np.testing.assert_allclose(
        np.asarray(h_naive, np.float32), np.asarray(h_flash, np.float32),
        atol=5e-2, rtol=5e-2)



# ----------------------------------------------------------------------
# LaneCSR + fused lane-superstep kernel (repro.kernels.lane_superstep)
# ----------------------------------------------------------------------

from repro.core.dks import DKSConfig as _DKSConfig  # noqa: E402
from repro.core.driver import lane_init as _lane_init  # noqa: E402
from repro.core.dks import superstep as _superstep  # noqa: E402
from repro.graph.generators import lod_like_graph as _lod  # noqa: E402
from repro.kernels.lane_superstep import (  # noqa: E402
    fused_lane_superstep,
    lane_csr_from_device_graph,
)


def _device_graph(v=200, e=900, seed=3):
    g, _ = _lod(v, e, seed=seed, vocab=40)
    return g.to_device()


def test_lane_csr_builder_invariants():
    dg = _device_graph()
    csr = lane_csr_from_device_graph(dg)
    src = np.asarray(csr.src_pad)
    w = np.asarray(csr.w_pad)
    seg = np.asarray(csr.seg)
    tail = np.asarray(csr.tail_row)
    n_rows, dmax = src.shape
    assert n_rows == csr.n_rows and n_rows % csr.block_v == 0
    # Pad rows carry seg=-1 and INF weights (they never join a segment);
    # real rows point at their destination node.
    pad_rows = seg < 0
    assert np.all(w[pad_rows] >= INF)
    # Block alignment: a node's virtual rows never straddle a block_v
    # boundary — the in-kernel segmented merge can then complete within
    # one grid block, with no second-level jnp hub merge.
    for node in np.unique(seg[seg >= 0]):
        rows = np.nonzero(seg == node)[0]
        assert rows.min() // csr.block_v == rows.max() // csr.block_v
        assert np.array_equal(rows, np.arange(rows.min(), rows.max() + 1))
        assert tail[node] == rows.max()  # the merge lands on the tail row
    # Every real (src -> dst) edge with finite weight appears exactly
    # once across the dst's rows.
    e_valid = np.asarray(dg.valid)
    dsts = np.asarray(dg.dst)[e_valid]
    per_node_edges = {int(n): int(c) for n, c in
                      zip(*np.unique(dsts, return_counts=True))}
    for node, want in per_node_edges.items():
        rows = np.nonzero(seg == node)[0]
        got = int(np.sum(w[rows] < INF))
        assert got == want


def test_lane_csr_hub_splitting_bumps_rows_not_dmax_past_block():
    """A hub with degree > dmax splits over multiple virtual rows; dmax
    only auto-bumps when one node's rows would exceed a whole block."""
    dg = _device_graph(v=120, e=2000, seed=5)   # dense -> hubs
    csr = lane_csr_from_device_graph(dg, dmax=4)
    seg = np.asarray(csr.seg)
    counts = np.bincount(seg[seg >= 0])
    assert counts.max() > 1      # at least one split node
    assert counts.max() <= csr.block_v


def test_fused_lane_superstep_matches_vmapped_superstep():
    """One fused kernel step == one vmapped jnp superstep, bit for bit,
    on a multi-lane state with a hub-split layout."""
    dg = _device_graph()
    csr = lane_csr_from_device_graph(dg, dmax=4)  # force hub splitting
    cfg_j = _DKSConfig(m=2, k=2, max_supersteps=8)
    cfg_p = _DKSConfig(m=2, k=2, max_supersteps=8,
                       relax_impl="pallas", combine_impl="pallas")
    rng = np.random.default_rng(0)
    masks = np.zeros((3, 2, dg.v_pad), bool)
    for lane in range(3):
        for kw in range(2):
            masks[lane, kw, rng.choice(dg.n_nodes, 4, replace=False)] = True
    st = _lane_init(dg, jnp.asarray(masks), cfg_j)
    ref = jax.vmap(lambda s: _superstep(dg, s, cfg_j))(st)
    out = fused_lane_superstep(dg, csr, st, cfg_p)
    np.testing.assert_array_equal(np.asarray(out.S), np.asarray(ref.S))
    np.testing.assert_array_equal(np.asarray(out.changed),
                                  np.asarray(ref.changed))
    np.testing.assert_array_equal(np.asarray(out.topk_w),
                                  np.asarray(ref.topk_w))
    np.testing.assert_array_equal(np.asarray(out.done),
                                  np.asarray(ref.done))


def test_fused_lane_superstep_freezes_done_lane():
    """A lane whose done flag is set must come out of the kernel with its
    table untouched (the in-kernel freeze mask), even though other lanes
    advance."""
    import dataclasses as dc

    dg = _device_graph()
    csr = lane_csr_from_device_graph(dg)
    cfg_p = _DKSConfig(m=2, k=1, max_supersteps=8,
                       relax_impl="pallas", combine_impl="pallas")
    rng = np.random.default_rng(1)
    masks = np.zeros((2, 2, dg.v_pad), bool)
    for lane in range(2):
        for kw in range(2):
            masks[lane, kw, rng.choice(dg.n_nodes, 3, replace=False)] = True
    st = _lane_init(dg, jnp.asarray(masks), cfg_p)
    done = jnp.asarray([True, False])
    st = dc.replace(st, done=done)
    out = fused_lane_superstep(dg, csr, st, cfg_p)
    np.testing.assert_array_equal(np.asarray(out.S[0]),
                                  np.asarray(st.S[0]))      # frozen
    assert not np.array_equal(np.asarray(out.S[1]),
                              np.asarray(st.S[1]))          # advanced
