"""Distribution tests requiring >1 device: run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (jax locks the device
count at first init, so the main pytest process stays single-device).

All mesh/shard_map plumbing goes through :mod:`repro.shardmap`, so these
tests exercise whichever jax generation is installed (0.4.x or >= 0.7).
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_in_subprocess(body: str, devices: int = 8) -> dict:
    prog = textwrap.dedent(f"""
        import os, json
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import jax
        import jax.numpy as jnp
        import numpy as np
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("RESULT::" + json.dumps(out))
    """)
    res = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
    )
    assert res.returncode == 0, f"stderr:\n{res.stderr[-4000:]}"
    line = [l for l in res.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line.split("RESULT::", 1)[1])


def test_int8_ring_allreduce_with_error_feedback():
    out = run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro import shardmap
        from repro.distributed import compressed_allreduce, init_compression
        mesh = shardmap.make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        # Distinct per-device gradients: feed the function a sharded array
        # whose shards differ.
        g_global = rng.normal(size=(8, 64)).astype(np.float32)
        expect = g_global.mean(axis=0)
        sh = jax.sharding.NamedSharding(mesh, P("data", None))
        g = jax.device_put(g_global, sh)
        grads = {"w": g}
        state = init_compression(grads)

        # shard_map consumes the leading axis as the per-device shard.
        import repro.distributed.compression as comp
        def leaf(gl, el):
            x = gl.reshape(-1) + el.reshape(-1)
            pad = (-x.shape[0]) % 8
            xp = jnp.pad(x, (0, pad))
            red = comp._ring_allreduce_int8(xp, "data", 8)[: x.shape[0]]
            return red.reshape(gl.shape), (x - red).reshape(gl.shape)
        f = jax.jit(shardmap.shard_map(
            leaf, mesh=mesh,
            in_specs=(P("data", None), P("data", None)),
            out_specs=(P("data", None), P("data", None)),
            check_vma=False))
        red, err = f(g, state.error["w"])
        red_np = np.asarray(red)
        # Every device row holds the (approximate) mean.
        err_vs_mean = np.abs(red_np - expect[None, :]).max()
        # int8 quantization error bound: a few scale quanta per hop.
        scale = np.abs(g_global).max() / 127.0
        out = {"err": float(err_vs_mean), "bound": float(scale * 16),
               "resid": float(np.abs(np.asarray(err)).max())}
    """)
    assert out["err"] <= out["bound"], out
    assert out["resid"] > 0.0  # error feedback captured the lost bits


def test_dks_sharded_matches_single_device():
    """The DKS superstep loop under an 8-device mesh produces identical
    top-K weights to the single-device run (SPMD correctness)."""
    out = run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro import shardmap
        from repro.core import DKSConfig, run_dks
        from repro.graph.generators import random_weighted_graph

        g = random_weighted_graph(64, 160, seed=5)
        dg = g.to_device(pad_nodes_to=64, pad_edges_to=((g.n_edges_sym+7)//8)*8)
        masks = np.zeros((3, dg.v_pad), bool)
        masks[0, 3] = masks[1, 17] = masks[2, 41] = True
        cfg = DKSConfig(m=3, k=2, max_supersteps=48)

        single = run_dks(dg, jnp.asarray(masks), cfg)

        mesh = shardmap.make_mesh((8,), ("data",))
        with shardmap.mesh_scope(mesh):
            sharded_graph = jax.device_put(
                dg, jax.tree_util.tree_map(
                    lambda _: jax.sharding.NamedSharding(mesh, P("data")),
                    dg))
            sharded = run_dks(sharded_graph, jnp.asarray(masks), cfg)
        out = {
            "single": np.asarray(single.topk_w).tolist(),
            "sharded": np.asarray(sharded.topk_w).tolist(),
            "single_steps": int(single.step),
            "sharded_steps": int(sharded.step),
        }
    """)
    assert out["single"] == out["sharded"], out
    assert out["single_steps"] == out["sharded_steps"]


def test_dks_frontier_relax_matches_dense():
    """Frontier-compressed sharded DKS == dense single-device DKS when the
    frontier cap is not hit; overflow raises budget_hit instead of silently
    dropping messages.  The mesh is explicit on the FrontierGraph — no
    ambient mesh scope is active around the sharded runs."""
    out = run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro import shardmap
        from repro.core import DKSConfig, run_dks
        from repro.core.dks_sharded import (
            pack_frontier_graph, run_dks_frontier)
        from repro.graph.generators import random_weighted_graph

        g = random_weighted_graph(64, 160, seed=5)
        dg = g.to_device(pad_nodes_to=64)
        masks = np.zeros((3, 64), bool)
        masks[0, 3] = masks[1, 17] = masks[2, 41] = True
        cfg = DKSConfig(m=3, k=2, max_supersteps=48, frontier_frac=1.0)

        dense = run_dks(dg, jnp.asarray(masks), cfg)

        mesh = shardmap.make_mesh((2, 4), ("data", "model"))
        fg = pack_frontier_graph(g, n_shards=8, mesh=mesh)
        fg = jax.device_put(fg, jax.tree_util.tree_map(
            lambda _: jax.sharding.NamedSharding(
                mesh, P(("data", "model"))), fg))
        m2 = np.zeros((3, fg.v_pad), bool)
        m2[:, :64] = masks
        frontier = run_dks_frontier(fg, jnp.asarray(m2), cfg)

        # Tiny cap -> overflow -> budget_hit (paper Sec. 5.4 semantics).
        cfg_tiny = DKSConfig(m=3, k=2, max_supersteps=48,
                             frontier_frac=0.01)
        capped = run_dks_frontier(fg, jnp.asarray(m2), cfg_tiny)
        out = {
            "dense": np.asarray(dense.topk_w).tolist(),
            "frontier": np.asarray(frontier.topk_w).tolist(),
            "budget_hit": bool(capped.budget_hit),
        }
    """)
    assert out["dense"] == out["frontier"], out
    assert out["budget_hit"] is True


def test_engine_sharded_query_matches_single_device():
    """QueryEngine end-to-end on partition="sharded" (8 host devices):
    query and query_stream serve identical top-K weights to the
    single-device engine, and the executable cache holds (1 trace for any
    number of same-shape queries)."""
    out = run_in_subprocess("""
        from repro.engine import ExecutionPolicy, QueryEngine
        from repro.graph.generators import lod_like_graph
        from repro.graph.index import InvertedIndex

        g, tokens = lod_like_graph(200, 600, seed=7, vocab=60)
        index = InvertedIndex.from_token_matrix(tokens)
        toks = [t for t in sorted(index.vocabulary(), key=index.df)
                if 2 <= index.df(t) <= 40]
        q2, q3 = toks[:2], toks[2:5]

        single = QueryEngine.build(
            g, index=index, policy=ExecutionPolicy(max_supersteps=32))
        # frontier_frac=1.0: no frontier cap, so the sharded run must match
        # the dense run superstep-for-superstep (no forced stop).
        sharded = QueryEngine.build(
            g, index=index,
            policy=ExecutionPolicy(partition="sharded", max_supersteps=32,
                                   frontier_frac=1.0))

        rs2 = single.query(q2, k=2, extract=False)
        rh2 = sharded.query(q2, k=2, extract=False)
        rs3 = single.query(q3, k=2, extract=False)
        rh3 = sharded.query(q3, k=2, extract=False)

        # Streaming on the sharded path: final update == query result.
        ups = list(sharded.query_stream(q3, k=2))
        ratios = [u.spa_ratio for u in ups]

        # Same-shape query again: compiled executable must be reused.
        sharded.query(q3, k=2, extract=False)
        out = {
            "w2_single": np.asarray(rs2.weights).tolist(),
            "w2_sharded": np.asarray(rh2.weights).tolist(),
            "w3_single": np.asarray(rs3.weights).tolist(),
            "w3_sharded": np.asarray(rh3.weights).tolist(),
            "steps": [rs3.supersteps, rh3.supersteps],
            "forced": bool(rh2.budget_hit or rh3.budget_hit),
            "stream_final_w": np.asarray(ups[-1].weights).tolist(),
            "stream_done": bool(ups[-1].done),
            "ratios_monotone": all(a >= b - 1e-9
                                   for a, b in zip(ratios, ratios[1:])),
            "traces_q3": sharded.trace_count(len(q3), 2),
        }
    """)
    assert out["w2_single"] == out["w2_sharded"], out
    assert out["w3_single"] == out["w3_sharded"], out
    assert out["forced"] is False
    assert out["steps"][0] == out["steps"][1]
    assert out["stream_final_w"] == out["w3_sharded"], out
    assert out["stream_done"] is True
    assert out["ratios_monotone"] is True
    assert out["traces_q3"] == 1, out


def test_engine_sharded_frontier_overflow_budget_hit():
    """A sharded run whose per-shard frontier exceeds f_cap must finish
    with budget_hit=True and a finite SPA ratio — the paper's Sec. 5.4
    forced stop, not silent message dropping."""
    out = run_in_subprocess("""
        from repro.engine import ExecutionPolicy, QueryEngine
        from repro.graph.generators import random_weighted_graph
        from repro.graph.index import InvertedIndex

        g = random_weighted_graph(64, 320, seed=3)
        # token v%16 -> every token matches 4 nodes spread over the shards.
        tokens = (np.arange(64, dtype=np.int64) % 16).reshape(64, 1)
        index = InvertedIndex.from_token_matrix(tokens)
        engine = QueryEngine.build(
            g, index=index,
            policy=ExecutionPolicy(partition="sharded", exit_mode="none",
                                   frontier_frac=0.01, max_supersteps=48))
        # Duplicated keyword: its 4 nodes hold both keywords, so the best
        # answer (weight 0) exists from superstep 0; the growing frontier
        # then overflows the tiny per-shard cap.
        res = engine.query([3, 3], k=1, extract=False)
        out = {
            "budget_hit": bool(res.budget_hit),
            "done": bool(res.done),
            "best": float(res.weights[0]),
            "spa_ratio": float(res.spa_ratio),
            "spa_is_none": res.spa is None,
        }
    """)
    assert out["budget_hit"] is True, out
    assert out["done"] is True
    assert out["best"] < 1e9  # an answer was found despite the forced stop
    assert np.isfinite(out["spa_ratio"]), out
    assert out["spa_is_none"] is False


def test_lm_train_step_sharded_runs():
    """A reduced LM train step executes correctly under a (2,4) mesh with
    the production sharding specs (numerics, not just lowering)."""
    out = run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro import shardmap
        from repro.configs import get_arch
        from repro.models import lm as lm_lib
        from repro.models import transformer as tfm
        from repro.optim import AdamWConfig
        from repro.launch.mesh import sharding_tree
        import dataclasses as dc

        cfg = get_arch("chatglm3-6b").config.smoke()
        cfg = dc.replace(cfg, d_model=64, n_heads=4, n_kv_heads=2, vocab=256)
        mesh = shardmap.make_mesh((2, 4), ("data", "model"))
        b = tfm.build(cfg, tp=4)
        with shardmap.mesh_scope(mesh):
            state = lm_lib.init_train_state(jax.random.PRNGKey(0), b)
            specs = tfm.param_specs(b)
            from repro.optim import OptState
            st_spec = lm_lib.TrainState(
                params=specs,
                opt=OptState(mu=specs, nu=specs, count=P()), step=P())
            sh = sharding_tree(mesh, st_spec)
            state = jax.device_put(state, sh)
            step = jax.jit(lm_lib.make_train_step(
                b, AdamWConfig(), attn_impl="naive"), donate_argnums=0)
            toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 256)
            batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
            losses = []
            for _ in range(3):
                state, m = step(state, batch)
                losses.append(float(m["loss"]))
        out = {"losses": losses}
    """)
    ls = out["losses"]
    assert all(np.isfinite(l) for l in ls), ls
    assert ls[-1] < ls[0], f"loss did not improve: {ls}"


import numpy as np  # noqa: E402
